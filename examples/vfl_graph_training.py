"""End-to-end driver (paper's task): full VFL training run comparing GLASU
against the paper's baselines on one dataset, with privacy hooks enabled.

Every scenario is one ``ExperimentConfig`` — the method (centralized /
standalone / simulated-centralized / glasu) picks the aggregation schedule,
client count, and eval mode; no hand-assembled config triples.

    PYTHONPATH=src python examples/vfl_graph_training.py [--dataset suzhou]
"""
import argparse

from repro.api import ExperimentConfig, Trainer


def run(label, cfg):
    res = Trainer(cfg).run()
    print(f"{label:28s} acc={res.test_acc * 100:5.1f}%  "
          f"comm={res.comm_bytes / 1e6:8.1f}MB  t={res.wall_seconds:5.1f}s")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="suzhou")
    ap.add_argument("--rounds", type=int, default=150)
    args = ap.parse_args()

    base = ExperimentConfig(
        name=f"{args.dataset}-comparison", dataset=args.dataset,
        n_clients=3, n_layers=4, hidden=64, backbone="gcnii",
        rounds=args.rounds, lr=0.01, eval_every=30)

    print(f"== {args.dataset} (3 clients, vertically partitioned) ==")
    run("centralized (M=1)", base.with_(method="centralized"))
    run("standalone (no comm)", base.with_(method="standalone"))
    run("simulated-centralized K=4", base.with_(method="simulated-centralized"))
    run("GLASU K=2 Q=1", base)
    run("GLASU K=2 Q=4", base.with_(n_local_steps=4))
    # GLASU + privacy hooks (§3.6)
    run("GLASU + secure-agg + DP", base.with_(n_local_steps=4,
                                              secure_agg=True, dp_sigma=0.05))


if __name__ == "__main__":
    main()
