"""End-to-end driver (paper's task): full VFL training run comparing GLASU
against the paper's baselines on one dataset, with privacy hooks and
compressed-exchange variants enabled.

Every scenario is one ``ExperimentConfig`` — the method (centralized /
standalone / simulated-centralized / glasu) picks the aggregation schedule,
client count, and eval mode; no hand-assembled config triples. The GLASU
rows run the device-resident engine (``rounds_per_step``) and the
compressed rows show bytes-per-round dropping with accuracy held.

    PYTHONPATH=src python examples/vfl_graph_training.py [--dataset suzhou]
"""
import argparse

from repro.api import ExperimentConfig, Trainer


def run(label, cfg):
    res = Trainer(cfg).run()
    per_round = res.comm_bytes / max(res.rounds_run, 1)
    print(f"{label:30s} acc={res.test_acc * 100:5.1f}%  "
          f"comm={res.comm_bytes / 1e6:8.1f}MB ({per_round / 1e3:6.1f}kB/rd)"
          f"  t={res.wall_seconds:5.1f}s")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="suzhou")
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--backend", default="vmapped",
                    choices=("vmapped", "simulation", "sharded"))
    args = ap.parse_args()

    base = ExperimentConfig(
        name=f"{args.dataset}-comparison", dataset=args.dataset,
        n_clients=3, n_layers=4, hidden=64, backbone="gcnii",
        backend=args.backend, rounds=args.rounds, rounds_per_step=5,
        lr=0.01, eval_every=30)

    print(f"== {args.dataset} (3 clients, vertically partitioned, "
          f"{args.backend} backend) ==")
    run("centralized (M=1)", base.with_(method="centralized"))
    run("standalone (no comm)", base.with_(method="standalone"))
    run("simulated-centralized K=4", base.with_(method="simulated-centralized"))
    run("GLASU K=2 Q=1", base)
    run("GLASU K=2 Q=4", base.with_(n_local_steps=4))
    # compressed embedding exchange (wire codecs at the Agg boundary)
    run("GLASU + int8 exchange", base.with_(n_local_steps=4,
                                            compression={"method": "int8"}))
    run("GLASU + topk_ef k=8", base.with_(
        n_local_steps=4, compression={"method": "topk_ef", "k": 8}))
    if args.backend == "vmapped":
        # GLASU + privacy hooks (§3.6; secure-agg masks need the exact
        # dense exchange, so these rows stay uncompressed)
        run("GLASU + secure-agg + DP", base.with_(
            n_local_steps=4, secure_agg=True, dp_sigma=0.05))


if __name__ == "__main__":
    main()
