"""End-to-end driver (paper's task): full VFL training run comparing GLASU
against the paper's baselines on one dataset, with privacy hooks enabled.

    PYTHONPATH=src python examples/vfl_graph_training.py [--dataset suzhou]
"""
import argparse

from repro.core.glasu import GlasuConfig
from repro.core.train import TrainConfig, make_centralized_dataset, train_glasu
from repro.graph.sampler import SamplerConfig
from repro.graph.synth import make_vfl_dataset


def run(name, data, mcfg, scfg, tcfg):
    res = train_glasu(data, mcfg, scfg, tcfg)
    print(f"{name:28s} acc={res.test_acc * 100:5.1f}%  "
          f"comm={res.comm_bytes / 1e6:8.1f}MB  t={res.wall_seconds:5.1f}s")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="suzhou")
    ap.add_argument("--rounds", type=int, default=150)
    args = ap.parse_args()

    data = make_vfl_dataset(args.dataset, n_clients=3, seed=0)
    d_in = max(c.feat_dim for c in data.clients)
    base = dict(n_clients=3, n_layers=4, hidden=64, n_classes=data.n_classes,
                d_in=d_in, backbone="gcnii")
    tcfg = TrainConfig(rounds=args.rounds, lr=0.01, eval_every=30)
    s = dict(n_layers=4, batch_size=16, fanout=3)

    print(f"== {args.dataset} (3 clients, vertically partitioned) ==")
    # centralized upper bound
    cdata = make_centralized_dataset(data)
    run("centralized (M=1)", cdata,
        GlasuConfig(**{**base, "n_clients": 1, "d_in": cdata.full.feat_dim,
                       "agg_layers": (1, 3)}),
        SamplerConfig(agg_layers=(1, 3), **s), tcfg)
    # standalone lower bound
    run("standalone (no comm)", data,
        GlasuConfig(**{**base, "agg_layers": ()}),
        SamplerConfig(agg_layers=(3,), **s),
        TrainConfig(rounds=args.rounds, lr=0.01, eval_every=30,
                    eval_mode="per_client"))
    # simulated centralized (K=L)
    run("simulated-centralized K=4", data,
        GlasuConfig(**{**base, "agg_layers": (0, 1, 2, 3)}),
        SamplerConfig(agg_layers=(0, 1, 2, 3), **s), tcfg)
    # GLASU
    run("GLASU K=2 Q=1", data,
        GlasuConfig(**{**base, "agg_layers": (1, 3)}),
        SamplerConfig(agg_layers=(1, 3), **s), tcfg)
    run("GLASU K=2 Q=4", data,
        GlasuConfig(**{**base, "agg_layers": (1, 3), "n_local_steps": 4}),
        SamplerConfig(agg_layers=(1, 3), **s), tcfg)
    # GLASU + privacy hooks (§3.6)
    run("GLASU + secure-agg + DP", data,
        GlasuConfig(**{**base, "agg_layers": (1, 3), "n_local_steps": 4,
                       "secure_agg": True, "dp_sigma": 0.05}),
        SamplerConfig(agg_layers=(1, 3), **s), tcfg)


if __name__ == "__main__":
    main()
