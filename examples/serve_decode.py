"""Batched greedy decoding through the per-layer KV caches.

Serves a small SmolLM-family model: prefills a prompt batch, then decodes
tokens autoregressively with the same cache machinery the decode_32k /
long_500k dry-run shapes exercise (including the sliding-window ring cache).

    PYTHONPATH=src python examples/serve_decode.py [--new-tokens 32]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_reduced
from repro.models import transformer as tfm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--window", type=int, default=0,
                    help=">0: sliding-window ring cache")
    args = ap.parse_args()

    cfg = get_reduced("smollm_360m")
    if args.window:
        cfg = cfg.with_(sliding_window=args.window)
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)

    total = args.prompt_len + args.new_tokens
    caches = tfm.init_caches(cfg, args.batch, total)
    step = jax.jit(lambda c, tok: tfm.lm_decode_step(params, c, cfg, tok))

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab,
                                      size=(args.batch, args.prompt_len)),
                         jnp.int32)
    # prefill by streaming the prompt through the decode path
    tok = prompt[:, 0:1]
    for i in range(args.prompt_len):
        nxt, caches = step(caches, prompt[:, i:i + 1])
    out = [nxt]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        nxt, caches = step(caches, out[-1])
        out.append(nxt)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"cache: {'ring(window=%d)' % args.window if args.window else 'full'}")
    print(f"generated {gen.shape} tokens, "
          f"{args.batch * (args.new_tokens - 1) / dt:.1f} tok/s (CPU)")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: {list(np.asarray(gen[b][:12]))} ...")


if __name__ == "__main__":
    main()
