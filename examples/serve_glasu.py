"""Serve a trained GLASU model: checkpoint -> session -> queries.

    PYTHONPATH=src python examples/serve_glasu.py

Trains a short run to a checkpoint (the quickstart recipe with
checkpointing on), restores PARAMS ONLY into an ``InferenceSession``
(optimizer and error-feedback state are never read), and fires a small
query mix:

  * a **cold** batch — full receptive-field plan, cross-client embedding
    exchange at every aggregation layer, bytes metered per fresh row;
  * the same batch **warm** — every node hits the hot-node aggregate
    cache at the top layer, no exchange, zero wire bytes, bitwise-equal
    logits;
  * the cold mix again on an **int8-compressed** session from the same
    checkpoint — same answers within codec tolerance, ~3x fewer bytes.

The micro-batcher at the end shows concurrent single-node requests
coalescing into one padded dispatch.
"""
import tempfile

import numpy as np

from repro.api import Trainer, get_preset
from repro.serve import InferenceSession, MicroBatcher, ServeConfig


def main():
    ckpt_dir = tempfile.mkdtemp(prefix="glasu-serve-")
    cfg = get_preset("cora-gcnii-glasu").with_(
        rounds=30, eval_every=30, ckpt_dir=ckpt_dir)
    Trainer(cfg).run()

    session = InferenceSession.from_checkpoint(
        ckpt_dir, serve=ServeConfig(max_batch=16))
    rng = np.random.default_rng(0)
    nodes = rng.choice(session.N, size=16, replace=False)

    cold = session.answer(nodes)
    print(f"\ncold : {len(nodes)} nodes in {cold.latency_s * 1e3:.1f} ms, "
          f"{cold.wire_bytes} B on the wire "
          f"(fresh rows per agg layer: {cold.fresh_rows})")

    warm = session.answer(nodes)
    print(f"warm : {warm.latency_s * 1e3:.1f} ms, {warm.wire_bytes} B "
          f"(cache hits {warm.cache_hits}/{len(nodes)}, bitwise equal: "
          f"{np.array_equal(cold.logits, warm.logits)})")

    int8 = InferenceSession.from_checkpoint(
        ckpt_dir, serve=ServeConfig(max_batch=16),
        compression={"method": "int8"})
    comp = int8.answer(nodes)
    agree = float((comp.preds == cold.preds).mean())
    print(f"int8 : {comp.wire_bytes} B "
          f"({cold.wire_bytes / comp.wire_bytes:.1f}x fewer), "
          f"prediction agreement {agree * 100:.0f}%")

    with MicroBatcher(session, deadline_ms=5.0) as mb:
        futs = [mb.submit([int(n)]) for n in nodes[:8]]
        preds = [int(f.result(timeout=30).preds[0]) for f in futs]
    print(f"batch: 8 single-node requests -> {mb.batches} dispatch(es), "
          f"preds {preds}")


if __name__ == "__main__":
    main()
