"""Quickstart: train a GLASU split-GCNII on the Cora proxy in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py

The whole experiment is one preset from the unified API — the 5-line version:

    from repro.api import Trainer, get_preset

    cfg = get_preset("cora-gcnii-glasu").with_(rounds=60, eval_every=20)
    result = Trainer(cfg).run()
    print(result.test_acc, result.comm_bytes)

``get_preset`` names every paper scenario (``<dataset>-<backbone>-<method>``,
45 combinations — see ``repro.api.list_presets()``); ``with_`` overrides any
field with validation; ``Trainer`` derives the model/sampler configs from the
dataset, runs the hook pipeline (periodic exact eval, comm metering, optional
early stop + checkpointing), and returns a ``TrainResult``.

Knobs demonstrated below:
  * ``rounds_per_step=4`` — the device-resident engine advances 4 rounds
    per jitted dispatch (``lax.scan``, donated buffers, prefetched
    sampling); semantics are identical for any value.
  * ``compression={"method": "int8"}`` — the embedding exchange at the
    aggregation boundary ships int8 codes + per-row scales instead of
    float32 (~3.6x fewer bytes/round end to end; also ``"fp8"`` and
    ``"topk_ef"`` with ``k``).
  * ``backend="simulation"`` runs the identical round as explicit
    client/server messages with a byte-audited log;
    ``backend="sharded"`` places each client on its own device.
"""
from repro.api import Trainer, get_preset


def main():
    cfg = get_preset("cora-gcnii-glasu").with_(
        rounds=60, eval_every=20, rounds_per_step=4,
        compression={"method": "int8"})
    res = Trainer(cfg).run()
    print(f"\nGLASU (K={len(cfg.agg_layers)}, Q={cfg.n_local_steps}, "
          f"{cfg.compression.method} exchange) on {cfg.dataset}-proxy:")
    print(f"  test accuracy   : {res.test_acc * 100:.1f}%")
    print(f"  communication   : {res.comm_bytes / 1e6:.1f} MB "
          f"({res.rounds_run} rounds)")
    print(f"  wall time       : {res.wall_seconds:.1f}s")
    print("  history         :",
          [f"r{h['round']}:{h['test_acc']:.2f}" for h in res.history])


if __name__ == "__main__":
    main()
