"""Quickstart: train a GLASU split-GCNII on the Cora proxy in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.glasu import GlasuConfig
from repro.core.train import TrainConfig, train_glasu
from repro.graph.sampler import SamplerConfig
from repro.graph.synth import make_vfl_dataset


def main():
    data = make_vfl_dataset("cora", n_clients=3, seed=0)
    d_in = max(c.feat_dim for c in data.clients)

    model_cfg = GlasuConfig(
        n_clients=3, n_layers=4, hidden=64, n_classes=data.n_classes,
        d_in=d_in, backbone="gcnii",
        agg_layers=(1, 3),       # lazy aggregation: K=2 of L=4 layers
        n_local_steps=4,         # stale updates: Q=4
    )
    sampler_cfg = SamplerConfig(n_layers=4, agg_layers=(1, 3), batch_size=16,
                                fanout=3)
    res = train_glasu(data, model_cfg, sampler_cfg,
                      TrainConfig(rounds=60, lr=0.01, eval_every=20))
    print(f"\nGLASU (K=2, Q=4) on cora-proxy:")
    print(f"  test accuracy   : {res.test_acc * 100:.1f}%")
    print(f"  communication   : {res.comm_bytes / 1e6:.1f} MB "
          f"({res.rounds_run} rounds)")
    print(f"  wall time       : {res.wall_seconds:.1f}s")
    print("  history         :",
          [f"r{h['round']}:{h['test_acc']:.2f}" for h in res.history])


if __name__ == "__main__":
    main()
