"""GLASU beyond graphs: vertical-split transformer training (~100M params).

The paper's technique as a backbone feature: the hidden dimension is split
into M=4 feature shards; only every 2nd layer aggregates across shards
(lazy aggregation, K=L/2) and each sampled batch is reused for Q=2 stale
local microsteps. Trains a ~100M-param LM on a synthetic bigram stream for a
few hundred steps and prints the loss curve.

    PYTHONPATH=src python examples/transformer_glasu.py [--steps 200]
"""
import argparse
import time

import jax

from repro.configs.base import ArchConfig, GlasuSplit
from repro.core.steps import make_train_step
from repro.data.pipeline import TokenStream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = ArchConfig(
        name="glasu-tp-20m", kind="dense",
        n_layers=6, d_model=384, n_heads=12, n_kv=4, d_head=32,
        d_ff=1024, vocab=8192, dtype="float32", optimizer="adamw", lr=1e-3,
        remat=False,
        glasu=GlasuSplit(n_clients=4, sync_every=2, local_steps=2),
    )
    print(f"params ~= {cfg.param_count() / 1e6:.0f}M "
          f"(block-diagonal lazy layers shrink this vs dense)")

    init_state, train_step = make_train_step(cfg)
    state = init_state(jax.random.PRNGKey(0))
    step = jax.jit(train_step)
    stream = TokenStream(cfg.vocab, seed=0)

    t0 = time.time()
    for i in range(args.steps):
        tokens, labels = stream.batch(args.batch, args.seq)
        state, metrics = step(state, {"tokens": tokens, "labels": labels})
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {int(state.step):4d}  loss={float(metrics['loss']):.3f}"
                  f"  ({time.time() - t0:.0f}s)")


if __name__ == "__main__":
    main()
