"""Synthetic dataset proxies for the paper's seven datasets (offline container).

The container has no network access, so the real Planetoid/HeriGraph/Reddit
downloads are replaced by stochastic-block-model graphs whose size statistics
are calibrated to the paper's Table 1 (node count, average degree, feature
dim, class count). Features are class-centroid + Gaussian noise so that graph
structure *and* features both carry label signal — the property the paper's
relative claims (centralized ≈ simulated ≈ GLASU ≫ standalone) depend on.

Vertical partitioning follows the paper's protocol (Appendix D.1):
  * Planetoid/Reddit-style: each client gets a uniform 80%-edge subsample of
    the single graph and a disjoint feature block.
  * HeriGraph-style ("natural" split): each client gets a structurally
    DIFFERENT subgraph (independent SBM draw with its own degree profile — the
    social/spatial/temporal subgraphs) and a disjoint feature block.

Reddit is scaled down (232,965 -> 8,192 nodes) to fit the 1-core CPU budget;
this is recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import os
import tempfile
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from .feature_store import MemmapFeatureStore, create_store
from .graph import Graph, VFLDataset, edges_to_csr


@dataclass(frozen=True)
class DatasetSpec:
    n_nodes: int
    avg_deg: float
    feat_dim: int
    n_classes: int
    natural_subgraphs: bool = False   # HeriGraph: clients hold different graph *types*
    homophily: float = 0.85           # fraction of edges intra-class
    feat_noise: float = 1.0
    train_frac: float = 0.30
    val_frac: float = 0.20


# Calibrated to paper Table 1 (Reddit scaled down; see module docstring).
# Planetoid datasets use the standard low-label splits (cora: 140 train
# nodes), which is what makes neighborhood aggregation + cross-client feature
# fusion matter — the regime the paper's Table 2 ordering depends on.
SPECS: Dict[str, DatasetSpec] = {
    "cora":      DatasetSpec(2708, 3.9, 1433, 7, feat_noise=2.5,
                             train_frac=140 / 2708, val_frac=500 / 2708),
    "pubmed":    DatasetSpec(19717, 4.5, 500, 3, feat_noise=2.5,
                             train_frac=60 / 19717, val_frac=500 / 19717),
    "citeseer":  DatasetSpec(3327, 2.7, 3703, 6, feat_noise=2.5,
                             train_frac=120 / 3327, val_frac=500 / 3327),
    "suzhou":    DatasetSpec(3137, 292.0, 979, 9, natural_subgraphs=True,
                             feat_noise=3.0, train_frac=0.3),
    "venice":    DatasetSpec(2951, 181.0, 979, 9, natural_subgraphs=True,
                             feat_noise=3.0, train_frac=0.3),
    "amsterdam": DatasetSpec(3727, 341.0, 979, 9, natural_subgraphs=True,
                             feat_noise=3.0, train_frac=0.3),
    "reddit":    DatasetSpec(8192, 60.0, 602, 41, feat_noise=2.0,
                             train_frac=0.1),
    # fast CI-size proxy used by unit tests
    "tiny":      DatasetSpec(256, 6.0, 32, 4),
}


def _sbm_edges(rng: np.random.Generator, labels: np.ndarray, avg_deg: float,
               homophily: float) -> np.ndarray:
    """Sample SBM edges with expected average degree ``avg_deg``."""
    n = len(labels)
    n_edges = int(n * avg_deg / 2)
    intra = int(n_edges * homophily)
    inter = n_edges - intra
    classes = np.unique(labels)
    by_class = {c: np.where(labels == c)[0] for c in classes}
    # intra-class pairs
    sizes = np.array([len(by_class[c]) for c in classes], dtype=np.float64)  # glint: disable=GL003 rng.choice(p=...) needs f64 probabilities summing to 1; host-only, never shipped to device
    probs = sizes / sizes.sum()
    cls_pick = rng.choice(len(classes), size=intra, p=probs)
    src, dst = [], []
    for ci, cnt in zip(*np.unique(cls_pick, return_counts=True)):
        nodes = by_class[classes[ci]]
        src.append(rng.choice(nodes, size=cnt))
        dst.append(rng.choice(nodes, size=cnt))
    # inter-class pairs
    src.append(rng.integers(0, n, size=inter))
    dst.append(rng.integers(0, n, size=inter))
    e = np.stack([np.concatenate(src), np.concatenate(dst)], axis=1)
    return e[e[:, 0] != e[:, 1]].astype(np.int32)


def _class_features(rng: np.random.Generator, labels: np.ndarray, dim: int,
                    noise: float) -> np.ndarray:
    n_classes = int(labels.max()) + 1
    centroids = rng.normal(size=(n_classes, dim)).astype(np.float32)
    x = centroids[labels] + noise * rng.normal(size=(len(labels), dim)).astype(np.float32)
    return x.astype(np.float32)


def _vfl_features(rng: np.random.Generator, labels: np.ndarray, dim: int,
                  noise: float, blocks) -> np.ndarray:
    """Complementary per-client feature blocks (the defining VFL property).

    Client m's block separates only the classes with ``c % M == m``; the
    other classes collapse onto a per-group centroid. No single client can
    classify alone, the union of blocks carries full class information —
    which is exactly why standalone training trails GLASU/centralized in the
    paper's Table 2, and the margin the aggregation layers must recover.
    """
    m_clients = len(blocks)
    n_classes = int(labels.max()) + 1
    feats = np.zeros((len(labels), dim), np.float32)
    for m, (lo, hi) in enumerate(blocks):
        width = hi - lo
        if width == 0:
            continue
        pseudo = np.where(labels % m_clients == m, labels,
                          n_classes + labels // m_clients)
        n_pseudo = int(pseudo.max()) + 1
        centroids = rng.normal(size=(n_pseudo, width)).astype(np.float32)
        feats[:, lo:hi] = (centroids[pseudo]
                           + noise * rng.normal(size=(len(labels), width))
                           .astype(np.float32))
    return feats


def _splits(rng: np.random.Generator, n: int, train_frac: float, val_frac: float):
    perm = rng.permutation(n)
    n_tr = int(n * train_frac)
    n_va = int(n * val_frac)
    return perm[:n_tr], perm[n_tr:n_tr + n_va], perm[n_tr + n_va:]


def _feature_blocks(dim: int, m: int):
    """Disjoint contiguous feature blocks, sizes as equal as possible."""
    cuts = np.linspace(0, dim, m + 1).astype(int)
    return [(cuts[i], cuts[i + 1]) for i in range(m)]


# --------------------------------------------------------- power-law scale
@dataclass(frozen=True)
class PowerLawSpec:
    """Chung-Lu power-law profile streamed through a MemmapFeatureStore.

    Unlike ``DatasetSpec`` graphs, features are written to disk chunk by
    chunk and never fully materialize on host — the profile exists to
    exercise the CSR kernel path and the streamed store at graph scales
    (ROADMAP's ogbn-arxiv/products class) the SBM proxies can't reach.
    """

    n_nodes: int
    avg_deg: float
    feat_dim: int
    n_classes: int
    gamma: float = 2.1            # degree exponent: P(deg = k) ~ k^-gamma
    max_deg: int = 1024           # expected-degree cap on hub nodes
    feat_noise: float = 2.0
    train_frac: float = 0.01
    val_frac: float = 0.005
    chunk_rows: int = 65536       # feature-store row chunk
    cache_chunks: int = 16        # LRU capacity (per client view)


POWERLAW_SPECS: Dict[str, PowerLawSpec] = {
    # the ROADMAP scale target: >= 2^20 nodes, M=2 disjoint feature blocks
    "powerlaw-1m":   PowerLawSpec(1 << 20, 8.0, 64, 16),
    # CI/unit-test proxy with the same code path at toy size
    "powerlaw-tiny": PowerLawSpec(4096, 8.0, 32, 8,
                                  train_frac=0.1, val_frac=0.1,
                                  chunk_rows=512, cache_chunks=4),
}


def _powerlaw_pairs(rng: np.random.Generator, n: int, avg_deg: float,
                    gamma: float, max_deg: int) -> np.ndarray:
    """Unique undirected (E, 2) pairs from a Chung-Lu expected-degree draw.

    Node weights follow ``i^(-1/(gamma-1))`` (shuffled so degree is
    independent of node id), capped so no hub's expected degree exceeds
    ``max_deg``; both endpoints of each edge are drawn by inverse-CDF
    lookup. Dedup runs on 1-D int64 keys (``lo * n + hi``) — never
    ``np.unique(axis=0)``, whose row-void views blow up at 10M+ edges.
    """
    w = np.arange(1, n + 1, dtype=np.float64) ** (-1.0 / (gamma - 1.0))  # glint: disable=GL003 host-only degree weights for the inverse-CDF draw; never shipped to device
    rng.shuffle(w)
    m = int(n * avg_deg / 2)
    w = np.minimum(w, w.sum() * max_deg / max(2 * m, 1))
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    src = np.searchsorted(cdf, rng.random(m)).astype(np.int64)  # glint: disable=GL003 lo*n+hi dedup keys need 64-bit headroom at n=2^20; host-only
    dst = np.searchsorted(cdf, rng.random(m)).astype(np.int64)  # glint: disable=GL003 lo*n+hi dedup keys need 64-bit headroom at n=2^20; host-only
    keep = src != dst
    lo = np.minimum(src[keep], dst[keep])
    hi = np.maximum(src[keep], dst[keep])
    keys = np.unique(lo * n + hi)
    return np.stack([keys // n, keys % n], axis=1).astype(np.int32)


def _pairs_to_csr(n: int, pairs: np.ndarray):
    """Symmetrize unique undirected pairs into CSR via int64 key sort."""
    if pairs.size == 0:
        return np.zeros(n + 1, np.int32), np.zeros(0, np.int32)
    a = pairs[:, 0].astype(np.int64)  # glint: disable=GL003 a*n+b sort keys need 64-bit headroom at n=2^20; host-only
    b = pairs[:, 1].astype(np.int64)  # glint: disable=GL003 a*n+b sort keys need 64-bit headroom at n=2^20; host-only
    keys = np.concatenate([a * n + b, b * n + a])
    keys.sort()
    indices = (keys % n).astype(np.int32)
    counts = np.bincount(keys // n, minlength=n)
    indptr = np.zeros(n + 1, dtype=np.int32)
    indptr[1:] = np.cumsum(counts).astype(np.int32)
    return indptr, indices


def _write_powerlaw_features(path: str, labels: np.ndarray, blocks,
                             spec: PowerLawSpec, seed: int) -> None:
    """Chunk-write the VFL-complementary feature matrix to disk.

    Same pseudo-label centroid construction as ``_vfl_features`` (client m
    separates only classes with ``c % M == m``), but only ``chunk_rows``
    rows are ever resident — the writer is what keeps the 1M-node build
    inside the streamed-store memory budget.
    """
    rng = np.random.default_rng(seed)
    m_clients = len(blocks)
    n = len(labels)
    n_classes = int(labels.max()) + 1
    pseudos, cents = [], []
    for m, (lo, hi) in enumerate(blocks):
        pseudo = np.where(labels % m_clients == m, labels,
                          n_classes + labels // m_clients)
        pseudos.append(pseudo)
        cents.append(rng.normal(
            size=(int(pseudo.max()) + 1, hi - lo)).astype(np.float32))
    mm = create_store(path, n, spec.feat_dim)
    try:
        for r0 in range(0, n, spec.chunk_rows):
            r1 = min(r0 + spec.chunk_rows, n)
            for m, (lo, hi) in enumerate(blocks):
                if hi == lo:
                    continue
                noise = rng.normal(size=(r1 - r0, hi - lo)).astype(np.float32)
                mm[r0:r1, lo:hi] = (cents[m][pseudos[m][r0:r1]]
                                    + spec.feat_noise * noise)
        mm.flush()
    finally:
        del mm


def make_powerlaw_dataset(name: str, n_clients: int = 2, seed: int = 0,
                          spec: Optional[PowerLawSpec] = None,
                          root: Optional[str] = None,
                          edge_keep_frac: float = 0.8) -> VFLDataset:
    """M-client VFL view of a power-law graph with STREAMED features.

    Every client's ``Graph.features`` is a ``MemmapFeatureStore`` column
    view over one shared on-disk matrix (written once per (name, seed,
    n_clients) into ``root``, default a fresh temp dir); the full graph
    holds the all-columns view. Training/serving paths gather only sampled
    rows per round, so peak host RSS stays bounded by the LRU capacity
    rather than ``N * d * 4``.
    """
    spec = spec or POWERLAW_SPECS[name]
    rng = np.random.default_rng(seed)
    n = spec.n_nodes
    labels = rng.integers(0, spec.n_classes, size=n).astype(np.int32)
    pairs = _powerlaw_pairs(rng, n, spec.avg_deg, spec.gamma, spec.max_deg)
    tr, va, te = _splits(rng, n, spec.train_frac, spec.val_frac)
    blocks = _feature_blocks(spec.feat_dim, n_clients)

    root = root or tempfile.mkdtemp(prefix=f"repro_{name}_")
    path = os.path.join(root, f"{name}_s{seed}_m{n_clients}.npy")
    if not os.path.exists(path):
        # the feature stream draws from its own generator so a cached file
        # never desyncs the graph/split draw above
        _write_powerlaw_features(path, labels, blocks, spec, seed + 1)
    store = MemmapFeatureStore(path, chunk_rows=spec.chunk_rows,
                               cache_chunks=spec.cache_chunks)

    clients = []
    for m in range(n_clients):
        keep = rng.random(len(pairs)) < edge_keep_frac
        indptr, indices = _pairs_to_csr(n, pairs[keep])
        lo, hi = blocks[m]
        clients.append(Graph(n, indptr, indices, store.view(lo, hi),
                             labels, tr, va, te))
    indptr, indices = _pairs_to_csr(n, pairs)
    full = Graph(n, indptr, indices, store, labels, tr, va, te)
    return VFLDataset(name, clients, full)


def make_vfl_dataset(name: str, n_clients: int = 3, seed: int = 0,
                     spec: Optional[DatasetSpec] = None,
                     edge_keep_frac: float = 0.8) -> VFLDataset:
    """Build the M-client vertically-partitioned view of dataset ``name``."""
    if spec is None and name in POWERLAW_SPECS:
        return make_powerlaw_dataset(name, n_clients=n_clients, seed=seed,
                                     edge_keep_frac=edge_keep_frac)
    spec = spec or SPECS[name]
    rng = np.random.default_rng(seed)
    n = spec.n_nodes
    labels = rng.integers(0, spec.n_classes, size=n).astype(np.int32)
    blocks = _feature_blocks(spec.feat_dim, n_clients)
    feats = _vfl_features(rng, labels, spec.feat_dim, spec.feat_noise, blocks)
    tr, va, te = _splits(rng, n, spec.train_frac, spec.val_frac)

    if spec.natural_subgraphs:
        # HeriGraph-style: each client an independent graph "modality" with
        # its own density profile; the full graph is their union.
        client_edges = []
        for m in range(n_clients):
            deg = spec.avg_deg / n_clients * (0.5 + m * (1.0 / max(n_clients - 1, 1)))
            hom = spec.homophily * (0.9 + 0.1 * (m % 2))
            client_edges.append(_sbm_edges(rng, labels, max(deg, 2.0), min(hom, 0.95)))
        full_edges = np.concatenate(client_edges, axis=0)
    else:
        full_edges = _sbm_edges(rng, labels, spec.avg_deg, spec.homophily)
        client_edges = []
        for m in range(n_clients):
            keep = rng.random(len(full_edges)) < edge_keep_frac
            client_edges.append(full_edges[keep])

    clients = []
    for m in range(n_clients):
        indptr, indices = edges_to_csr(n, client_edges[m])
        lo, hi = blocks[m]
        clients.append(Graph(n, indptr, indices, feats[:, lo:hi].copy(),
                             labels, tr, va, te))
    indptr, indices = edges_to_csr(n, full_edges)
    full = Graph(n, indptr, indices, feats, labels, tr, va, te)
    return VFLDataset(name, clients, full)
