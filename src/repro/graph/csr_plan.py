"""Host-side CSR planning for the sparse aggregation kernel.

The CSR segment-sum kernel (``kernels/graph_agg.py``,
``graph_agg_csr_pallas``) consumes a padded row-tile *slab* layout; the
sparse structure that produces it is concrete host data — exactly like
the sampler's neighbor-table builds in ``graph.py`` — so the planning
lives here, outside the traced kernel modules. The jitted kernel sees
only the padded static-shape slab arrays.

Layout: tile i's edges occupy slots [i*slab, (i+1)*slab) of three
(n_tiles*slab, 1) arrays — ``idx`` the source id, ``seg`` the LOCAL
destination row in [0, 128) (``CSR_PAD_ROW`` marks padding slots),
``ew`` the edge weight (1.0 when unweighted, 0.0 on padding). ``slab``
is the max per-tile edge count rounded up to a lane multiple, so the
layout's overhead is bounded by tile skew (≈ 128·avg_deg + max_deg per
tile) — callers at graph scale feed a degree-capped CSR, the same
policy every neighbor table in the repo already applies
(``table_cap``/``eval_table_cap``).
"""
from __future__ import annotations

import numpy as np

from ..kernels.graph_agg import CSR_PAD_ROW, DST_BLOCK


def _as_indptr(indptr) -> np.ndarray:
    return np.asarray(indptr, dtype=np.int64)  # glint: disable=GL003 slot arithmetic below forms nnz*slab products that outgrow int32 at graph scale; host-only, never shipped to device


def csr_segments(indptr) -> np.ndarray:
    """(nnz,) int32 destination-row id per CSR edge (the segment ids the
    pure-jnp oracles feed to ``segment_sum``)."""
    indptr = _as_indptr(indptr)
    n_dst = len(indptr) - 1
    return np.repeat(np.arange(n_dst, dtype=np.int32), np.diff(indptr))


def csr_slot_map(indptr, total: int) -> np.ndarray:
    """(nnz,) int32 slab slot per CSR edge for a layout of ``total`` rows.

    Edges are CSR-ordered, so an edge's offset within its tile is its
    global position minus the tile's first edge position. Used to scatter
    *traced* per-edge values (edge weights) into the slab on device while
    keeping the slot arithmetic concrete.
    """
    indptr = _as_indptr(indptr)
    n_dst = len(indptr) - 1
    nnz = int(indptr[-1])
    n_tiles = max(1, -(-n_dst // DST_BLOCK))
    slab = total // n_tiles
    rows = np.repeat(np.arange(n_dst, dtype=np.int64), np.diff(indptr))  # glint: disable=GL003 see _as_indptr: 64-bit slot headroom; host-only
    tile = rows // DST_BLOCK
    slot = (tile * slab + np.arange(nnz, dtype=np.int64)  # glint: disable=GL003 see _as_indptr: 64-bit slot headroom; host-only
            - indptr[tile * DST_BLOCK])
    return slot.astype(np.int32)


def plan_csr_slabs(indptr, indices, edge_weight=None):
    """Host CSR -> padded row-tile slab layout (concrete numpy).

    Returns ``(idx_slab, seg_slab, ew_slab, n_dst)`` shaped as in the
    module docstring.
    """
    indptr = _as_indptr(indptr)
    n_dst = len(indptr) - 1
    nnz = int(indptr[-1])
    n_tiles = max(1, -(-n_dst // DST_BLOCK))
    deg = np.diff(indptr)
    deg_pad = np.zeros(n_tiles * DST_BLOCK, np.int64)  # glint: disable=GL003 see _as_indptr: 64-bit slot headroom; host-only
    deg_pad[:n_dst] = deg
    tile_nnz = deg_pad.reshape(n_tiles, DST_BLOCK).sum(axis=1)
    slab = max(DST_BLOCK,
               int(-(-int(tile_nnz.max()) // DST_BLOCK) * DST_BLOCK))
    idx_slab = np.zeros((n_tiles * slab, 1), np.int32)
    seg_slab = np.full((n_tiles * slab, 1), CSR_PAD_ROW, np.int32)
    ew_slab = np.zeros((n_tiles * slab, 1), np.float32)
    if nnz:
        rows = np.repeat(np.arange(n_dst, dtype=np.int64), deg)  # glint: disable=GL003 see _as_indptr: 64-bit slot headroom; host-only
        slot = csr_slot_map(indptr, n_tiles * slab)
        idx_slab[slot, 0] = np.asarray(indices, np.int32)[:nnz]
        seg_slab[slot, 0] = (rows % DST_BLOCK).astype(np.int32)
        ew_slab[slot, 0] = (1.0 if edge_weight is None
                            else np.asarray(edge_weight, np.float32))
    return idx_slab, seg_slab, ew_slab, n_dst
