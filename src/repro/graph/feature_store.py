"""Streamed node-feature storage for graphs too large to hold in RAM.

``MemmapFeatureStore`` keeps the (N, d) float32 feature matrix on disk as a
standard ``.npy`` file and serves row gathers through a bounded LRU cache of
row chunks — the working set in host memory is ``cache_chunks * chunk_rows *
d * 4`` bytes no matter how large N grows. The store duck-types the three
things the rest of the repo reads off ``Graph.features``:

  * ``store[row_ids]`` — fancy-indexed row gather (what ``sampler.py`` /
    ``prefetch.py`` do once per round for the sampled set, and what
    ``serve/session.py`` plans do for their level-0 source sets);
  * ``store.shape`` / ``store.dtype`` — shape bookkeeping
    (``Graph.feat_dim``, the sampler's ``d_pad``).

Vertical partitioning reuses ONE backing file: ``store.view(lo, hi)``
restricts a store to a client's column block without copying anything on
disk (mirroring how ``synth.make_vfl_dataset`` slices the in-memory
feature matrix per client). Views keep their own chunk caches — a chunk
cached for client m holds only m's columns, so per-client working sets
stay disjoint and individually bounded.

Deliberately NOT provided: ``__array__`` or whole-matrix iteration. Code
that would silently materialize all N rows (e.g. the exact full-graph
eval tables) fails loudly instead — materialization at graph scale is the
bug this store exists to prevent. Callers that genuinely need everything
must opt in chunk by chunk via ``iter_chunks``.
"""
from __future__ import annotations

import os
from collections import OrderedDict
from typing import Iterator, Optional, Tuple

import numpy as np


class MemmapFeatureStore:
    """Row-chunked, LRU-cached view onto an on-disk (N, d) feature matrix."""

    def __init__(self, path: str, *, chunk_rows: int = 8192,
                 cache_chunks: int = 16,
                 col_slice: Optional[Tuple[int, int]] = None):
        self.path = str(path)
        # mmap_mode keeps the OS in charge of file pages; the LRU below
        # bounds the *materialized* chunk copies we actually gather from
        self._mm = np.load(self.path, mmap_mode="r")
        if self._mm.ndim != 2:
            raise ValueError(f"feature store expects a 2-D matrix, got "
                             f"shape {self._mm.shape}")
        self.chunk_rows = int(chunk_rows)
        self.cache_chunks = int(cache_chunks)
        if self.chunk_rows <= 0 or self.cache_chunks <= 0:
            raise ValueError("chunk_rows and cache_chunks must be positive")
        lo, hi = col_slice if col_slice is not None \
            else (0, self._mm.shape[1])
        if not 0 <= lo <= hi <= self._mm.shape[1]:
            raise ValueError(f"column slice [{lo}, {hi}) outside "
                             f"[0, {self._mm.shape[1]})")
        self._cols = (int(lo), int(hi))
        self._cache: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self.cache_hits = 0
        self.cache_misses = 0

    # ------------------------------------------------------------- shape
    @property
    def shape(self) -> Tuple[int, int]:
        lo, hi = self._cols
        return (int(self._mm.shape[0]), hi - lo)

    @property
    def dtype(self):
        return self._mm.dtype

    @property
    def ndim(self) -> int:
        return 2

    def __len__(self) -> int:
        return self.shape[0]

    @property
    def nbytes_disk(self) -> int:
        """Size of the full on-disk matrix (the bytes streaming avoids)."""
        return int(self._mm.shape[0] * self._mm.shape[1]
                   * self._mm.dtype.itemsize)

    @property
    def cache_capacity_bytes(self) -> int:
        """Hard bound on resident chunk bytes for THIS view's cache."""
        lo, hi = self._cols
        return (self.cache_chunks * self.chunk_rows * (hi - lo)
                * self._mm.dtype.itemsize)

    # ------------------------------------------------------------ gather
    def _chunk(self, c: int) -> np.ndarray:
        cached = self._cache.get(c)
        if cached is not None:
            self.cache_hits += 1
            self._cache.move_to_end(c)
            return cached
        self.cache_misses += 1
        lo, hi = self._cols
        r0 = c * self.chunk_rows
        block = np.array(self._mm[r0:r0 + self.chunk_rows, lo:hi])
        self._cache[c] = block
        while len(self._cache) > self.cache_chunks:
            self._cache.popitem(last=False)
        return block

    def __getitem__(self, rows) -> np.ndarray:
        """Gather feature rows by integer id(s); chunk-batched through the
        LRU so each touched chunk is read from disk at most once per call."""
        scalar = np.isscalar(rows) or (isinstance(rows, np.ndarray)
                                       and rows.ndim == 0)
        ids = np.atleast_1d(np.asarray(rows, dtype=np.int64))  # glint: disable=GL003 numpy's native index dtype; row ids stay on host
        if ids.ndim != 1:
            ids_flat = ids.ravel()
        else:
            ids_flat = ids
        n = self.shape[0]
        if ids_flat.size and (ids_flat.min() < 0 or ids_flat.max() >= n):
            raise IndexError(f"row ids out of range [0, {n})")
        out = np.empty((ids_flat.size, self.shape[1]), dtype=self.dtype)
        cids = ids_flat // self.chunk_rows
        order = np.argsort(cids, kind="stable")
        sorted_cids = cids[order]
        bounds = np.flatnonzero(np.diff(sorted_cids)) + 1
        for grp in np.split(order, bounds):
            block = self._chunk(int(cids[grp[0]]))
            out[grp] = block[ids_flat[grp] - int(cids[grp[0]])
                             * self.chunk_rows]
        out = out.reshape(ids.shape + (self.shape[1],))
        return out[0] if scalar else out

    def __array__(self, dtype=None, copy=None):
        # without this, numpy's sequence protocol (__len__ + __getitem__)
        # would let np.asarray(store) silently materialize all N rows —
        # the exact failure mode the store exists to prevent
        raise TypeError(
            f"refusing to materialize the full {self.shape[0]}x"
            f"{self.shape[1]} feature matrix; gather rows with "
            "store[row_ids] or stream with iter_chunks()")

    def iter_chunks(self) -> Iterator[Tuple[int, np.ndarray]]:
        """(row_offset, chunk) pairs in order — the explicit opt-in for
        whole-matrix consumers (bypasses the LRU; nothing is retained)."""
        lo, hi = self._cols
        for r0 in range(0, self.shape[0], self.chunk_rows):
            yield r0, np.array(self._mm[r0:r0 + self.chunk_rows, lo:hi])

    # ------------------------------------------------------------- views
    def view(self, col_lo: int, col_hi: int) -> "MemmapFeatureStore":
        """A column-block view over the same backing file (own LRU)."""
        base = self._cols[0]
        return MemmapFeatureStore(
            self.path, chunk_rows=self.chunk_rows,
            cache_chunks=self.cache_chunks,
            col_slice=(base + col_lo, base + col_hi))

    def drop_cache(self) -> None:
        self._cache.clear()


def create_store(path: str, n_rows: int, n_cols: int,
                 dtype=np.float32) -> np.memmap:
    """Allocate the backing ``.npy`` and return a writable row memmap.

    Writers fill it chunk-by-chunk (never holding more than a chunk in
    RAM), flush, then open ``MemmapFeatureStore(path)`` for reading.
    """
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    return np.lib.format.open_memmap(
        path, mode="w+", dtype=np.dtype(dtype), shape=(n_rows, n_cols))


def is_streamed(features) -> bool:
    """True if ``features`` is a streamed store rather than a resident
    array (the branch point for eval/serve paths that would otherwise
    materialize all N rows)."""
    return isinstance(features, MemmapFeatureStore)
