"""FastGCN-style layer-wise neighborhood sampling for GLASU (paper Alg 2).

Semantics reproduced from the paper:

  * ``S[L]`` (the mini-batch) is shared across clients.
  * Aggregation at layer ``l`` requires the *output* node set ``S[l+1]`` to be
    shared: the server takes the union of the clients' index sets and
    broadcasts it (Alg 2's ``Aggregate``/``Broadcast``).
  * At layers where aggregation is skipped (lazy aggregation), every client
    samples and keeps its OWN node set ``S_m[l]`` — the extra flexibility the
    paper highlights in §3.2.

TPU adaptation: XLA wants static shapes, so every per-layer node set is padded
to a precomputed size and the bipartite adjacency ``A(E[l])`` is represented
as a (n_{l+1}, fanout+1) gather-index tensor (column 0 = self loop) with a
validity mask; aggregation is a masked mean (GraphSAGE-mean normalization).
Sampling itself runs on host in numpy — exactly as in the paper, where it is
server/client coordination, not accelerator work.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, NamedTuple, Sequence

import numpy as np

from .graph import Graph, VFLDataset


class SampledBatch(NamedTuple):
    """Static-shape mini-batch for one GLASU round (all clients stacked)."""

    feats: np.ndarray                 # (M, n0, d_pad) f32 client-0-layer features
    gather_idx: tuple                 # per layer l: (M, n_{l+1}, F+1) int32
    gather_mask: tuple                # per layer l: (M, n_{l+1}, F+1) f32
    row_valid: tuple                  # per layer l: (M, n_{l+1}) f32 (1 = real row)
    labels: np.ndarray                # (S,) int32
    self_pos: tuple                   # per layer l: (M, n_{l+1}) int32 pos of S[l+1] in S[l]

    @property
    def n_layers(self) -> int:
        return len(self.gather_idx)


def _padded_tables(g: Graph, cap: int, rng: np.random.Generator):
    """Pre-pack CSR into a (N, cap) neighbor table for vectorized sampling."""
    n = g.n_nodes
    table = np.full((n, cap), -1, dtype=np.int32)
    deg = np.zeros(n, dtype=np.int32)
    for i in range(n):
        nbrs = g.neighbors(i)
        if len(nbrs) > cap:
            nbrs = rng.choice(nbrs, size=cap, replace=False)
        table[i, :len(nbrs)] = nbrs
        deg[i] = len(nbrs)
    return table, deg


@dataclass
class SamplerConfig:
    n_layers: int = 4
    agg_layers: Sequence[int] = (1, 3)   # paper's "uniform" K=2 for L=4
    batch_size: int = 16
    fanout: int = 3
    size_cap: int = 512
    table_cap: int = 64                  # hub-node pre-subsample (Reddit/HeriGraph)


class GlasuSampler:
    """Produces SampledBatch rounds; owns per-client padded neighbor tables."""

    def __init__(self, data: VFLDataset, cfg: SamplerConfig, seed: int = 0):
        assert (cfg.n_layers - 1) in cfg.agg_layers, \
            "final layer must aggregate (clients need a shared H[L])"
        self.data = data
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        self.M = data.n_clients
        table_rng = np.random.default_rng(seed + 1)
        self.tables = [_padded_tables(c, cfg.table_cap, table_rng) for c in data.clients]
        self.d_pad = max(c.feat_dim for c in data.clients)
        self.layer_sizes = self._plan_sizes()

    # ``S[j]`` is shared iff (j-1) in I or j == L.
    def _shared(self, j: int) -> bool:
        return j == self.cfg.n_layers or (j - 1) in self.cfg.agg_layers

    def _plan_sizes(self) -> List[int]:
        cfg = self.cfg
        sizes = [0] * (cfg.n_layers + 1)
        sizes[cfg.n_layers] = cfg.batch_size
        for l in range(cfg.n_layers - 1, -1, -1):
            mult = self.M if (self._shared(l) and not self._shared(l + 1)) else 1
            bound = mult * sizes[l + 1] * (cfg.fanout + 1)
            # center nodes can never be dropped -> floor of mult * n_{l+1}
            sizes[l] = max(min(bound, cfg.size_cap), mult * sizes[l + 1])
        return sizes

    def _sample_neighbors(self, m: int, centers: np.ndarray) -> np.ndarray:
        """(n, F) sampled neighbor ids for client m (with replacement), -1 pad."""
        table, deg = self.tables[m]
        f = self.cfg.fanout
        valid = centers >= 0
        safe = np.where(valid, centers, 0)
        d = deg[safe]
        cols = (self.rng.integers(0, 1 << 30, size=(len(centers), f))
                % np.maximum(d, 1)[:, None]).astype(np.int64)
        nb = table[safe[:, None], cols]
        nb = np.where((d[:, None] > 0) & valid[:, None], nb, -1)
        return nb.astype(np.int32)

    @staticmethod
    def _build_set(centers_list, nbrs_list, size) -> np.ndarray:
        """Order: unique centers first (never dropped), then other candidates."""
        centers = np.unique(np.concatenate(centers_list))
        centers = centers[centers >= 0]
        others = np.unique(np.concatenate([x.ravel() for x in nbrs_list]))
        others = others[others >= 0]
        others = np.setdiff1d(others, centers, assume_unique=True)
        if len(centers) > size:
            raise RuntimeError("layer size too small for center set")
        room = size - len(centers)
        if len(others) > room:
            others = others[:room]  # deterministic truncation
        s = np.concatenate([centers, others])
        out = np.full(size, -1, dtype=np.int32)
        out[:len(s)] = s
        return out

    @staticmethod
    def _positions(node_set: np.ndarray, query: np.ndarray):
        """positions of ``query`` ids in ``node_set`` (-1 if absent)."""
        order = np.argsort(node_set, kind="stable")
        sorted_set = node_set[order]
        q = query.ravel()
        loc = np.searchsorted(sorted_set, q)
        loc = np.clip(loc, 0, len(sorted_set) - 1)
        hit = (sorted_set[loc] == q) & (q >= 0)
        pos = np.where(hit, order[loc], -1)
        return pos.reshape(query.shape).astype(np.int32)

    def sample_round(self) -> SampledBatch:
        cfg, M = self.cfg, self.M
        L = cfg.n_layers
        train_idx = self.data.full.train_idx
        batch = self.rng.choice(train_idx, size=cfg.batch_size,
                                replace=len(train_idx) < cfg.batch_size).astype(np.int32)
        cur = [batch.copy() for _ in range(M)]      # S_m[L] (shared)
        gidx, gmask, rvalid, spos = [None] * L, [None] * L, [None] * L, [None] * L

        for l in range(L - 1, -1, -1):
            nbrs = [self._sample_neighbors(m, cur[m]) for m in range(M)]
            size = self.layer_sizes[l]
            if self._shared(l):
                shared_set = self._build_set(cur, nbrs, size)
                sets = [shared_set] * M
            else:
                sets = [self._build_set([cur[m]], [nbrs[m]], size) for m in range(M)]

            gi = np.zeros((M, self.layer_sizes[l + 1], cfg.fanout + 1), np.int32)
            gm = np.zeros_like(gi, dtype=np.float32)
            rv = np.zeros((M, self.layer_sizes[l + 1]), np.float32)
            sp = np.zeros((M, self.layer_sizes[l + 1]), np.int32)
            for m in range(M):
                cpos = self._positions(sets[m], cur[m])          # self positions
                npos = self._positions(sets[m], nbrs[m])         # neighbor positions
                gi[m, :, 0] = np.maximum(cpos, 0)
                gm[m, :, 0] = (cpos >= 0).astype(np.float32)
                gi[m, :, 1:] = np.maximum(npos, 0)
                gm[m, :, 1:] = (npos >= 0).astype(np.float32)
                rv[m] = (cur[m] >= 0).astype(np.float32)
                gm[m] *= rv[m][:, None]
                sp[m] = np.maximum(cpos, 0)
            gidx[l], gmask[l], rvalid[l], spos[l] = gi, gm, rv, sp
            cur = sets

        feats = np.zeros((M, self.layer_sizes[0], self.d_pad), np.float32)
        for m in range(M):
            s = cur[m]
            ok = s >= 0
            x = self.data.clients[m].features
            feats[m, ok, :x.shape[1]] = x[s[ok]]
        labels = self.data.full.labels[batch].astype(np.int32)
        return SampledBatch(feats, tuple(gidx), tuple(gmask), tuple(rvalid),
                            labels, tuple(spos))

    def comm_bytes_per_joint_inference(self, hidden: int, agg: str = "mean") -> int:
        """Paper cost model: per aggregation layer, every client uploads its
        (n_{l+1}, h) block and receives the aggregate back; plus index sync."""
        total = 0
        for l in self.cfg.agg_layers:
            n = self.layer_sizes[l + 1]
            up = self.M * n * hidden * 4
            down_h = hidden * (self.M if agg == "concat" else 1)
            down = self.M * n * down_h * 4
            total += up + down
        for j in range(self.cfg.n_layers + 1):
            if self._shared(j):
                total += 2 * self.M * self.layer_sizes[j] * 4  # index union sync
        return total
