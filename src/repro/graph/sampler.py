"""FastGCN-style layer-wise neighborhood sampling for GLASU (paper Alg 2).

Semantics reproduced from the paper:

  * ``S[L]`` (the mini-batch) is shared across clients.
  * Aggregation at layer ``l`` requires the *output* node set ``S[l+1]`` to be
    shared: the server takes the union of the clients' index sets and
    broadcasts it (Alg 2's ``Aggregate``/``Broadcast``).
  * At layers where aggregation is skipped (lazy aggregation), every client
    samples and keeps its OWN node set ``S_m[l]`` — the extra flexibility the
    paper highlights in §3.2.

TPU adaptation: XLA wants static shapes, so every per-layer node set is padded
to a precomputed size and the bipartite adjacency ``A(E[l])`` is represented
as a (n_{l+1}, fanout+1) gather-index tensor (column 0 = self loop) with a
validity mask; aggregation is a masked mean (GraphSAGE-mean normalization).
Sampling itself runs on host in numpy — exactly as in the paper, where it is
server/client coordination, not accelerator work.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, NamedTuple, Sequence

import numpy as np

from .graph import Graph, VFLDataset, scatter_neighbor_rows


class SampledBatch(NamedTuple):
    """Static-shape mini-batch for one GLASU round (all clients stacked).

    The arrays are views into per-layer scratch buffers owned by the sampler
    and are overwritten by the next ``sample_round`` call — consume or copy
    them (``jnp.array``, not ``jnp.asarray``: the latter zero-copy aliases
    host numpy buffers on CPU) before sampling again. The training loop
    does this structurally: ``graph.prefetch.PrefetchSampler`` copies each
    round into round-stacked generation buffers off the main thread and
    gates their reuse on compute completion.
    """

    feats: np.ndarray                 # (M, n0, d_pad) f32 client-0-layer features
    gather_idx: tuple                 # per layer l: (M, n_{l+1}, F+1) int32
    gather_mask: tuple                # per layer l: (M, n_{l+1}, F+1) f32
    row_valid: tuple                  # per layer l: (M, n_{l+1}) f32 (1 = real row)
    labels: np.ndarray                # (S,) int32
    self_pos: tuple                   # per layer l: (M, n_{l+1}) int32 pos of S[l+1] in S[l]

    @property
    def n_layers(self) -> int:
        return len(self.gather_idx)


def _padded_tables(g: Graph, cap: int, rng: np.random.Generator):
    """Pre-pack CSR into a (N, cap) neighbor table for vectorized sampling.

    Fully vectorized (no per-node Python loop):

      * rows with degree <= cap keep all neighbors, scattered straight from
        CSR (column order is irrelevant — sampling draws a uniform column);
      * hub rows (degree > cap) keep a uniform without-replacement subsample:
        one random matrix over the hub rows, invalid columns masked to +inf,
        ``argpartition`` picks the cap smallest keys per row. Hub rows are
        chunked so the scratch matrix stays bounded regardless of max degree.
    """
    n = g.n_nodes
    table = np.full((n, cap), -1, dtype=np.int32)
    deg_full = np.diff(g.indptr)
    scatter_neighbor_rows(table, g.indptr, g.indices, deg_full, cap, rng)
    deg = np.minimum(deg_full, cap).astype(np.int32)
    return table, deg


@dataclass
class SamplerConfig:
    n_layers: int = 4
    agg_layers: Sequence[int] = (1, 3)   # paper's "uniform" K=2 for L=4
    batch_size: int = 16
    fanout: int = 3
    size_cap: int = 512
    table_cap: int = 64                  # hub-node pre-subsample (Reddit/HeriGraph)


class GlasuSampler:
    """Produces SampledBatch rounds; owns per-client padded neighbor tables."""

    def __init__(self, data: VFLDataset, cfg: SamplerConfig, seed: int = 0):
        assert (cfg.n_layers - 1) in cfg.agg_layers, \
            "final layer must aggregate (clients need a shared H[L])"
        self.data = data
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        self.M = data.n_clients
        table_rng = np.random.default_rng(seed + 1)
        self.tables = [_padded_tables(c, cfg.table_cap, table_rng) for c in data.clients]
        self.d_pad = max(c.feat_dim for c in data.clients)
        self.layer_sizes = self._plan_sizes()
        # per-layer scratch reused across rounds (see SampledBatch docstring)
        M, F1 = self.M, cfg.fanout + 1
        self._scratch = [
            (np.zeros((M, self.layer_sizes[l + 1], F1), np.int32),
             np.zeros((M, self.layer_sizes[l + 1], F1), np.float32),
             np.zeros((M, self.layer_sizes[l + 1]), np.float32),
             np.zeros((M, self.layer_sizes[l + 1]), np.int32))
            for l in range(cfg.n_layers)]
        self._feat_scratch = np.zeros((M, self.layer_sizes[0], self.d_pad),
                                      np.float32)
        # O(1) id -> position lookup used by _positions (reset after each
        # use); positions are bounded by size_cap so int32 suffices and
        # halves the table's footprint/refill traffic
        self._pos_lut = np.full(data.n_nodes, -1, dtype=np.int32)
        # per-layer (M, n_{l+1}, F+1) gather-query buffer reused across
        # rounds (center column + fanout columns), sized like the gi scratch
        self._query_scratch = [
            np.zeros((M, self.layer_sizes[l + 1], F1), np.int32)
            for l in range(cfg.n_layers)]
        # candidate mark array used by _build_set (reset after each use)
        self._mark = np.zeros(data.n_nodes, dtype=np.uint8)
        # all clients' tables stacked for the batched per-layer draw
        self._tables = np.stack([t for t, _ in self.tables])   # (M, N, cap)
        self._degs = np.stack([d for _, d in self.tables])     # (M, N)
        self._m_idx = np.arange(M)

    # ``S[j]`` is shared iff (j-1) in I or j == L.
    def _shared(self, j: int) -> bool:
        return j == self.cfg.n_layers or (j - 1) in self.cfg.agg_layers

    def _plan_sizes(self) -> List[int]:
        cfg = self.cfg
        sizes = [0] * (cfg.n_layers + 1)
        sizes[cfg.n_layers] = cfg.batch_size
        for l in range(cfg.n_layers - 1, -1, -1):
            mult = self.M if (self._shared(l) and not self._shared(l + 1)) else 1
            bound = mult * sizes[l + 1] * (cfg.fanout + 1)
            # center nodes can never be dropped -> floor of mult * n_{l+1}
            sizes[l] = max(min(bound, cfg.size_cap), mult * sizes[l + 1])
        return sizes

    def _sample_neighbors(self, m: int, centers: np.ndarray) -> np.ndarray:
        """(n, F) sampled neighbor ids for client m (with replacement), -1 pad."""
        return self._sample_neighbors_all(centers[None],
                                          self._m_idx[m:m + 1])[0]

    def _sample_neighbors_all(self, centers: np.ndarray,
                              m_idx=None) -> np.ndarray:
        """(M, n) centers -> (M, n, F) sampled neighbors for every client in
        one batched draw (with replacement), -1 pad."""
        if m_idx is None:
            m_idx = self._m_idx
        f = self.cfg.fanout
        valid = centers >= 0
        safe = np.where(valid, centers, 0)
        d = self._degs[m_idx[:, None], safe]                  # (M, n)
        # direct bounded draw per row — a wide draw reduced mod d skews the
        # first (2^30 mod d) neighbor slots upward
        cols = self.rng.integers(0, np.maximum(d, 1)[..., None],
                                 size=(*centers.shape, f))
        nb = self._tables[m_idx[:, None, None], safe[..., None], cols]
        return np.where((d[..., None] > 0) & valid[..., None], nb, -1)

    def _build_set(self, centers_list, nbrs_list, size) -> np.ndarray:
        """Order: unique centers first (never dropped), then other candidates.

        Dedup runs on the cached mark array (O(N) scans, no sorts); both id
        groups come out ascending, matching the previous np.unique order.
        """
        mark = self._mark
        for x in nbrs_list:
            v = np.asarray(x).ravel()
            mark[v[v >= 0]] = 1
        for x in centers_list:
            v = np.asarray(x).ravel()
            mark[v[v >= 0]] = 2
        ids = np.flatnonzero(mark)
        vals = mark[ids]
        centers = ids[vals == 2]
        others = ids[vals == 1]
        mark[ids] = 0
        if len(centers) > size:
            raise RuntimeError("layer size too small for center set")
        room = size - len(centers)
        if len(others) > room:
            # ids come out sorted — truncating directly would always keep
            # the lowest node ids and permanently drop high-id neighbors;
            # permute with the round RNG first (reproducible under the seed)
            others = self.rng.permutation(others)[:room]
        out = np.full(size, -1, dtype=np.int32)
        out[:len(centers)] = centers
        out[len(centers):len(centers) + len(others)] = others
        return out

    def _positions(self, node_set: np.ndarray, query: np.ndarray):
        """positions of ``query`` ids in ``node_set`` (-1 if absent).

        O(|set| + |query|) via the cached id->position lookup table (touched
        entries are reset afterwards so the table stays all -1). Node sets
        from ``_build_set`` keep their valid ids as a prefix (-1 padding at
        the tail), which the lookup fill exploits.
        """
        lut = self._pos_lut
        k = int((node_set >= 0).sum())
        ids = node_set[:k]
        lut[ids] = np.arange(k)
        q = query.ravel()
        pos = np.where(q >= 0, lut[np.maximum(q, 0)], -1)
        lut[ids] = -1
        return pos.reshape(query.shape).astype(np.int32)

    def sample_round(self) -> SampledBatch:
        cfg, M = self.cfg, self.M
        L = cfg.n_layers
        train_idx = self.data.full.train_idx
        batch = self.rng.choice(train_idx, size=cfg.batch_size,
                                replace=len(train_idx) < cfg.batch_size).astype(np.int32)
        cur = np.tile(batch, (M, 1))                # S_m[L] (shared), (M, n)
        gidx, gmask, rvalid, spos = [None] * L, [None] * L, [None] * L, [None] * L

        for l in range(L - 1, -1, -1):
            nbrs = self._sample_neighbors_all(cur)  # (M, n, F), one draw
            size = self.layer_sizes[l]
            gi, gm, rv, sp = self._scratch[l]       # reused across rounds
            # self positions ride as column 0 of the gather query, so one
            # _positions call per client (or one batched call when shared)
            # fills the whole (n, F+1) index/mask block; the query buffer is
            # preallocated per layer — no per-round concatenate allocation
            query = self._query_scratch[l]
            query[..., 0] = cur
            query[..., 1:] = nbrs
            if self._shared(l):
                sset = self._build_set([cur], [nbrs], size)
                pos = self._positions(sset, query)          # (M, n, F+1)
                gi[...] = np.maximum(pos, 0)
                gm[...] = pos >= 0
                cur_next = np.tile(sset, (M, 1))
            else:
                sets = []
                for m in range(M):
                    s = self._build_set([cur[m]], [nbrs[m]], size)
                    pos = self._positions(s, query[m])
                    gi[m] = np.maximum(pos, 0)
                    gm[m] = pos >= 0
                    sets.append(s)
                cur_next = np.stack(sets)
            rv[...] = cur >= 0
            gm *= rv[..., None]
            sp[...] = gi[..., 0]
            gidx[l], gmask[l], rvalid[l], spos[l] = gi, gm, rv, sp
            cur = cur_next

        feats = self._feat_scratch
        feats.fill(0.0)
        for m in range(M):
            s = cur[m]
            ok = s >= 0
            x = self.data.clients[m].features
            feats[m, ok, :x.shape[1]] = x[s[ok]]
        labels = self.data.full.labels[batch].astype(np.int32)
        return SampledBatch(feats, tuple(gidx), tuple(gmask), tuple(rvalid),
                            labels, tuple(spos))

    def shape_shell_batch(self) -> SampledBatch:
        """Zero-stride shells with one round's static shapes/dtypes.

        For shape-driven consumers — abstract tracing (``jax.eval_shape``)
        and message/byte accounting — without touching the live scratch
        buffers or allocating real arrays.
        """
        z = lambda a: np.broadcast_to(np.zeros((), a.dtype), a.shape)
        gi, gm, rv, sp = zip(*[(z(i), z(m), z(v), z(p))
                               for i, m, v, p in self._scratch])
        return SampledBatch(
            feats=z(self._feat_scratch), gather_idx=gi, gather_mask=gm,
            row_valid=rv,
            labels=np.broadcast_to(np.int32(0), (self.cfg.batch_size,)),
            self_pos=sp)

    def comm_bytes_per_joint_inference(self, hidden: int, agg: str = "mean",
                                       compressor=None,
                                       n_uploads: int | None = None) -> int:
        """Paper cost model: per aggregation layer, every client uploads its
        (n_{l+1}, h) block and receives the aggregate back; plus index sync.

        With a ``compressor`` (``comm.compression.Compressor``) embedding
        messages are priced at their exact wire size instead of 4 B/float;
        the int32 index-sync traffic is codec-independent and unchanged.

        ``n_uploads`` (fault-tolerant rounds) prices only the uploads that
        were DELIVERED by the deadline — a dropped or late upload never
        reaches the server, so it costs zero on the wire. Downlink and
        index sync still go to all M clients: every client (present or
        not) runs its local updates against the broadcast aggregate.
        """
        m_up = self.M if n_uploads is None else int(n_uploads)
        if not 0 <= m_up <= self.M:
            raise ValueError(f"n_uploads must be in [0, {self.M}], "
                             f"got {n_uploads}")
        total = 0
        for l in self.cfg.agg_layers:
            n = self.layer_sizes[l + 1]
            down_h = hidden * (self.M if agg == "concat" else 1)
            if compressor is None:
                up = m_up * n * hidden * 4
                down = self.M * n * down_h * 4
            else:
                up = m_up * compressor.wire_bytes(n, hidden)
                down = self.M * compressor.wire_bytes(n, down_h)
            total += up + down
        for j in range(self.cfg.n_layers + 1):
            if self._shared(j):
                total += 2 * self.M * self.layer_sizes[j] * 4  # index union sync
        return total
