"""Background sampler prefetch: overlap host sampling with device compute.

The Trainer's round loop used to be strictly serial — sample on host (numpy),
copy the batch out of the sampler's scratch (``jnp.array``), dispatch, repeat
— so the device sat idle through every sampling phase and the main thread
paid a full-batch copy per round. ``PrefetchSampler`` moves sampling to a
worker thread that fills preallocated *generation* buffers (round-stacked,
ready for ``make_multi_round_fn``) while the device computes the previous
step.

Safety: on CPU JAX, ``jax.device_put``/``jnp.asarray`` zero-copy alias host
numpy buffers, so a generation may only be refilled once the computation
that read it has finished. The consumer enforces that by returning a
generation token to the worker only after blocking on an output of the step
that consumed it (``retire``). With the default two generations this is
classic double buffering: the worker samples step N+1 while the device runs
step N, and refilling a buffer waits on the completion of the step that read
it — never on the step currently in flight.

The worker owns the sampler's ``np.random.Generator`` for the lifetime of
the pipeline; each ``StepBatch`` carries the generator's bit state *after*
its rounds were drawn, so checkpointing can persist an exact resume point at
any step boundary even though the worker has sampled ahead.
"""
from __future__ import annotations

import copy
import queue
import threading
from typing import List, NamedTuple, Sequence

import jax
import numpy as np

from .sampler import GlasuSampler, SampledBatch


def stack_rounds(batches: Sequence[SampledBatch]) -> SampledBatch:
    """Stack per-round batches on a new leading round axis (fresh arrays)."""
    return jax.tree.map(lambda *xs: np.stack(xs), *batches)


def unstack_round(batches: SampledBatch, i: int) -> SampledBatch:
    """Round ``i``'s slice of a round-stacked batch (views)."""
    return jax.tree.map(lambda x: x[i], batches)


class StepBatch(NamedTuple):
    data: SampledBatch          # every leaf: (K, ...) view into a generation
    rounds: int                 # K
    gen: int                    # generation buffer index (retire() token)
    rng_state_after: dict       # sampler bit-generator state after this step


class _WorkerError(NamedTuple):
    exc: BaseException


_STOP = -1


class PrefetchSampler:
    """Double-buffered background sampling over a fixed step schedule.

    Usage (the Trainer's loop):

        pf = PrefetchSampler(sampler, schedule)
        try:
            for _ in schedule:
                step = pf.get()                  # blocks on the worker only
                out = backend.run_step(..., step.data, ...)
                pf.retire(step, out.losses)      # recycles old generations
        finally:
            pf.close()
    """

    def __init__(self, sampler: GlasuSampler, schedule: Sequence[int],
                 n_buffers: int = 2):
        if any(k < 1 for k in schedule):
            raise ValueError(f"step schedule must be positive: {schedule}")
        self.sampler = sampler
        self.schedule = list(schedule)
        self.n_buffers = max(1, min(int(n_buffers), len(self.schedule)))
        k_max = max(self.schedule, default=0)
        self._bufs: List[SampledBatch] = [
            self._alloc_generation(k_max) for _ in range(self.n_buffers)]
        self._free: "queue.Queue[int]" = queue.Queue()
        for g in range(self.n_buffers):
            self._free.put(g)
        self._out: "queue.Queue[Any]" = queue.Queue()
        self._inflight: List[tuple] = []     # (gen, output handle) FIFO
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._work, name="glasu-prefetch", daemon=True)
        self._thread.start()

    # ---------------------------------------------------------- allocation
    def _alloc_generation(self, k: int) -> SampledBatch:
        """One round-stacked scratch generation matching the sampler's
        static shapes (leading axis k)."""
        s = self.sampler
        cfg = s.cfg
        mk = lambda like: np.zeros((k,) + like.shape, like.dtype)
        gi, gm, rv, sp = [], [], [], []
        for l in range(cfg.n_layers):
            i, m, v, p = s._scratch[l]
            gi.append(mk(i))
            gm.append(mk(m))
            rv.append(mk(v))
            sp.append(mk(p))
        return SampledBatch(
            feats=mk(s._feat_scratch),
            gather_idx=tuple(gi), gather_mask=tuple(gm),
            row_valid=tuple(rv),
            labels=np.zeros((k, cfg.batch_size), np.int32),
            self_pos=tuple(sp))

    # -------------------------------------------------------------- worker
    def _work(self):
        try:
            for k in self.schedule:
                gen = self._free.get()
                if gen == _STOP or self._stop.is_set():
                    return
                buf = self._bufs[gen]
                view = unstack_round(buf, slice(0, k))
                for i in range(k):
                    if self._stop.is_set():  # close() mid-fill: exit promptly
                        return               # instead of finishing the step
                    b = self.sampler.sample_round()
                    jax.tree.map(lambda dst, src, i=i: np.copyto(dst[i], src),
                                 view, b)
                state = copy.deepcopy(
                    self.sampler.rng.bit_generator.state)
                self._out.put(StepBatch(view, k, gen, state))
        except BaseException as e:          # propagate to the consumer
            self._out.put(_WorkerError(e))

    # ------------------------------------------------------------ consumer
    def get(self) -> StepBatch:
        item = self._out.get()
        if isinstance(item, _WorkerError):
            raise RuntimeError("sampler prefetch worker failed") from item.exc
        return item

    def retire(self, step: StepBatch, sync_handle) -> None:
        """Register the step as dispatched; recycle the oldest generation
        once the pipeline is full, blocking on ITS computation only (the
        step currently in flight keeps running)."""
        self._inflight.append((step.gen, sync_handle))
        while len(self._inflight) >= self.n_buffers:
            gen, handle = self._inflight.pop(0)
            if handle is not None:
                jax.block_until_ready(handle)
            self._free.put(gen)

    def close(self) -> None:
        self._stop.set()
        self._free.put(_STOP)
        while True:                          # unblock a worker stuck on put
            try:
                self._out.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=10.0)
        # drain whatever raced in between the final get_nowait and join
        while True:
            try:
                self._out.get_nowait()
            except queue.Empty:
                break
        self._inflight.clear()
