"""Graph data structures for vertically-partitioned GNN training.

Host-side (numpy) CSR graphs. Each VFL client holds the SAME node set but its
own edge set ``E_m`` and a disjoint feature block ``X_m`` (paper §2.1). The
JAX side only ever sees padded, static-shape index tensors produced by the
sampler; the CSR structures here stay on host — mirroring the paper, where
sampling (Alg 2) is a host/server coordination step.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class Graph:
    """Undirected graph in CSR with per-node features/labels."""

    n_nodes: int
    indptr: np.ndarray          # (N+1,) int64
    indices: np.ndarray         # (nnz,) int32 neighbor ids
    features: np.ndarray        # (N, d) float32
    labels: np.ndarray          # (N,) int32
    train_idx: np.ndarray
    val_idx: np.ndarray
    test_idx: np.ndarray

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    @property
    def feat_dim(self) -> int:
        return int(self.features.shape[1])

    @property
    def n_classes(self) -> int:
        return int(self.labels.max()) + 1

    def neighbors(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i]:self.indptr[i + 1]]

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def padded_neighbor_table(self, max_deg: int, rng: np.random.Generator,
                              include_self: bool = True):
        """(N, max_deg+1) neighbor table for exact chunked full-graph eval.

        Column 0 is the node itself (self-loop). Nodes with more than
        ``max_deg`` neighbors get a uniform subsample (deterministic given
        ``rng``) — this is the eval-time analogue of FastGCN sampling.
        Returns (idx, mask) int32/float32.
        """
        n = self.n_nodes
        width = max_deg + (1 if include_self else 0)
        idx = np.zeros((n, width), dtype=np.int32)
        mask = np.zeros((n, width), dtype=np.float32)
        for i in range(n):
            nbrs = self.neighbors(i)
            if len(nbrs) > max_deg:
                nbrs = rng.choice(nbrs, size=max_deg, replace=False)
            off = 0
            if include_self:
                idx[i, 0] = i
                mask[i, 0] = 1.0
                off = 1
            idx[i, off:off + len(nbrs)] = nbrs
            mask[i, off:off + len(nbrs)] = 1.0
        return idx, mask


def edges_to_csr(n_nodes: int, edges: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Symmetrize an (E, 2) edge list into CSR (indptr, indices)."""
    if edges.size == 0:
        return np.zeros(n_nodes + 1, np.int64), np.zeros(0, np.int32)
    und = np.concatenate([edges, edges[:, ::-1]], axis=0)
    und = np.unique(und, axis=0)
    und = und[und[:, 0] != und[:, 1]]  # no explicit self loops (added by sampler)
    order = np.lexsort((und[:, 1], und[:, 0]))
    und = und[order]
    counts = np.bincount(und[:, 0], minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, und[:, 1].astype(np.int32)


@dataclass
class VFLDataset:
    """M client views of one vertically-partitioned graph dataset."""

    name: str
    clients: List[Graph]            # client m: own E_m, features X_m (N, d_m)
    full: Graph                     # union graph with full features (centralized baseline)
    n_classes: int = field(init=False)

    def __post_init__(self):
        self.n_classes = self.full.n_classes

    @property
    def n_clients(self) -> int:
        return len(self.clients)

    @property
    def n_nodes(self) -> int:
        return self.full.n_nodes
