"""Graph data structures for vertically-partitioned GNN training.

Host-side (numpy) CSR graphs. Each VFL client holds the SAME node set but its
own edge set ``E_m`` and a disjoint feature block ``X_m`` (paper §2.1). The
JAX side only ever sees padded, static-shape index tensors produced by the
sampler; the CSR structures here stay on host — mirroring the paper, where
sampling (Alg 2) is a host/server coordination step.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

import numpy as np


def scatter_neighbor_rows(table, indptr, indices, deg_full, cap,
                          rng: np.random.Generator, col_offset: int = 0,
                          mask=None):
    """Fill ``table[:, col_offset:col_offset+cap]`` with (subsampled) CSR
    neighbor rows, fully vectorized (no per-node Python loop):

      * rows with degree <= cap keep all neighbors, scattered straight from
        CSR (column order is irrelevant to masked-mean aggregation and to
        uniform column draws);
      * hub rows (degree > cap) keep a uniform without-replacement subsample:
        one random key matrix over the hub rows, invalid columns masked to
        +inf, ``argpartition`` picks the cap smallest keys per row. Hub rows
        are chunked so the key matrix stays bounded regardless of max degree.

    Optionally sets ``mask`` to 1.0 at every filled slot. Shared by the
    sampler's training tables and the eval-time ``padded_neighbor_table``.
    """
    under = deg_full <= cap
    iu = np.flatnonzero(under)
    if len(iu):
        du = deg_full[iu]
        rowu = np.repeat(iu, du)
        posu = (np.arange(len(rowu), dtype=np.int32)
                - np.repeat(np.cumsum(du) - du, du))
        table[rowu, col_offset + posu] = \
            indices[np.repeat(indptr[:-1][iu], du) + posu]
        if mask is not None:
            mask[rowu, col_offset + posu] = 1.0
    ih = np.flatnonzero(~under)
    if len(ih):
        dmax = int(deg_full[ih].max())
        chunk = max(1, int(5_000_000 // max(dmax, 1)))
        cols = np.arange(cap)
        for lo in range(0, len(ih), chunk):
            rows = ih[lo:lo + chunk]
            d = deg_full[rows]
            keys = rng.random((len(rows), dmax), dtype=np.float32)
            keys[np.arange(dmax)[None, :] >= d[:, None]] = np.inf
            pick = np.argpartition(keys, cap - 1, axis=1)[:, :cap]
            table[rows[:, None], col_offset + cols[None, :]] = \
                indices[indptr[rows][:, None] + pick]
            if mask is not None:
                mask[rows[:, None], col_offset + cols[None, :]] = 1.0


@dataclass
class Graph:
    """Undirected graph in CSR with per-node features/labels."""

    n_nodes: int
    indptr: np.ndarray          # (N+1,) int64
    indices: np.ndarray         # (nnz,) int32 neighbor ids
    features: np.ndarray        # (N, d) float32
    labels: np.ndarray          # (N,) int32
    train_idx: np.ndarray
    val_idx: np.ndarray
    test_idx: np.ndarray

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    @property
    def feat_dim(self) -> int:
        return int(self.features.shape[1])

    @property
    def n_classes(self) -> int:
        return int(self.labels.max()) + 1

    def neighbors(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i]:self.indptr[i + 1]]

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def padded_neighbor_table(self, max_deg: int, rng: np.random.Generator,
                              include_self: bool = True):
        """(N, max_deg+1) neighbor table for exact chunked full-graph eval.

        Column 0 is the node itself (self-loop). Nodes with more than
        ``max_deg`` neighbors get a uniform subsample (deterministic given
        ``rng``) — this is the eval-time analogue of FastGCN sampling.
        Returns (idx, mask) int32/float32.
        """
        n = self.n_nodes
        off = 1 if include_self else 0
        width = max_deg + off
        idx = np.zeros((n, width), dtype=np.int32)
        mask = np.zeros((n, width), dtype=np.float32)
        if include_self:
            idx[:, 0] = np.arange(n, dtype=np.int32)
            mask[:, 0] = 1.0
        scatter_neighbor_rows(idx, self.indptr, self.indices,
                              np.diff(self.indptr), max_deg, rng,
                              col_offset=off, mask=mask)
        return idx, mask


def edges_to_csr(n_nodes: int, edges: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Symmetrize an (E, 2) edge list into CSR (indptr, indices)."""
    if edges.size == 0:
        return np.zeros(n_nodes + 1, np.int32), np.zeros(0, np.int32)
    und = np.concatenate([edges, edges[:, ::-1]], axis=0)
    und = np.unique(und, axis=0)
    und = und[und[:, 0] != und[:, 1]]  # no explicit self loops (added by sampler)
    order = np.lexsort((und[:, 1], und[:, 0]))
    und = und[order]
    counts = np.bincount(und[:, 0], minlength=n_nodes)
    # int32 CSR repo-wide (x64 stays off end to end): caps at 2^31 edges,
    # far past the roadmap's 1M-node profiles
    indptr = np.zeros(n_nodes + 1, dtype=np.int32)
    indptr[1:] = np.cumsum(counts).astype(np.int32)
    return indptr, und[:, 1].astype(np.int32)


@dataclass
class VFLDataset:
    """M client views of one vertically-partitioned graph dataset."""

    name: str
    clients: List[Graph]            # client m: own E_m, features X_m (N, d_m)
    full: Graph                     # union graph with full features (centralized baseline)
    n_classes: int = field(init=False)

    def __post_init__(self):
        self.n_classes = self.full.n_classes

    @property
    def n_clients(self) -> int:
        return len(self.clients)

    @property
    def n_nodes(self) -> int:
        return self.full.n_nodes
