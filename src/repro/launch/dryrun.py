import os

# jax locks the device count on first init, so this must run before any jax
# import; the 512 placeholder host devices exist ONLY here — smoke tests and
# benchmarks see 1 device. APPEND to any user-set XLA_FLAGS (never clobber
# other flags), and respect an explicit user-chosen device count.
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (mandate e): lower + compile every (architecture x
input shape) on the production meshes, print memory/cost analysis, and
extract the collective schedule for the roofline analysis.

The block above MUST stay first (before the jax imports below).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm_360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out results/dryrun
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ARCH_IDS, INPUT_SHAPES, ArchConfig, InputShape, get_arch
from ..core.steps import make_serve_step, make_train_step
from ..data.pipeline import input_specs
from ..models.layers import activation_mesh
from . import hlo_cost
from . import sharding as shd
from .mesh import make_production_mesh

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "u32": 4, "s32": 4,
                "u8": 1, "s8": 1, "pred": 1, "u16": 2, "s16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "u64": 8, "s64": 8, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def shape_overrides(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """Per-shape config adjustments (documented in DESIGN.md §4).

    long_500k requires sub-quadratic attention: attention-bearing archs get a
    sliding window (ring-buffer KV cache); SSM archs run natively.
    """
    if shape.name == "long_500k" and cfg.attn != "none" and cfg.block != "rwkv6":
        cfg = cfg.with_(sliding_window=8192)
    return cfg


def parse_collectives(hlo_text: str):
    """Sum per-device result bytes of every cross-device collective op.

    Methodology (EXPERIMENTS.md §Roofline): ring-algorithm cost ~ result
    bytes x (n-1)/n ~ result bytes; all-reduce counts twice (reduce-scatter
    + all-gather phases). Shapes in the partitioned module are per-device.
    """
    stats = {}
    pat = re.compile(
        r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+(" + "|".join(_COLLECTIVES)
        + r")(?:-start)?\(")
    for m in pat.finditer(hlo_text):
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * _DTYPE_BYTES[dt]
        if op == "all-reduce":
            b *= 2
        rec = stats.setdefault(op, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += b
    return stats


def memory_dict(compiled):
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def cost_dict(compiled):
    try:
        ca = compiled.cost_analysis()
    except Exception:  # glint: disable=GL012 cost_analysis is best-effort backend metadata; absent/odd analyses degrade to {} and the report simply omits cost columns
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)}


def lower_train(cfg: ArchConfig, shape: InputShape, mesh):
    init_state, train_step = make_train_step(cfg)
    state_abs = jax.eval_shape(init_state, jax.random.PRNGKey(0))
    batch_abs = input_specs(cfg, shape)

    pspecs = shd.param_specs(state_abs.params, mesh)
    ospecs = shd.opt_state_specs(state_abs.opt_state, pspecs, mesh)
    state_specs = type(state_abs)(pspecs, ospecs, P())
    state_sh = shd.tree_shardings(state_specs, mesh)
    batch_sh = shd.batch_shardings(cfg, shape, batch_abs, mesh)
    metrics_sh = {"loss": NamedSharding(mesh, P()),
                  "aux": NamedSharding(mesh, P()),
                  "grad_norm": NamedSharding(mesh, P())}

    with activation_mesh(mesh):
        lowered = jax.jit(
            train_step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, metrics_sh),
            donate_argnums=(0,),
        ).lower(state_abs, batch_abs)
    return lowered


def lower_prefill(cfg: ArchConfig, shape: InputShape, mesh):
    """Inference prefill: full forward over (B, S) tokens -> last-pos logits.

    Compute-equivalent to KV-cache-filling prefill (cache writes are free
    relative to the matmuls); no loss, no backward, no optimizer.
    """
    from ..models import transformer as tfm

    def prefill_step(params, batch):
        kwargs = {}
        if cfg.is_encdec:
            kwargs["src_embeds"] = batch["src_embeds"]
            kwargs["tokens"] = batch["tokens"]
        elif cfg.frontend == "vision":
            kwargs["embeds"] = batch["patch_embeds"]
            kwargs["tokens"] = batch["tokens"]
        else:
            kwargs["tokens"] = batch["tokens"]
        hidden, _ = tfm.lm_forward(params, cfg, return_hidden=True, **kwargs)
        logits = hidden[:, -1:] @ params["unemb"]
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    params_abs = jax.eval_shape(
        lambda k: tfm.init_lm(k, cfg), jax.random.PRNGKey(0))
    batch_abs = {k: v for k, v in input_specs(
        cfg, InputShape(shape.name, shape.seq_len, shape.global_batch,
                        "train")).items() if k != "labels"}
    pspecs = shd.param_specs(params_abs, mesh)
    p_sh = shd.tree_shardings(pspecs, mesh)
    batch_sh = shd.batch_shardings(cfg, shape, batch_abs, mesh)
    out_sh = NamedSharding(mesh, P())

    with activation_mesh(mesh):
        lowered = jax.jit(
            prefill_step, in_shardings=(p_sh, batch_sh),
            out_shardings=out_sh,
        ).lower(params_abs, batch_abs)
    return lowered


def lower_serve(cfg: ArchConfig, shape: InputShape, mesh):
    init_serve, serve_step = make_serve_step(cfg, shape)
    params_abs, caches_abs = jax.eval_shape(init_serve, jax.random.PRNGKey(0))
    specs = input_specs(cfg, shape)

    pspecs = shd.param_specs(params_abs, mesh)
    cspecs = shd.cache_specs(cfg, shape, caches_abs, mesh)
    p_sh = shd.tree_shardings(pspecs, mesh)
    c_sh = shd.tree_shardings(cspecs, mesh)
    tok_sh = NamedSharding(mesh, shd.batch_spec(cfg, shape, mesh, "token",
                                                specs["token"].shape))
    args = [params_abs, caches_abs, specs["token"]]
    in_sh = [p_sh, c_sh, tok_sh]
    if "enc_out" in specs:
        enc_sh = NamedSharding(mesh, shd.batch_spec(
            cfg, shape, mesh, "enc_out", specs["enc_out"].shape))
        args.append(specs["enc_out"])
        in_sh.append(enc_sh)

        def step(params, caches, token, enc_out):
            return serve_step(params, caches, token, enc_out=enc_out)
    else:
        step = serve_step

    with activation_mesh(mesh):
        lowered = jax.jit(
            step,
            in_shardings=tuple(in_sh),
            out_shardings=(tok_sh, c_sh),
            donate_argnums=(1,),
        ).lower(*args)
    return lowered


def run_combo(arch_id: str, shape_name: str, multi_pod: bool,
              cfg_override=None):
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch_id, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "mode": shape.mode, "ok": False}
    t0 = time.perf_counter()
    try:
        # inside the try: get_arch raises for ids whose full-size config
        # module was removed — record that like any other sweep failure
        cfg = cfg_override or get_arch(arch_id)
        cfg = shape_overrides(cfg, shape)
        mesh = make_production_mesh(multi_pod=multi_pod)
        if shape.mode == "train":
            lowered = lower_train(cfg, shape, mesh)
        elif shape.mode == "prefill":
            lowered = lower_prefill(cfg, shape, mesh)
        else:
            lowered = lower_serve(cfg, shape, mesh)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        mem = memory_dict(compiled)
        cost = cost_dict(compiled)
        walk = hlo_cost.analyze(compiled.as_text())
        print(f"  memory_analysis: {mem}")
        print(f"  hlo-walk (trip-count-aware): flops={walk['flops']:.3e} "
              f"hbm_bytes={walk['hbm_bytes']:.3e} "
              f"collective_bytes={walk['collective_bytes']:.3e}")
        rec.update(ok=True, lower_s=t1 - t0, compile_s=t2 - t1, memory=mem,
                   cost_raw=cost, flops=walk["flops"],
                   hbm_bytes=walk["hbm_bytes"],
                   collectives=walk["collectives"],
                   collective_bytes=walk["collective_bytes"],
                   n_devices=int(np.prod(list(mesh.shape.values()))),
                   params=int(cfg.param_count()),
                   active_params=int(cfg.active_param_count()),
                   seq_len=shape.seq_len, global_batch=shape.global_batch)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = time.perf_counter() - t0
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                path = outdir / f"{tag}.json"
                if args.skip_existing and path.exists():
                    old = json.loads(path.read_text())
                    if old.get("ok"):
                        print(f"[skip] {tag}")
                        continue
                print(f"[dryrun] {tag} ...", flush=True)
                rec = run_combo(arch, shape, mp)
                path.write_text(json.dumps(rec, indent=1))
                status = "OK" if rec["ok"] else f"FAIL ({rec.get('error')})"
                n_fail += 0 if rec["ok"] else 1
                print(f"[dryrun] {tag}: {status} "
                      f"({rec['total_s']:.1f}s)", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
