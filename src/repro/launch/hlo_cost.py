"""Trip-count-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` on XLA:CPU counts every while-loop body ONCE —
a scanned 126-layer stack or an 8-microbatch accumulation loop under-reports
by the trip count (verified: a 10-iteration scan of a matmul reports 1
matmul). This walker parses ``compiled.as_text()`` and accumulates, with
loop multipliers:

  * flops            — 2*M*N*K for dot ops (recursing INTO fusions),
                       convolutions approximated as dots
  * hbm_bytes        — operand + result bytes at FUSION BOUNDARY granularity
                       (fusion internals never touch HBM under XLA's model)
  * collective bytes — per collective op kind, result bytes (all-reduce x2)

Methodology notes live in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "u32": 4, "s32": 4,
                "u8": 1, "s8": 1, "pred": 1, "u16": 2, "s16": 2, "s64": 8,
                "u64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([^\s=]+)\s*=\s*(.+?)\s+"
    r"([a-z][a-z0-9\-]*)\((.*)$")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


class Instr:
    __slots__ = ("name", "type_str", "opcode", "rest")

    def __init__(self, name, type_str, opcode, rest):
        self.name, self.type_str, self.opcode, self.rest = \
            name, type_str, opcode, rest


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, list] = {}
        self.instr_types: Dict[str, Dict[str, str]] = {}
        self._parse(hlo_text)
        self._memo: Dict[str, dict] = {}

    def _parse(self, text: str):
        cur = None
        self.entry = None
        for line in text.splitlines():
            m = re.match(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(.*->.*\{\s*$", line)
            if m:
                cur = m.group(2)
                self.comps[cur] = []
                self.instr_types[cur] = {}
                if m.group(1):
                    self.entry = cur
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                continue
            im = _INSTR_RE.match(line)
            if im:
                ins = Instr(im.group(1), im.group(2), im.group(3), im.group(4))
                self.comps[cur].append(ins)
                self.instr_types[cur][ins.name] = ins.type_str

    # ------------------------------------------------------------- helpers
    def _called(self, rest: str, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w\.\-]+)", rest)
        return m.group(1) if m else None

    def _trip_count(self, cond_comp: str) -> int:
        """Scan/fori conditions compare an induction var to a constant."""
        best = 1
        for ins in self.comps.get(cond_comp, ()):
            if ins.opcode == "constant":
                m = re.search(r"constant\((\d+)\)", "constant(" + ins.rest)
                if m:
                    best = max(best, int(m.group(1)))
        return best

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        _, out_dims = _shape_dims(ins.type_str)
        out = 1
        for d in out_dims:
            out *= d
        # contracted size from lhs shape + contracting dims
        ops = re.findall(r"%([\w\.\-]+)", ins.rest)
        lhs_type = self.instr_types[comp].get(ops[0], "") if ops else ""
        _, lhs_dims = _shape_dims(lhs_type)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
        contracted = 1
        if m and lhs_dims:
            for d in m.group(1).split(","):
                if d and int(d) < len(lhs_dims):
                    contracted *= lhs_dims[int(d)]
        return 2.0 * out * contracted

    def _operand_bytes(self, comp: str, ins: Instr) -> int:
        ops = re.findall(r"%([\w\.\-]+)", ins.rest.split("),")[0] + ")")
        total = 0
        for o in ops:
            t = self.instr_types[comp].get(o)
            if t:
                total += _shape_bytes(t)
        return total

    # ---------------------------------------------------------------- walk
    def comp_cost(self, comp: str) -> dict:
        if comp in self._memo:
            return self._memo[comp]
        acc = {"flops": 0.0, "hbm_bytes": 0.0,
               "collectives": {k: {"count": 0.0, "bytes": 0.0}
                               for k in _COLL_OPS}}
        self._memo[comp] = acc  # guard cycles
        for ins in self.comps.get(comp, ()):
            op = ins.opcode
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all") or op.endswith("-done"):
                continue
            if op == "while":
                body = self._called(ins.rest, "body")
                cond = self._called(ins.rest, "condition")
                tm = re.search(r'"known_trip_count":\{"n":"(\d+)"', ins.rest)
                if tm:
                    trips = int(tm.group(1))
                else:
                    trips = self._trip_count(cond) if cond else 1
                sub = self.comp_cost(body) if body else None
                if sub:
                    self._add(acc, sub, trips)
                continue
            if op in ("fusion", "call", "custom-call", "async-start"):
                callee = (self._called(ins.rest, "calls")
                          or self._called(ins.rest, "to_apply"))
                if callee:
                    sub = self.comp_cost(callee)
                    acc["flops"] += sub["flops"]
                    # fusion internals do not touch HBM; charge the boundary
                    acc["hbm_bytes"] += (_shape_bytes(ins.type_str)
                                         + self._operand_bytes(comp, ins))
                    for k, v in sub["collectives"].items():
                        acc["collectives"][k]["count"] += v["count"]
                        acc["collectives"][k]["bytes"] += v["bytes"]
                    continue
            if op == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                      ins.rest)
                if branches:
                    subs = [self.comp_cost(b.strip().lstrip("%"))
                            for b in branches[0].split(",")]
                    if subs:
                        big = max(subs, key=lambda s: s["flops"])
                        self._add(acc, big, 1)
                continue
            base = op.replace("-start", "")
            if base in _COLL_OPS:
                b = _shape_bytes(ins.type_str)
                if base == "all-reduce":
                    b *= 2
                acc["collectives"][base]["count"] += 1
                acc["collectives"][base]["bytes"] += b
                acc["hbm_bytes"] += _shape_bytes(ins.type_str)
                continue
            if base in ("dot", "convolution"):
                acc["flops"] += self._dot_flops(comp, ins)
            acc["hbm_bytes"] += (_shape_bytes(ins.type_str)
                                 + self._operand_bytes(comp, ins))
        self._memo[comp] = acc
        return acc

    @staticmethod
    def _add(acc, sub, mult):
        acc["flops"] += sub["flops"] * mult
        acc["hbm_bytes"] += sub["hbm_bytes"] * mult
        for k, v in sub["collectives"].items():
            acc["collectives"][k]["count"] += v["count"] * mult
            acc["collectives"][k]["bytes"] += v["bytes"] * mult

    def entry_cost(self) -> dict:
        entry = self.entry or next(iter(self.comps))
        cost = self.comp_cost(entry)
        out = dict(cost)
        out["collectives"] = {k: v for k, v in cost["collectives"].items()
                              if v["count"]}
        out["collective_bytes"] = sum(v["bytes"]
                                      for v in cost["collectives"].values())
        out["entry"] = entry
        return out


def analyze(hlo_text: str) -> dict:
    return HloCost(hlo_text).entry_cost()
