"""Training launcher for the architecture zoo.

Runs real optimization steps for any `--arch` (reduced variant by default —
full configs are exercised via dryrun.py on the production mesh) with
synthetic token streams, periodic metrics, and npz checkpointing. On a TPU
slice the same entry point applies the production sharding from
`launch/sharding.py`; on this CPU container it runs single-device.

    PYTHONPATH=src python -m repro.launch.train --arch smollm_360m \
        --steps 50 --batch 4 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax

from ..configs.base import ARCH_IDS, InputShape, get_arch, get_reduced
from ..core import checkpoint
from ..core.steps import make_train_step
from ..data.pipeline import TokenStream, synth_train_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m", choices=ARCH_IDS)
    ap.add_argument("--full", action="store_true",
                    help="full published config (needs a real TPU slice)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch) if args.full else get_reduced(args.arch)
    cfg = cfg.with_(grad_accum=1)
    print(f"[train] {cfg.name} ({'full' if args.full else 'reduced'}), "
          f"~{cfg.param_count() / 1e6:.0f}M params, devices={jax.device_count()}")

    init_state, train_step = make_train_step(cfg)
    state = init_state(jax.random.PRNGKey(0))
    if args.resume and args.ckpt_dir and checkpoint.latest_step(args.ckpt_dir):
        state = checkpoint.restore(args.ckpt_dir, state)
        print(f"[train] resumed at step {int(state.step)}")
    step_fn = jax.jit(train_step, donate_argnums=0)

    shape = InputShape("cli", args.seq, args.batch, "train")
    stream = TokenStream(cfg.vocab, seed=0)
    t0 = time.time()
    for i in range(args.steps):
        if cfg.is_encdec or cfg.frontend == "vision":
            batch = synth_train_batch(cfg, shape, seed=i)
        else:
            tokens, labels = stream.batch(args.batch, args.seq)
            batch = {"tokens": tokens, "labels": labels}
        state, metrics = step_fn(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {int(state.step):5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time() - t0):.0f}s)")
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            fn = checkpoint.save(args.ckpt_dir, int(state.step), state)
            checkpoint.cleanup(args.ckpt_dir)
            print(f"[ckpt] {fn}")


if __name__ == "__main__":
    main()
