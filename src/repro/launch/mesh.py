"""Production mesh builders (functions, not module constants, so importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips per pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU integration tests (xla_force_host_platform_device_count)."""
    return jax.make_mesh((data, model), ("data", "model"))


# v5e hardware constants for the roofline analysis
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
