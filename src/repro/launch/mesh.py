"""Production mesh builders (functions, not module constants, so importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips per pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU integration tests (xla_force_host_platform_device_count)."""
    return jax.make_mesh((data, model), ("data", "model"))


def client_mesh_size(n_clients: int, n_devices: int) -> int:
    """Largest divisor of ``n_clients`` that fits on ``n_devices``.

    Even client blocks per device are required by the GLASU shard_map round
    body; a non-dividing axis would leave ragged shards. With fewer devices
    than any divisor > 1, the mesh degenerates to one device (m_loc = M),
    which runs the identical collective code path trivially.
    """
    if n_clients < 1 or n_devices < 1:
        raise ValueError(f"need positive counts, got n_clients={n_clients} "
                         f"n_devices={n_devices}")
    return max(d for d in range(1, min(n_clients, n_devices) + 1)
               if n_clients % d == 0)


def make_client_mesh(n_clients: int, *, max_devices=None, devices=None):
    """One-axis ``('clients',)`` mesh for the sharded GLASU backend.

    Places each client (or an even block of clients) on its own device: the
    axis size is the largest divisor of ``n_clients`` the available devices
    allow, so ``shard_map`` blocks are always even. CPU-testable via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    devs = list(devices) if devices is not None else list(jax.devices())
    if max_devices is not None:
        if max_devices < 1:
            raise ValueError(f"max_devices must be >= 1, got {max_devices}")
        devs = devs[:max_devices]
    d = client_mesh_size(n_clients, len(devs))
    return jax.make_mesh((d,), ("clients",), devices=devs[:d])


# v5e hardware constants for the roofline analysis
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
