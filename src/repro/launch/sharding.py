"""Sharding-spec inference for the architecture zoo.

Parameter specs are derived from leaf *names* (the init functions use a
stable naming convention) with structural overrides for expert-stacked and
client-stacked weights. Every rule is divisibility-guarded: a dim that the
mesh axis does not divide falls back to replication (e.g. yi-34b's 56 heads
on a 16-way model axis shard the flat head*dh dim instead of the head axis).

Activation sharding is applied inside model code via layers.shard(); this
module covers jit boundary in/out shardings: params, optimizer state,
batches, and decode caches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, InputShape
from ..optim.optimizers import AdafactorState, AdamState, SGDState

# leaf name -> spec for the TRAILING dims (left-padded with None)
_NAME_RULES = {
    "emb": ("model", None),
    "unemb": (None, "model"),
    "wq": (None, "model"), "wk": (None, "model"), "wv": (None, "model"),
    "wg": (None, "model"), "wr": (None, "model"),
    "wo": ("model", None),
    "w_gate": (None, "model"), "w_up": (None, "model"), "w_down": ("model", None),
    "w_uk": (None, "model"), "w_uv": (None, "model"),
    "w_dkv": (), "w_kr": (), "router": (),
    "w_in": (None, "model"), "w_out": ("model", None),
    "conv_w": (None, "model"), "conv_b": ("model",),
    "A_log": ("model",), "D": ("model",), "dt_bias": ("model",),
    "w_A": (), "w_B": (None, "model"),
    "u": ("model", None),
    "mix": (), "w_base": ("model",),
    "g": (), "b": (),
    "b_up": ("model",), "b_down": (),
}


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return mesh.shape[name] if name in mesh.axis_names else 0


def _guard(mesh: Mesh, shape, spec):
    """Replace axis names that don't exist or don't divide the dim."""
    out = []
    for dim, s in zip(shape, spec):
        size = _axis_size(mesh, s)
        out.append(s if size and dim % size == 0 and size > 1 else None)
    return P(*out)


def _leaf_spec(path, leaf, mesh: Mesh) -> P:
    keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
    name = keys[-1]
    in_moe = "moe" in keys and "shared" not in keys
    in_locals = "locals" in keys
    nd = leaf.ndim
    if in_locals:
        # (n_groups, sync_every-1, M, ...) — shard the client axis
        spec = [None] * nd
        if nd >= 3:
            spec[2] = "model"
        return _guard(mesh, leaf.shape, spec)
    if in_moe and name in ("w_gate", "w_up", "w_down") and nd >= 3:
        # (..., E, d, f) — expert parallel
        spec = [None] * nd
        spec[nd - 3] = "model"
        return _guard(mesh, leaf.shape, spec)
    rule = _NAME_RULES.get(name, ())
    spec = [None] * (nd - len(rule)) + list(rule)
    spec = spec[:nd]
    spec = _add_fsdp(mesh, leaf, spec)
    return _guard(mesh, leaf.shape, spec)


_FSDP_MIN_BYTES = 16 * 2**20


def _add_fsdp(mesh, leaf, spec):
    """ZeRO-3-style: large weights additionally shard a free dim over 'data'
    (GSPMD all-gathers per layer inside the scan). Without this, llama3-405b
    weights are 50 GB/chip at TP=16."""
    if "data" not in mesh.axis_names:
        return spec
    try:
        nbytes = leaf.size * leaf.dtype.itemsize
    except (AttributeError, TypeError):
        return spec  # abstract/spec leaf without size metadata: skip FSDP
    if nbytes < _FSDP_MIN_BYTES or leaf.ndim < 2:
        return spec
    dp = mesh.shape["data"]
    # pick the largest unsharded trailing dim divisible by the data axis
    best, best_dim = None, 0
    for i in range(leaf.ndim - 1, 0, -1):
        if spec[i] is None and leaf.shape[i] % dp == 0 and leaf.shape[i] > best_dim:
            best, best_dim = i, leaf.shape[i]
    if best is not None:
        spec = list(spec)
        spec[best] = "data"
    return spec


def param_specs(params, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, mesh), params)


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh))


def opt_state_specs(opt_state, pspecs, mesh: Mesh):
    """Optimizer-state specs derived structurally from the param specs."""
    scalar = P()
    if isinstance(opt_state, AdamState):
        return AdamState(scalar, pspecs, pspecs)
    if isinstance(opt_state, SGDState):
        mom = pspecs if opt_state.momentum is not None else None
        return SGDState(scalar, mom)
    if isinstance(opt_state, AdafactorState):
        def fit(leaf, s):
            """Trim/align the param spec to the factored leaf's actual rank."""
            if leaf.ndim == 0:
                return P()
            t = (list(s) + [None] * leaf.ndim)[:leaf.ndim]
            return P(*t)

        def map2(fn, tree_sds):
            leaves, treedef = jax.tree.flatten(tree_sds)
            specs = treedef.flatten_up_to(pspecs)  # P leaves stay intact
            return treedef.unflatten([fn(l, s) for l, s in zip(leaves, specs)])

        vr = map2(lambda le, s: fit(le, list(s)[:-1] if len(s) else []),
                  opt_state.vr)
        vc = map2(lambda le, s: fit(le, (list(s)[:-2] + list(s)[-1:])
                                    if len(s) >= 2 else list(s)),
                  opt_state.vc)
        v = map2(lambda le, s: fit(le, list(s)), opt_state.v)
        return AdafactorState(scalar, vr, vc, v)
    raise ValueError(f"unknown optimizer state {type(opt_state)}")


def batch_spec(cfg: ArchConfig, shape: InputShape, mesh: Mesh, name: str,
               arr_shape) -> P:
    dp = _axis_size(mesh, ("pod", "data") if "pod" in mesh.axis_names
                    else ("data",))
    dp_axes = ("pod", "data") if "pod" in mesh.axis_names else "data"
    batch = arr_shape[0]
    first = dp_axes if batch % dp == 0 and dp > 1 else None
    rest = [None] * (len(arr_shape) - 1)
    if name in ("src_embeds", "patch_embeds", "enc_out"):
        pass  # (B, T, D): feature dim replicated (consumed by full-width layers)
    return P(first, *rest)


def batch_shardings(cfg: ArchConfig, shape: InputShape, specs_or_batch,
                    mesh: Mesh):
    return {k: NamedSharding(mesh, batch_spec(cfg, shape, mesh, k, v.shape))
            for k, v in specs_or_batch.items()}


def cache_specs(cfg: ArchConfig, shape: InputShape, caches, mesh: Mesh):
    """Decode-cache shardings.

    Leaves are (L, B, C, heads, dh)-ish stacks. Policy: shard batch over
    (pod, data) when divisible; otherwise (long_500k, B=1) shard the cache
    *sequence* dim over 'data'. Head/state axes shard over 'model' when
    divisible.
    """
    dp_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dp = _axis_size(mesh, dp_axes)
    batch = shape.global_batch
    batch_ok = batch % dp == 0 and dp > 1

    def leaf_rule(path, leaf):
        nd = leaf.ndim
        if nd == 0 or leaf.dtype == jnp.int32:
            return P()
        spec = [None] * nd
        # dim 0 is the layer stack; dim 1 is batch (for stacked caches)
        if nd >= 2:
            if batch_ok and leaf.shape[1] == batch:
                spec[1] = dp_axes
            elif not batch_ok and nd >= 3 and leaf.shape[2] >= dp:
                # shard sequence dim over data (flash-decode style)
                if leaf.shape[2] % dp == 0:
                    spec[2] = dp_axes
        # shard a head-like axis over model: prefer dim -2 for (…, H, dh)
        tp = _axis_size(mesh, "model")
        for cand in (nd - 2, nd - 1):
            if cand is not None and cand >= 2 and spec[cand] is None:
                if leaf.shape[cand] % tp == 0 and leaf.shape[cand] >= tp > 1:
                    spec[cand] = "model"
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_rule, caches)


def tree_shardings(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------- GLASU client-stacked path
# The federated split model (core/glasu.py) stacks the M clients as the
# leading axis of every parameter, optimizer-state, and batch leaf. The
# sharded backend places that axis on the 'clients' mesh axis; like every
# rule in this module the spec is divisibility-guarded — an axis that does
# not divide M falls back to replication (the safe generic placement; the
# shard_map round body itself additionally REQUIRES divisibility and the
# client mesh is built to guarantee it, see launch.mesh.make_client_mesh).

def client_leaf_spec(leaf, mesh: Mesh, axis: str = "clients",
                     lead: int = 0) -> P:
    """Shard dim ``lead`` (the client-stacked dim) over ``axis``, guarded."""
    spec = [None] * leaf.ndim
    if leaf.ndim > lead:
        spec[lead] = axis
    return _guard(mesh, leaf.shape, spec)


def client_param_specs(params, mesh: Mesh, axis: str = "clients"):
    """Specs for GLASU's client-stacked parameter tree (every leaf (M, ...))."""
    return jax.tree.map(lambda l: client_leaf_spec(l, mesh, axis), params)


def client_batch_specs(batch, mesh: Mesh, axis: str = "clients",
                       round_stacked: bool = False):
    """Specs for a ``SampledBatch``: client-stacked leaves shard their client
    dim (dim 0, or dim 1 under a leading round axis); ``labels`` is the
    shared mini-batch (replicated, paper Alg 2)."""
    lead = 1 if round_stacked else 0
    leaf = lambda l: client_leaf_spec(l, mesh, axis, lead=lead)
    per = lambda xs: tuple(leaf(x) for x in xs)
    return type(batch)(
        feats=leaf(batch.feats), gather_idx=per(batch.gather_idx),
        gather_mask=per(batch.gather_mask), row_valid=per(batch.row_valid),
        labels=P(), self_pos=per(batch.self_pos))


def client_comp_state_specs(comp_state, mesh: Mesh, axis: str = "clients"):
    """Specs for the compressed-exchange error-feedback carry
    (``core.glasu.init_comp_state``): the per-layer uplink accumulator is
    client-stacked ``(M, n, h)`` (sharded over ``axis``, guarded like every
    client rule), the downlink accumulator is server state (replicated)."""
    return {l: {"up": client_leaf_spec(st["up"], mesh, axis), "down": P()}
            for l, st in comp_state.items()}


def client_fault_state_specs(fault_state, mesh: Mesh, axis: str = "clients",
                             replicated: bool = False):
    """Specs for the fault-tolerant stale-embedding cache
    (``core.glasu.init_fault_state``): every per-layer cache stack is
    client-stacked ``(M, n, h)`` and shards its client dim over ``axis``
    (guarded). The round's ``RoundFaults`` masks are replicated — they are
    (M,) vectors every device reads in full.

    ``replicated=True`` (fault tolerance composed with wire compression):
    the cache holds the server's DECODED view, recomputed identically on
    every device from the gathered payload — the whole stack is replicated
    (mirrors ``core.glasu._fault_state_specs``)."""
    if replicated:
        return {l: P() for l in fault_state}
    return {l: client_leaf_spec(cache, mesh, axis)
            for l, cache in fault_state.items()}
