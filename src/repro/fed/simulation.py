"""Explicit client/server message-passing simulation of one GLASU round.

The vmapped runtime in ``core/glasu.py`` is the fast path; this module
replays JointInference (Alg 3) as literal messages between client nodes and
a parameter-free server — the deployment topology of the paper (Fig 1). It
exists to (a) validate the vmapped math against an independent
implementation, (b) audit the byte meter message-by-message, and (c) provide
the integration point where real transports (gRPC etc.) would plug in.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ..core import glasu
from ..core.glasu import GlasuConfig
from ..graph.sampler import SampledBatch


@dataclass
class Message:
    sender: str
    receiver: str
    kind: str                 # 'upload' | 'broadcast' | 'index_sync'
    layer: int
    nbytes: int


@dataclass
class MessageLog:
    messages: List[Message] = field(default_factory=list)

    def send(self, sender, receiver, kind, layer, payload):
        nbytes = int(np.asarray(payload).size
                     * np.asarray(payload).dtype.itemsize)
        self.messages.append(Message(sender, receiver, kind, layer, nbytes))

    def total_bytes(self, kind=None) -> int:
        return sum(m.nbytes for m in self.messages
                   if kind is None or m.kind == kind)


def simulate_joint_inference(params, batch: SampledBatch, cfg: GlasuConfig):
    """Alg 3 with explicit messages. Returns (per-client logits, log).

    Mean aggregation; per-client python loop (no vmap) so the computation is
    an independent implementation of the same algebra.
    """
    assert cfg.agg == "mean"
    m_clients = cfg.n_clients
    log = MessageLog()

    h = []
    h0 = []
    for m in range(m_clients):
        pm = jax.tree.map(lambda v: v[m], params)
        hm = batch.feats[m] @ pm["inp"]["W"] + pm["inp"]["b"]
        h.append(hm)
        h0.append(hm)

    for l in range(cfg.n_layers):
        layer = glasu._client_layer(cfg, l)
        h_plus = []
        for m in range(m_clients):
            pm = jax.tree.map(lambda v: v[m], params)
            hp = layer(pm["layers"][l], h[m], h0[m],
                       batch.gather_idx[l][m], batch.gather_mask[l][m])
            h_plus.append(hp)
            h0[m] = h0[m][batch.self_pos[l][m]]
        if l in cfg.agg_layers:
            for m in range(m_clients):                 # uploads
                log.send(f"client{m}", "server", "upload", l, h_plus[m])
            agg = sum(h_plus) / m_clients              # server mean (Agg)
            for m in range(m_clients):                 # broadcasts
                log.send("server", f"client{m}", "broadcast", l, agg)
                h[m] = agg
        else:
            for m in range(m_clients):
                h[m] = h_plus[m]

    logits = []
    for m in range(m_clients):
        pm = jax.tree.map(lambda v: v[m], params)
        logits.append(h[m] @ pm["cls"]["W"] + pm["cls"]["b"])
    return jnp.stack(logits), log
