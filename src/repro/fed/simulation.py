"""Explicit client/server message-passing simulation of one GLASU round.

The vmapped runtime in ``core/glasu.py`` is the fast path; this module
replays JointInference (Alg 3) as literal messages between client nodes and
a parameter-free server — the deployment topology of the paper (Fig 1). It
exists to (a) validate the vmapped math against an independent
implementation, (b) audit the byte meter message-by-message, and (c) provide
the integration point where real transports (gRPC etc.) would plug in.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ..comm import compression
from ..comm.compression import Compressor
from ..core import glasu
from ..core.glasu import GlasuConfig
from ..graph.sampler import SampledBatch


@dataclass
class Message:
    sender: str
    receiver: str
    kind: str                 # 'upload' | 'broadcast' | 'index_sync'
    layer: int
    nbytes: int
    t: float = 0.0            # virtual ms when the message lands
    dropped: bool = False     # sent but never delivered (lost or past deadline)


@dataclass
class MessageLog:
    messages: List[Message] = field(default_factory=list)

    def send(self, sender, receiver, kind, layer, payload,
             t: float = 0.0, dropped: bool = False):
        """Log one message; ``payload`` is an array or a pytree of arrays
        (a compressed wire message: codes + scales, values + indices)."""
        nbytes = sum(int(np.asarray(leaf).size
                         * np.asarray(leaf).dtype.itemsize)
                     for leaf in jax.tree.leaves(payload))
        self.send_nbytes(sender, receiver, kind, layer, nbytes,
                         t=t, dropped=dropped)

    def send_nbytes(self, sender, receiver, kind, layer, nbytes: int,
                    t: float = 0.0, dropped: bool = False):
        """Log one message by its exact wire size (shape-only replays)."""
        self.messages.append(Message(sender, receiver, kind, layer,
                                     int(nbytes), float(t), bool(dropped)))

    def total_bytes(self, kind=None, delivered_only: bool = True) -> int:
        """Sum of wire bytes, optionally filtered by ``kind``.

        ``delivered_only`` (the default) excludes dropped messages: a lost
        or past-deadline upload never reaches the server, so it must not
        count toward the audited communication cost. Pass
        ``delivered_only=False`` to price the traffic the clients SENT
        (delivered + dropped).
        """
        return sum(m.nbytes for m in self.messages
                   if (kind is None or m.kind == kind)
                   and (not delivered_only or not m.dropped))

    def dropped_messages(self) -> List[Message]:
        return [m for m in self.messages if m.dropped]


def simulate_joint_inference(params, batch: SampledBatch, cfg: GlasuConfig,
                             log: MessageLog = None,
                             return_stale: bool = False,
                             compressor: Compressor = None, comp_state=None,
                             fault_state=None, plan=None):
    """Alg 3 with explicit messages. Returns (per-client logits, log), or
    (logits, stale, log) with ``return_stale=True`` where ``stale`` is the
    Extract buffer dict {l: (M, n_{l+1}, h)} matching ``glasu.joint_inference``.

    Mean aggregation; per-client python loop (no vmap) so the computation is
    an independent implementation of the same algebra.

    With a ``compressor`` the exchange is compressed message-by-message:
    each client encodes its upload (plus its error-feedback residual when
    ``comp_state`` carries one) and the LOGGED payload is the actual wire
    message — the byte audit stays term-by-term exact. The server decodes,
    aggregates the dequantized uploads, and broadcasts the compressed
    aggregate; each client reconstructs its stale buffer from the decoded
    broadcast minus its own dequantized upload and continues forward with
    its exact fresh block (the same protocol as
    ``glasu._compressed_aggregate``, implemented independently). In that
    mode the return tuples gain a trailing ``new_comp_state``.

    With ``fault_state``/``plan`` (a ``fed.faults.RoundPlan``) the deadline
    round is replayed message by message: every ATTEMPTED upload is logged
    at its virtual arrival time ``plan.t_start + latency``, with
    ``dropped=True`` when it was lost or landed past the deadline (dropped
    messages never count on the delivered-only meter). The server
    substitutes each absent client's cached block, aggregates with the
    plan's weights (the same weighted Agg as ``glasu._fault_agg_math``),
    and broadcasts at ``plan.t_end``. The return tuples gain a trailing
    ``new_fault_state``.

    Composed (both ``compressor`` and ``fault_state``): attempted uploads
    are logged at their COMPRESSED wire size (a dropped upload still
    shipped a compressed payload; the delivered-only meter just never
    counts it), the cache holds each client's last DELIVERED decoded
    block, and EF residuals freeze for clients that never transmitted —
    the same protocol as ``glasu._compressed_aggregate``'s composed mode,
    implemented independently. The return tuples gain TWO trailing values:
    ``new_comp_state, new_fault_state``.
    """
    assert cfg.agg == "mean"
    m_clients = cfg.n_clients
    log = log if log is not None else MessageLog()
    stale: Dict[int, Any] = {}
    new_state: Dict[int, Any] = {}
    new_cache: Dict[int, Any] = {}

    h = []
    h0 = []
    for m in range(m_clients):
        pm = jax.tree.map(lambda v: v[m], params)
        hm = batch.feats[m] @ pm["inp"]["W"] + pm["inp"]["b"]
        h.append(hm)
        h0.append(hm)

    for l in range(cfg.n_layers):
        layer = glasu._client_layer(cfg, l)
        h_plus = []
        for m in range(m_clients):
            pm = jax.tree.map(lambda v: v[m], params)
            hp = layer(pm["layers"][l], h[m], h0[m],
                       batch.gather_idx[l][m], batch.gather_mask[l][m])
            h_plus.append(hp)
            h0[m] = h0[m][batch.self_pos[l][m]]
        if l in cfg.agg_layers:
            if fault_state is not None and compressor is not None:
                # composed deadline round over the wire codec
                ef_l = comp_state.get(l) if comp_state else None
                w = np.asarray(plan.weight, np.float64)  # glint: disable=GL003 host-side reference aggregation; f64 accumulation keeps the python-float replay deterministic
                denom = max(float(w.sum()), 1.0)
                eff, new_ef_up = [], []
                for m in range(m_clients):
                    up_in = h_plus[m] if ef_l is None \
                        else h_plus[m] + ef_l["up"][m]
                    payload = compressor.encode(up_in)
                    x_hat = compressor.decode(payload, h_plus[m].shape[-1])
                    if plan.attempted[m]:          # shipped a wire payload
                        lat = float(plan.latency_ms[m])
                        t_arrive = (plan.t_start + lat if np.isfinite(lat)
                                    else plan.t_end)
                        log.send(f"client{m}", "server", "upload", l,
                                 payload, t=t_arrive,
                                 dropped=plan.present[m] == 0)
                    delivered = plan.present[m] > 0
                    # cache the DECODED view of delivered uploads only
                    eff.append(x_hat if delivered else fault_state[l][m])
                    if ef_l is not None:
                        # absent clients never transmitted: residual frozen
                        new_ef_up.append(
                            compressor.ef_decay * (up_in - x_hat)
                            if delivered else ef_l["up"][m])
                agg = sum(float(w[m]) * eff[m]
                          for m in range(m_clients)) / denom
                down_payload, down_hat, ef_down = \
                    compression.roundtrip_with_ef(
                        compressor, agg,
                        None if ef_l is None else ef_l["down"])
                for m in range(m_clients):         # broadcasts at close
                    log.send("server", f"client{m}", "broadcast", l,
                             down_payload, t=plan.t_end)
                stale[l] = jnp.stack([down_hat - float(w[m]) * eff[m] / denom
                                      for m in range(m_clients)])
                for m in range(m_clients):
                    h[m] = stale[l][m] + float(w[m]) * h_plus[m] / denom
                new_cache[l] = jnp.stack(eff)
                if ef_l is not None:
                    new_state[l] = {"up": jnp.stack(new_ef_up),
                                    "down": ef_down}
            elif fault_state is not None:
                w = np.asarray(plan.weight, np.float64)  # glint: disable=GL003 host-side reference aggregation; f64 accumulation keeps the python-float replay deterministic
                denom = max(float(w.sum()), 1.0)
                eff = []
                for m in range(m_clients):
                    if plan.attempted[m]:              # sent an upload
                        lat = float(plan.latency_ms[m])
                        t_arrive = (plan.t_start + lat if np.isfinite(lat)
                                    else plan.t_end)
                        log.send(f"client{m}", "server", "upload", l,
                                 h_plus[m], t=t_arrive,
                                 dropped=plan.present[m] == 0)
                    eff.append(h_plus[m] if plan.present[m] > 0
                               else fault_state[l][m])
                agg = sum(float(w[m]) * eff[m]
                          for m in range(m_clients)) / denom
                for m in range(m_clients):             # broadcasts at close
                    log.send("server", f"client{m}", "broadcast", l, agg,
                             t=plan.t_end)
                    h[m] = agg
                stale[l] = jnp.stack([agg - float(w[m]) * eff[m] / denom
                                      for m in range(m_clients)])
                new_cache[l] = jnp.stack(eff)
            elif compressor is None:
                for m in range(m_clients):             # uploads
                    log.send(f"client{m}", "server", "upload", l, h_plus[m])
                agg = sum(h_plus) / m_clients          # server mean (Agg)
                for m in range(m_clients):             # broadcasts
                    log.send("server", f"client{m}", "broadcast", l, agg)
                    h[m] = agg
                # Extract(H, H_m^+): the all-but-m buffer each client keeps
                stale[l] = jnp.stack([agg - h_plus[m] / m_clients
                                      for m in range(m_clients)])
            else:
                ef_l = comp_state.get(l) if comp_state else None
                up_hats, new_ef_up = [], []
                for m in range(m_clients):             # compressed uploads
                    payload, x_hat, ef_m = compression.roundtrip_with_ef(
                        compressor, h_plus[m],
                        None if ef_l is None else ef_l["up"][m])
                    log.send(f"client{m}", "server", "upload", l, payload)
                    up_hats.append(x_hat)
                    if ef_m is not None:
                        new_ef_up.append(ef_m)
                agg = sum(up_hats) / m_clients         # mean of dequantized
                down_payload, down_hat, ef_down = \
                    compression.roundtrip_with_ef(
                        compressor, agg,
                        None if ef_l is None else ef_l["down"])
                for m in range(m_clients):             # compressed broadcasts
                    log.send("server", f"client{m}", "broadcast", l,
                             down_payload)
                stale[l] = jnp.stack([down_hat - up_hats[m] / m_clients
                                      for m in range(m_clients)])
                for m in range(m_clients):
                    h[m] = stale[l][m] + h_plus[m] / m_clients
                if ef_l is not None:
                    new_state[l] = {"up": jnp.stack(new_ef_up),
                                    "down": ef_down}
        else:
            for m in range(m_clients):
                h[m] = h_plus[m]

    logits = []
    for m in range(m_clients):
        pm = jax.tree.map(lambda v: v[m], params)
        logits.append(h[m] @ pm["cls"]["W"] + pm["cls"]["b"])
    out = (jnp.stack(logits),)
    if return_stale:
        out = out + (stale,)
    out = out + (log,)
    if compressor is not None and fault_state is not None:
        out = out + (new_state, new_cache)
    elif compressor is not None:
        out = out + (new_state,)
    elif fault_state is not None:
        out = out + (new_cache,)
    return out


def log_index_sync(log: MessageLog, batch: SampledBatch, cfg: GlasuConfig,
                   t: float = 0.0):
    """Replay Alg 2's index-set coordination as messages.

    At every layer boundary ``j`` whose node set is shared — ``j == L`` (the
    mini-batch) or ``j = l+1`` for an aggregation layer ``l`` — each client
    uploads its candidate int32 index set and the server broadcasts the
    padded union back. Sizes are read off the already-sampled batch so the
    log is an exact audit of the sampler's cost model.
    """
    if not cfg.agg_layers:
        return
    sizes = {0: batch.feats.shape[1]}
    for l in range(cfg.n_layers):
        sizes[l + 1] = batch.gather_idx[l].shape[1]
    idx_dtype = np.dtype(np.int32)
    for j in range(cfg.n_layers + 1):
        shared = j == cfg.n_layers or (j - 1) in cfg.agg_layers
        if not shared:
            continue
        payload = np.zeros(sizes[j], idx_dtype)
        for m in range(cfg.n_clients):
            log.send(f"client{m}", "server", "index_sync", j, payload, t=t)
            log.send("server", f"client{m}", "index_sync", j, payload, t=t)


def log_agg_traffic(log: MessageLog, batch: SampledBatch, cfg: GlasuConfig,
                    compressor: Compressor = None):
    """Replay JointInference's aggregation messages shape-only (no compute).

    Per aggregation layer, each client uploads its (n_{l+1}, h) block and the
    server broadcasts the aggregate back ((n_{l+1}, h) for mean,
    (n_{l+1}, M*h) for concat) — the exact message sequence of
    ``simulate_joint_inference``, enumerated from the batch's static shapes.
    With a ``compressor`` the logged sizes are the codec's exact wire sizes
    (``Compressor.wire_bytes``), matching the payloads the compute-level
    simulation would ship. Together with ``log_index_sync`` this
    reconstructs one round's full message log without running the model;
    the sharded backend audits its collective byte meter against it (mean
    AND concat — the compute-level simulation itself stays mean-only).
    """
    if not cfg.agg_layers:
        return
    for l in sorted(cfg.agg_layers):
        n = batch.gather_idx[l].shape[1]
        down_h = cfg.hidden * (cfg.n_clients if cfg.agg == "concat" else 1)
        if compressor is None:
            up_bytes = n * cfg.hidden * 4
            down_bytes = n * down_h * 4
        else:
            up_bytes = compressor.wire_bytes(n, cfg.hidden)
            down_bytes = compressor.wire_bytes(n, down_h)
        for m in range(cfg.n_clients):
            log.send_nbytes(f"client{m}", "server", "upload", l, up_bytes)
        for m in range(cfg.n_clients):
            log.send_nbytes("server", f"client{m}", "broadcast", l,
                            down_bytes)


def log_query_traffic(log: MessageLog, fresh_counts, cfg: GlasuConfig,
                      compressor: Compressor = None):
    """Replay one SERVED query's messages shape-only (no compute).

    ``fresh_counts`` maps aggregation layer -> number of rows the serving
    session had to exchange fresh (cache misses among the needed rows);
    cached rows ship nothing. Per layer with n fresh rows, each client
    uploads its (n, h) block and receives the aggregate back — priced at
    the codec's exact wire size, identical to ``log_agg_traffic`` — plus
    one server->client ``index_sync`` leg carrying the int32 fresh-row id
    list (training syncs index unions both ways per shared level; a query
    only tells clients which rows to recompute). The serve benchmark
    audits ``InferenceSession``'s per-answer byte counters against this
    replay term-by-term.
    """
    for l in sorted(cfg.agg_layers):
        n = int(fresh_counts.get(l, 0))
        if n == 0:
            continue
        down_h = cfg.hidden * (cfg.n_clients if cfg.agg == "concat" else 1)
        if compressor is None:
            up_bytes = n * cfg.hidden * 4
            down_bytes = n * down_h * 4
        else:
            up_bytes = compressor.wire_bytes(n, cfg.hidden)
            down_bytes = compressor.wire_bytes(n, down_h)
        for m in range(cfg.n_clients):
            log.send_nbytes("server", f"client{m}", "index_sync", l, n * 4)
        for m in range(cfg.n_clients):
            log.send_nbytes(f"client{m}", "server", "upload", l, up_bytes)
        for m in range(cfg.n_clients):
            log.send_nbytes("server", f"client{m}", "broadcast", l,
                            down_bytes)


def simulate_round(params, opt_state, batch: SampledBatch, cfg: GlasuConfig,
                   optimizer, compressor: Compressor = None,
                   comp_state=None):
    """One full GLASU round (Alg 1) over explicit messages.

    JointInference runs message-by-message (plus the index-sync traffic of
    Alg 2); the Q LocalUpdates are client-local by construction (Alg 4 uses
    only the stale buffers each client already holds), so they reuse
    ``glasu.local_update_steps`` and emit no messages.

    Returns (params, opt_state, losses, log, comp_state) — the trailing
    error-feedback carry is ``None`` unless a ``compressor`` threads one.
    """
    log = MessageLog()
    if cfg.agg_layers:
        log_index_sync(log, batch, cfg)
        if compressor is None:
            _, stale, _ = simulate_joint_inference(params, batch, cfg,
                                                   log=log,
                                                   return_stale=True)
        else:
            _, stale, _, comp_state = simulate_joint_inference(
                params, batch, cfg, log=log, return_stale=True,
                compressor=compressor, comp_state=comp_state)
    else:
        stale = {}
    g_hl = None
    if cfg.labels_at_client is not None:
        g_hl = glasu.label_owner_grad(params, batch, stale, cfg)
    params, opt_state, losses = glasu.local_update_steps(
        params, opt_state, batch, stale, cfg, optimizer, g_hl=g_hl)
    return params, opt_state, losses, log, comp_state


def simulate_fault_round(params, opt_state, batch: SampledBatch,
                         cfg: GlasuConfig, optimizer, fault_state, plan,
                         compressor: Compressor = None, comp_state=None):
    """One fault-tolerant GLASU round over explicit, timestamped messages.

    The index sync opens the round at ``plan.t_start`` (every client —
    present or not — coordinates node sets and runs its local updates);
    the aggregation exchange replays the deadline protocol of
    ``simulate_joint_inference`` with ``fault_state``/``plan``. The Q
    LocalUpdates weight each client's fresh block exactly as the server's
    weighted Agg did (``fault_w``/``fault_denom``).

    Returns (params, opt_state, losses, log, new_fault_state). With a
    ``compressor`` the exchange runs composed (compressed wire payloads +
    deadline substitution; see ``simulate_joint_inference``) and the
    return gains a trailing ``new_comp_state``.
    """
    log = MessageLog()
    log_index_sync(log, batch, cfg, t=plan.t_start)
    if compressor is None:
        _, stale, _, new_cache = simulate_joint_inference(
            params, batch, cfg, log=log, return_stale=True,
            fault_state=fault_state, plan=plan)
    else:
        _, stale, _, comp_state, new_cache = simulate_joint_inference(
            params, batch, cfg, log=log, return_stale=True,
            compressor=compressor, comp_state=comp_state,
            fault_state=fault_state, plan=plan)
    w = jnp.asarray(plan.weight, jnp.float32)
    denom = jnp.maximum(jnp.sum(w), 1.0)
    params, opt_state, losses = glasu.local_update_steps(
        params, opt_state, batch, stale, cfg, optimizer,
        fault_w=w, fault_denom=denom)
    if compressor is None:
        return params, opt_state, losses, log, new_cache
    return params, opt_state, losses, log, new_cache, comp_state
