"""Client fault model for the federated runtime: who shows up, and when.

GLASU's stale updates (§3.5) prove the model tolerates old cross-client
embeddings; this module turns that slack into an operational fault model.
A validated, seeded ``FaultConfig`` drives a host-side ``FaultSchedule``
that advances one per-client *virtual clock* per round and emits a
``RoundPlan`` — which clients attempted an upload, which arrived before
the server's deadline, and which absent clients' cached embeddings are
still inside the staleness bound. The device-side round engine
(``core.glasu.fault_joint_inference``) consumes only the plan's two
shape-static ``(M,)`` mask vectors, so the jitted/scanned hot path never
changes shape with the fault draw.

Semantics (documented, deliberately simple — see ``docs/FAULTS.md``):

  * Faults hit the AGGREGATION EXCHANGE only. Every client still runs its
    Q local updates each round (an absent client is *late*, not idle); a
    crashed client's block is excluded from the aggregate via its weight.
  * ``present[m] = 1``: client m's upload arrived before the deadline.
    The server uses its fresh block and refreshes its cache slot.
  * ``weight[m] = 1``: client m's block (fresh, or cached within
    ``max_staleness`` rounds) participates in the weighted mean. A client
    whose cache has aged out carries weight 0 — its block is excluded
    entirely rather than silently averaged in stale.
  * The hard ``max_staleness`` bound forces a synchronous CATCH-UP round:
    when any live client's cache age reaches the bound, the next round
    selects every live client and the server waits for all of them (no
    deadline, no drops — retransmission until delivery).

The schedule is sequential host state (one ``np.random.Generator``), so a
fixed seed replays the identical fault trace on every backend; ``state()``
/ ``load_state()`` round-trip it through the checkpoint sidecar.
"""
from __future__ import annotations

import math
from dataclasses import asdict, dataclass
from typing import List, NamedTuple, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class FaultConfig:
    """Validated fault-injection block (``ExperimentConfig.faults``).

    All times are VIRTUAL milliseconds — the simulation's clock, not wall
    time. The default block is the *degraded* fault model: every client
    present every round, zero latency — the fault-tolerant round path runs
    but must match the fault-free engine (the conformance baseline).
    """
    seed: int = 0
    # participation: fraction of clients the server selects per round
    participation: float = 1.0
    # upload loss: each attempted upload is dropped with this probability
    drop_prob: float = 0.0
    # server deadline per round; 0 = none (wait for every attempted upload)
    deadline_ms: float = 0.0
    # per-upload latency: base * speed_m * lognormal(sigma), heavy-tailed
    # with probability straggler_prob (Pareto(alpha) multiplier * scale)
    base_latency_ms: float = 0.0
    latency_sigma: float = 0.5
    client_speed_sigma: float = 0.0       # persistent per-client speed factor
    straggler_prob: float = 0.0
    straggler_scale: float = 10.0
    straggler_alpha: float = 1.5
    # crash/rejoin: a live client crashes with crash_prob per round and
    # stays dark for rejoin_after rounds
    crash_prob: float = 0.0
    rejoin_after: int = 5
    # hard staleness bound on cached embeddings (rounds); reaching it
    # forces a synchronous catch-up round
    max_staleness: int = 5

    def __post_init__(self):
        def err(msg):
            raise ValueError(f"FaultConfig: {msg}")

        if not (0.0 < self.participation <= 1.0):
            err(f"participation must be in (0, 1], got {self.participation}")
        if not (0.0 <= self.drop_prob < 1.0):
            err(f"drop_prob must be in [0, 1), got {self.drop_prob}")
        if self.deadline_ms < 0 or not math.isfinite(self.deadline_ms):
            err(f"deadline_ms must be finite and >= 0, got {self.deadline_ms}")
        if self.base_latency_ms < 0:
            err(f"base_latency_ms must be >= 0, got {self.base_latency_ms}")
        if self.latency_sigma < 0 or self.client_speed_sigma < 0:
            err("latency_sigma and client_speed_sigma must be >= 0")
        if not (0.0 <= self.straggler_prob <= 1.0):
            err(f"straggler_prob must be in [0, 1], got {self.straggler_prob}")
        if self.straggler_scale <= 0 or self.straggler_alpha <= 0:
            err("straggler_scale and straggler_alpha must be > 0")
        if not (0.0 <= self.crash_prob < 1.0):
            err(f"crash_prob must be in [0, 1), got {self.crash_prob}")
        if self.rejoin_after < 1:
            err(f"rejoin_after must be >= 1, got {self.rejoin_after}")
        if self.max_staleness < 1:
            err(f"max_staleness must be >= 1, got {self.max_staleness}")
        if self.drop_prob > 0.0 and self.deadline_ms == 0.0:
            err("drop_prob > 0 requires a deadline: without one the server "
                "would wait forever for a dropped upload (set deadline_ms)")

    @property
    def active(self) -> bool:
        """True when any draw can make a client absent from a round."""
        return (self.participation < 1.0 or self.drop_prob > 0.0
                or self.crash_prob > 0.0
                or (self.deadline_ms > 0.0 and self.base_latency_ms > 0.0))

    def to_dict(self) -> dict:
        return asdict(self)


class RoundPlan(NamedTuple):
    """One round's host-side fault draw (everything a backend needs)."""
    round: int
    present: np.ndarray       # (M,) float32 — upload delivered by deadline
    weight: np.ndarray        # (M,) float32 — fresh or valid-cache block
    active: np.ndarray        # (M,) bool — not crashed this round
    attempted: np.ndarray     # (M,) bool — selected & live (sent an upload)
    latency_ms: np.ndarray    # (M,) float64 — upload latency (inf: no attempt)
    t_start: float            # virtual ms at round start
    t_end: float              # virtual ms at round end
    catch_up: bool            # synchronous staleness-bound recovery round

    @property
    def n_present(self) -> int:
        return int(self.present.sum())

    @property
    def duration_ms(self) -> float:
        return self.t_end - self.t_start


def stack_plans(plans: Sequence[RoundPlan]):
    """(present (K, M), weight (K, M)) float32 stacks for the scanned step."""
    present = np.stack([p.present for p in plans]).astype(np.float32)
    weight = np.stack([p.weight for p in plans]).astype(np.float32)
    return present, weight


class FaultSchedule:
    """Sequential per-client virtual-clock engine over a ``FaultConfig``.

    ``next_round()`` advances one round: crash transitions, participation
    selection, per-upload latency draws, drop draws, deadline cut, cache
    ages, and the catch-up trigger. All state is host-side numpy; the
    device only ever sees the emitted masks.
    """

    def __init__(self, cfg: FaultConfig, n_clients: int):
        if n_clients < 1:
            raise ValueError(f"n_clients must be >= 1, got {n_clients}")
        self.cfg = cfg
        self.m = int(n_clients)
        self.rng = np.random.default_rng(cfg.seed)
        if cfg.client_speed_sigma > 0.0:
            self.speed = np.exp(cfg.client_speed_sigma
                                * self.rng.standard_normal(self.m))
        else:
            self.speed = np.ones(self.m)
        self.age = np.zeros(self.m, np.int32)       # rounds since last upload
        self.delivered_ever = np.zeros(self.m, bool)
        self.crash_until = np.zeros(self.m, np.int32)
        self.round = 0
        self.t = 0.0

    # ---------------------------------------------------------------- draws
    def _draw_latency(self, attempted: np.ndarray) -> np.ndarray:
        cfg = self.cfg
        lat = np.full(self.m, np.inf)
        if not attempted.any():
            return lat
        base = cfg.base_latency_ms * self.speed
        jitter = np.exp(cfg.latency_sigma * self.rng.standard_normal(self.m)
                        - 0.5 * cfg.latency_sigma ** 2)  # median-preserving
        draw = base * jitter
        if cfg.straggler_prob > 0.0:
            tail = self.rng.random(self.m) < cfg.straggler_prob
            mult = cfg.straggler_scale * (
                1.0 + self.rng.pareto(cfg.straggler_alpha, self.m))
            draw = np.where(tail, draw * mult, draw)
        lat[attempted] = draw[attempted]
        return lat

    # ---------------------------------------------------------------- rounds
    def next_round(self) -> RoundPlan:
        cfg, m, r = self.cfg, self.m, self.round
        # crash transitions: live clients crash with crash_prob and stay
        # dark for rejoin_after rounds (draw consumed every round so the
        # stream stays aligned whether or not anyone crashes)
        if cfg.crash_prob > 0.0:
            crash_draw = self.rng.random(m) < cfg.crash_prob
            live = self.crash_until <= r
            crashes = live & crash_draw
            self.crash_until = np.where(crashes, r + cfg.rejoin_after,
                                        self.crash_until)
        active = self.crash_until <= r

        # hard staleness bound: any live client whose cache age has reached
        # the bound forces a synchronous catch-up round NOW
        catch_up = bool(np.any(active & (self.age >= cfg.max_staleness)))

        if catch_up:
            attempted = active.copy()
            latency = self._draw_latency(attempted)
            present = attempted.copy()      # server waits for every upload
            lat_live = latency[attempted]
            duration = float(lat_live.max()) if lat_live.size else 0.0
        else:
            n_sel = max(1, int(math.ceil(cfg.participation * m)))
            sel = self.rng.choice(m, size=n_sel, replace=False)
            selected = np.zeros(m, bool)
            selected[sel] = True
            attempted = selected & active
            latency = self._draw_latency(attempted)
            dropped = attempted & (self.rng.random(m) < cfg.drop_prob)
            deadline = cfg.deadline_ms if cfg.deadline_ms > 0.0 else np.inf
            present = attempted & ~dropped & (latency <= deadline)
            if not attempted.any():
                duration = 0.0
            elif bool(np.all(present == attempted)):
                # everything arrived: the server closes the round early
                duration = float(latency[attempted].max())
                if np.isfinite(deadline):
                    duration = min(duration, float(deadline))
            else:
                # a drop or a straggler: the server waits out the deadline
                duration = float(deadline)

        # block weights: fresh, or a cache still inside the bound
        cache_ok = self.delivered_ever & (self.age <= cfg.max_staleness)
        weight = (present | cache_ok).astype(np.float32)

        self.age = np.where(present, 0, self.age + 1)
        self.delivered_ever |= present
        t_start = self.t
        self.t = t_start + duration
        self.round = r + 1
        return RoundPlan(round=r, present=present.astype(np.float32),
                         weight=weight, active=active, attempted=attempted,
                         latency_ms=latency, t_start=t_start, t_end=self.t,
                         catch_up=catch_up)

    def draw_step(self, k: int) -> List[RoundPlan]:
        """The Trainer's per-step helper: the next ``k`` rounds of plans."""
        return [self.next_round() for _ in range(k)]

    # ----------------------------------------------------------- persistence
    def state(self) -> dict:
        """JSON-serializable snapshot after ``self.round`` rounds drawn."""
        return {"rng": self.rng.bit_generator.state,
                "speed": self.speed.tolist(),
                "age": self.age.tolist(),
                "delivered_ever": self.delivered_ever.tolist(),
                "crash_until": self.crash_until.tolist(),
                "round": self.round, "t": self.t}

    def load_state(self, state: dict) -> None:
        self.rng.bit_generator.state = state["rng"]
        self.speed = np.asarray(state["speed"], np.float64)  # glint: disable=GL003 host-side schedule state, never on device; f64 keeps the JSON state round-trip bit-exact for replay
        self.age = np.asarray(state["age"], np.int32)
        self.delivered_ever = np.asarray(state["delivered_ever"], bool)
        self.crash_until = np.asarray(state["crash_until"], np.int32)
        self.round = int(state["round"])
        self.t = float(state["t"])


def make_schedule(cfg: Optional[FaultConfig],
                  n_clients: int) -> Optional[FaultSchedule]:
    """``None``-propagating constructor (the Trainer's binding point)."""
    return None if cfg is None else FaultSchedule(cfg, n_clients)
