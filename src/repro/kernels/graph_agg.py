"""Pallas TPU kernel: sampled-neighborhood aggregation + weight matmul.

This is the per-layer hotspot of the paper's split GNN:

    H_m^+[l] = (masked-mean over sampled neighbors of H_m[l]) @ W_m[l]

TPU adaptation (vs the CUDA gather-scatter formulation): destination nodes
are tiled in blocks of 128 (MXU/VREG lane alignment); the per-tile gather of
fanout neighbor rows runs as dynamic-slice DMAs from the source-activation
buffer (kept in ANY/HBM memory space) into a VMEM accumulator; the masked
mean is fused with the weight matmul on the MXU. Output tile: (128, d_out).

Grid: (n_dst // 128,). Per-tile VMEM footprint: gather indices (128 x F int32)
+ accumulator (128 x d) + weight (d x d_out) — with the GNN's d, d_out <= 512
this stays well under the ~16 MB v5e VMEM budget; d_out is additionally tiled
if d * d_out grows beyond it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DST_BLOCK = 128


def _graph_agg_kernel(idx_ref, mask_ref, h_ref, w_ref, out_ref, *, fanout):
    """One destination tile: gather+mean (DMA loop) fused with the matmul."""
    acc = jnp.zeros((DST_BLOCK, h_ref.shape[1]), jnp.float32)

    def body(f, acc):
        # one neighbor column: dynamic one-row loads from the source buffer
        def row(r, acc):
            src = idx_ref[r, f]
            hrow = h_ref[pl.dslice(src, 1), :]
            m = mask_ref[r, f]
            return acc.at[r].add(hrow[0].astype(jnp.float32) * m)

        return jax.lax.fori_loop(0, DST_BLOCK, row, acc)

    acc = jax.lax.fori_loop(0, fanout, body, acc)
    denom = jnp.maximum(jnp.sum(mask_ref[...], axis=1, keepdims=True), 1.0)
    agg = (acc / denom).astype(w_ref.dtype)
    out_ref[...] = jnp.dot(agg, w_ref[...],
                           preferred_element_type=jnp.float32
                           ).astype(out_ref.dtype)


def graph_agg_pallas(h, idx, mask, w, *, interpret: bool = True):
    """h: (n_src, d), idx/mask: (n_dst, F), w: (d, d_out) -> (n_dst, d_out)."""
    n_dst, fanout = idx.shape
    d = h.shape[1]
    d_out = w.shape[1]
    pad = (-n_dst) % DST_BLOCK
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    grid = (idx.shape[0] // DST_BLOCK,)
    out = pl.pallas_call(
        functools.partial(_graph_agg_kernel, fanout=fanout),
        grid=grid,
        in_specs=[
            pl.BlockSpec((DST_BLOCK, fanout), lambda i: (i, 0)),   # idx tile
            pl.BlockSpec((DST_BLOCK, fanout), lambda i: (i, 0)),   # mask tile
            pl.BlockSpec((h.shape[0], d), lambda i: (0, 0)),       # source rows
            pl.BlockSpec((d, d_out), lambda i: (0, 0)),            # weights
        ],
        out_specs=pl.BlockSpec((DST_BLOCK, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((idx.shape[0], d_out), w.dtype),
        interpret=interpret,
    )(idx, mask, h, w)
    return out[:n_dst]
