"""Pallas TPU kernels: sampled-neighborhood aggregation for GLASU sub-layers.

These are the per-layer hotspots of the paper's split GNN (§3.1, Alg 3/4):

    GCN    H_m^+[l] = relu( (masked-mean nbrs of H) @ W + b )
    GCNII  z = (1-a)·mean + a·H0[self];  relu((1-b)·z + b·(z @ W) + b)
    GAT    per-head masked softmax attention over the sampled fanout

TPU adaptation (vs the CUDA gather-scatter formulation): destination nodes
are tiled in blocks of 128 (MXU/VREG lane alignment) and the fanout gather is
reformulated as a one-hot *scatter-matrix matmul*: for every destination tile
we build A in VREGs with

    A[r, s] = sum_f mask[r, f] * [idx[r, f] == s]        (BD x n_src)

so the masked gather-sum is ``A @ H`` — one MXU contraction instead of
128·F scalar DMAs per tile (the seed kernel's double ``fori_loop``).  The
masked mean and the weight matmul fuse behind it in the same program.

``d_out`` is tiled for real: the grid is (dst tiles, d_out tiles) and each
program writes one (128, DOUT_BLOCK) output tile, so weight/output VMEM stays
bounded for wide layers.  Each d_out tile recomputes the (cheap) scatter
matrix instead of caching it in scratch: the GLASU core ``jax.vmap``s these
kernels over the client axis, and Pallas batching *prepends* a grid axis,
which would shift every ``pl.program_id``-gated scratch reuse.  With the
usual hidden sizes (d_out <= 128) there is exactly one d_out tile and nothing
is recomputed.

Per-tile VMEM: scatter matrix (128 x n_src) + source rows (n_src x d) +
one weight tile (d x DOUT_BLOCK) — with the sampler's n_src <= size_cap (512)
and d <= 512 this stays well under the ~16 MB v5e budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DST_BLOCK = 128
DOUT_BLOCK = 128
NEG_INF = -1e9


def _scatter_matrix(idx, mask, n_src):
    """One-hot accumulation matrix: A[r, s] = sum_f mask[r, f]·[idx[r, f]==s].

    ``A @ H`` is the masked gather-sum over the fanout — the whole gather
    runs on the MXU. The loop over fanout columns is a *Python* loop over a
    static, small F (3-64), unrolled at trace time; every op is 2D.
    """
    src = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], n_src), 1)
    a = jnp.zeros((idx.shape[0], n_src), jnp.float32)
    for f in range(idx.shape[1]):  # glint: disable=GL004 static fanout unroll at trace time (F is 3-64; see module docstring)
        a = a + jnp.where(idx[:, f:f + 1] == src, mask[:, f:f + 1], 0.0)
    return a


def _select_matrix(idx_col, n_src):
    """Unmasked one-hot row-select matrix for a single index column."""
    src = jax.lax.broadcasted_iota(jnp.int32, (idx_col.shape[0], n_src), 1)
    return jnp.where(idx_col[:, None] == src, 1.0, 0.0)


def _masked_mean(idx_ref, mask_ref, h_ref):
    """(BD, d) masked mean of gathered source rows, f32."""
    mask = mask_ref[...].astype(jnp.float32)
    a = _scatter_matrix(idx_ref[...], mask, h_ref.shape[0])
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    s = jnp.dot(a, h_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    return s / denom


def _pad_rows(x, block):
    pad = (-x.shape[0]) % block
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x


def _dout_block(d_out: int) -> int:
    return d_out if d_out <= DOUT_BLOCK else DOUT_BLOCK


def _pad_cols(x, block):
    pad = (-x.shape[-1]) % block
    if pad:
        x = jnp.pad(x, ((0, 0),) * (x.ndim - 1) + ((0, pad),))
    return x


# ----------------------------------------------------------------- GCN / agg
def _graph_agg_kernel(idx_ref, mask_ref, h_ref, w_ref, out_ref):
    agg = _masked_mean(idx_ref, mask_ref, h_ref)
    out_ref[...] = jnp.dot(agg.astype(w_ref.dtype), w_ref[...],
                           preferred_element_type=jnp.float32
                           ).astype(out_ref.dtype)


def graph_agg_pallas(h, idx, mask, w, *, interpret: bool = True):
    """h: (n_src, d), idx/mask: (n_dst, F), w: (d, d_out) -> (n_dst, d_out)."""
    n_dst, fanout = idx.shape
    d = h.shape[1]
    d_out = w.shape[1]
    bo = _dout_block(d_out)
    idx = _pad_rows(idx, DST_BLOCK)
    mask = _pad_rows(mask, DST_BLOCK)
    wp = _pad_cols(w, bo)
    grid = (idx.shape[0] // DST_BLOCK, wp.shape[1] // bo)
    out = pl.pallas_call(
        _graph_agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((DST_BLOCK, fanout), lambda i, j: (i, 0),
                         memory_space=pltpu.VMEM),                   # idx tile
            pl.BlockSpec((DST_BLOCK, fanout), lambda i, j: (i, 0),
                         memory_space=pltpu.VMEM),                   # mask
            pl.BlockSpec((h.shape[0], d), lambda i, j: (0, 0),
                         memory_space=pltpu.VMEM),                   # sources
            pl.BlockSpec((d, bo), lambda i, j: (0, j),
                         memory_space=pltpu.VMEM),                   # W tile
        ],
        out_specs=pl.BlockSpec((DST_BLOCK, bo), lambda i, j: (i, j),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((idx.shape[0], wp.shape[1]), w.dtype),
        interpret=interpret,
    )(idx, mask, h, wp)
    return out[:n_dst, :d_out]


# ----------------------------------------------------------- CSR / sparse
# The one-hot kernel above builds a (128, n_src) scatter matrix per tile —
# O(n_dst·n_src·d) dense MXU work, perfect while the sampler caps n_src at
# size_cap (512) but quadratic-looking the moment the source set grows
# toward graph scale. The CSR path replaces it with a per-tile *edge slab*:
# the host planner lays the CSR out as (n_tiles, slab) edge blocks — tile i
# owns destination rows [128i, 128i+128) and exactly its own edges, padded
# to a uniform slab length — so each program touches O(slab·d) work
# regardless of n_src. Assignment runs as a (128, slab) comparison matrix
# against the LOCAL destination row of each edge (sentinel 128 = padding,
# matches no row), making the kernel grid-position-free: no program_id, no
# SMEM scalars, safe under the core's client-axis vmap exactly like the
# dense kernels. The source-row gather is a vector ``jnp.take`` — the one
# TPU-adaptation point (lowers via Mosaic dynamic-gather; interpret mode on
# CPU executes it as XLA gather).

CSR_PAD_ROW = DST_BLOCK          # local-seg sentinel: matches no tile row


def ell_to_slabs(idx, mask):
    """Padded-fanout (ELL) tables -> the kernel's slab layout, traceable.

    idx/mask: (n_dst, F) — the sampler's gather tables. Every row owns
    exactly F slots, so the slab is the uniform 128·F and the conversion is
    pure reshapes/iota (jit-safe; this is the in-trace dispatch path of
    ``ops.graph_agg``). Masked-off entries become weight-0 edges — the
    denominator clamp keeps the masked-mean semantics bitwise.
    """
    n_dst, fanout = idx.shape
    idx = _pad_rows(idx, DST_BLOCK)
    mask = _pad_rows(mask, DST_BLOCK)
    n_pad = idx.shape[0]
    n_tiles = n_pad // DST_BLOCK
    slab = DST_BLOCK * fanout
    local = jnp.broadcast_to(
        (jnp.arange(n_pad, dtype=jnp.int32) % DST_BLOCK)[:, None],
        (n_pad, fanout))
    idx_slab = idx.astype(jnp.int32).reshape(n_tiles * slab, 1)
    seg_slab = local.reshape(n_tiles * slab, 1)
    ew_slab = mask.astype(jnp.float32).reshape(n_tiles * slab, 1)
    return idx_slab, seg_slab, ew_slab, n_dst


def _csr_agg_kernel(idx_ref, seg_ref, ew_ref, h_ref, w_ref, out_ref):
    """One (dst tile, d_out tile) program over the tile's edge slab."""
    seg = jnp.transpose(seg_ref[...])                   # (1, slab) local row
    ew = jnp.transpose(ew_ref[...]).astype(jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (DST_BLOCK, seg.shape[1]), 0)
    a = jnp.where(rows == seg, ew, 0.0)                 # (128, slab)
    gathered = jnp.take(h_ref[...].astype(jnp.float32), idx_ref[...][:, 0],
                        axis=0)                         # (slab, d)
    s = jnp.dot(a, gathered, preferred_element_type=jnp.float32)
    denom = jnp.maximum(jnp.sum(a, axis=1, keepdims=True), 1.0)
    out_ref[...] = jnp.dot((s / denom).astype(w_ref.dtype), w_ref[...],
                           preferred_element_type=jnp.float32
                           ).astype(out_ref.dtype)


def graph_agg_csr_pallas(h, idx_slab, seg_slab, ew_slab, w, n_dst: int, *,
                         interpret: bool = True):
    """CSR segment-mean + matmul over the planned slab layout.

    h: (n_src, d); idx/seg/ew slabs: (n_tiles*slab, 1) from
    ``graph.csr_plan.plan_csr_slabs`` / ``ell_to_slabs``; w: (d, d_out)
    -> (n_dst, d_out).
    Grid is (dst tiles, d_out tiles); each program reads ONE tile's edge
    slab and one weight tile — VMEM per program is slab·(2·4B) + n_src·d·4B
    for the shared source rows + the (128, slab) assignment matrix.
    """
    d = h.shape[1]
    d_out = w.shape[1]
    bo = _dout_block(d_out)
    wp = _pad_cols(w, bo)
    n_tiles = max(1, -(-n_dst // DST_BLOCK))
    slab = idx_slab.shape[0] // n_tiles
    grid = (n_tiles, wp.shape[1] // bo)
    out = pl.pallas_call(
        _csr_agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((slab, 1), lambda i, j: (i, 0),
                         memory_space=pltpu.VMEM),                # edge srcs
            pl.BlockSpec((slab, 1), lambda i, j: (i, 0),
                         memory_space=pltpu.VMEM),                # local rows
            pl.BlockSpec((slab, 1), lambda i, j: (i, 0),
                         memory_space=pltpu.VMEM),                # weights
            pl.BlockSpec((h.shape[0], d), lambda i, j: (0, 0),
                         memory_space=pltpu.VMEM),                # sources
            pl.BlockSpec((d, bo), lambda i, j: (0, j),
                         memory_space=pltpu.VMEM),                # W tile
        ],
        out_specs=pl.BlockSpec((DST_BLOCK, bo), lambda i, j: (i, j),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((n_tiles * DST_BLOCK, wp.shape[1]),
                                       w.dtype),
        interpret=interpret,
    )(idx_slab, seg_slab, ew_slab, h, wp)
    return out[:n_dst, :d_out]


# -------------------------------------------------------------------- GCNII
def _gcnii_kernel(idx_ref, mask_ref, h_ref, h0_ref, w_ref, b_ref, col_ref,
                  out_ref, *, alpha, beta, block_out):
    agg = _masked_mean(idx_ref, mask_ref, h_ref)
    # initial residual: H0 at the output node set (self column, unmasked —
    # mirrors the reference's plain h0[idx[:, 0]] gather)
    sel0 = _select_matrix(idx_ref[...][:, 0], h0_ref.shape[0])
    h0_sel = jnp.dot(sel0, h0_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    z = (1.0 - alpha) * agg + alpha * h0_sel                 # (BD, d_pad)
    zw = jnp.dot(z.astype(w_ref.dtype), w_ref[...],
                 preferred_element_type=jnp.float32)
    # identity-map skip needs z restricted to this output tile's columns.
    # col_ref carries the tile's column offset as data (a (1, 1) block of an
    # offsets array indexed by the column grid axis) instead of
    # pl.program_id(1) — vmap over the client axis prepends a grid dimension
    # and would silently shift program_id axes.
    z_cols = jax.lax.dynamic_slice_in_dim(z, col_ref[0, 0], block_out, axis=1)
    out = (1.0 - beta) * z_cols + beta * zw + b_ref[...].astype(jnp.float32)
    out_ref[...] = jax.nn.relu(out).astype(out_ref.dtype)


def gcnii_layer_pallas(h, h0, idx, mask, w, b, *, alpha: float, beta: float,
                       interpret: bool = True):
    """Fused GCNII client sub-layer (constant width d == d_out).

    h/h0: (n_src, d); idx/mask: (n_dst, F+1) with self at column 0;
    w: (d, d); b: (d,) -> relu((1-β)z + β(z@W) + b), z = (1-α)·mean + α·h0.
    """
    n_dst, fanout1 = idx.shape
    d = h.shape[1]
    bo = _dout_block(d)
    hp = _pad_cols(h, bo)
    h0p = _pad_cols(h0, bo)
    wp = _pad_cols(_pad_rows(w, bo), bo)
    bp = _pad_cols(b[None, :], bo)
    idx = _pad_rows(idx, DST_BLOCK)
    mask = _pad_rows(mask, DST_BLOCK)
    d_pad = hp.shape[1]
    n_col_tiles = d_pad // bo
    col_offsets = (jnp.arange(n_col_tiles, dtype=jnp.int32) * bo)[:, None]
    grid = (idx.shape[0] // DST_BLOCK, n_col_tiles)
    out = pl.pallas_call(
        functools.partial(_gcnii_kernel, alpha=alpha, beta=beta,
                          block_out=bo),
        grid=grid,
        in_specs=[
            pl.BlockSpec((DST_BLOCK, fanout1), lambda i, j: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((DST_BLOCK, fanout1), lambda i, j: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((hp.shape[0], d_pad), lambda i, j: (0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((h0p.shape[0], d_pad), lambda i, j: (0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((d_pad, bo), lambda i, j: (0, j),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, bo), lambda i, j: (0, j),
                         memory_space=pltpu.VMEM),
            # column offset: a (1, 1) scalar tile, SMEM by the guide idiom
            pl.BlockSpec((1, 1), lambda i, j: (j, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((DST_BLOCK, bo), lambda i, j: (i, j),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((idx.shape[0], d_pad), w.dtype),
        interpret=interpret,
    )(idx, mask, hp, h0p, wp, bp, col_offsets)
    return out[:n_dst, :d]


# ---------------------------------------------------------------------- GAT
def _gat_kernel(idx_ref, mask_ref, h_ref, w_ref, asrc_ref, adst_ref, b_ref,
                out_ref):
    """One (dst tile, head) program: project, gather, masked softmax, mix.

    The fanout gather runs as per-column one-hot matmuls; attention logits
    are assembled column-by-column with an iota mask (all ops 2D, unrolled
    over the static fanout — no 3D tensors, no program_id)."""
    idx = idx_ref[...]
    mask = mask_ref[...].astype(jnp.float32)
    n_dst, f1 = idx.shape
    n_src = h_ref.shape[0]
    wh = jnp.dot(h_ref[...].astype(jnp.float32),
                 w_ref[...].astype(jnp.float32),
                 preferred_element_type=jnp.float32)          # (n_src, dh)
    e_dst = jnp.sum(wh * adst_ref[...].astype(jnp.float32),
                    axis=1, keepdims=True)                    # (n_src, 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n_dst, f1), 1)
    gathered = []
    e = jnp.zeros((n_dst, f1), jnp.float32)
    for f in range(f1):  # glint: disable=GL004 static fanout unroll at trace time (F is 3-64; see module docstring)
        sel = _select_matrix(idx[:, f], n_src)
        gathered.append(jnp.dot(sel, wh, preferred_element_type=jnp.float32))
        ecol = jnp.dot(sel, e_dst, preferred_element_type=jnp.float32)
        e = e + jnp.where(cols == f, ecol, 0.0)
    e_src = jnp.sum(gathered[0] * asrc_ref[...].astype(jnp.float32),
                    axis=1, keepdims=True)                    # self = col 0
    e = jax.nn.leaky_relu(e_src + e, negative_slope=0.2)
    e = jnp.where(mask > 0, e, NEG_INF)
    att = jax.nn.softmax(e, axis=1) * mask
    out = jnp.zeros_like(gathered[0])
    for f in range(f1):
        out = out + att[:, f:f + 1] * gathered[f]
    out_ref[...] = jax.nn.elu(
        out + b_ref[...].astype(jnp.float32)).astype(out_ref.dtype)


def gat_layer_pallas(h, idx, mask, w, a_src, a_dst, b, *,
                     interpret: bool = True):
    """Fused multi-head GAT client sub-layer.

    h: (n_src, d); idx/mask: (n_dst, F+1) with self at column 0;
    w: (d, H, dh); a_src/a_dst: (H, dh); b: (H*dh,) -> (n_dst, H*dh).
    Grid is (dst tiles, heads): each program handles one head's (128, dh)
    output block; the head axis rides the BlockSpec index maps so no head
    dimension is ever materialized in VMEM.
    """
    n_dst, fanout1 = idx.shape
    d, n_heads, dh = w.shape
    idx = _pad_rows(idx, DST_BLOCK)
    mask = _pad_rows(mask, DST_BLOCK)
    w2 = w.reshape(d, n_heads * dh)
    b2 = b.reshape(1, n_heads * dh)
    grid = (idx.shape[0] // DST_BLOCK, n_heads)
    out = pl.pallas_call(
        _gat_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((DST_BLOCK, fanout1), lambda i, k: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((DST_BLOCK, fanout1), lambda i, k: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((h.shape[0], d), lambda i, k: (0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((d, dh), lambda i, k: (0, k),
                         memory_space=pltpu.VMEM),            # head's W
            pl.BlockSpec((1, dh), lambda i, k: (k, 0),
                         memory_space=pltpu.VMEM),            # head's a_src
            pl.BlockSpec((1, dh), lambda i, k: (k, 0),
                         memory_space=pltpu.VMEM),            # head's a_dst
            pl.BlockSpec((1, dh), lambda i, k: (0, k),
                         memory_space=pltpu.VMEM),            # head's bias
        ],
        out_specs=pl.BlockSpec((DST_BLOCK, dh), lambda i, k: (i, k),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((idx.shape[0], n_heads * dh), h.dtype),
        interpret=interpret,
    )(idx, mask, h, w2, a_src, a_dst, b2)
    return out[:n_dst]
