"""Pallas TPU kernel: flash attention (causal / sliding-window / bidirectional)
with native GQA (kv-head reuse via BlockSpec index maps — no materialized
head repeat).

Grid: (batch, q_heads, n_q_blocks). Each program owns one (BLOCK_Q, dh) query
tile in VMEM and streams (BLOCK_K, dh) key/value tiles with the online-
softmax running (m, l, acc) state. Causality and the sliding window are
enforced (a) coarsely by skipping fully-masked kv blocks via the loop bounds
and (b) exactly by an in-tile position mask. Block sizes default to 128
(MXU-aligned); dh must be a multiple of 8 (v5e VREG sublane).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, causal, window,
                  block_q, block_k, seq_k, scale):
    qi = pl.program_id(2)  # glint: disable=GL005 never vmapped: callers pass pre-batched (b, h, s, dh) and batch/head ride the grid
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (BQ, dh)
    q_start = qi * block_q

    # kv block range actually visible to this q tile
    n_kv_blocks = (seq_k + block_k - 1) // block_k
    hi = n_kv_blocks if not causal else \
        jnp.minimum((q_start + block_q + block_k - 1) // block_k, n_kv_blocks)
    lo = 0
    if window is not None:
        lo = jnp.maximum(q_start - window + 1, 0) // block_k

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k_start = kb * block_k
        k = k_ref[0, 0, pl.dslice(k_start, block_k), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(k_start, block_k), :].astype(jnp.float32)
        s = q @ k.T                                     # (BQ, BK)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_cur = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_cur, l_cur

    acc0 = jnp.zeros((block_q, q_ref.shape[3]), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = True):
    """q: (B, S, H, dh); k/v: (B, T, Kv, dh) -> (B, S, H, dh)."""
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    pad_q = (-s) % block_q
    pad_k = (-t) % block_k
    qt = jnp.moveaxis(q, 2, 1)                       # (B, H, S, dh)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sp, tp = s + pad_q, t + pad_k

    grid = (b, h, sp // block_q)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, window=window,
                          block_q=block_q, block_k=block_k, seq_k=t,
                          scale=1.0 / (dh ** 0.5)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda bi, hi, qi: (bi, hi, qi, 0),
                         memory_space=pltpu.VMEM),
            # GQA: kv head = q head // group — no repeat materialization
            pl.BlockSpec((1, 1, tp, dh),
                         lambda bi, hi, qi: (bi, hi // g, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, tp, dh),
                         lambda bi, hi, qi: (bi, hi // g, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda bi, hi, qi: (bi, hi, qi, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, h, sp, dh), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out[:, :, :s], 1, 2)
