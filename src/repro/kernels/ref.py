"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def graph_agg_ref(h, idx, mask, w):
    """GLASU client sub-layer hotspot: masked-mean neighbor gather + matmul.

    h: (n_src, d); idx/mask: (n_dst, F); w: (d, d_out) -> (n_dst, d_out).
    """
    g = h[idx]                                     # (n_dst, F, d)
    s = jnp.sum(g * mask[..., None], axis=1)
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    return (s / denom) @ w


def gcnii_layer_ref(h, h0, idx, mask, w, b, alpha: float, beta: float):
    """Fused GCNII client sub-layer (initial residual + identity map).

    h/h0: (n_src, d); idx/mask: (n_dst, F+1), self at column 0; w: (d, d).
    """
    g = h[idx]
    s = jnp.sum(g * mask[..., None], axis=1)
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    z = (1.0 - alpha) * (s / denom) + alpha * h0[idx[:, 0]]
    return jax.nn.relu((1.0 - beta) * z + beta * (z @ w) + b)


def gat_layer_ref(h, idx, mask, w, a_src, a_dst, b):
    """Fused multi-head GAT client sub-layer (masked softmax attention).

    h: (n_src, d); idx/mask: (n_dst, F+1), self at column 0; w: (d, H, dh);
    a_src/a_dst: (H, dh); b: (H*dh,) -> (n_dst, H*dh).
    """
    n_heads, dh = a_src.shape
    wh = jnp.einsum("nd,dhk->nhk", h, w)
    wh_nb = wh[idx]                                 # (n_dst, F+1, H, dh)
    wh_self = wh[idx[:, 0]]
    e = (jnp.einsum("nhk,hk->nh", wh_self, a_src)[:, None, :]
         + jnp.einsum("nfhk,hk->nfh", wh_nb, a_dst))
    e = jax.nn.leaky_relu(e, negative_slope=0.2)
    e = jnp.where(mask[..., None] > 0, e, -1e9)
    att = jax.nn.softmax(e, axis=1) * mask[..., None]
    out = jnp.einsum("nfh,nfhk->nhk", att, wh_nb)
    return jax.nn.elu(out.reshape(out.shape[0], n_heads * dh) + b)


def flash_attention_ref(q, k, v, causal: bool = True,
                        window: Optional[int] = None):
    """q: (B, S, H, dh); k/v: (B, T, Kv, dh) -> (B, S, H, dh)."""
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / jnp.sqrt(dh)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    m = jnp.ones((s, t), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    scores = jnp.where(m[None, None, None], scores, -1e30)
    att = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", att, v)
    return out.reshape(b, s, h, dh)
