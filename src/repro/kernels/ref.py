"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..graph.csr_plan import csr_segments


def graph_agg_ref(h, idx, mask, w):
    """GLASU client sub-layer hotspot: masked-mean neighbor gather + matmul.

    h: (n_src, d); idx/mask: (n_dst, F); w: (d, d_out) -> (n_dst, d_out).
    """
    g = h[idx]                                     # (n_dst, F, d)
    s = jnp.sum(g * mask[..., None], axis=1)
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    return (s / denom) @ w


def graph_agg_csr_ref(h, indptr, indices, w, edge_weight=None):
    """CSR oracle for the sparse aggregation path: segment-mean + matmul.

    h: (n_src, d); indptr: (n_dst+1,) CONCRETE numpy (host CSR — the sparse
    structure is data the planner consumes, never a traced value); indices:
    (nnz,) source ids; w: (d, d_out); edge_weight: optional (nnz,) f32
    (defaults to 1, i.e. an unweighted mean). Zero-degree rows produce
    exactly zero output (the clamped denominator of the dense path's
    masked mean), so CSR and one-hot results agree bitwise in structure.

    Differentiable wrt ``h``/``w``/``edge_weight`` — the custom_vjp
    backward of the public op differentiates the same segment-sum algebra
    (``csr_slab_ref``) over the kernel's padded slab layout.
    """
    n_dst = len(indptr) - 1
    seg = jnp.asarray(csr_segments(indptr))
    ew = (jnp.ones(indices.shape[0], jnp.float32) if edge_weight is None
          else edge_weight.astype(jnp.float32))
    g = jnp.take(h.astype(jnp.float32), indices, axis=0)    # (nnz, d)
    s = jax.ops.segment_sum(g * ew[:, None], seg, num_segments=n_dst)
    denom = jnp.maximum(
        jax.ops.segment_sum(ew, seg, num_segments=n_dst), 1.0)
    return ((s / denom[:, None]).astype(w.dtype) @ w)


def csr_slab_ref(h, idx_slab, seg_slab, ew_slab, w, n_dst: int):
    """Segment-sum oracle over the kernel's padded row-tile slab layout.

    idx_slab/seg_slab/ew_slab: (n_tiles*slab, 1) — seg holds the LOCAL
    destination row within its 128-row tile (128 = padding sentinel). The
    global segment id is reconstructed from the slab position, padding
    edges land in a scratch bucket past the last row. Algebraically equal
    to ``graph_agg_csr_ref`` on the unpadded CSR; this is the function the
    CSR kernel's ``custom_vjp`` backward differentiates (traceable — no
    concrete indptr needed).
    """
    from .graph_agg import DST_BLOCK
    total = idx_slab.shape[0]
    n_tiles = -(-n_dst // DST_BLOCK)
    slab = total // n_tiles
    n_pad = n_tiles * DST_BLOCK
    tile = jnp.arange(total, dtype=jnp.int32) // slab
    seg = seg_slab[:, 0]
    seg_global = jnp.where(seg < DST_BLOCK, seg + DST_BLOCK * tile, n_pad)
    ew = ew_slab[:, 0].astype(jnp.float32)
    g = jnp.take(h.astype(jnp.float32), idx_slab[:, 0], axis=0)
    s = jax.ops.segment_sum(g * ew[:, None], seg_global,
                            num_segments=n_pad + 1)[:n_dst]
    denom = jnp.maximum(
        jax.ops.segment_sum(ew, seg_global, num_segments=n_pad + 1)[:n_dst],
        1.0)
    return ((s / denom[:, None]).astype(w.dtype) @ w)


def gcnii_layer_ref(h, h0, idx, mask, w, b, alpha: float, beta: float):
    """Fused GCNII client sub-layer (initial residual + identity map).

    h/h0: (n_src, d); idx/mask: (n_dst, F+1), self at column 0; w: (d, d).
    """
    g = h[idx]
    s = jnp.sum(g * mask[..., None], axis=1)
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    z = (1.0 - alpha) * (s / denom) + alpha * h0[idx[:, 0]]
    return jax.nn.relu((1.0 - beta) * z + beta * (z @ w) + b)


def gat_layer_ref(h, idx, mask, w, a_src, a_dst, b):
    """Fused multi-head GAT client sub-layer (masked softmax attention).

    h: (n_src, d); idx/mask: (n_dst, F+1), self at column 0; w: (d, H, dh);
    a_src/a_dst: (H, dh); b: (H*dh,) -> (n_dst, H*dh).
    """
    n_heads, dh = a_src.shape
    wh = jnp.einsum("nd,dhk->nhk", h, w)
    wh_nb = wh[idx]                                 # (n_dst, F+1, H, dh)
    wh_self = wh[idx[:, 0]]
    e = (jnp.einsum("nhk,hk->nh", wh_self, a_src)[:, None, :]
         + jnp.einsum("nfhk,hk->nfh", wh_nb, a_dst))
    e = jax.nn.leaky_relu(e, negative_slope=0.2)
    e = jnp.where(mask[..., None] > 0, e, -1e9)
    att = jax.nn.softmax(e, axis=1) * mask[..., None]
    out = jnp.einsum("nfh,nfhk->nhk", att, wh_nb)
    return jax.nn.elu(out.reshape(out.shape[0], n_heads * dh) + b)


def flash_attention_ref(q, k, v, causal: bool = True,
                        window: Optional[int] = None):
    """q: (B, S, H, dh); k/v: (B, T, Kv, dh) -> (B, S, H, dh)."""
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / jnp.sqrt(dh)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    m = jnp.ones((s, t), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    scores = jnp.where(m[None, None, None], scores, -1e30)
    att = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", att, v)
    return out.reshape(b, s, h, dh)
