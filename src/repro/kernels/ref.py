"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def graph_agg_ref(h, idx, mask, w):
    """GLASU client sub-layer hotspot: masked-mean neighbor gather + matmul.

    h: (n_src, d); idx/mask: (n_dst, F); w: (d, d_out) -> (n_dst, d_out).
    """
    g = h[idx]                                     # (n_dst, F, d)
    s = jnp.sum(g * mask[..., None], axis=1)
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    return (s / denom) @ w


def flash_attention_ref(q, k, v, causal: bool = True,
                        window: Optional[int] = None):
    """q: (B, S, H, dh); k/v: (B, T, Kv, dh) -> (B, S, H, dh)."""
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / jnp.sqrt(dh)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    m = jnp.ones((s, t), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    scores = jnp.where(m[None, None, None], scores, -1e30)
    att = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", att, v)
    return out.reshape(b, s, h, dh)
