"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode — the kernel
body executes in Python/XLA for correctness validation; on TPU the same
``pallas_call`` lowers to Mosaic. The switch is automatic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from .flash_attention import flash_attention_pallas
from .graph_agg import graph_agg_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None):
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=_interpret())


@jax.jit
def graph_agg(h, idx, mask, w):
    return graph_agg_pallas(h, idx, mask, w, interpret=_interpret())
