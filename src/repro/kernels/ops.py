"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode — the kernel
body executes in Python/XLA for correctness validation; on TPU the same
``pallas_call`` lowers to Mosaic. The switch is automatic.

``use_pallas=True`` in the GLASU core routes all three paper backbones
(GCN, GCNII, GAT) through these fused kernels. ``pallas_call`` has no
reverse-mode rule, and GLASU *trains* through the client sub-layers
(Alg 4's LocalUpdate), so each graph op carries a ``custom_vjp``: the
forward pass is the fused kernel, the backward pass differentiates the
pure-jnp oracle in ``kernels/ref.py`` (bit-identical math, XLA-fused).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..graph.csr_plan import csr_slot_map, plan_csr_slabs
from . import ref
from .flash_attention import flash_attention_pallas
from .graph_agg import (ell_to_slabs, gat_layer_pallas, gcnii_layer_pallas,
                        graph_agg_csr_pallas, graph_agg_pallas)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# Density heuristic for ``graph_agg``: the one-hot scatter matrix costs
# O(n_dst·n_src·d) MXU work and (128, n_src) VMEM per tile — unbeatable
# while the sampler caps n_src at size_cap (512), hopeless at graph scale.
# Above this source-set size the padded-fanout tables are re-laid out as
# CSR edge slabs in-trace (``ell_to_slabs``) and the segment-sum kernel
# runs instead. The threshold is deliberately ABOVE every shipped profile
# (largest eval source set: reddit, 8192 rows), so all existing golden /
# conformance fixtures stay on the dense path bitwise; kernel_bench
# measures the true crossover per shape and gates that CSR wins above it.
CSR_DISPATCH_MIN_SRC = 16384


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None):
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=_interpret())


# ---------------------------------------------------------------- graph ops
@jax.custom_vjp
def _graph_agg(h, idx, mask, w):
    return graph_agg_pallas(h, idx, mask, w, interpret=_interpret())


def _graph_agg_fwd(h, idx, mask, w):
    out = graph_agg_pallas(h, idx, mask, w, interpret=_interpret())
    return out, (h, idx, mask, w)


def _graph_agg_bwd(res, g):
    _, vjp = jax.vjp(ref.graph_agg_ref, *res)
    return vjp(g)


_graph_agg.defvjp(_graph_agg_fwd, _graph_agg_bwd)


# sparse twin of ``_graph_agg``: same (h, idx, mask, w) contract, forward
# re-lays the fanout tables out as CSR edge slabs and runs the segment-sum
# kernel; backward differentiates the SAME dense oracle (identical algebra,
# so dense- and CSR-dispatched training produce matching gradients)
@jax.custom_vjp
def _graph_agg_sparse(h, idx, mask, w):
    idx_s, seg_s, ew_s, n_dst = ell_to_slabs(idx, mask)
    return graph_agg_csr_pallas(h, idx_s, seg_s, ew_s, w, n_dst,
                                interpret=_interpret())


def _graph_agg_sparse_fwd(h, idx, mask, w):
    return _graph_agg_sparse(h, idx, mask, w), (h, idx, mask, w)


_graph_agg_sparse.defvjp(_graph_agg_sparse_fwd, _graph_agg_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _graph_agg_csr(h, idx_slab, seg_slab, ew_slab, w, n_dst):
    return graph_agg_csr_pallas(h, idx_slab, seg_slab, ew_slab, w, n_dst,
                                interpret=_interpret())


def _graph_agg_csr_fwd(h, idx_slab, seg_slab, ew_slab, w, n_dst):
    out = graph_agg_csr_pallas(h, idx_slab, seg_slab, ew_slab, w, n_dst,
                               interpret=_interpret())
    return out, (h, idx_slab, seg_slab, ew_slab, w)


def _graph_agg_csr_bwd(n_dst, res, g):
    fn = lambda *a: ref.csr_slab_ref(*a, n_dst)
    _, vjp = jax.vjp(fn, *res)
    return vjp(g)


_graph_agg_csr.defvjp(_graph_agg_csr_fwd, _graph_agg_csr_bwd)


@functools.partial(jax.jit, static_argnames=("n_dst",))
def _graph_agg_csr_jit(h, idx_slab, seg_slab, ew_slab, w, n_dst):
    return _graph_agg_csr(h, idx_slab, seg_slab, ew_slab, w, n_dst)


def graph_agg_csr(h, indptr, indices, w, edge_weight=None):
    """Sparse aggregation over a host CSR: segment-mean of ``h`` rows per
    destination, fused with the weight matmul.

    ``indptr``/``indices`` are CONCRETE (numpy) — the slab planner runs on
    host, exactly like the sampler's table builds; the jitted kernel sees
    only the padded static-shape slab arrays (one compile per (shapes,
    n_dst) signature). Differentiable wrt ``h``/``w``/``edge_weight``; the
    backward pass differentiates ``ref.csr_slab_ref`` (the same segment-sum
    algebra, XLA-fused). Oracle: ``ref.graph_agg_csr_ref``.
    """
    idx_s, seg_s, ew_s, n_dst = plan_csr_slabs(indptr, indices)
    if edge_weight is not None:
        # keep the traced edge weights out of the host planner: scatter the
        # (nnz,) weights into the padded slab with the planner's slot map
        ew_s = _scatter_edge_weights(indptr, idx_s.shape[0], edge_weight)
    return _graph_agg_csr_jit(h, idx_s, seg_s, ew_s, w, n_dst)


def _scatter_edge_weights(indptr, total, edge_weight):
    """(nnz,) traced weights -> (total, 1) slab array via the concrete
    slot map (host planning in ``graph.csr_plan``, device scatter here)."""
    slot = csr_slot_map(indptr, total)
    ew = jnp.zeros((total,), jnp.float32)
    ew = ew.at[jnp.asarray(slot)].set(edge_weight.astype(jnp.float32))
    return ew[:, None]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _gcnii_layer(alpha, beta, h, h0, idx, mask, w, b):
    return gcnii_layer_pallas(h, h0, idx, mask, w, b, alpha=alpha, beta=beta,
                              interpret=_interpret())


def _gcnii_layer_fwd(alpha, beta, h, h0, idx, mask, w, b):
    out = gcnii_layer_pallas(h, h0, idx, mask, w, b, alpha=alpha, beta=beta,
                             interpret=_interpret())
    return out, (h, h0, idx, mask, w, b)


def _gcnii_layer_bwd(alpha, beta, res, g):
    fn = lambda *a: ref.gcnii_layer_ref(*a, alpha, beta)
    _, vjp = jax.vjp(fn, *res)
    return vjp(g)


_gcnii_layer.defvjp(_gcnii_layer_fwd, _gcnii_layer_bwd)


@jax.custom_vjp
def _gat_layer(h, idx, mask, w, a_src, a_dst, b):
    return gat_layer_pallas(h, idx, mask, w, a_src, a_dst, b,
                            interpret=_interpret())


def _gat_layer_fwd(h, idx, mask, w, a_src, a_dst, b):
    out = gat_layer_pallas(h, idx, mask, w, a_src, a_dst, b,
                           interpret=_interpret())
    return out, (h, idx, mask, w, a_src, a_dst, b)


def _gat_layer_bwd(res, g):
    _, vjp = jax.vjp(ref.gat_layer_ref, *res)
    return vjp(g)


_gat_layer.defvjp(_gat_layer_fwd, _gat_layer_bwd)


@jax.jit
def graph_agg(h, idx, mask, w):
    """Masked-mean neighbor gather fused with the weight matmul (GCN core).

    Dispatches on the STATIC source-set size: small sets (every training /
    eval profile shipped today) run the one-hot scatter-matrix kernel;
    sets at or above ``CSR_DISPATCH_MIN_SRC`` run the CSR segment-sum
    kernel over in-trace edge slabs. Both paths share the dense oracle's
    backward, and the decision is a trace-time shape check — no runtime
    branch, no retrace beyond the usual shape signature.
    """
    if h.shape[0] >= CSR_DISPATCH_MIN_SRC:
        return _graph_agg_sparse(h, idx, mask, w)
    return _graph_agg(h, idx, mask, w)


@functools.partial(jax.jit, static_argnames=("alpha", "beta"))
def gcnii_layer(h, h0, idx, mask, w, b, alpha: float, beta: float):
    """Fused GCNII sub-layer: gather-mean + initial residual + identity map."""
    return _gcnii_layer(alpha, beta, h, h0, idx, mask, w, b)


@jax.jit
def gat_layer(h, idx, mask, w, a_src, a_dst, b):
    """Fused multi-head GAT sub-layer: projection + masked attention + mix."""
    return _gat_layer(h, idx, mask, w, a_src, a_dst, b)
