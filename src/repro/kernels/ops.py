"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode — the kernel
body executes in Python/XLA for correctness validation; on TPU the same
``pallas_call`` lowers to Mosaic. The switch is automatic.

``use_pallas=True`` in the GLASU core routes all three paper backbones
(GCN, GCNII, GAT) through these fused kernels. ``pallas_call`` has no
reverse-mode rule, and GLASU *trains* through the client sub-layers
(Alg 4's LocalUpdate), so each graph op carries a ``custom_vjp``: the
forward pass is the fused kernel, the backward pass differentiates the
pure-jnp oracle in ``kernels/ref.py`` (bit-identical math, XLA-fused).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from . import ref
from .flash_attention import flash_attention_pallas
from .graph_agg import gat_layer_pallas, gcnii_layer_pallas, graph_agg_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None):
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=_interpret())


# ---------------------------------------------------------------- graph ops
@jax.custom_vjp
def _graph_agg(h, idx, mask, w):
    return graph_agg_pallas(h, idx, mask, w, interpret=_interpret())


def _graph_agg_fwd(h, idx, mask, w):
    out = graph_agg_pallas(h, idx, mask, w, interpret=_interpret())
    return out, (h, idx, mask, w)


def _graph_agg_bwd(res, g):
    _, vjp = jax.vjp(ref.graph_agg_ref, *res)
    return vjp(g)


_graph_agg.defvjp(_graph_agg_fwd, _graph_agg_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _gcnii_layer(alpha, beta, h, h0, idx, mask, w, b):
    return gcnii_layer_pallas(h, h0, idx, mask, w, b, alpha=alpha, beta=beta,
                              interpret=_interpret())


def _gcnii_layer_fwd(alpha, beta, h, h0, idx, mask, w, b):
    out = gcnii_layer_pallas(h, h0, idx, mask, w, b, alpha=alpha, beta=beta,
                             interpret=_interpret())
    return out, (h, h0, idx, mask, w, b)


def _gcnii_layer_bwd(alpha, beta, res, g):
    fn = lambda *a: ref.gcnii_layer_ref(*a, alpha, beta)
    _, vjp = jax.vjp(fn, *res)
    return vjp(g)


_gcnii_layer.defvjp(_gcnii_layer_fwd, _gcnii_layer_bwd)


@jax.custom_vjp
def _gat_layer(h, idx, mask, w, a_src, a_dst, b):
    return gat_layer_pallas(h, idx, mask, w, a_src, a_dst, b,
                            interpret=_interpret())


def _gat_layer_fwd(h, idx, mask, w, a_src, a_dst, b):
    out = gat_layer_pallas(h, idx, mask, w, a_src, a_dst, b,
                           interpret=_interpret())
    return out, (h, idx, mask, w, a_src, a_dst, b)


def _gat_layer_bwd(res, g):
    _, vjp = jax.vjp(ref.gat_layer_ref, *res)
    return vjp(g)


_gat_layer.defvjp(_gat_layer_fwd, _gat_layer_bwd)


@jax.jit
def graph_agg(h, idx, mask, w):
    """Masked-mean neighbor gather fused with the weight matmul (GCN core)."""
    return _graph_agg(h, idx, mask, w)


@functools.partial(jax.jit, static_argnames=("alpha", "beta"))
def gcnii_layer(h, h0, idx, mask, w, b, alpha: float, beta: float):
    """Fused GCNII sub-layer: gather-mean + initial residual + identity map."""
    return _gcnii_layer(alpha, beta, h, h0, idx, mask, w, b)


@jax.jit
def gat_layer(h, idx, mask, w, a_src, a_dst, b):
    """Fused multi-head GAT sub-layer: projection + masked attention + mix."""
    return _gat_layer(h, idx, mask, w, a_src, a_dst, b)
