"""State-space sequence blocks: Mamba2 (SSD) and RWKV6 (Finch).

Both are TPU-adapted:
  * Mamba2 uses the chunked SSD formulation — intra-chunk quadratic attention
    (MXU-friendly (chunk x chunk) matmuls) + inter-chunk state passing via
    ``lax.scan`` — instead of the CUDA selective-scan kernel. Constant-size
    state makes long_500k decode native.
  * RWKV6 time-mix keeps a (H, dk, dv) matrix state with data-dependent decay
    w_t; training runs a ``lax.scan`` over time, decode is an O(1) update.

Shapes follow the released models; weights are plain dict pytrees.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import BATCH, dense_init, shard, wcol, wrow


# ---------------------------------------------------------------------- Mamba2
def mamba2_init(key, d_model, d_state, n_heads, d_head, d_conv=4,
                expand=2, dtype=jnp.float32):
    d_inner = n_heads * d_head
    ks = jax.random.split(key, 5)
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "w_in": dense_init(ks[0], d_model,
                           2 * d_inner + 2 * d_state + n_heads, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner + 2 * d_state))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner + 2 * d_state,), dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),          # A = -exp(A_log)
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "w_out": dense_init(ks[2], d_inner, d_model, dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, C), w: (K, C)."""
    k = w.shape[0]
    pads = [jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :x.shape[1]] for i in range(k)]
    out = sum(pads[i] * w[k - 1 - i] for i in range(k))
    return out + b


def _ssd_chunk_scan(xh, bmat, cmat, dt, a_per_head, chunk: int):
    """Chunked SSD (Mamba2 paper §6): returns y of shape (B, S, H, P).

    xh: (B,S,H,P) inputs; bmat/cmat: (B,S,N); dt: (B,S,H); a: (H,) negative.
    State: (B,H,P,N).
    """
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    nc = s // chunk
    xs = xh.reshape(b, nc, chunk, h, p)
    bs = bmat.reshape(b, nc, chunk, n)
    cs = cmat.reshape(b, nc, chunk, n)
    dts = dt.reshape(b, nc, chunk, h)

    # per-step log decay: da = dt * a  (negative)
    da = dts * a_per_head                                    # (B,NC,L,H)
    cum = jnp.cumsum(da, axis=2)                             # inclusive
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,NC,Lq,Lk,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk (diagonal blocks): y_intra = (C B^T ∘ L) (dt x)
    dtx = xs * dts[..., None]                                # (B,NC,L,H,P)
    cb = jnp.einsum("bnli,bnmi->bnlm", cs, bs)               # (B,NC,Lq,Lk)
    y_intra = jnp.einsum("bnlm,bnlmh,bnmhp->bnlhp", cb, lmat, dtx)

    # chunk summaries for the inter-chunk recurrence
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,NC,L,H)
    state_chunk = jnp.einsum("bnli,bnlh,bnlhp->bnhpi",
                             bs, decay_to_end * dts, xs)     # (B,NC,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B,NC,H)

    def scan_fn(carry, inp):
        dec, upd = inp                                       # carry: (B,H,P,N)
        out = carry
        carry = carry * dec[:, :, None, None] + upd
        return carry, out

    init = jnp.zeros((b, h, p, n), jnp.float32)
    _, states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(chunk_decay, 1, 0),
         jnp.moveaxis(state_chunk.astype(jnp.float32), 1, 0)))
    # states[i] = state entering chunk i
    states = jnp.moveaxis(states, 0, 1)                      # (B,NC,H,P,N)

    decay_from_start = jnp.exp(cum)                          # (B,NC,L,H)
    y_inter = jnp.einsum("bnli,bnhpi,bnlh->bnlhp", cs, states, decay_from_start)
    return (y_intra + y_inter).reshape(b, s, h, p)


def mamba2_forward(p, x, d_state, n_heads, d_head, chunk: int = 256):
    """Training/prefill forward. x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    d_inner = n_heads * d_head
    zxbcdt = x @ wcol(p["w_in"])
    z, xr, bmat, cmat, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + d_state,
                 2 * d_inner + 2 * d_state], axis=-1)
    conv_in = jnp.concatenate([xr, bmat, cmat], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xr = conv_out[..., :d_inner]
    bmat = conv_out[..., d_inner:d_inner + d_state]
    cmat = conv_out[..., d_inner + d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    a = -jnp.exp(p["A_log"])                                      # (H,)
    xh = xr.reshape(b, s, n_heads, d_head)
    xh = shard(xh, BATCH, None, "model", None)
    y = _ssd_chunk_scan(xh, bmat.astype(jnp.float32), cmat.astype(jnp.float32),
                        dt, a, min(chunk, s))
    y = y + xh * p["D"][None, None, :, None]
    y = (y.reshape(b, s, d_inner) * jax.nn.silu(z)).astype(x.dtype)
    return y @ wrow(p["w_out"])


class Mamba2Cache(NamedTuple):
    state: jnp.ndarray       # (B, H, P, N)
    conv: jnp.ndarray        # (B, K-1, conv_channels) last inputs


def mamba2_cache_init(batch, n_heads, d_head, d_state, conv_channels,
                      d_conv=4, dtype=jnp.float32):
    return Mamba2Cache(jnp.zeros((batch, n_heads, d_head, d_state), dtype),
                       jnp.zeros((batch, d_conv - 1, conv_channels), dtype))


def mamba2_decode(p, x, cache: Mamba2Cache, d_state, n_heads, d_head):
    """One-token recurrent step: h' = exp(dt a) h + dt B x. x: (B, 1, D)."""
    b, _, d = x.shape
    d_inner = n_heads * d_head
    zxbcdt = x[:, 0] @ p["w_in"]
    z, xr, bmat, cmat, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + d_state,
                 2 * d_inner + 2 * d_state], axis=-1)
    conv_in = jnp.concatenate([xr, bmat, cmat], axis=-1)       # (B, C)
    hist = jnp.concatenate([cache.conv, conv_in[:, None]], axis=1)  # (B,K,C)
    k = p["conv_w"].shape[0]
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, p["conv_w"])
                           + p["conv_b"])
    xr = conv_out[:, :d_inner]
    bmat = conv_out[:, d_inner:d_inner + d_state].astype(jnp.float32)
    cmat = conv_out[:, d_inner + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    a = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * a)                                         # (B,H)
    xh = xr.reshape(b, n_heads, d_head).astype(jnp.float32)
    upd = jnp.einsum("bhp,bn,bh->bhpn", xh, bmat, dt)
    state = cache.state.astype(jnp.float32) * dec[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, cmat) + xh * p["D"][None, :, None]
    y = (y.reshape(b, d_inner) * jax.nn.silu(z)).astype(x.dtype)
    out = (y @ p["w_out"])[:, None]
    return out, Mamba2Cache(state.astype(cache.state.dtype), hist[:, 1:])


# ---------------------------------------------------------------------- RWKV6
def rwkv6_init(key, d_model, n_heads, d_head, lora_rank=64, dtype=jnp.float32):
    ks = jax.random.split(key, 10)
    d_inner = n_heads * d_head
    return {
        # token-shift mix coefficients for r,k,v,w,g
        "mix": (jax.random.uniform(ks[0], (5, d_model)) * 0.5 + 0.25).astype(dtype),
        "wr": dense_init(ks[1], d_model, d_inner, dtype=dtype),
        "wk": dense_init(ks[2], d_model, d_inner, dtype=dtype),
        "wv": dense_init(ks[3], d_model, d_inner, dtype=dtype),
        "wg": dense_init(ks[4], d_model, d_inner, dtype=dtype),
        # data-dependent decay LoRA: w_t = exp(-exp(base + tanh(x A) B))
        "w_base": jnp.full((d_inner,), -1.0, jnp.float32),
        "w_A": dense_init(ks[5], d_model, lora_rank, dtype=dtype),
        "w_B": dense_init(ks[6], lora_rank, d_inner, scale=0.01, dtype=dtype),
        "u": (jax.random.normal(ks[7], (n_heads, d_head)) * 0.1).astype(jnp.float32),
        "ln_x": {"g": jnp.ones((d_inner,), dtype)},
        "wo": dense_init(ks[8], d_inner, d_model, dtype=dtype),
    }


def _rwkv_mix(p, x, x_prev):
    """Token shift: lerp between x_t and x_{t-1} per projection."""
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    outs = []
    for i in range(5):
        m = p["mix"][i]
        outs.append(x * m + shifted * (1 - m))
    return outs  # xr, xk, xv, xw, xg


def rwkv6_forward(p, x, n_heads, d_head):
    """Training/prefill: scan the WKV recurrence over time. x: (B,S,D)."""
    b, s, d = x.shape
    x_prev0 = jnp.zeros((b, d), x.dtype)
    xr, xk, xv, xw, xg = _rwkv_mix(p, x, x_prev0)
    r = (xr @ wcol(p["wr"])).reshape(b, s, n_heads, d_head)
    k = (xk @ wcol(p["wk"])).reshape(b, s, n_heads, d_head)
    v = (xv @ wcol(p["wv"])).reshape(b, s, n_heads, d_head)
    g = jax.nn.silu(xg @ wcol(p["wg"]))
    logw = -jnp.exp(p["w_base"]
                    + (jnp.tanh(xw @ p["w_A"]) @ p["w_B"]).astype(jnp.float32))
    logw = logw.reshape(b, s, n_heads, d_head)
    r = shard(r, BATCH, None, "model", None)

    chunk = 32
    if s % chunk == 0 and s >= chunk:
        # r/k/v stay in the model dtype (bf16): full-sequence f32 copies of
        # these were the next-largest HBM term after chunking (§Perf log)
        outs = _rwkv6_wkv_chunked(r, k, v, logw, p["u"], chunk)
        y = outs.reshape(b, s, n_heads * d_head).astype(x.dtype)
    else:
        def step(state, inp):
            rt, kt, vt, lwt = inp                             # (B,H,dk/dv)
            # out_t = r · (S + u k v^T); S' = diag(w) S + k v^T
            kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
            out = jnp.einsum("bhk,bhkv->bhv", rt,
                             state + p["u"][None, :, :, None] * kv)
            state = state * jnp.exp(lwt)[..., None] + kv
            return state, out

        init = jnp.zeros((b, n_heads, d_head, d_head), jnp.float32)
        seq = (jnp.moveaxis(r, 1, 0).astype(jnp.float32),
               jnp.moveaxis(k, 1, 0).astype(jnp.float32),
               jnp.moveaxis(v, 1, 0).astype(jnp.float32),
               jnp.moveaxis(logw, 1, 0))
        _, outs = jax.lax.scan(step, init, seq)
        y = jnp.moveaxis(outs, 0, 1).reshape(b, s, n_heads * d_head).astype(x.dtype)
    # group-norm-ish output norm then gate
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True, dtype=jnp.float32)
    y = (y * jax.lax.rsqrt(var + 1e-6).astype(x.dtype)) * p["ln_x"]["g"]
    return (y * g) @ wrow(p["wo"])


def _rwkv6_wkv_chunked(r, k, v, logw, u, chunk: int = 16):
    """Chunked WKV recurrence (Perf iterations 1+3 for rwkv6 train:
    per-token scan was 5000x memory-bound — the (B,H,dk,dv) state was read
    and written through HBM every token; chunking updates it once per
    ``chunk`` tokens, and the FACTORED intra-chunk form
        scores_tj = <r_t exp(cum_{t-1}), k_j exp(-cum_j)>
    avoids materializing the (B,C,C,H,dk) pairwise-decay tensor.

    logw is clamped to >= -3.5 so exp(-cum) stays inside f32 range over a
    16-token chunk (per-step decays below e^-3.5 are indistinguishable from
    zero after a few steps anyway). Semantics (validated by unit test):
        out_t = r_t . (S_{t-1} + u k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    b, s, h, dk = k.shape
    dv = v.shape[-1]
    nc = s // chunk
    rs = jnp.moveaxis(r.reshape(b, nc, chunk, h, dk), 1, 0)
    ks = jnp.moveaxis(k.reshape(b, nc, chunk, h, dk), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, nc, chunk, h, dv), 1, 0)
    ws = jnp.moveaxis(logw.reshape(b, nc, chunk, h, dk), 1, 0)

    tri_lt = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)   # j < t

    def one_chunk(state, inp):
        rc, kc, vc, wc = inp                   # (B,C,H,dk|dv)
        rcf = rc.astype(jnp.float32)
        kcf = kc.astype(jnp.float32)
        vcf = vc.astype(jnp.float32)
        wcl = jnp.maximum(wc, -3.5)
        cum = jnp.cumsum(wcl, axis=1)          # inclusive log-decay
        cum_prev = cum - wcl                   # exclusive (C_{t-1})
        # mid-centering keeps every factored exponent <= (chunk/2)*3.5 < 88
        # (f32 exp range), which makes chunk=32 provably overflow-safe
        c0 = cum[:, chunk // 2 - 1:chunk // 2]
        a = rcf * jnp.exp(cum_prev - c0)       # centered: intra scores only
        bq = kcf * jnp.exp(c0 - cum)
        scores = jnp.einsum("bthk,bjhk->bhtj", a, bq)
        # where-mask, not multiply: masked (j >= t) entries can overflow to
        # inf under extreme decays, and inf * 0 would poison the output
        scores = jnp.where(tri_lt[None, None], scores, 0.0)
        # diagonal bonus term u
        diag = jnp.einsum("bthk,bthk,hk->bth", rcf, kcf, u)
        out = jnp.einsum("bhtj,bjhv->bthv", scores, vcf)
        out = out + diag[..., None] * vcf
        # incoming state contribution (UNcentered decay, exponent <= 0)
        a_state = rcf * jnp.exp(cum_prev)
        out = out + jnp.einsum("bthk,bhkv->bthv", a_state, state)
        # chunk-end state
        decay_end = jnp.exp(cum[:, -1:] - cum)                # (B,C,H,dk)
        new_state = (state * jnp.exp(cum[:, -1])[..., None]
                     + jnp.einsum("bjhk,bjhv->bhkv", kcf * decay_end, vcf))
        return new_state, out

    init = jnp.zeros((b, h, dk, dv), jnp.float32)
    _, outs = jax.lax.scan(one_chunk, init, (rs, ks, vs, ws))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, dv)


class RWKV6Cache(NamedTuple):
    state: jnp.ndarray       # (B, H, dk, dv) wkv state
    x_prev: jnp.ndarray      # (B, D) last input (token shift)


def rwkv6_cache_init(batch, n_heads, d_head, d_model, dtype=jnp.float32):
    return RWKV6Cache(jnp.zeros((batch, n_heads, d_head, d_head), jnp.float32),
                      jnp.zeros((batch, d_model), dtype))


def rwkv6_decode(p, x, cache: RWKV6Cache, n_heads, d_head):
    """O(1) decode step. x: (B, 1, D)."""
    b, _, d = x.shape
    xr, xk, xv, xw, xg = _rwkv_mix(p, x, cache.x_prev)
    r = (xr @ p["wr"]).reshape(b, n_heads, d_head).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(b, n_heads, d_head).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(b, n_heads, d_head).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])[:, 0]
    w = jnp.exp(-jnp.exp(p["w_base"]
                         + (jnp.tanh(xw @ p["w_A"]) @ p["w_B"]).astype(jnp.float32)))
    w = w.reshape(b, n_heads, d_head)
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    out = jnp.einsum("bhk,bhkv->bhv", r,
                     cache.state + p["u"][None, :, :, None] * kv)
    state = cache.state * w[..., None] + kv
    y = out.reshape(b, n_heads * d_head).astype(x.dtype)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True, dtype=jnp.float32)
    y = (y * jax.lax.rsqrt(var + 1e-6).astype(x.dtype)) * p["ln_x"]["g"]
    out = ((y * g) @ p["wo"])[:, None]
    return out, RWKV6Cache(state, x[:, 0])


def rwkv6_channel_mix_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"mix": (jax.random.uniform(k1, (2, d_model)) * 0.5 + 0.25).astype(dtype),
            "wk": dense_init(k2, d_model, d_ff, dtype=dtype),
            "wv": dense_init(k3, d_ff, d_model, dtype=dtype)}


def rwkv6_channel_mix(p, x, x_prev=None):
    b = x.shape[0]
    if x_prev is None:
        x_prev = jnp.zeros((b, x.shape[-1]), x.dtype)
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xk = x * p["mix"][0] + shifted * (1 - p["mix"][0])
    h = jnp.square(jax.nn.relu(xk @ wcol(p["wk"])))
    h = shard(h, BATCH, None, "model")
    return h @ wrow(p["wv"])
