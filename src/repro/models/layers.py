"""Shared neural-net layers for the architecture zoo (pure JAX, no flax).

Every module is an (init_fn, apply_fn) pair over plain dict pytrees. A light
sharding-constraint shim lets the same code run unsharded on CPU and under a
production mesh in launch/dryrun.py.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# ----------------------------------------------------------------- sharding shim
_MESH_STATE = threading.local()


@contextlib.contextmanager
def activation_mesh(mesh):
    """Activate sharding constraints inside model code (used by launch/)."""
    prev = getattr(_MESH_STATE, "mesh", None)
    _MESH_STATE.mesh = mesh
    try:
        yield
    finally:
        _MESH_STATE.mesh = prev


def current_mesh():
    return getattr(_MESH_STATE, "mesh", None)


def shard(x, *spec):
    """with_sharding_constraint if a mesh is active, else identity.

    Axis names absent from the active mesh are dropped (lets the same model
    code serve (data, model) and (pod, data, model) meshes), and axes that do
    not evenly divide the dim are dropped (e.g. kv=8 heads on a 16-way model
    axis) — an indivisible constraint triggers involuntary SPMD remat.
    """
    mesh = current_mesh()
    if mesh is None:
        return x

    def clean(dim, s):
        if isinstance(s, (tuple, list)):
            kept, size = [], 1
            for a in s:
                if a in mesh.axis_names and dim % (size * mesh.shape[a]) == 0:
                    kept.append(a)
                    size *= mesh.shape[a]
            return tuple(kept) or None
        if s is None or s not in mesh.axis_names or dim % mesh.shape[s]:
            return None
        return s

    cleaned = tuple(clean(d, s) for d, s in zip(x.shape, spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*cleaned)))


BATCH = ("pod", "data")   # canonical batch sharding axes


def wcol(w):
    """Use-site constraint: column-parallel weight (d_in, out->'model').

    Weights are STORED FSDP-sharded ('data' on a free dim); constraining the
    use to the pure-TP layout makes GSPMD all-gather the (small) weight once
    per use and reduce-scatter its gradient — instead of partial-sum
    all-reducing the (large) activations per matmul (measured 11.8 TB/step of
    all-reduce on llama3-405b train_4k).
    """
    spec = [None] * (w.ndim - 1) + ["model"]
    return shard(w, *spec)


def wrow(w):
    """Use-site constraint: row-parallel weight ('model' on d_in)."""
    spec = [None] * (w.ndim - 2) + ["model", None]
    return shard(w, *spec)


def shard_seq(x):
    """Megatron-SP-style residual-stream constraint: (B, S, D) with the
    SEQUENCE dim sharded over 'model'. Cuts the saved scan-residual stacks by
    the TP degree (the qkv/mlp matmuls all-gather internally). No-ops when
    the mesh is absent or S does not divide."""
    mesh = current_mesh()
    if mesh is None or x.ndim != 3:
        return x
    tp = mesh.shape.get("model", 1)
    if tp <= 1 or x.shape[1] % tp or x.shape[1] <= 1:
        return shard(x, BATCH, None, None)
    return shard(x, BATCH, "model", None)


# ----------------------------------------------------------------------- init
def dense_init(key, d_in, d_out, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab, d, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------- norms
def rmsnorm_init(d, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    # NOTE dtype discipline: f32 accumulation via reduce-with-convert. An
    # einsum(x, x, preferred_element_type=f32) variant leaks f32 cotangents
    # through the VJP and turns the whole backward pass f32 (measured +25 GB
    # on llama3-405b train_4k).
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * p["g"]


def layernorm_init(d, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
            * p["g"] + p["b"])


# ----------------------------------------------------------------------- rope
def rope_freqs(d_head: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, dh) rotated pairwise; positions: (..., S).

    cos/sin are computed in f32 but cast to x.dtype BEFORE the multiply —
    an f32 product materializes full-sequence f32 q/k buffers (measured
    +4.3 GB/buffer on llama3-405b train_4k).
    """
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(ang).astype(x.dtype)[..., None, :]    # broadcast over heads
    sin = jnp.sin(ang).astype(x.dtype)[..., None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


# ------------------------------------------------------------------------ mlp
def swiglu_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, d_model, d_ff, dtype=dtype),
            "w_up": dense_init(k2, d_model, d_ff, dtype=dtype),
            "w_down": dense_init(k3, d_ff, d_model, dtype=dtype)}


def swiglu(p, x):
    h = jax.nn.silu(x @ wcol(p["w_gate"])) * (x @ wcol(p["w_up"]))
    h = shard(h, BATCH, None, "model")
    return h @ wrow(p["w_down"])


def gelu_mlp_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"w_up": dense_init(k1, d_model, d_ff, dtype=dtype),
            "b_up": jnp.zeros((d_ff,), dtype),
            "w_down": dense_init(k2, d_ff, d_model, dtype=dtype),
            "b_down": jnp.zeros((d_model,), dtype)}


def gelu_mlp(p, x):
    h = jax.nn.gelu(x @ wcol(p["w_up"]) + p["b_up"])
    h = shard(h, BATCH, None, "model")
    return h @ wrow(p["w_down"]) + p["b_down"]
