"""Attention variants: GQA/MHA/MQA, sliding-window, MLA (DeepSeek), cross-attn.

Prefill paths take (B, S, D); decode paths take one token with a KV cache —
either a full-length cache or a ring buffer when a sliding window is set
(the long_500k memory story). All matmuls keep the head axis last-but-one so
the 'model' mesh axis shards heads.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import (BATCH, apply_rope, dense_init, rmsnorm, rmsnorm_init,
                     shard, wcol, wrow)

NEG_INF = -1e30


# ------------------------------------------------------------------------ GQA
def gqa_init(key, d_model, n_heads, n_kv, d_head, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d_model, n_heads * d_head, dtype=dtype),
        "wk": dense_init(k2, d_model, n_kv * d_head, dtype=dtype),
        "wv": dense_init(k3, d_model, n_kv * d_head, dtype=dtype),
        "wo": dense_init(k4, n_heads * d_head, d_model, dtype=dtype),
    }


def _split_heads(x, n, d):
    return x.reshape(*x.shape[:-1], n, d)


def _sdpa(q, k, v, mask):
    """q: (B,S,H,dh), k/v: (B,T,Kv,dh), mask: (B?,1?,S,T) bool -> (B,S,H,dh)."""
    b, s, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / jnp.sqrt(dh).astype(q.dtype)
    scores = jnp.where(mask[:, None, None] if mask.ndim == 3 else mask, scores, NEG_INF)
    att = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", att, v)
    return out.reshape(b, s, h, v.shape[-1])


def causal_mask(s, t=None, window: Optional[int] = None, offset: int = 0):
    """(1, 1, s, t) boolean mask; ``offset`` = absolute pos of query 0."""
    t = t if t is not None else s
    qpos = jnp.arange(s)[:, None] + offset
    kpos = jnp.arange(t)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m[None, None]


CHUNK_THRESHOLD = 1024
Q_CHUNK = 512


def _sdpa_chunked(q, k, v, causal: bool, window: Optional[int],
                  chunk: int = Q_CHUNK):
    """Memory-efficient attention: scan over query chunks so the live score
    block is (B, H, chunk, T) instead of (B, H, S, S) — the XLA analogue of
    flash attention's tiling (the Pallas kernel is the TPU-native version).
    With a sliding window only a (window + chunk) kv slice is touched."""
    b, s, h, dh = q.shape
    t = k.shape[1]
    pad = (-s) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // chunk
    qs = q.reshape(b, nq, chunk, h, dh)
    use_slice = window is not None and causal and (window + chunk) < t
    kv_span = min(window + chunk, t) if window is not None else t

    @jax.checkpoint
    def body(_, inp):
        qi, i = inp                                     # (B, chunk, H, dh)
        q_start = i * chunk
        if use_slice:
            lo = jnp.clip(q_start - window + 1, 0, t - kv_span)
            ki = jax.lax.dynamic_slice_in_dim(k, lo, kv_span, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, lo, kv_span, axis=1)
        else:
            lo, ki, vi = 0, k, v
        qpos = q_start + jnp.arange(chunk)[:, None]
        kpos = lo + jnp.arange(ki.shape[1])[None, :]
        m = kpos < t
        if causal:
            m &= kpos <= qpos
        if window is not None:
            m &= kpos > qpos - window
        out = _sdpa(qi, ki, vi, m[None, None])
        return None, out

    _, outs = jax.lax.scan(body, None,
                           (jnp.moveaxis(qs, 1, 0), jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * chunk, h, v.shape[-1])
    return out[:, :s]


def gqa_prefill(p, x, n_heads, n_kv, d_head, *, causal=True,
                window: Optional[int] = None, use_rope=True, rope_theta=10000.0,
                use_flash: bool = False):
    b, s, d = x.shape
    q = _split_heads(x @ wcol(p["wq"]), n_heads, d_head)
    k = _split_heads(x @ wcol(p["wk"]), n_kv, d_head)
    v = _split_heads(x @ wcol(p["wv"]), n_kv, d_head)
    q = shard(q, BATCH, None, "model", None)
    k = shard(k, BATCH, None, "model", None)
    if use_rope:
        pos = jnp.arange(s)[None]
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    if use_flash:
        from ..kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, window=window)
    elif s > CHUNK_THRESHOLD:
        out = _sdpa_chunked(q, k, v, causal, window)
    else:
        if causal:
            mask = causal_mask(s, window=window)
        else:
            mask = jnp.ones((1, 1, s, s), bool)
        out = _sdpa(q, k, v, mask)
    out = shard(out, BATCH, None, "model", None)
    return out.reshape(b, s, n_heads * d_head) @ wrow(p["wo"])


class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, C, Kv, dh) — C = seq_len or ring window
    v: jnp.ndarray
    pos: jnp.ndarray        # () int32: number of tokens already cached


def kv_cache_init(batch, capacity, n_kv, d_head, dtype, prefill_len: int = 0):
    """Fresh cache; ``prefill_len`` marks already-populated slots (dry-run)."""
    return KVCache(jnp.zeros((batch, capacity, n_kv, d_head), dtype),
                   jnp.zeros((batch, capacity, n_kv, d_head), dtype),
                   jnp.asarray(prefill_len, jnp.int32))


def gqa_decode(p, x, cache: KVCache, n_heads, n_kv, d_head, *, ring: bool = False,
               use_rope=True, rope_theta=10000.0):
    """One-token decode step. x: (B, 1, D) -> ((B, 1, D), new cache)."""
    b, _, d = x.shape
    cap = cache.k.shape[1]
    q = _split_heads(x @ wcol(p["wq"]), n_heads, d_head)
    k = _split_heads(x @ wcol(p["wk"]), n_kv, d_head)
    v = _split_heads(x @ wcol(p["wv"]), n_kv, d_head)
    pos = cache.pos
    if use_rope:
        pq = pos[None, None].astype(jnp.float32) * jnp.ones((b, 1))
        q = apply_rope(q, pq, rope_theta)
        k = apply_rope(k, pq, rope_theta)
    slot = (pos % cap) if ring else jnp.minimum(pos, cap - 1)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
    new_k = shard(new_k, BATCH, None, "model", None)
    new_v = shard(new_v, BATCH, None, "model", None)
    idx = jnp.arange(cap)
    if ring:
        # every slot holds one of the last ``cap`` tokens once pos >= cap
        valid = jnp.where(pos >= cap, jnp.ones_like(idx, bool), idx <= pos)
    else:
        valid = idx <= pos
    mask = valid[None, None, None, :]
    out = _sdpa(q, new_k, new_v, mask)
    out = out.reshape(b, 1, n_heads * d_head) @ wrow(p["wo"])
    return out, KVCache(new_k, new_v, pos + 1)


# ------------------------------------------------------------------------ MLA
def mla_init(key, d_model, n_heads, kv_lora, d_nope, d_rope, d_v,
             dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * (d_nope + d_rope), dtype=dtype),
        "w_dkv": dense_init(ks[1], d_model, kv_lora, dtype=dtype),
        "w_kr": dense_init(ks[2], d_model, d_rope, dtype=dtype),
        "kv_norm": rmsnorm_init(kv_lora, dtype),
        "w_uk": dense_init(ks[3], kv_lora, n_heads * d_nope, dtype=dtype),
        "w_uv": dense_init(ks[4], kv_lora, n_heads * d_v, dtype=dtype),
        "wo": dense_init(ks[5], n_heads * d_v, d_model, dtype=dtype),
    }


def mla_prefill(p, x, n_heads, kv_lora, d_nope, d_rope, d_v, *, causal=True,
                rope_theta=10000.0):
    b, s, _ = x.shape
    q = _split_heads(x @ wcol(p["wq"]), n_heads, d_nope + d_rope)
    q_nope, q_rope = q[..., :d_nope], q[..., d_nope:]
    pos = jnp.arange(s)[None]
    q_rope = apply_rope(q_rope, pos, rope_theta)
    latent = rmsnorm(p["kv_norm"], x @ p["w_dkv"])           # (B,S,kvl)
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], pos, rope_theta)
    k_nope = _split_heads(latent @ wcol(p["w_uk"]), n_heads, d_nope)
    v = _split_heads(latent @ wcol(p["w_uv"]), n_heads, d_v)
    q_nope = shard(q_nope, BATCH, None, "model", None)
    # fold the shared rope key into per-head keys: MLA scores become standard
    # MHA over concat(nope, rope) head dims -> reuse the chunked sdpa
    q_c = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_c = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, n_heads, d_rope))], axis=-1)
    if s > CHUNK_THRESHOLD:
        out = _sdpa_chunked(q_c, k_c, v, causal, None)
    else:
        mask = causal_mask(s) if causal else jnp.ones((1, 1, s, s), bool)
        out = _sdpa(q_c, k_c, v, mask)
    return out.reshape(b, s, n_heads * d_v) @ wrow(p["wo"])


class MLACache(NamedTuple):
    latent: jnp.ndarray     # (B, C, kv_lora)
    k_rope: jnp.ndarray     # (B, C, d_rope)
    pos: jnp.ndarray


def mla_cache_init(batch, capacity, kv_lora, d_rope, dtype, prefill_len=0):
    return MLACache(jnp.zeros((batch, capacity, kv_lora), dtype),
                    jnp.zeros((batch, capacity, d_rope), dtype),
                    jnp.asarray(prefill_len, jnp.int32))


def mla_decode(p, x, cache: MLACache, n_heads, kv_lora, d_nope, d_rope, d_v, *,
               rope_theta=10000.0):
    """Absorbed-matrix MLA decode: attention runs in the latent space."""
    b = x.shape[0]
    cap = cache.latent.shape[1]
    pos = cache.pos
    q = _split_heads(x @ wcol(p["wq"]), n_heads, d_nope + d_rope)  # (B,1,H,*)
    q_nope, q_rope = q[..., :d_nope], q[..., d_nope:]
    pq = pos[None, None].astype(jnp.float32) * jnp.ones((b, 1))
    q_rope = apply_rope(q_rope, pq, rope_theta)
    latent_t = rmsnorm(p["kv_norm"], x @ p["w_dkv"])          # (B,1,kvl)
    k_rope_t = apply_rope((x @ p["w_kr"])[:, :, None, :], pq, rope_theta)[:, :, 0]
    new_lat = jax.lax.dynamic_update_slice_in_dim(cache.latent, latent_t, pos, 1)
    new_kr = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, k_rope_t, pos, 1)
    w_uk = p["w_uk"].reshape(kv_lora, n_heads, d_nope)
    q_lat = jnp.einsum("bshd,lhd->bshl", q_nope, w_uk)        # absorb W_uk
    scores = (jnp.einsum("bshl,btl->bhst", q_lat, new_lat)
              + jnp.einsum("bshd,btd->bhst", q_rope, new_kr))
    scores = scores / jnp.sqrt(d_nope + d_rope).astype(x.dtype)
    valid = (jnp.arange(cap) <= pos)[None, None, None]
    scores = jnp.where(valid, scores, NEG_INF)
    att = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhst,btl->bshl", att, new_lat)        # (B,1,H,kvl)
    w_uv = p["w_uv"].reshape(kv_lora, n_heads, d_v)
    out = jnp.einsum("bshl,lhv->bshv", o_lat, w_uv)
    out = out.reshape(b, 1, n_heads * d_v) @ wrow(p["wo"])
    return out, MLACache(new_lat, new_kr, pos + 1)


# ---------------------------------------------------------------- cross attn
def cross_attn_init(key, d_model, n_heads, n_kv, d_head, dtype=jnp.float32):
    return gqa_init(key, d_model, n_heads, n_kv, d_head, dtype)


def cross_attn(p, x, enc_kv, n_heads, n_kv, d_head):
    """x: (B,S,D) queries over precomputed encoder (k, v)."""
    b, s, _ = x.shape
    q = _split_heads(x @ wcol(p["wq"]), n_heads, d_head)
    k, v = enc_kv
    t = k.shape[1]
    mask = jnp.ones((1, 1, s, t), bool)
    out = _sdpa(q, k, v, mask)
    return out.reshape(b, s, n_heads * d_head) @ wrow(p["wo"])


def cross_kv(p, enc_out, n_kv, d_head):
    k = _split_heads(enc_out @ wcol(p["wk"]), n_kv, d_head)
    v = _split_heads(enc_out @ wcol(p["wv"]), n_kv, d_head)
    return k, v
