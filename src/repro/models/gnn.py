"""GNN backbone sub-layers operating on sampled bipartite blocks.

Each function implements one *client* sub-layer (paper §3.1):

    H_m^+[l] = sigma( A(E_m[l]) · H_m[l] · W_m[l] )

where the sampled bipartite adjacency A(E_m[l]) is represented by
(gather_idx, gather_mask): for each output node i, column 0 is the self loop
and columns 1..F are sampled neighbors; aggregation is a masked mean
(GraphSAGE-mean normalization of the properly-scaled FastGCN submatrix).

Backbones (paper §5.4): GCN [3], GCNII [7] (two skip connections), GAT [6].
All are written for a SINGLE client on a SINGLE sampled block; the GLASU core
vmaps them over the client axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_mean(h, idx, mask):
    """Masked-mean neighborhood aggregation.

    h: (n_l, d); idx/mask: (n_{l+1}, F+1) -> (n_{l+1}, d)
    """
    g = h[idx]                                     # (n1, F+1, d)
    s = jnp.sum(g * mask[..., None], axis=1)
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    return s / denom


def init_gcn_layer(key, d_in, d_out):
    k1, _ = jax.random.split(key)
    scale = jnp.sqrt(2.0 / d_in)
    return {"W": jax.random.normal(k1, (d_in, d_out)) * scale,
            "b": jnp.zeros((d_out,))}


def gcn_layer(p, h, h0, idx, mask):
    agg = gather_mean(h, idx, mask)
    return jax.nn.relu(agg @ p["W"] + p["b"])


def init_gcnii_layer(key, d_in, d_out):
    assert d_in == d_out, "GCNII layers keep a constant width"
    return init_gcn_layer(key, d_in, d_out)


def gcnii_layer(p, h, h0, idx, mask, alpha: float = 0.1, beta: float = 0.5):
    """GCNII: initial-residual + identity-mapping skip connections."""
    agg = gather_mean(h, idx, mask)
    z = (1.0 - alpha) * agg + alpha * h0[idx[:, 0]]  # h0 at the output node set
    return jax.nn.relu((1.0 - beta) * z + beta * (z @ p["W"]) + p["b"])


def init_gat_layer(key, d_in, d_out, n_heads: int = 2):
    assert d_out % n_heads == 0
    dh = d_out // n_heads
    k1, k2, k3 = jax.random.split(key, 3)
    scale = jnp.sqrt(2.0 / d_in)
    return {"W": jax.random.normal(k1, (d_in, n_heads, dh)) * scale,
            "a_src": jax.random.normal(k2, (n_heads, dh)) * 0.1,
            "a_dst": jax.random.normal(k3, (n_heads, dh)) * 0.1,
            "b": jnp.zeros((d_out,))}


def gat_layer(p, h, h0, idx, mask):
    """Multi-head GAT over the sampled fanout (masked softmax attention)."""
    n_heads, dh = p["a_src"].shape
    wh = jnp.einsum("nd,dhk->nhk", h, p["W"])       # (n_l, H, dh)
    wh_nb = wh[idx]                                 # (n1, F+1, H, dh)
    wh_self = wh[idx[:, 0]]                         # (n1, H, dh)
    e = (jnp.einsum("nhk,hk->nh", wh_self, p["a_src"])[:, None, :]
         + jnp.einsum("nfhk,hk->nfh", wh_nb, p["a_dst"]))
    e = jax.nn.leaky_relu(e, negative_slope=0.2)
    e = jnp.where(mask[..., None] > 0, e, -1e9)
    att = jax.nn.softmax(e, axis=1) * mask[..., None]
    out = jnp.einsum("nfh,nfhk->nhk", att, wh_nb)
    out = out.reshape(out.shape[0], n_heads * dh)
    return jax.nn.elu(out + p["b"])


BACKBONES = {
    "gcn": (init_gcn_layer, gcn_layer),
    "gcnii": (init_gcnii_layer, gcnii_layer),
    "gat": (init_gat_layer, gat_layer),
}
