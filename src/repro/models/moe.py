"""Mixture-of-Experts layer: top-k router, shared experts, capacity dispatch.

TPU-native design: tokens are sorted by assigned expert and packed into a
static (E, C) slot grid (capacity-based, MaxText-style), so expert compute is
one batched einsum that the 'model' mesh axis shards over experts. Dropped
tokens (over capacity) fall back to the shared-expert/residual path, matching
standard capacity-factor semantics. A load-balance auxiliary loss (Switch-
style) is returned for the training objective.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import BATCH, dense_init, shard, swiglu, swiglu_init


def _wexp(w):
    """Expert weights at use: ('model' on E, rest gathered from FSDP)."""
    return shard(w, "model", None, None)


def moe_init(key, d_model, d_ff_expert, n_experts, n_shared, d_ff_shared,
             dtype=jnp.float32):
    k_router, k_e, k_s = jax.random.split(key, 3)
    ke = jax.random.split(k_e, 3)
    scale = (2.0 / (d_model + d_ff_expert)) ** 0.5
    p = {
        "router": dense_init(k_router, d_model, n_experts, scale=0.02, dtype=jnp.float32),
        "w_gate": (jax.random.normal(ke[0], (n_experts, d_model, d_ff_expert)) * scale).astype(dtype),
        "w_up": (jax.random.normal(ke[1], (n_experts, d_model, d_ff_expert)) * scale).astype(dtype),
        "w_down": (jax.random.normal(ke[2], (n_experts, d_ff_expert, d_model)) * scale).astype(dtype),
    }
    if n_shared > 0:
        p["shared"] = swiglu_init(k_s, d_model, d_ff_shared, dtype)
    return p


class MoEStats(NamedTuple):
    aux_loss: jnp.ndarray       # Switch load-balance loss
    dropped_frac: jnp.ndarray   # fraction of (token, k) routes over capacity


def moe_apply(p, x, n_experts: int, top_k: int, capacity_factor: float = 1.25,
              router_dtype=jnp.float32):
    """x: (B, S, D) -> (y, MoEStats). Capacity C = ceil(T*k/E * factor)."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(router_dtype) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)       # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)     # renormalize top-k

    # Switch aux loss: E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    one_hot_top1 = jax.nn.one_hot(expert_ids[:, 0], n_experts, dtype=router_dtype)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = n_experts * jnp.sum(me * ce)

    # ---- sort-based dispatch into a static (E, C) slot grid
    cap = int(max(1, -(-t * top_k // n_experts) * capacity_factor))
    flat_expert = expert_ids.reshape(-1)                       # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), top_k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert)                           # stable in jnp
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # position within expert group
    first = jnp.searchsorted(se, jnp.arange(n_experts))        # group starts
    pos_in_e = jnp.arange(t * top_k) - first[se]
    keep = pos_in_e < cap
    # over-capacity routes go out of bounds and are dropped by mode="drop"
    slot = jnp.where(keep, se * cap + pos_in_e, n_experts * cap)  # (T*k,)

    # scatter token ids (+1, 0 = empty) into slots
    slot_token = jnp.zeros((n_experts * cap,), jnp.int32)
    slot_gate = jnp.zeros((n_experts * cap,), x.dtype)
    slot_token = slot_token.at[slot].set(st + 1, mode="drop")
    slot_gate = slot_gate.at[slot].set(sg.astype(x.dtype), mode="drop")
    gathered = xt[jnp.maximum(slot_token - 1, 0)]              # (E*C, D)
    gathered = gathered * (slot_token > 0)[:, None].astype(x.dtype)
    xe = gathered.reshape(n_experts, cap, d)
    # experts over 'model', CAPACITY over 'data': without the data sharding
    # every data rank replicates the full expert matmuls (measured 16x
    # overcompute on deepseek-v2 train_4k — §Perf bonus iteration)
    xe = shard(xe, "model", "data", None)

    # ---- expert computation (SwiGLU), sharded over experts x capacity
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, _wexp(p["w_gate"])))
    h = h * jnp.einsum("ecd,edf->ecf", xe, _wexp(p["w_up"]))
    h = shard(h, "model", "data", None)
    ye = jnp.einsum("ecf,efd->ecd", h,
                    _wexp(p["w_down"])).reshape(n_experts * cap, d)

    # ---- weighted scatter back to tokens
    y = jnp.zeros((t, d), x.dtype)
    y = y.at[jnp.maximum(slot_token - 1, 0)].add(ye * slot_gate[:, None],
                                                 mode="drop")
    y = y.reshape(b, s, d)
    y = shard(y, BATCH, None, None)

    if "shared" in p:
        y = y + swiglu(p["shared"], x)

    dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / (t * top_k)
    return y, MoEStats(aux.astype(jnp.float32), dropped)
