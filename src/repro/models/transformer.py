"""Architecture zoo assembly: decoder LMs (dense/MoE/SSM/hybrid), enc-dec,
and the GLASU vertical-split transformer (the paper's technique as a
first-class backbone feature).

Design rules:
  * every homogeneous layer stack is a ``lax.scan`` over stacked weights
    (keeps HLO O(1 layer) so 80 CPU dry-run compiles stay tractable);
  * decode paths scan the same stacks over stacked per-layer caches;
  * all client/shard-crossing points carry explicit sharding constraints.

GLASU-split mode (cfg.glasu): the hidden dimension is vertically partitioned
into M feature shards ("clients" on the 'model' mesh axis). Every
``sync_every``-th layer consumes the *gathered* full hidden state (concat
aggregation — one all-gather); all other layers are block-diagonal per client
and collective-free. This is the paper's lazy aggregation transplanted to a
transformer: K = L / sync_every aggregation layers out of L. Stale updates
(Q) are realized in the training step, which caches sync-layer activations
from the first microstep and replaces the collective in the remaining Q-1.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as attn
from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import (BATCH, dense_init, embed_init, rmsnorm, rmsnorm_init,
                     shard, shard_seq, swiglu, swiglu_init, wcol)


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# =====================================================================
# Block initializers (single layer; stacks are vmapped over layer keys)
# =====================================================================
def _init_attn(key, cfg: ArchConfig):
    if cfg.attn == "mla":
        return attn.mla_init(key, cfg.d_model, cfg.n_heads, cfg.kv_lora,
                             cfg.d_nope, cfg.d_rope, cfg.d_head, _dtype(cfg))
    return attn.gqa_init(key, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head,
                         _dtype(cfg))


def _init_dense_block(key, cfg: ArchConfig, use_moe: bool):
    k1, k2 = jax.random.split(key)
    p = {"attn_norm": rmsnorm_init(cfg.d_model, _dtype(cfg)),
         "attn": _init_attn(k1, cfg),
         "mlp_norm": rmsnorm_init(cfg.d_model, _dtype(cfg))}
    if use_moe:
        p["moe"] = moe_lib.moe_init(k2, cfg.d_model, cfg.d_ff_expert,
                                    cfg.n_experts, cfg.n_shared_experts,
                                    cfg.d_ff_expert * cfg.n_shared_experts,
                                    _dtype(cfg))
    else:
        p["mlp"] = swiglu_init(k2, cfg.d_model, cfg.d_ff, _dtype(cfg))
    return p


def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


# =====================================================================
# Dense / MoE decoder block (prefill + decode)
# =====================================================================
def _attn_prefill(p, x, cfg: ArchConfig, causal=True, window=None):
    if cfg.attn == "mla":
        return attn.mla_prefill(p, x, cfg.n_heads, cfg.kv_lora, cfg.d_nope,
                                cfg.d_rope, cfg.d_head, causal=causal,
                                rope_theta=cfg.rope_theta)
    return attn.gqa_prefill(p, x, cfg.n_heads, cfg.n_kv, cfg.d_head,
                            causal=causal, window=window,
                            rope_theta=cfg.rope_theta, use_flash=cfg.use_flash)


def dense_block(p, x, cfg: ArchConfig, use_moe: bool, window=None):
    x = shard_seq(x)
    attn_out = _attn_prefill(p["attn"], rmsnorm(p["attn_norm"], x), cfg,
                             window=window)
    x = x + attn_out
    x = shard_seq(x)
    h = rmsnorm(p["mlp_norm"], x)
    if use_moe:
        y, stats = moe_lib.moe_apply(p["moe"], h, cfg.n_experts, cfg.top_k,
                                     cfg.capacity_factor)
        aux = stats.aux_loss
    else:
        y, aux = swiglu(p["mlp"], h), jnp.zeros((), jnp.float32)
    y = x + y
    return shard_seq(y), aux


def dense_block_decode(p, x, cache, cfg: ArchConfig, use_moe: bool, ring: bool):
    h = rmsnorm(p["attn_norm"], x)
    if cfg.attn == "mla":
        attn_out, cache = attn.mla_decode(p["attn"], h, cache, cfg.n_heads,
                                          cfg.kv_lora, cfg.d_nope, cfg.d_rope,
                                          cfg.d_head, rope_theta=cfg.rope_theta)
    else:
        attn_out, cache = attn.gqa_decode(p["attn"], h, cache, cfg.n_heads,
                                          cfg.n_kv, cfg.d_head, ring=ring,
                                          rope_theta=cfg.rope_theta)
    x = x + attn_out
    h = rmsnorm(p["mlp_norm"], x)
    if use_moe:
        y, _ = moe_lib.moe_apply(p["moe"], h, cfg.n_experts, cfg.top_k,
                                 cfg.capacity_factor)
    else:
        y = swiglu(p["mlp"], h)
    return x + y, cache


# =====================================================================
# SSM blocks
# =====================================================================
def _init_mamba_block(key, cfg: ArchConfig):
    k1, _ = jax.random.split(key)
    return {"norm": rmsnorm_init(cfg.d_model, _dtype(cfg)),
            "mamba": ssm_lib.mamba2_init(k1, cfg.d_model, cfg.d_state,
                                         cfg.ssm_heads, cfg.ssm_head_dim,
                                         dtype=_dtype(cfg))}


def mamba_block(p, x, cfg: ArchConfig):
    x = shard_seq(x)
    y = ssm_lib.mamba2_forward(p["mamba"], rmsnorm(p["norm"], x), cfg.d_state,
                               cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_chunk)
    return shard_seq(x + y)


def mamba_block_decode(p, x, cache, cfg: ArchConfig):
    y, cache = ssm_lib.mamba2_decode(p["mamba"], rmsnorm(p["norm"], x), cache,
                                     cfg.d_state, cfg.ssm_heads, cfg.ssm_head_dim)
    return x + y, cache


def _init_rwkv_block(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {"tm_norm": rmsnorm_init(cfg.d_model, _dtype(cfg)),
            "time_mix": ssm_lib.rwkv6_init(k1, cfg.d_model, cfg.ssm_heads,
                                           cfg.ssm_head_dim, dtype=_dtype(cfg)),
            "cm_norm": rmsnorm_init(cfg.d_model, _dtype(cfg)),
            "chan_mix": ssm_lib.rwkv6_channel_mix_init(k2, cfg.d_model, cfg.d_ff,
                                                       _dtype(cfg))}


def rwkv_block(p, x, cfg: ArchConfig):
    x = shard_seq(x)
    x = x + ssm_lib.rwkv6_forward(p["time_mix"], rmsnorm(p["tm_norm"], x),
                                  cfg.ssm_heads, cfg.ssm_head_dim)
    x = x + ssm_lib.rwkv6_channel_mix(p["chan_mix"], rmsnorm(p["cm_norm"], x))
    return shard_seq(x)


class RWKVBlockCache(NamedTuple):
    time_mix: ssm_lib.RWKV6Cache
    cm_x_prev: jnp.ndarray


def rwkv_block_decode(p, x, cache: RWKVBlockCache, cfg: ArchConfig):
    y, tm = ssm_lib.rwkv6_decode(p["time_mix"], rmsnorm(p["tm_norm"], x), cache.time_mix,
                                 cfg.ssm_heads, cfg.ssm_head_dim)
    x = x + y
    h = rmsnorm(p["cm_norm"], x)
    y = ssm_lib.rwkv6_channel_mix(p["chan_mix"], h, cache.cm_x_prev)
    return x + y, RWKVBlockCache(tm, h[:, 0])


# =====================================================================
# Model init
# =====================================================================
def init_lm(key, cfg: ArchConfig):
    ks = jax.random.split(key, 8)
    dt = _dtype(cfg)
    params: Dict[str, Any] = {
        "emb": embed_init(ks[0], cfg.vocab, cfg.d_model, dt),
        "final_norm": rmsnorm_init(cfg.d_model, dt),
        "unemb": dense_init(ks[1], cfg.d_model, cfg.vocab, dtype=dt),
    }
    if cfg.glasu is not None:
        return _init_glasu_lm(params, ks, cfg)
    if cfg.is_encdec:
        params["enc"] = _stack_init(
            lambda k: _init_dense_block(k, cfg, False), ks[2], cfg.enc_layers)
        params["dec"] = _stack_init(
            lambda k: {**_init_dense_block(k, cfg, False),
                       "xattn_norm": rmsnorm_init(cfg.d_model, dt),
                       "xattn": attn.cross_attn_init(
                           jax.random.fold_in(k, 7), cfg.d_model, cfg.n_heads,
                           cfg.n_kv, cfg.d_head, dt)},
            ks[3], cfg.dec_layers)
        return params
    if cfg.block == "mamba2":
        n_groups = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        leftover = cfg.n_layers - n_groups * cfg.attn_every
        if cfg.attn_every:
            params["ssm_groups"] = _stack_init(
                lambda k: _stack_init(lambda kk: _init_mamba_block(kk, cfg),
                                      k, cfg.attn_every), ks[2], n_groups)
            params["shared_attn"] = _init_dense_block(ks[3], cfg, False)
        if leftover or not cfg.attn_every:
            n = leftover if cfg.attn_every else cfg.n_layers
            params["ssm_tail"] = _stack_init(
                lambda k: _init_mamba_block(k, cfg), ks[4], n)
        return params
    if cfg.block == "rwkv6":
        params["blocks"] = _stack_init(lambda k: _init_rwkv_block(k, cfg),
                                       ks[2], cfg.n_layers)
        return params
    # dense / moe decoder
    n_moe_layers = cfg.n_layers - cfg.n_dense_layers
    if cfg.n_dense_layers:
        params["dense_head"] = _stack_init(
            lambda k: _init_dense_block(k, cfg, False), ks[2], cfg.n_dense_layers)
    params["blocks"] = _stack_init(
        lambda k: _init_dense_block(k, cfg, cfg.moe), ks[3], n_moe_layers)
    return params


# =====================================================================
# Forward (train / prefill)
# =====================================================================
def _best_group(n: int) -> int:
    """Largest divisor of n not exceeding sqrt(n) (nested-remat group count)."""
    best = 1
    d = 1
    while d * d <= n:
        if n % d == 0:
            best = d
        d += 1
    return best


def _scan_stack(block_fn, stacked_params, x, remat: bool):
    """Scan a homogeneous layer stack with sqrt(L) nested rematerialization.

    Plain scan-of-checkpointed-blocks saves an (L, B, S, D) residual stack;
    two-level scan (outer groups checkpointed, inner layers checkpointed)
    saves (G + L/G) residuals instead — the classic sqrt-remat trade, worth
    ~10x activation memory at L=126 (llama3-405b).
    """
    fn = jax.checkpoint(block_fn) if remat else block_fn

    def body(carry, p):
        out = fn(p, carry)
        if isinstance(out, tuple) and len(out) == 2:
            return out[0], out[1]
        if isinstance(out, tuple):
            out = out[0]
        return out, jnp.zeros((), jnp.float32)

    n_layers = jax.tree.leaves(stacked_params)[0].shape[0]
    groups = _best_group(n_layers) if remat else 1
    if groups <= 1:
        x, aux = jax.lax.scan(body, x, stacked_params)
        return x, jnp.sum(aux)

    regrouped = jax.tree.map(
        lambda v: v.reshape(groups, n_layers // groups, *v.shape[1:]),
        stacked_params)

    @jax.checkpoint
    def group_body(carry, gp):
        out, aux = jax.lax.scan(body, carry, gp)
        return out, jnp.sum(aux)

    x, aux = jax.lax.scan(group_body, x, regrouped)
    return x, jnp.sum(aux)


def lm_forward(params, cfg: ArchConfig, tokens=None, embeds=None,
               src_embeds=None, window=None, return_hidden=False):
    """Returns (logits, aux_loss) — or (hidden, aux_loss) with
    ``return_hidden`` so the caller can run a memory-chunked loss head.
    Inputs: tokens (B, S) and/or prefix ``embeds`` (B, P, D) for VLM/audio
    stubs; ``src_embeds`` for encoder-decoder source side.
    """
    window = window if window is not None else cfg.sliding_window
    pieces = []
    if embeds is not None:
        pieces.append(embeds.astype(_dtype(cfg)))
    if tokens is not None:
        pieces.append(shard(params["emb"], "model", None)[tokens])
    x = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces, axis=1)
    x = shard(x, BATCH, None, None)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.glasu is not None:
        x, aux_total, _ = _glasu_trunk(params, x, cfg, window)
    elif cfg.is_encdec:
        enc = src_embeds.astype(_dtype(cfg))
        enc = shard(enc, BATCH, None, None)
        enc, _ = _scan_stack(lambda p, h: (dense_block_bidir(p, h, cfg),),
                             params["enc"], enc, cfg.remat)
        enc = rmsnorm(params["final_norm"], enc)

        def dec_block(p, h):
            out, aux = dense_block(p, h, cfg, False, window)
            kv = attn.cross_kv(p["xattn"], enc, cfg.n_kv, cfg.d_head)
            out = out + attn.cross_attn(p["xattn"],
                                        rmsnorm(p["xattn_norm"], out), kv,
                                        cfg.n_heads, cfg.n_kv, cfg.d_head)
            return out, aux

        x, aux_total = _scan_stack(dec_block, params["dec"], x, cfg.remat)
    elif cfg.block == "mamba2":
        x = _zamba_trunk_prefill(params, x, cfg, window)
    elif cfg.block == "rwkv6":
        x, _ = _scan_stack(lambda p, h: (rwkv_block(p, h, cfg),),
                           params["blocks"], x, cfg.remat)
    else:
        if cfg.n_dense_layers:
            x, _ = _scan_stack(lambda p, h: dense_block(p, h, cfg, False, window),
                               params["dense_head"], x, cfg.remat)
        x, aux_total = _scan_stack(lambda p, h: dense_block(p, h, cfg, cfg.moe, window),
                                   params["blocks"], x, cfg.remat)

    x = rmsnorm(params["final_norm"], x)
    if return_hidden:
        return x, aux_total
    logits = x @ wcol(params["unemb"])
    logits = shard(logits, BATCH, None, "model")
    return logits, aux_total


def dense_block_bidir(p, x, cfg: ArchConfig):
    h = _attn_prefill(p["attn"], rmsnorm(p["attn_norm"], x), cfg, causal=False)
    x = x + h
    return x + swiglu(p["mlp"], rmsnorm(p["mlp_norm"], x))


def _zamba_trunk_prefill(params, x, cfg: ArchConfig, window):
    if "ssm_groups" in params:
        n_groups = params["ssm_groups"]["norm"]["g"].shape[0]

        def group_fn(h, gp):
            h, _ = _scan_stack(lambda p, hh: (mamba_block(p, hh, cfg),),
                               gp, h, cfg.remat)
            h, _ = dense_block(params["shared_attn"], h, cfg, False, window)
            return h, None

        x, _ = jax.lax.scan(group_fn, x, params["ssm_groups"])
    if "ssm_tail" in params:
        x, _ = _scan_stack(lambda p, h: (mamba_block(p, h, cfg),),
                           params["ssm_tail"], x, cfg.remat)
    return x


# =====================================================================
# Decode (serve_step): one token through stacked caches
# =====================================================================
def init_caches(cfg: ArchConfig, batch: int, seq_len: int, prefill_len: int = 0):
    """Stacked per-layer decode caches sized for ``seq_len`` context.

    Sliding-window archs get a ring buffer of size ``window`` instead of the
    full context — the long_500k memory story.
    """
    dt = _dtype(cfg)
    cap = seq_len
    ring = False
    if cfg.sliding_window and seq_len > cfg.sliding_window:
        cap, ring = cfg.sliding_window, True

    def kv(n):
        return jax.vmap(lambda _: attn.kv_cache_init(
            batch, cap, cfg.n_kv, cfg.d_head, dt, prefill_len))(jnp.arange(n))

    if cfg.glasu is not None:
        return {"kv": kv(cfg.n_layers)}
    if cfg.is_encdec:
        return {"self": kv(cfg.dec_layers)}
    if cfg.block == "mamba2":
        conv_ch = cfg.ssm_heads * cfg.ssm_head_dim + 2 * cfg.d_state
        caches = {}
        if cfg.attn_every:
            n_groups = cfg.n_layers // cfg.attn_every
            caches["ssm_groups"] = jax.vmap(lambda _: jax.vmap(
                lambda __: ssm_lib.mamba2_cache_init(
                    batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.d_state,
                    conv_ch, dtype=dt))(jnp.arange(cfg.attn_every)))(
                jnp.arange(n_groups))
            caches["shared_attn"] = kv(n_groups)
            leftover = cfg.n_layers - n_groups * cfg.attn_every
        else:
            leftover = cfg.n_layers
        if leftover:
            caches["ssm_tail"] = jax.vmap(lambda _: ssm_lib.mamba2_cache_init(
                batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.d_state, conv_ch,
                dtype=dt))(jnp.arange(leftover))
        return caches
    if cfg.block == "rwkv6":
        return {"blocks": jax.vmap(lambda _: RWKVBlockCache(
            ssm_lib.rwkv6_cache_init(batch, cfg.ssm_heads, cfg.ssm_head_dim,
                                     cfg.d_model, dt),
            jnp.zeros((batch, cfg.d_model), dt)))(jnp.arange(cfg.n_layers))}
    caches = {}
    if cfg.attn == "mla":
        caches["blocks"] = jax.vmap(lambda _: attn.mla_cache_init(
            batch, cap, cfg.kv_lora, cfg.d_rope, dt, prefill_len))(
            jnp.arange(cfg.n_layers - cfg.n_dense_layers))
        if cfg.n_dense_layers:
            caches["dense_head"] = jax.vmap(lambda _: attn.mla_cache_init(
                batch, cap, cfg.kv_lora, cfg.d_rope, dt, prefill_len))(
                jnp.arange(cfg.n_dense_layers))
    else:
        caches["blocks"] = kv(cfg.n_layers - cfg.n_dense_layers)
        if cfg.n_dense_layers:
            caches["dense_head"] = kv(cfg.n_dense_layers)
    return caches


def _uses_ring(cfg: ArchConfig, caches) -> bool:
    """Static ring-buffer flag, derived from the cache capacity (a shape)."""
    if cfg.sliding_window is None:
        return False
    for key in ("kv", "self", "blocks", "shared_attn"):
        c = caches.get(key)
        if isinstance(c, attn.KVCache):
            return c.k.shape[2] == cfg.sliding_window
    return False


def lm_decode_step(params, caches, cfg: ArchConfig, token, enc_out=None):
    """One greedy decode step. token: (B, 1) int32 -> (next_token, caches)."""
    x = shard(params["emb"], "model", None)[token]
    ring = _uses_ring(cfg, caches)

    if cfg.glasu is not None:
        x, new_kv = _glasu_decode(params, x, caches["kv"], cfg, ring)
        caches = {**caches, "kv": new_kv}
    elif cfg.is_encdec:
        def body(h, pc):
            p, c = pc
            out, nc = dense_block_decode(p, h, c, cfg, False, ring)
            kvx = attn.cross_kv(p["xattn"], enc_out, cfg.n_kv, cfg.d_head)
            out = out + attn.cross_attn(p["xattn"], rmsnorm(p["xattn_norm"], out),
                                        kvx, cfg.n_heads, cfg.n_kv, cfg.d_head)
            return out, nc

        x, new_self = jax.lax.scan(body, x, (params["dec"], caches["self"]))
        caches = {**caches, "self": new_self}
    elif cfg.block == "mamba2":
        caches = dict(caches)
        if "ssm_groups" in caches:
            def group_body(h, inp):
                gp, gc, ac = inp

                def inner(hh, pc):
                    p, c = pc
                    return mamba_block_decode(p, hh, c, cfg)

                h, ngc = jax.lax.scan(inner, h, (gp, gc))
                h, nac = dense_block_decode(params["shared_attn"], h, ac, cfg,
                                            False, ring)
                return h, (ngc, nac)

            x, (ngc, nac) = jax.lax.scan(
                group_body, x, (params["ssm_groups"], caches["ssm_groups"],
                                caches["shared_attn"]))
            caches["ssm_groups"], caches["shared_attn"] = ngc, nac
        if "ssm_tail" in caches:
            def tail(h, pc):
                p, c = pc
                return mamba_block_decode(p, h, c, cfg)

            x, nt = jax.lax.scan(tail, x, (params["ssm_tail"], caches["ssm_tail"]))
            caches["ssm_tail"] = nt
    elif cfg.block == "rwkv6":
        def body(h, pc):
            p, c = pc
            return rwkv_block_decode(p, h, c, cfg)

        x, nb = jax.lax.scan(body, x, (params["blocks"], caches["blocks"]))
        caches = {**caches, "blocks": nb}
    else:
        caches = dict(caches)

        def body(h, pc):
            p, c = pc
            return dense_block_decode(p, h, c, cfg, cfg.moe, ring)

        if cfg.n_dense_layers:
            def body_d(h, pc):
                p, c = pc
                return dense_block_decode(p, h, c, cfg, False, ring)

            x, nd = jax.lax.scan(body_d, x, (params["dense_head"],
                                             caches["dense_head"]))
            caches["dense_head"] = nd
        x, nb = jax.lax.scan(body, x, (params["blocks"], caches["blocks"]))
        caches["blocks"] = nb

    x = rmsnorm(params["final_norm"], x)
    logits = x @ wcol(params["unemb"])
    next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_token, caches


# =====================================================================
# GLASU vertical split (paper technique on a transformer backbone)
# =====================================================================
def _glasu_dims(cfg: ArchConfig):
    g = cfg.glasu
    m = g.n_clients
    assert cfg.d_model % m == 0 and cfg.n_heads % m == 0
    assert cfg.d_ff % m == 0 and max(cfg.n_kv, m) % min(cfg.n_kv, m) == 0
    return m, cfg.d_model // m, cfg.n_heads // m, max(cfg.n_kv // m, 1), cfg.d_ff // m


def _init_glasu_lm(params, ks, cfg: ArchConfig):
    m, dm, hm, kvm, fm = _glasu_dims(cfg)
    dt = _dtype(cfg)
    g = cfg.glasu
    n_groups = cfg.n_layers // g.sync_every

    def init_sync(key):
        # full-input layer: standard dense block (weights consume gathered D)
        return _init_dense_block(key, cfg, False)

    def init_local(key):
        # block-diagonal client sub-layer: each client maps its d/M slice
        def one(k):
            k1, k2, k3, k4, k5, k6, k7 = jax.random.split(k, 7)
            return {
                "attn_norm": rmsnorm_init(dm, dt),
                "wq": dense_init(k1, dm, hm * cfg.d_head, dtype=dt),
                "wk": dense_init(k2, dm, kvm * cfg.d_head, dtype=dt),
                "wv": dense_init(k3, dm, kvm * cfg.d_head, dtype=dt),
                "wo": dense_init(k4, hm * cfg.d_head, dm, dtype=dt),
                "mlp_norm": rmsnorm_init(dm, dt),
                "w_gate": dense_init(k5, dm, fm, dtype=dt),
                "w_up": dense_init(k6, dm, fm, dtype=dt),
                "w_down": dense_init(k7, fm, dm, dtype=dt),
            }
        return jax.vmap(one)(jax.random.split(key, m))

    def init_group(key):
        k1, k2 = jax.random.split(key)
        gp = {"sync": init_sync(k1)}
        if g.sync_every > 1:
            gp["locals"] = _stack_init(init_local, k2, g.sync_every - 1)
        return gp

    params["groups"] = _stack_init(init_group, ks[2], n_groups)
    return params


def _glasu_local_block(p, x_loc, cfg: ArchConfig, window, positions=None,
                       cache=None, ring=False):
    """Client-local (block-diagonal) layer. x_loc: (B, S, M, dm).

    Attention runs independently inside each client's head group — zero
    cross-client communication (the lazy-aggregation layers of the paper).
    """
    m, dm, hm, kvm, fm = _glasu_dims(cfg)
    b, s = x_loc.shape[0], x_loc.shape[1]
    h = rmsnorm_m(p["attn_norm"], x_loc)
    q = jnp.einsum("bsmd,mdh->bsmh", h, p["wq"]).reshape(b, s, m, hm, cfg.d_head)
    k = jnp.einsum("bsmd,mdh->bsmh", h, p["wk"]).reshape(b, s, m, kvm, cfg.d_head)
    v = jnp.einsum("bsmd,mdh->bsmh", h, p["wv"]).reshape(b, s, m, kvm, cfg.d_head)
    pos = positions if positions is not None else jnp.arange(s)[None]
    q = attn.apply_rope(q.reshape(b, s, m * hm, cfg.d_head), pos, cfg.rope_theta)
    k = attn.apply_rope(k.reshape(b, s, m * kvm, cfg.d_head), pos, cfg.rope_theta)
    q = shard(q.reshape(b, s, m, hm, cfg.d_head), BATCH, None, "model", None, None)
    k = shard(k.reshape(b, s, m, kvm, cfg.d_head), BATCH, None, "model", None, None)
    if cache is not None:
        kc, vc, cpos = cache
        cap = kc.shape[1]
        slot = (cpos % cap) if ring else jnp.minimum(cpos, cap - 1)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        idx = jnp.arange(cap)
        valid = jnp.where(cpos >= cap, jnp.ones_like(idx, bool), idx <= cpos) \
            if ring else (idx <= cpos)
        mask = valid[None, None, None, :]
        out = jax.vmap(attn._sdpa, in_axes=(2, 2, 2, None), out_axes=2)(
            q, kc, vc, mask)
        new_cache = (kc, vc, cpos + 1)
    else:
        if s > attn.CHUNK_THRESHOLD:
            out = jax.vmap(
                lambda qm, km, vm: attn._sdpa_chunked(qm, km, vm, True, window),
                in_axes=(2, 2, 2), out_axes=2)(q, k, v)
        else:
            mask = attn.causal_mask(s, window=window)
            out = jax.vmap(attn._sdpa, in_axes=(2, 2, 2, None), out_axes=2)(
                q, k, v, mask)
        new_cache = None
    out = out.reshape(b, s, m, hm * cfg.d_head)
    x_loc = x_loc + jnp.einsum("bsmh,mhd->bsmd", out, p["wo"])
    h = rmsnorm_m(p["mlp_norm"], x_loc)
    y = jax.nn.silu(jnp.einsum("bsmd,mdf->bsmf", h, p["w_gate"])) \
        * jnp.einsum("bsmd,mdf->bsmf", h, p["w_up"])
    y = shard(y, BATCH, None, "model", None)
    x_loc = x_loc + jnp.einsum("bsmf,mfd->bsmd", y, p["w_down"])
    return shard(x_loc, BATCH, None, "model", None), new_cache


def rmsnorm_m(p, x, eps=1e-6):
    """Per-client RMSNorm: p['g'] has shape (M, dm) or (dm,)."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * p["g"]


def _glasu_trunk(params, x, cfg: ArchConfig, window, collect_stale=False,
                 stale=None):
    """(B,S,D) -> (B,S,D). Sync layers gather; local layers stay sharded.

    Groups are executed under a checkpointed ``lax.scan`` (63 unrolled groups
    on llama3-405b cost 840 GB of live activations; scanned+remat ~20 GB).
    With ``collect_stale`` the gathered sync inputs are stacked and returned
    so the training loop can run Q-1 collective-free stale microsteps; with
    ``stale`` given, the gather is REPLACED by the cached activations with
    the live shard's slice refreshed (the paper's Extract/combine, Alg 4).
    """
    m, dm, hm, kvm, fm = _glasu_dims(cfg)
    g = cfg.glasu
    b, s, d = x.shape
    x_loc = x.reshape(b, s, m, dm)
    x_loc = shard(x_loc, BATCH, None, "model", None)
    n_groups = cfg.n_layers // g.sync_every

    def group_fn(carry, inp):
        x_loc = carry
        gp, stale_g = inp
        if stale is not None:
            full = _replace_own_shard(stale_g, x_loc, m)
        else:
            full = x_loc.reshape(b, s, d)
            full = shard(full, BATCH, None, None)       # forces the all-gather
        stale_out = full if collect_stale else jnp.zeros((), x.dtype)
        full, aux = dense_block(gp["sync"], full, cfg, False, window)
        x_loc = full.reshape(b, s, m, dm)
        x_loc = shard(x_loc, BATCH, None, "model", None)
        if g.sync_every > 1:
            def local_body(c, lp):
                out, _ = _glasu_local_block(lp, c, cfg, window)
                return out, jnp.zeros((), jnp.float32)

            x_loc, _ = jax.lax.scan(local_body, x_loc, gp["locals"])
        return x_loc, (stale_out, aux)

    fn = jax.checkpoint(group_fn) if cfg.remat else group_fn
    if stale is not None:
        xs = (params["groups"], stale)
    else:
        xs = (params["groups"],
              jnp.zeros((n_groups,), x.dtype))          # dummy stale slots
    x_loc, (stale_out, aux) = jax.lax.scan(fn, x_loc, xs)
    x = x_loc.reshape(b, s, d)
    return x, jnp.sum(aux), (stale_out if collect_stale else [])


def _replace_own_shard(full, x_loc, m):
    """Under SPMD each model-shard group refreshes its own slice of the
    stale gathered activations; expressed globally as a reshape-merge."""
    b, s, d = full.shape
    dm = d // m
    merged = full.reshape(b, s, m, dm)
    # own (fresh) slice wins — globally this is simply x_loc, since every
    # client's fresh slice is present exactly once
    merged = x_loc
    return shard(merged.reshape(b, s, d), BATCH, None, None)


def _glasu_decode(params, x, kv_caches, cfg: ArchConfig, ring):
    m, dm, hm, kvm, fm = _glasu_dims(cfg)
    g = cfg.glasu
    b = x.shape[0]
    n_groups = cfg.n_layers // g.sync_every
    x_loc = x.reshape(b, 1, m, dm)
    new_k, new_v, new_pos = [], [], []
    li = 0
    for gi in range(n_groups):
        gp = jax.tree.map(lambda v: v[gi], params["groups"])
        full = x_loc.reshape(b, 1, cfg.d_model)
        c = jax.tree.map(lambda v: v[li], kv_caches)
        full, nc = dense_block_decode(gp["sync"], full, c, cfg, False, ring)
        new_k.append(nc.k), new_v.append(nc.v), new_pos.append(nc.pos)
        li += 1
        x_loc = full.reshape(b, 1, m, dm)
        for lj in range(g.sync_every - 1):
            lp = jax.tree.map(lambda v: v[lj], gp["locals"])
            c = jax.tree.map(lambda v: v[li], kv_caches)
            # local cache: reuse KVCache with kv heads = m * kvm stored flat
            kc = c.k.reshape(b, c.k.shape[1], m, kvm, cfg.d_head)
            vc = c.v.reshape(b, c.v.shape[1], m, kvm, cfg.d_head)
            pos = jnp.arange(1)[None] * 0 + c.pos
            x_loc, (kc, vc, npos) = _glasu_local_block(
                lp, x_loc, cfg, None, positions=pos.astype(jnp.float32),
                cache=(kc, vc, c.pos), ring=ring)
            new_k.append(kc.reshape(b, kc.shape[1], m * kvm, cfg.d_head))
            new_v.append(vc.reshape(b, vc.shape[1], m * kvm, cfg.d_head))
            new_pos.append(npos)
            li += 1
    caches = attn.KVCache(jnp.stack(new_k), jnp.stack(new_v), jnp.stack(new_pos))
    return x_loc.reshape(b, 1, cfg.d_model), caches
