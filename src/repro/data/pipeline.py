"""Synthetic token/embedding pipeline + dry-run input specs.

For smoke tests and the runnable examples we generate deterministic synthetic
batches (PRNG streams — the container is offline). For the multi-pod dry-run
we produce ``jax.ShapeDtypeStruct`` stand-ins: weak-type-correct, shardable,
zero allocation.

Modality frontends are STUBS by mandate: [audio]/[vlm] configs receive
precomputed frame/patch embeddings of the right shape via ``frontend_*``
entries; the transformer backbone under test is real.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, InputShape


def train_batch_shapes(cfg: ArchConfig, shape: InputShape) -> Dict[str, tuple]:
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, tuple] = {}
    if cfg.is_encdec:
        # source frames (stub audio embeddings) + target tokens
        src = cfg.frontend_tokens or s
        out["src_embeds"] = (b, src, cfg.d_model)
        out["tokens"] = (b, s)
        out["labels"] = (b, s)
    elif cfg.frontend == "vision":
        p = cfg.frontend_tokens
        out["patch_embeds"] = (b, p, cfg.d_model)
        out["tokens"] = (b, s - p)
        out["labels"] = (b, s)          # over the full interleaved sequence
    else:
        out["tokens"] = (b, s)
        out["labels"] = (b, s)
    return out


def input_specs(cfg: ArchConfig, shape: InputShape):
    """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
    if shape.mode == "train":
        shapes = train_batch_shapes(cfg, shape)
        specs = {}
        for name, shp in shapes.items():
            dt = jnp.int32 if name in ("tokens", "labels") else jnp.dtype(cfg.dtype)
            specs[name] = jax.ShapeDtypeStruct(shp, dt)
        return specs
    # decode: one new token per sequence
    b = shape.global_batch
    specs = {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.is_encdec:
        src = cfg.frontend_tokens or min(shape.seq_len, 4096)
        specs["enc_out"] = jax.ShapeDtypeStruct((b, src, cfg.d_model),
                                                jnp.dtype(cfg.dtype))
    return specs


def synth_train_batch(cfg: ArchConfig, shape: InputShape, seed: int = 0,
                      dtype=None):
    """Materialized random batch (smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    shapes = train_batch_shapes(cfg, shape)
    batch = {}
    for name, shp in shapes.items():
        if name in ("tokens", "labels"):
            batch[name] = jnp.asarray(
                rng.integers(0, cfg.vocab, size=shp), jnp.int32)
        else:
            batch[name] = jnp.asarray(
                rng.normal(size=shp).astype(np.float32),
                dtype or jnp.dtype(cfg.dtype))
    return batch


class TokenStream:
    """Deterministic infinite synthetic LM data (markov-ish bigram stream),
    used by the end-to-end training example so loss visibly decreases."""

    def __init__(self, vocab: int, seed: int = 0, order: int = 1):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        # sparse bigram transition: each token has 4 likely successors
        self.next_tok = rng.integers(0, vocab, size=(vocab, 4))
        self.rng = rng

    def batch(self, batch_size: int, seq_len: int):
        toks = np.zeros((batch_size, seq_len + 1), np.int32)
        toks[:, 0] = self.rng.integers(0, self.vocab, size=batch_size)
        for t in range(seq_len):
            choice = self.rng.integers(0, 4, size=batch_size)
            nxt = self.next_tok[toks[:, t], choice]
            noise = self.rng.random(batch_size) < 0.05
            rand = self.rng.integers(0, self.vocab, size=batch_size)
            toks[:, t + 1] = np.where(noise, rand, nxt)
        return (jnp.asarray(toks[:, :-1], jnp.int32),
                jnp.asarray(toks[:, 1:], jnp.int32))
