"""Phi-3.5-MoE (42B, 6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct].

32L, d_model=4096, 32 heads (GQA kv=8, head_dim=128), 16 experts top-2,
expert d_ff=6400, vocab=32064.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", kind="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_head=128,
    d_ff=6400, vocab=32064,
    moe=True, n_experts=16, top_k=2, n_shared_experts=0, d_ff_expert=6400,
    grad_accum=4,
    dtype="bfloat16", optimizer="adamw", lr=2e-4,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, d_model=256, n_heads=4, n_kv=2, d_head=64,
                        d_ff=512, vocab=512, n_experts=4, top_k=2,
                        d_ff_expert=128, dtype="float32", remat=False, grad_accum=1)
