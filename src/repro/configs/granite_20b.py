"""Granite-20B-Code [arXiv:2405.04324] — dense MQA (kv=1) code LM.

52L, d_model=6144, 48 heads (MQA kv=1, head_dim=128), d_ff=24576,
vocab=49152. kv=1 makes the KV-cache collective degenerate (fully
replicated keys) — noted in the roofline discussion.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", kind="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv=1, d_head=128,
    d_ff=24576, vocab=49152,
    grad_accum=4,
    dtype="bfloat16", optimizer="adafactor", lr=1e-4,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, d_model=256, n_heads=4, n_kv=1, d_head=64,
                        d_ff=512, vocab=512, dtype="float32",
                        optimizer="adamw", remat=False, grad_accum=1)
