"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-360M] — small llama-arch dense LM.

32L, d_model=960, 15 heads (GQA kv=5, head_dim=64), d_ff=2560, vocab=49152.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m", kind="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv=5, d_head=64,
    d_ff=2560, vocab=49152,
    dtype="bfloat16", optimizer="adamw", lr=3e-4,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, d_model=240, n_heads=3, n_kv=1, d_head=80,
                        d_ff=512, vocab=512, dtype="float32", remat=False)
