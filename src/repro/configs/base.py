"""Architecture config schema + input-shape suite + registry.

The CPU smoke-test variants of the transformer zoo live in the inline
``REDUCED_CONFIGS`` registry below. The paper's own GNN scenarios
(``GNN_ARCH_IDS``) keep one module each in this package; resolve those with
``get_gnn_arch`` / ``get_gnn_reduced``. The full-size transformer
hyperparameter modules were seed-era dead weight and were removed — see git
history for the published numbers.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class GlasuSplit:
    """The paper's technique applied to a transformer backbone (§DESIGN.md 4).

    The hidden dimension is vertically partitioned into ``n_clients`` feature
    shards (mapped onto the 'model' mesh axis). Cross-shard mixing (concat
    aggregation + re-projection) happens ONLY at ``sync_layers``; all other
    layers are block-diagonal (client-local, collective-free). ``local_steps``
    = Q stale-update steps per sampled batch.
    """
    n_clients: int = 4
    sync_every: int = 2            # aggregate every k-th layer (K = L/sync_every)
    local_steps: int = 1           # Q


@dataclass(frozen=True)
class ArchConfig:
    name: str
    kind: str                      # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    # --- MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0        # leading dense layers (DeepSeek: 1)
    router_aux_weight: float = 0.01
    capacity_factor: float = 1.25
    # --- attention variant
    attn: str = "gqa"              # gqa | mla | none
    kv_lora: int = 0
    d_nope: int = 0
    d_rope: int = 0
    sliding_window: Optional[int] = None
    rope_theta: float = 10000.0
    # --- ssm / hybrid
    block: str = "attn"            # attn | mamba2 | rwkv6
    d_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    attn_every: int = 0            # zamba2: shared attn block every N ssm layers
    ssm_chunk: int = 256
    # --- encoder-decoder
    enc_layers: int = 0
    dec_layers: int = 0
    # --- modality frontend STUB (audio/vlm): input_specs provides embeddings
    frontend: Optional[str] = None
    frontend_tokens: int = 0
    # --- training
    dtype: str = "bfloat16"
    optimizer: str = "adamw"       # adamw | adafactor | sgd
    lr: float = 3e-4
    remat: bool = True
    grad_accum: int = 1            # microbatches per step (activation memory lever)
    # --- paper technique
    glasu: Optional[GlasuSplit] = None
    # --- kernels
    use_flash: bool = False

    @property
    def is_encdec(self) -> bool:
        return self.kind in ("encdec", "audio") and self.enc_layers > 0

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate total parameter count (for MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        if self.block == "mamba2":
            d_inner = self.ssm_heads * self.ssm_head_dim
            per = d * (2 * d_inner + 2 * self.d_state + self.ssm_heads) + d_inner * d
            n_ssm = self.n_layers
            attn_blocks = (self.n_layers // self.attn_every) if self.attn_every else 0
            per_attn = (d * (self.n_heads + 2 * self.n_kv) * self.d_head
                        + self.n_heads * self.d_head * d + 3 * d * f)
            return per * n_ssm + (per_attn if attn_blocks else 0) + 2 * v * d
        if self.block == "rwkv6":
            d_inner = self.ssm_heads * self.ssm_head_dim
            per = 4 * d * d_inner + d_inner * d + 2 * d * f
            return per * self.n_layers + 2 * v * d
        if self.attn == "mla":
            attn = (d * self.n_heads * (self.d_nope + self.d_rope)
                    + d * (self.kv_lora + self.d_rope)
                    + self.kv_lora * self.n_heads * (self.d_nope + self.d_head)
                    + self.n_heads * self.d_head * d)
        else:
            attn = (d * (self.n_heads + 2 * self.n_kv) * self.d_head
                    + self.n_heads * self.d_head * d)
        mlp_dense = 3 * d * f
        if self.moe:
            mlp_moe = 3 * d * self.d_ff_expert * self.n_experts \
                + 3 * d * self.d_ff_expert * self.n_shared_experts
            n_moe = self.n_layers - self.n_dense_layers
            mlp_total = mlp_moe * n_moe + mlp_dense * self.n_dense_layers
        else:
            n = self.enc_layers + self.dec_layers if self.is_encdec else self.n_layers
            mlp_total = mlp_dense * n
        n = self.enc_layers + self.dec_layers if self.is_encdec else self.n_layers
        total = attn * n + mlp_total + 2 * v * d
        if self.is_encdec:
            total += attn * self.dec_layers  # cross attention
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        mlp_active = 3 * d * self.d_ff_expert * (self.top_k + self.n_shared_experts)
        mlp_all = 3 * d * self.d_ff_expert * (self.n_experts + self.n_shared_experts)
        n_moe = self.n_layers - self.n_dense_layers
        return self.param_count() - (mlp_all - mlp_active) * n_moe


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# Reduced (CPU smoke) variants of the transformer zoo, keyed by arch id.
# Values are kwargs diffs from ArchConfig defaults — everything not listed is
# the dataclass default. These were previously computed per-module as
# ``reduced()``; the full-size modules are gone, the smoke variants stay.
REDUCED_CONFIGS = {
    "seamless_m4t_large_v2": dict(name='seamless-m4t-large-v2', kind='audio', n_layers=2, d_model=256, n_heads=4, n_kv=4, d_head=64, d_ff=512, vocab=512, enc_layers=2, dec_layers=2, frontend='audio', dtype='float32', lr=0.0001, remat=False),
    "pixtral_12b": dict(name='pixtral-12b', kind='vlm', n_layers=2, d_model=256, n_heads=4, n_kv=2, d_head=64, d_ff=512, vocab=512, rope_theta=1000000.0, frontend='vision', frontend_tokens=16, dtype='float32', lr=0.0002, remat=False),
    "smollm_360m": dict(name='smollm-360m', kind='dense', n_layers=2, d_model=240, n_heads=3, n_kv=1, d_head=80, d_ff=512, vocab=512, dtype='float32', remat=False),
    "deepseek_v2_lite_16b": dict(name='deepseek-v2-lite-16b', kind='moe', n_layers=2, d_model=256, n_heads=4, n_kv=4, d_head=64, d_ff=512, vocab=512, moe=True, n_experts=4, top_k=2, n_shared_experts=1, d_ff_expert=128, n_dense_layers=1, attn='mla', kv_lora=64, d_nope=32, d_rope=16, dtype='float32', lr=0.0002, remat=False),
    "phi35_moe_42b": dict(name='phi3.5-moe-42b-a6.6b', kind='moe', n_layers=2, d_model=256, n_heads=4, n_kv=2, d_head=64, d_ff=512, vocab=512, moe=True, n_experts=4, top_k=2, d_ff_expert=128, dtype='float32', lr=0.0002, remat=False),
    "zamba2_1p2b": dict(name='zamba2-1.2b', kind='hybrid', n_layers=2, d_model=256, n_heads=4, n_kv=4, d_head=64, d_ff=512, vocab=512, block='mamba2', d_state=16, ssm_heads=8, ssm_head_dim=32, attn_every=2, ssm_chunk=32, dtype='float32', remat=False),
    "rwkv6_7b": dict(name='rwkv6-7b', kind='ssm', n_layers=2, d_model=256, n_heads=0, n_kv=0, d_head=0, d_ff=512, vocab=512, attn='none', block='rwkv6', ssm_heads=4, ssm_head_dim=64, dtype='float32', remat=False),
    "llama3_405b": dict(name='llama3-405b', kind='dense', n_layers=2, d_model=512, n_heads=8, n_kv=2, d_head=64, d_ff=1024, vocab=512, rope_theta=500000.0, dtype='float32', lr=8e-05, remat=False),
    "yi_34b": dict(name='yi-34b', kind='dense', n_layers=2, d_model=448, n_heads=7, n_kv=1, d_head=64, d_ff=1024, vocab=512, rope_theta=5000000.0, dtype='float32', lr=0.0001, remat=False),
    "granite_20b": dict(name='granite-20b', kind='dense', n_layers=2, d_model=256, n_heads=4, n_kv=1, d_head=64, d_ff=512, vocab=512, dtype='float32', lr=0.0001, remat=False),
}

ARCH_IDS = [
    "seamless_m4t_large_v2", "pixtral_12b", "smollm_360m",
    "deepseek_v2_lite_16b", "phi35_moe_42b", "zamba2_1p2b",
    "rwkv6_7b", "llama3_405b", "yi_34b", "granite_20b",
]

# Paper's own GNN configs live beside the transformer zoo. Each id is a real
# module whose CONFIG is an ``repro.api.config.ExperimentConfig`` (the GNN
# experiments are full scenarios, not bare architectures); resolve them with
# ``get_gnn_arch`` / ``get_gnn_reduced``.
GNN_ARCH_IDS = ["glasu_gcnii", "glasu_gcn", "glasu_gat"]


def get_arch(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "p")
    if arch_id in REDUCED_CONFIGS:
        raise ValueError(
            f"full-size config for {arch_id!r} was removed with the seed-era "
            f"stub modules; use get_reduced({arch_id!r}) for the CPU smoke "
            f"variant, or recover the published hyperparameters from git "
            f"history")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def _gnn_module(arch_id: str):
    if arch_id not in GNN_ARCH_IDS:
        raise ValueError(f"unknown GNN arch {arch_id!r}; expected one of "
                         f"{GNN_ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{arch_id}")


def get_gnn_arch(arch_id: str):
    """Resolve a GNN_ARCH_IDS entry to its ExperimentConfig."""
    return _gnn_module(arch_id).CONFIG


def get_gnn_reduced(arch_id: str):
    """CPU smoke-test variant of a GNN_ARCH_IDS entry."""
    return _gnn_module(arch_id).reduced()


def get_reduced(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "p")
    try:
        return ArchConfig(**REDUCED_CONFIGS[arch_id])
    except KeyError:
        raise ValueError(f"unknown arch {arch_id!r}; expected one of "
                         f"{ARCH_IDS} (GNN scenarios resolve via "
                         f"get_gnn_reduced)") from None
