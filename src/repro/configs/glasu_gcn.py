# glint: disable-file=GL010 loaded dynamically via importlib in configs.base (GNN_ARCH_IDS registry)
"""GLASU split-GCN [paper §5.3 backbone study] — plain GCN client layers.

Same split/aggregation schedule as the GCNII config; GCN is also the only
backbone supporting concat aggregation (kept on mean here, matching §5.2).
"""
from ..api.config import ExperimentConfig

CONFIG = ExperimentConfig(
    name="glasu_gcn", dataset="cora", method="glasu", backbone="gcn",
    n_clients=3, n_layers=4, hidden=64, k=2, n_local_steps=4,
    rounds=200, lr=0.01, optimizer="adam",
)


def reduced() -> ExperimentConfig:
    return CONFIG.with_(name="glasu_gcn-reduced", dataset="tiny", hidden=16,
                        batch_size=8, size_cap=96, rounds=8, eval_every=4)
