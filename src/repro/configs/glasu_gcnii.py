# glint: disable-file=GL010 loaded dynamically via importlib in configs.base (GNN_ARCH_IDS registry)
"""GLASU split-GCNII [paper §5.1] — the headline backbone (Tables 2-4).

L=4, hidden=64, M=3 clients, K=2 uniform aggregation (layers 1,3), Q=4 stale
updates, Adam lr=0.01 on the Cora proxy.
"""
from ..api.config import ExperimentConfig

CONFIG = ExperimentConfig(
    name="glasu_gcnii", dataset="cora", method="glasu", backbone="gcnii",
    n_clients=3, n_layers=4, hidden=64, k=2, n_local_steps=4,
    rounds=200, lr=0.01, optimizer="adam",
)


def reduced() -> ExperimentConfig:
    return CONFIG.with_(name="glasu_gcnii-reduced", dataset="tiny", hidden=16,
                        batch_size=8, size_cap=96, rounds=8, eval_every=4)
