"""RWKV-6 "Finch" 7B [arXiv:2404.05892] — attention-free, data-dependent decay.

32L, d_model=4096 (64 heads x 64), channel-mix d_ff=14336, vocab=65536.
GLASU §Arch-applicability: no attention exists, so lazy aggregation of
attention layers is inapplicable; the vertical feature split applies to the
time-mix/channel-mix widths instead (see DESIGN.md).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", kind="ssm",
    n_layers=32, d_model=4096, n_heads=0, n_kv=0, d_head=0,
    d_ff=14336, vocab=65536,
    attn="none", block="rwkv6", ssm_heads=64, ssm_head_dim=64,
    grad_accum=2,
    dtype="bfloat16", optimizer="adamw", lr=3e-4,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, d_model=256, d_ff=512, vocab=512,
                        ssm_heads=4, ssm_head_dim=64,
                        dtype="float32", remat=False, grad_accum=1)
