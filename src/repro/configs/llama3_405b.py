"""Llama-3.1-405B [arXiv:2407.21783] — frontier dense GQA LM.

126L, d_model=16384, 128 heads (GQA kv=8, head_dim=128), d_ff=53248,
vocab=128256, rope theta 500k. Optimizer = adafactor so optimizer state fits
the v5e HBM budget at 256/512 chips (see DESIGN.md hardware adaptation).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", kind="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv=8, d_head=128,
    d_ff=53248, vocab=128256,
    grad_accum=4,
    rope_theta=500000.0, dtype="bfloat16", optimizer="adafactor", lr=8e-5,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, d_model=512, n_heads=8, n_kv=2, d_head=64,
                        d_ff=1024, vocab=512, dtype="float32",
                        optimizer="adamw", remat=False, grad_accum=1)
