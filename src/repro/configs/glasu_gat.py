# glint: disable-file=GL010 loaded dynamically via importlib in configs.base (GNN_ARCH_IDS registry)
"""GLASU split-GAT [paper §5.3 backbone study] — 2-head attention layers.

Attention coefficients are client-local (each client attends over its own
sampled bipartite graph); aggregation across clients stays parameter-free.
"""
from ..api.config import ExperimentConfig

CONFIG = ExperimentConfig(
    name="glasu_gat", dataset="cora", method="glasu", backbone="gat",
    n_clients=3, n_layers=4, hidden=64, gat_heads=2, k=2, n_local_steps=4,
    rounds=200, lr=0.01, optimizer="adam",
)


def reduced() -> ExperimentConfig:
    return CONFIG.with_(name="glasu_gat-reduced", dataset="tiny", hidden=16,
                        batch_size=8, size_cap=96, rounds=8, eval_every=4)
