"""DeepSeek-V2-Lite (16B, 2.4B active) [arXiv:2405.04434] — MLA + MoE.

27L, d_model=2048, 16 heads MLA (kv_lora=512, d_nope=128, d_rope=64, d_v=128),
MoE: 64 routed experts top-6 + 2 shared, expert d_ff=1408; layer 0 dense
(d_ff=10944); vocab=102400. (The assignment header says "MoE 64e top-6";
its bracket note "160 routed" refers to full V2 — we follow the primary
64-expert Lite spec and record the discrepancy here.)
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", kind="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv=16, d_head=128,
    d_ff=10944, vocab=102400,
    moe=True, n_experts=64, top_k=6, n_shared_experts=2, d_ff_expert=1408,
    n_dense_layers=1,
    attn="mla", kv_lora=512, d_nope=128, d_rope=64,
    grad_accum=2,
    dtype="bfloat16", optimizer="adamw", lr=2e-4,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, n_dense_layers=1, d_model=256, n_heads=4,
                        n_kv=4, d_head=64, d_ff=512, vocab=512,
                        n_experts=4, top_k=2, n_shared_experts=1,
                        d_ff_expert=128, kv_lora=64, d_nope=32, d_rope=16,
                        dtype="float32", remat=False, grad_accum=1)
