"""SeamlessM4T-Large v2 [arXiv:2308.11596] — [audio] encoder-decoder backbone.

24L/24L enc-dec, d_model=1024, 16 heads (MHA, kv=16), d_ff=8192,
vocab=256206. The mel-spectrogram + conformer feature frontend is a STUB per
mandate: input_specs provides precomputed frame embeddings (B, T, d_model).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", kind="audio",
    n_layers=24, enc_layers=24, dec_layers=24,
    d_model=1024, n_heads=16, n_kv=16, d_head=64,
    d_ff=8192, vocab=256206,
    frontend="audio", frontend_tokens=0,   # source length = input seq_len
    dtype="bfloat16", optimizer="adamw", lr=1e-4,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, enc_layers=2, dec_layers=2, d_model=256,
                        n_heads=4, n_kv=4, d_ff=512, vocab=512,
                        dtype="float32", remat=False)
