"""Yi-34B [arXiv:2403.04652] — llama-arch dense GQA LM.

60L, d_model=7168, 56 heads (GQA kv=8, head_dim=128), d_ff=20480,
vocab=64000.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b", kind="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv=8, d_head=128,
    d_ff=20480, vocab=64000,
    grad_accum=4,
    rope_theta=5e6, dtype="bfloat16", optimizer="adafactor", lr=1e-4,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, d_model=448, n_heads=7, n_kv=1, d_head=64,
                        d_ff=1024, vocab=512, dtype="float32",
                        optimizer="adamw", remat=False, grad_accum=1)
