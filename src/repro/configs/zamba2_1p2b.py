"""Zamba2-1.2B [arXiv:2411.15242] — hybrid Mamba2 + shared attention blocks.

38 Mamba2 layers (d_model=2048, d_state=64, 64 SSM heads x 64 head dim,
expand=2) with a weight-SHARED attention+MLP block applied every 6 layers
(32 heads MHA kv=32, d_ff=8192); vocab=32000.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", kind="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv=32, d_head=64,
    d_ff=8192, vocab=32000,
    block="mamba2", d_state=64, ssm_heads=64, ssm_head_dim=64, attn_every=6,
    dtype="bfloat16", optimizer="adamw", lr=3e-4,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, d_model=256, n_heads=4, n_kv=4, d_head=64,
                        d_ff=512, vocab=512, ssm_heads=8, ssm_head_dim=32,
                        d_state=16, attn_every=2, ssm_chunk=32,
                        dtype="float32", remat=False)
