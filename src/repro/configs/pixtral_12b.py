"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409] — [vlm] decoder backbone.

Pixtral-ViT vision tower is a STUB (input_specs provides patch embeddings);
the language backbone is Mistral-Nemo-style: 40L, d_model=5120, 32 heads
(GQA kv=8, head_dim=128), d_ff=14336, vocab=131072.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", kind="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, d_head=128,
    d_ff=14336, vocab=131072,
    frontend="vision", frontend_tokens=1024,
    grad_accum=4,
    rope_theta=1e6, dtype="bfloat16", optimizer="adamw", lr=2e-4,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, d_model=256, n_heads=4, n_kv=2, d_head=64,
                        d_ff=512, vocab=512, frontend_tokens=16,
                        dtype="float32", remat=False, grad_accum=1)
