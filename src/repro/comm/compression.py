"""Embedding-exchange compression for the §3.1 aggregation boundary.

GLASU's lazy aggregation and stale updates reduce *how often* clients and
server exchange embeddings; this module compresses *what* is exchanged —
the orthogonal communication axis studied for federated GNNs (FedGCN, Yao
et al. 2022) and limited-communication VFL (Sun et al. 2023). A
``Compressor`` encodes a float32 embedding block into its wire
representation (the arrays that would actually cross the network), decodes
it back to the float32 the receiver works with, and prices one message
exactly (``wire_bytes``), so every byte meter in the repo — analytic,
message log, trace-recorded collectives — stays term-by-term auditable.

Codecs:

  * ``none`` / ``identity`` — no compression; ``make_compressor`` returns
    ``None`` and callers take the uncompressed code path verbatim (so the
    default configuration stays bit-identical to the historical runs).
  * ``int8``  — per-row absmax affine quantization: each row ships as int8
    codes plus one float32 scale (``d + 4`` bytes per ``4d``-byte row).
    All-zero rows are guarded with a unit scale instead of dividing by 0.
  * ``fp8``   — direct cast to ``float8_e4m3fn`` (values clipped into the
    format's finite range first; e4m3fn has no inf and would otherwise
    round overflow to NaN). 1 byte per element, no side channel.
  * ``topk_ef`` — top-k magnitude sparsification: each row ships its k
    largest-|x| entries as (float16 value, int16 column) pairs — 4k bytes
    per row, an 8x reduction at k = d/8. With ``k >= d`` the codec
    degenerates to identity (the dense float32 row is cheaper than
    value+index pairs, so that is what goes on the wire).

Error feedback (EF): a client that compresses its upload keeps the
residual ``x - decode(encode(x))`` in a local accumulator and adds it to
the *next* round's upload, so quantization error is re-injected instead of
lost (Seide et al. 2014; mandatory for top-k to converge). The codecs
themselves are stateless; EF is applied by the call sites via
``roundtrip_with_ef`` wherever encode and decode happen in one place —
the sharded uplink alone inlines the same sequence, because the
``all_gather`` sits between its encode and decode. The accumulators live
in the round state (see ``core.glasu.init_comp_state``), are threaded
through the scanned round engines alongside the optimizer state, and
persist in checkpoints.

Caveat (documented, deliberate): the round engines key EF accumulators by
*slot* (row position in the fixed-shape sampled batch), not by node id —
the sampled node set changes every round, so slot ``i`` carries the
residual of whatever node occupied it last round. This is the standard
fixed-shape-pipeline formulation; it preserves the magnitude statistics EF
needs (and is exactly zero for ``k >= d`` or identity), but it is not
per-node EF. ``docs/BACKENDS.md`` discusses the trade-off.

Everything here is pure ``jax.numpy`` on the last axis, so the codecs run
unchanged under ``vmap``, ``lax.scan``, and ``shard_map`` — the sharded
backend encodes *before* its ``all_gather`` so the collective itself moves
the wire representation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

COMPRESSION_METHODS = ("none", "identity", "int8", "fp8", "topk_ef")

# methods whose uplink keeps an error-feedback accumulator by default
_EF_DEFAULT = {"none": False, "identity": False, "int8": False, "fp8": False,
               "topk_ef": True}


@dataclass(frozen=True)
class CompressionConfig:
    """Validated compression block of an ``ExperimentConfig``.

    ``method`` picks the codec; ``k`` is the per-row budget of ``topk_ef``
    (required there, forbidden elsewhere); ``error_feedback`` toggles the
    uplink/downlink residual accumulators (default: on for ``topk_ef``,
    off for the quantizers, where the per-round error is already zero-mean
    and bounded by half a quantization step).

    ``ef_decay`` scales the residual carried to the next round,
    ``ef <- ef_decay * (input - decoded)``. With the round engines' slot-
    keyed accumulators (node sets change every round, see the module
    docstring) an undecayed residual can accumulate signal from past nodes
    faster than top-k drains it and eventually injects stale mass into the
    wrong node's upload — decay bounds the carry at
    ``ef_decay / (1 - ef_decay)`` times the per-round residual. The
    default 0.5 keeps EF's variance-reduction benefit while staying stable
    on round-varying node sets; 1.0 recovers classic undecayed EF (safe
    when node sets are fixed across rounds).
    """

    method: str = "none"
    k: Optional[int] = None
    error_feedback: Optional[bool] = None
    ef_decay: float = 0.5

    def __post_init__(self):
        if self.method not in COMPRESSION_METHODS:
            raise ValueError(
                f"unknown compression method {self.method!r}; expected one "
                f"of {COMPRESSION_METHODS}")
        if self.method == "topk_ef":
            if self.k is None or self.k < 1:
                raise ValueError(
                    "compression method 'topk_ef' requires k >= 1 "
                    f"(got k={self.k})")
        elif self.k is not None:
            raise ValueError(
                f"compression k={self.k} is only meaningful for method "
                f"'topk_ef' (got method {self.method!r})")
        if not 0.0 <= self.ef_decay <= 1.0:
            raise ValueError(
                f"ef_decay must be in [0, 1], got {self.ef_decay}")

    @property
    def resolved_error_feedback(self) -> bool:
        if self.error_feedback is not None:
            return bool(self.error_feedback)
        return _EF_DEFAULT[self.method]

    @property
    def active(self) -> bool:
        return self.method not in ("none", "identity")


class Compressor:
    """Wire codec: float32 block <-> wire payload + exact byte pricing.

    ``encode`` maps ``(..., d)`` float32 to a dict of wire-dtype arrays
    (the message that crosses the network); ``decode`` maps it back to
    ``(..., d)`` float32. Decode is elementwise per row, so slicing a
    decoded stack equals decoding the sliced payload — the sharded path
    relies on this to update local EF from the gathered decode.
    ``wire_bytes(n, d)`` prices one logical ``(n, d)`` message and must
    equal the byte size of the ``encode`` output exactly (tested).
    """

    method: str = "abstract"
    error_feedback: bool = False
    ef_decay: float = 0.5

    def encode(self, x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        raise NotImplementedError

    def decode(self, payload: Dict[str, jnp.ndarray], d: int) -> jnp.ndarray:
        raise NotImplementedError

    def roundtrip(self, x: jnp.ndarray) -> jnp.ndarray:
        """What the receiver reconstructs from ``x``'s wire message."""
        return self.decode(self.encode(x), x.shape[-1])

    def wire_bytes(self, n_rows: int, d: int) -> int:
        raise NotImplementedError


class Int8Quantizer(Compressor):
    """Per-row absmax int8: codes in [-127, 127] + one f32 scale per row."""

    method = "int8"

    def encode(self, x):
        absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        # all-zero rows: absmax == 0 would divide by zero; a unit scale
        # encodes (and decodes) them exactly as zeros
        scale = jnp.where(absmax > 0.0, absmax / 127.0, 1.0)
        q = jnp.clip(jnp.round(x / scale), -127.0, 127.0).astype(jnp.int8)
        return {"q": q, "scale": scale.astype(jnp.float32)}

    def decode(self, payload, d):
        return payload["q"].astype(jnp.float32) * payload["scale"]

    def wire_bytes(self, n_rows, d):
        return n_rows * d + n_rows * 4


class FloatQuantizer(Compressor):
    """Direct cast to a narrow float format (fp8 e4m3 by default).

    Values are clipped into the target's finite range first: e4m3fn has no
    inf, so an unclipped overflow would round to NaN and poison the
    aggregate. No per-row side channel — 1 byte/element for fp8.
    """

    method = "fp8"

    def __init__(self, dtype=jnp.float8_e4m3fn):
        self.dtype = dtype
        self._max = float(jnp.finfo(dtype).max)
        self._itemsize = jnp.dtype(dtype).itemsize

    def encode(self, x):
        return {"q": jnp.clip(x, -self._max, self._max).astype(self.dtype)}

    def decode(self, payload, d):
        return payload["q"].astype(jnp.float32)

    def wire_bytes(self, n_rows, d):
        return n_rows * d * self._itemsize


class TopKCompressor(Compressor):
    """Top-k magnitude sparsification: (f16 value, i16 column) pairs.

    Keeps the k largest-|x| entries per row. With ``k >= d`` the whole row
    survives, and the codec sends the dense float32 row instead (4d bytes
    beats the 6d of value+index pairs) — exact identity, zero residual.
    """

    method = "topk_ef"
    error_feedback = True

    def __init__(self, k: int):
        if k < 1:
            raise ValueError(f"top-k needs k >= 1, got {k}")
        self.k = int(k)

    # f16 has no inf-free format: clip into the finite range like the fp8
    # codec (an unclipped overflow would ship inf and poison the mean);
    # the clipped-off magnitude lands in the EF residual.
    _F16_MAX = 65504.0
    # i16 covers d <= 32768 columns (indices are 0-based); wider rows
    # (huge concat broadcasts) ship i32 — silently wrapped indices would
    # scatter out of bounds and be DROPPED under jit, no error raised
    _I16_COLS = 2 ** 15

    def encode(self, x):
        d = x.shape[-1]
        if self.k >= d:
            return {"dense": x}
        _, idx = jax.lax.top_k(jnp.abs(x), self.k)
        vals = jnp.take_along_axis(x, idx, axis=-1)
        vals = jnp.clip(vals, -self._F16_MAX, self._F16_MAX)
        idx_dtype = jnp.int16 if d <= self._I16_COLS else jnp.int32
        return {"v": vals.astype(jnp.float16), "i": idx.astype(idx_dtype)}

    def decode(self, payload, d):
        if "dense" in payload:
            return payload["dense"]
        v = payload["v"].astype(jnp.float32)
        i = payload["i"].astype(jnp.int32)
        lead = v.shape[:-1]
        flat_v = v.reshape(-1, self.k)
        flat_i = i.reshape(-1, self.k)
        rows = jnp.arange(flat_v.shape[0])[:, None]
        out = jnp.zeros((flat_v.shape[0], d), jnp.float32)
        out = out.at[rows, flat_i].set(flat_v)
        return out.reshape(lead + (d,))

    def wire_bytes(self, n_rows, d):
        if self.k >= d:
            return n_rows * d * 4
        idx_bytes = 2 if d <= self._I16_COLS else 4
        return n_rows * self.k * (2 + idx_bytes)


def make_compressor(cfg: Optional[CompressionConfig]) -> Optional[Compressor]:
    """Build the codec for a compression block; ``None`` means 'take the
    uncompressed code path' (for ``None`` config, ``none``/``identity``)."""
    if cfg is None or not cfg.active:
        return None
    if cfg.method == "int8":
        comp: Compressor = Int8Quantizer()
    elif cfg.method == "fp8":
        comp = FloatQuantizer()
    elif cfg.method == "topk_ef":
        comp = TopKCompressor(cfg.k)
    else:  # pragma: no cover — CompressionConfig already validated
        raise ValueError(f"unknown compression method {cfg.method!r}")
    comp.error_feedback = cfg.resolved_error_feedback
    comp.ef_decay = cfg.ef_decay
    return comp


def roundtrip_with_ef(comp: Compressor, x: jnp.ndarray,
                      ef: Optional[jnp.ndarray]
                      ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray,
                                 Optional[jnp.ndarray]]:
    """Compress ``x`` (plus the carried residual) through the wire.

    Returns ``(payload, x_hat, new_ef)``: the wire message, what the
    receiver reconstructs, and the sender's updated residual accumulator
    scaled by ``comp.ef_decay`` (``None`` in iff ``None`` out — error
    feedback disabled).
    """
    x_in = x if ef is None else x + ef
    payload = comp.encode(x_in)
    x_hat = comp.decode(payload, x.shape[-1])
    new_ef = None if ef is None else comp.ef_decay * (x_in - x_hat)
    return payload, x_hat, new_ef
