"""Wire-level communication: embedding-exchange compression codecs."""
from .compression import (COMPRESSION_METHODS, CompressionConfig, Compressor,
                          FloatQuantizer, Int8Quantizer, TopKCompressor,
                          make_compressor, roundtrip_with_ef)

__all__ = [
    "COMPRESSION_METHODS", "CompressionConfig", "Compressor",
    "FloatQuantizer", "Int8Quantizer", "TopKCompressor", "make_compressor",
    "roundtrip_with_ef",
]
