"""Pure-JAX optimizers (the container has no optax; the paper uses SGD).

Each optimizer is an (init, update) pair over arbitrary pytrees:

    opt = adam(1e-3)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]
ScalarOrSchedule = Union[float, Schedule]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]


def _lr(lr: ScalarOrSchedule, step):
    return lr(step) if callable(lr) else jnp.asarray(lr)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


# ------------------------------------------------------------------- factory
OPTIMIZER_NAMES = ("sgd", "momentum", "adam", "adamw", "adafactor")


def make_optimizer(name: str, lr: ScalarOrSchedule, momentum: float = 0.9,
                   weight_decay: float = 0.01) -> "Optimizer":
    """Single optimizer factory for the whole repo (union of names).

    'sgd' is plain SGD; 'momentum' is SGD with heavy-ball momentum — callers
    that historically spelled momentum-SGD as 'sgd' normalize the name before
    calling (see core.train / core.steps shims).
    """
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return sgd(lr, momentum=momentum)
    if name == "adam":
        return adam(lr)
    if name == "adamw":
        return adamw(lr, weight_decay=weight_decay)
    if name == "adafactor":
        return adafactor(lr)
    raise ValueError(f"unknown optimizer {name!r}; expected one of "
                     f"{OPTIMIZER_NAMES}")


# ----------------------------------------------------------------- schedules
def constant_schedule(v: float) -> Schedule:
    return lambda step: jnp.asarray(v)


def linear_warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.0) -> Schedule:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return sched


def inverse_sqrt(peak: float, warmup: int) -> Schedule:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        return peak * jnp.minimum(step / jnp.maximum(warmup, 1),
                                  jnp.sqrt(jnp.maximum(warmup, 1) / jnp.maximum(step, 1)))
    return sched


# ---------------------------------------------------------------- optimizers
class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Any


def sgd(lr: ScalarOrSchedule, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        mom = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return SGDState(jnp.zeros([], jnp.int32), mom)

    def update(grads, state, params=None):
        step = state.step + 1
        eta = _lr(lr, state.step)
        if momentum:
            new_mom = jax.tree.map(lambda m, g: momentum * m + g, state.momentum, grads)
            if nesterov:
                upd = jax.tree.map(lambda m, g: -eta * (momentum * m + g), new_mom, grads)
            else:
                upd = jax.tree.map(lambda m: -eta * m, new_mom)
            return upd, SGDState(step, new_mom)
        return jax.tree.map(lambda g: -eta * g, grads), SGDState(step, None)

    return Optimizer(init, update)


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adam(lr: ScalarOrSchedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    """Adam / AdamW (decoupled weight decay when weight_decay > 0)."""

    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return AdamState(jnp.zeros([], jnp.int32), z,
                         jax.tree.map(jnp.zeros_like, z))

    def update(grads, state, params=None):
        step = state.step + 1
        eta = _lr(lr, state.step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def u(m, v, p):
            upd = -eta * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                upd = upd - eta * weight_decay * p.astype(jnp.float32)
            return upd

        if params is None:
            upd = jax.tree.map(lambda m, v: u(m, v, None), mu, nu)
        else:
            upd = jax.tree.map(u, mu, nu, params)
        return upd, AdamState(step, mu, nu)

    return Optimizer(init, update)


def adamw(lr: ScalarOrSchedule, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    # accumulate in f32 via reduce dtype, but scale in the grad dtype —
    # `g * f32_scalar` silently promotes every gradient buffer to f32
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g), dtype=jnp.float32)
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree.map(lambda g: (g * scale.astype(g.dtype)), grads), gnorm


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any     # factored second moment (rows)
    vc: Any     # factored second moment (cols)
    v: Any      # full second moment for <2D leaves


def adafactor(lr: ScalarOrSchedule, eps: float = 1e-30,
              clip_threshold: float = 1.0, decay: float = 0.8) -> Optimizer:
    """Memory-factored Adam (T5X-style, beta1=0): O(rows+cols) second moment.

    The production-scale configs (e.g. llama3-405b) use this so optimizer
    state fits the per-chip HBM budget in the dry-run memory analysis.
    """

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        vr = jax.tree.map(lambda p: jnp.zeros(p.shape[:-1], jnp.float32)
                          if _factored(p) else jnp.zeros((), jnp.float32), params)
        vc = jax.tree.map(lambda p: jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                          if _factored(p) else jnp.zeros((), jnp.float32), params)
        v = jax.tree.map(lambda p: jnp.zeros((), jnp.float32) if _factored(p)
                         else jnp.zeros_like(p, dtype=jnp.float32), params)
        return AdafactorState(jnp.zeros([], jnp.int32), vr, vc, v)

    def update(grads, state, params=None):
        step = state.step + 1
        eta = _lr(lr, state.step)
        beta2 = 1.0 - step.astype(jnp.float32) ** (-decay)

        def upd(g, vr, vc, v):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if g.ndim >= 2:
                nvr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
                nvc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = (nvr / jnp.maximum(jnp.mean(nvr, axis=-1, keepdims=True), eps)
                         )[..., None] * nvc[..., None, :]
                u = g * jax.lax.rsqrt(denom + eps)
                nv = v
            else:
                nv = beta2 * v + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(nv + eps)
                nvr, nvc = vr, vc
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -eta * u, nvr, nvc, nv

        out = jax.tree.map(upd, grads, state.vr, state.vc, state.v)
        treedef = jax.tree.structure(grads)
        flat = treedef.flatten_up_to(out)
        updates = treedef.unflatten([o[0] for o in flat])
        vr = treedef.unflatten([o[1] for o in flat])
        vc = treedef.unflatten([o[2] for o in flat])
        v = treedef.unflatten([o[3] for o in flat])
        return updates, AdafactorState(step, vr, vc, v)

    return Optimizer(init, update)
