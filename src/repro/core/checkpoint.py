"""Checkpointing: save/restore arbitrary pytrees (params + optimizer state).

npz-based (the container has no orbax); leaves are stored flat with
path-derived keys so restore round-trips exact tree structure and dtypes
(bf16 saved via uint16 view). Step-numbered files + a LATEST pointer.
"""
from __future__ import annotations

import json
import zipfile
from pathlib import Path
from typing import Any, NamedTuple, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[dict, str]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = {}
    metas = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        if arr.dtype == np.dtype("bfloat16"):
            arrays[f"leaf_{i}"] = arr.view(np.uint16)
            metas.append("bfloat16")
        else:
            arrays[f"leaf_{i}"] = arr
            metas.append(str(arr.dtype))
    return arrays, json.dumps({"n": len(leaves), "dtypes": metas,
                               "treedef": str(treedef)})


def save(ckpt_dir: str, step: int, tree: Any, name: str = "ckpt") -> str:
    """Save a pytree as ``<name>_<step>.npz``. ``name="ckpt"`` is the main
    training state and advances the LATEST pointer; other names (e.g.
    ``"comp"`` for error-feedback accumulators) are step-aligned sidecars.
    """
    path = Path(ckpt_dir)
    path.mkdir(parents=True, exist_ok=True)
    arrays, meta = _flatten(tree)
    fn = path / f"{name}_{step:08d}.npz"
    np.savez(fn, __meta__=np.frombuffer(meta.encode(), dtype=np.uint8),
             **arrays)
    if name == "ckpt":
        (path / "LATEST").write_text(str(step))
    return str(fn)


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            name: str = "ckpt") -> Any:
    """Restore into the structure/dtypes of ``like`` (an example pytree).

    Errors are loud: a missing file raises FileNotFoundError; a truncated,
    garbled, or structurally mismatched npz raises RuntimeError naming the
    file. A resuming trainer must never silently continue on a half-read
    state (see docs/FAULTS.md for the sidecar contract this backs).
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    fn = Path(ckpt_dir) / f"{name}_{step:08d}.npz"
    if not fn.exists():
        raise FileNotFoundError(
            f"no {name} checkpoint for step {step} in {ckpt_dir}; found: "
            f"{sorted(f.name for f in Path(ckpt_dir).glob(f'{name}_*.npz'))}")
    import jax.numpy as jnp
    try:
        data = np.load(fn)
        meta = json.loads(bytes(data["__meta__"]).decode())
    except (zipfile.BadZipFile, OSError, ValueError, KeyError,
            json.JSONDecodeError) as e:
        raise RuntimeError(
            f"corrupt checkpoint {fn}: {type(e).__name__}: {e}") from e
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if meta["n"] != len(leaves):
        raise RuntimeError(
            f"corrupt/mismatched checkpoint {fn}: stores {meta['n']} "
            f"leaves, restore target has {len(leaves)}")
    restored = []
    for i, dt in enumerate(meta["dtypes"]):  # glint: disable=GL004 host-side restore over heterogeneous pytree leaves; never traced
        try:
            arr = data[f"leaf_{i}"]
        except (zipfile.BadZipFile, KeyError, OSError, ValueError) as e:
            raise RuntimeError(
                f"corrupt checkpoint {fn}: leaf_{i} unreadable: "
                f"{type(e).__name__}: {e}") from e
        if dt == "bfloat16":
            restored.append(jnp.asarray(arr).view(jnp.bfloat16))
        else:
            restored.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, restored)


class InferenceRestore(NamedTuple):
    """``load_for_inference`` result: exactly what a serving process needs."""
    params: Any            # the trained per-client parameter stack
    config: Any            # ExperimentConfig that wrote the checkpoint
    step: int              # training round the params were saved at
    data: Any              # VFLDataset the config binds to (feature stores)


def load_for_inference(ckpt_dir: str, step: Optional[int] = None,
                       data=None) -> InferenceRestore:
    """Restore PARAMS ONLY from a training checkpoint, for serving.

    A training checkpoint stores ``{"params", "opt_state"}`` as one flat
    leaf list; serving needs none of the optimizer state (nor the
    ``comp_<step>.npz`` error-feedback sidecars — compression state is a
    training-time carry). This loader reconstructs the tree structure from
    the ``experiment.json`` the CheckpointHook writes alongside, then pulls
    ONLY the params leaves out of the npz (members decompress lazily, so
    opt-state bytes are never read).

    Errors are loud by design — a serving process must not come up on a
    half-readable checkpoint:

      * no ``experiment.json``     -> FileNotFoundError (can't rebuild the
        model structure the leaves belong to)
      * no ``LATEST`` / bad step   -> FileNotFoundError listing what exists
      * corrupt npz / leaf-count or dtype mismatch -> RuntimeError

    ``data`` short-circuits the dataset rebuild when the caller already
    holds the VFLDataset (tests, benchmarks); it must match the config's
    dataset binding.
    """
    import jax.numpy as jnp

    path = Path(ckpt_dir)
    meta_file = path / "experiment.json"
    if not meta_file.exists():
        raise FileNotFoundError(
            f"no experiment.json in {ckpt_dir}: cannot reconstruct the "
            "model structure this checkpoint's leaves belong to (the "
            "CheckpointHook writes it next to every save)")
    from ..api.config import ExperimentConfig   # local: core must not
    cfg = ExperimentConfig.from_dict(            # import api at module level
        json.loads(meta_file.read_text()))

    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(
            f"no LATEST pointer in {ckpt_dir} and no explicit step given; "
            f"found: {sorted(f.name for f in path.glob('ckpt_*.npz'))}")
    fn = path / f"ckpt_{step:08d}.npz"
    if not fn.exists():
        raise FileNotFoundError(
            f"no checkpoint for step {step} in {ckpt_dir}; found: "
            f"{sorted(f.name for f in path.glob('ckpt_*.npz'))}")

    if data is None:
        from ..graph.synth import make_vfl_dataset
        data = make_vfl_dataset(cfg.dataset, n_clients=cfg.n_clients,
                                seed=cfg.seed)
        if cfg.method == "centralized":
            from .train import make_centralized_dataset
            data = make_centralized_dataset(data)
    from . import glasu
    mcfg = cfg.glasu_config(data)
    params_abs = jax.eval_shape(
        lambda k: glasu.init_params(k, mcfg), jax.random.PRNGKey(0))
    opt_abs = jax.eval_shape(cfg.make_optimizer().init, params_abs)
    # mark each flat leaf slot as params/not-params in the SAME dict-key
    # flatten order the CheckpointHook saved ({"params", "opt_state"})
    marks = jax.tree_util.tree_leaves(
        {"params": jax.tree.map(lambda _: True, params_abs),
         "opt_state": jax.tree.map(lambda _: False, opt_abs)})

    try:
        blob = np.load(fn)
        meta = json.loads(bytes(blob["__meta__"]).decode())
    except (zipfile.BadZipFile, OSError, ValueError, KeyError,
            json.JSONDecodeError) as e:
        raise RuntimeError(
            f"corrupt checkpoint {fn}: {type(e).__name__}: {e}") from e
    if meta["n"] != len(marks):
        raise RuntimeError(
            f"corrupt/mismatched checkpoint {fn}: stores {meta['n']} "
            f"leaves, the config's params+opt_state tree has {len(marks)} "
            "(different optimizer or model than experiment.json claims?)")
    p_leaves = []
    for i, (is_param, dt) in enumerate(zip(marks, meta["dtypes"])):  # glint: disable=GL004 host-side restore over heterogeneous pytree leaves; never traced
        if not is_param:
            continue                     # opt_state member: never loaded
        try:
            arr = blob[f"leaf_{i}"]
        except (zipfile.BadZipFile, KeyError, OSError, ValueError) as e:
            raise RuntimeError(
                f"corrupt checkpoint {fn}: leaf_{i} unreadable: "
                f"{type(e).__name__}: {e}") from e
        p_leaves.append(jnp.asarray(arr).view(jnp.bfloat16)
                        if dt == "bfloat16" else jnp.asarray(arr))
    params = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params_abs), p_leaves)
    for leaf, like in zip(p_leaves, jax.tree_util.tree_leaves(params_abs)):
        if leaf.shape != like.shape:
            raise RuntimeError(
                f"corrupt/mismatched checkpoint {fn}: params leaf shape "
                f"{leaf.shape} != expected {like.shape}")
    return InferenceRestore(params=params, config=cfg, step=int(step),
                            data=data)


def cleanup(ckpt_dir: str, keep: int = 3):
    files = sorted(Path(ckpt_dir).glob("ckpt_*.npz"))
    for f in files[:-keep]:
        f.unlink()
    # sidecars (comp_*.npz EF state, state_*.json loop state) are pruned
    # by CheckpointHook against the surviving ckpt steps — step-aligned,
    # not count-based, so a run that stops writing a sidecar kind doesn't
    # strand stale files
