"""Checkpointing: save/restore arbitrary pytrees (params + optimizer state).

npz-based (the container has no orbax); leaves are stored flat with
path-derived keys so restore round-trips exact tree structure and dtypes
(bf16 saved via uint16 view). Step-numbered files + a LATEST pointer.
"""
from __future__ import annotations

import json
import os
import re
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[dict, str]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = {}
    metas = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        if arr.dtype == np.dtype("bfloat16"):
            arrays[f"leaf_{i}"] = arr.view(np.uint16)
            metas.append("bfloat16")
        else:
            arrays[f"leaf_{i}"] = arr
            metas.append(str(arr.dtype))
    return arrays, json.dumps({"n": len(leaves), "dtypes": metas,
                               "treedef": str(treedef)})


def save(ckpt_dir: str, step: int, tree: Any, name: str = "ckpt") -> str:
    """Save a pytree as ``<name>_<step>.npz``. ``name="ckpt"`` is the main
    training state and advances the LATEST pointer; other names (e.g.
    ``"comp"`` for error-feedback accumulators) are step-aligned sidecars.
    """
    path = Path(ckpt_dir)
    path.mkdir(parents=True, exist_ok=True)
    arrays, meta = _flatten(tree)
    fn = path / f"{name}_{step:08d}.npz"
    np.savez(fn, __meta__=np.frombuffer(meta.encode(), dtype=np.uint8),
             **arrays)
    if name == "ckpt":
        (path / "LATEST").write_text(str(step))
    return str(fn)


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            name: str = "ckpt") -> Any:
    """Restore into the structure/dtypes of ``like`` (an example pytree)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    fn = Path(ckpt_dir) / f"{name}_{step:08d}.npz"
    data = np.load(fn)
    meta = json.loads(bytes(data["__meta__"]).decode())
    import jax.numpy as jnp
    leaves, treedef = jax.tree_util.tree_flatten(like)
    assert meta["n"] == len(leaves), \
        f"checkpoint has {meta['n']} leaves, tree has {len(leaves)}"
    restored = []
    for i, dt in enumerate(meta["dtypes"]):
        arr = data[f"leaf_{i}"]
        if dt == "bfloat16":
            restored.append(jnp.asarray(arr).view(jnp.bfloat16))
        else:
            restored.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, restored)


def cleanup(ckpt_dir: str, keep: int = 3):
    files = sorted(Path(ckpt_dir).glob("ckpt_*.npz"))
    for f in files[:-keep]:
        f.unlink()
    # sidecars (comp_*.npz EF state, state_*.json loop state) are pruned
    # by CheckpointHook against the surviving ckpt steps — step-aligned,
    # not count-based, so a run that stops writing a sidecar kind doesn't
    # strand stale files
