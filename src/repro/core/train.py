"""Legacy GLASU training surface (paper Alg 1) — now a shim.

``TrainConfig``/``TrainResult`` remain the stable result types; the loop
itself lives in ``repro.api.trainer.Trainer`` (hook-driven: periodic exact
eval, early stopping, comm metering per §3.2/§3.4, checkpointing), and
``train_glasu`` adapts the seed's three-config call sites onto it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from ..graph.graph import VFLDataset
from ..graph.sampler import SamplerConfig
from ..optim import optimizers as opt_lib
from . import glasu


@dataclass
class TrainConfig:
    rounds: int = 200                  # T
    lr: float = 0.05
    optimizer: str = "adam"
    eval_every: int = 25
    eval_table_cap: int = 32
    seed: int = 0
    eval_mode: str = "ensemble"        # 'per_client' for standalone


@dataclass
class TrainResult:
    test_acc: float
    val_acc: float
    history: List[Dict] = field(default_factory=list)
    comm_bytes: int = 0
    rounds_run: int = 0
    wall_seconds: float = 0.0
    params: Optional[dict] = None


def _eval_neighbor_tables(data: VFLDataset, cap: int, seed: int):
    """Per-client padded eval neighbor tables only (no feature staging) —
    the piece of ``_eval_tables`` that streamed-store datasets can still
    afford; rng consumption order matches ``_eval_tables`` exactly."""
    rng = np.random.default_rng(seed)
    idx, mask = [], []
    for c in data.clients:
        i, m = c.padded_neighbor_table(cap, rng)
        idx.append(i)
        mask.append(m)
    return jnp.asarray(np.stack(idx)), jnp.asarray(np.stack(mask))


def _eval_tables(data: VFLDataset, cap: int, seed: int):
    from ..graph.feature_store import is_streamed
    if any(is_streamed(c.features) for c in data.clients):
        raise RuntimeError(
            "exact full-graph evaluation materializes all (M, N, d_pad) "
            "features on device, which defeats a streamed feature store; "
            f"dataset {data.name!r} must be served/benched through "
            "row-gather paths (sampler rounds, serve plans) instead")
    nbr_idx, nbr_mask = _eval_neighbor_tables(data, cap, seed)
    d_pad = max(c.feat_dim for c in data.clients)
    feats = []
    for c in data.clients:
        x = np.zeros((c.n_nodes, d_pad), np.float32)
        x[:, :c.feat_dim] = c.features
        feats.append(x)
    return jnp.asarray(np.stack(feats)), nbr_idx, nbr_mask


def make_optimizer(cfg: TrainConfig) -> opt_lib.Optimizer:
    """Deprecated shim — the single factory lives in repro.optim.optimizers.

    Preserves the historical behavior exactly: this driver only ever knew
    sgd/momentum/adam, and every other name fell back to adam.
    """
    name = cfg.optimizer if cfg.optimizer in ("sgd", "momentum", "adam") \
        else "adam"
    return opt_lib.make_optimizer(name, cfg.lr)


def train_glasu(data: VFLDataset, model_cfg: glasu.GlasuConfig,
                sampler_cfg: SamplerConfig, train_cfg: TrainConfig,
                target_acc: Optional[float] = None) -> TrainResult:
    """Run T rounds of Alg 1; optionally stop at a target accuracy (Table 4).

    Deprecated shim over the unified experiment API: adapts the three legacy
    configs into one ``ExperimentConfig`` and delegates to ``api.Trainer``
    (which reproduces this driver's sampling order, eval cadence, byte meter,
    and best-val bookkeeping exactly). New code should build an
    ``ExperimentConfig`` — or start from ``api.presets`` — directly.
    """
    from ..api import ExperimentConfig, Trainer
    cfg = ExperimentConfig.from_legacy(model_cfg, sampler_cfg, train_cfg,
                                       target_acc=target_acc,
                                       dataset=data.name)
    return Trainer(cfg, data=data).run()


def make_centralized_dataset(data: VFLDataset) -> VFLDataset:
    """M=1 view holding the union graph + full features (paper's Cent.)."""
    return VFLDataset(data.name + "-centralized", [data.full], data.full)
