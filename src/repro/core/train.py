"""GLASU training driver (paper Alg 1) with communication accounting.

The driver owns the host-side sampler, the jitted round function, periodic
exact full-graph evaluation, and the byte meter that implements the paper's
communication cost model (uploads + broadcasts at aggregation layers, index
sync — §3.2/§3.4: saving factor QL/K vs per-layer-per-iteration baselines).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.graph import VFLDataset
from ..graph.sampler import GlasuSampler, SamplerConfig
from ..optim import optimizers as opt_lib
from . import glasu


@dataclass
class TrainConfig:
    rounds: int = 200                  # T
    lr: float = 0.05
    optimizer: str = "adam"
    eval_every: int = 25
    eval_table_cap: int = 32
    seed: int = 0
    eval_mode: str = "ensemble"        # 'per_client' for standalone


@dataclass
class TrainResult:
    test_acc: float
    val_acc: float
    history: List[Dict] = field(default_factory=list)
    comm_bytes: int = 0
    rounds_run: int = 0
    wall_seconds: float = 0.0
    params: Optional[dict] = None


def _eval_tables(data: VFLDataset, cap: int, seed: int):
    rng = np.random.default_rng(seed)
    idx, mask, feats = [], [], []
    d_pad = max(c.feat_dim for c in data.clients)
    for c in data.clients:
        i, m = c.padded_neighbor_table(cap, rng)
        idx.append(i)
        mask.append(m)
        x = np.zeros((c.n_nodes, d_pad), np.float32)
        x[:, :c.feat_dim] = c.features
        feats.append(x)
    return (jnp.asarray(np.stack(feats)), jnp.asarray(np.stack(idx)),
            jnp.asarray(np.stack(mask)))


def make_optimizer(cfg: TrainConfig) -> opt_lib.Optimizer:
    if cfg.optimizer == "sgd":
        return opt_lib.sgd(cfg.lr)
    if cfg.optimizer == "momentum":
        return opt_lib.sgd(cfg.lr, momentum=0.9)
    return opt_lib.adam(cfg.lr)


def train_glasu(data: VFLDataset, model_cfg: glasu.GlasuConfig,
                sampler_cfg: SamplerConfig, train_cfg: TrainConfig,
                target_acc: Optional[float] = None) -> TrainResult:
    """Run T rounds of Alg 1; optionally stop at a target accuracy (Table 4)."""
    assert model_cfg.n_clients == data.n_clients
    sampler = GlasuSampler(data, sampler_cfg, seed=train_cfg.seed)
    optimizer = make_optimizer(train_cfg)
    key = jax.random.PRNGKey(train_cfg.seed)
    params = glasu.init_params(key, model_cfg)
    opt_state = optimizer.init(params)
    round_fn = glasu.make_round_fn(model_cfg, optimizer)

    feats_full, nbr_idx, nbr_mask = _eval_tables(
        data, train_cfg.eval_table_cap, train_cfg.seed)
    eval_fn = jax.jit(lambda p: glasu.full_forward(
        p, model_cfg, feats_full, nbr_idx, nbr_mask,
        chunk=min(4096, data.n_nodes)))

    bytes_per_round = (sampler.comm_bytes_per_joint_inference(
        model_cfg.hidden, model_cfg.agg)
        if model_cfg.agg_layers and data.n_clients > 1 else 0)

    result = TrainResult(0.0, 0.0)
    t0 = time.perf_counter()
    for t in range(train_cfg.rounds):
        batch = sampler.sample_round()
        batch = jax.tree.map(jnp.asarray, batch)
        params, opt_state, losses = round_fn(params, opt_state, batch,
                                             jax.random.fold_in(key, t))
        result.comm_bytes += bytes_per_round
        result.rounds_run = t + 1
        if (t + 1) % train_cfg.eval_every == 0 or t == train_cfg.rounds - 1:
            logits = eval_fn(params)
            val = float(glasu.accuracy_from_logits(
                logits, data.full.labels, data.full.val_idx, train_cfg.eval_mode))
            test = float(glasu.accuracy_from_logits(
                logits, data.full.labels, data.full.test_idx, train_cfg.eval_mode))
            result.history.append({"round": t + 1, "loss": float(losses[-1]),
                                   "val_acc": val, "test_acc": test,
                                   "comm_bytes": result.comm_bytes,
                                   "seconds": time.perf_counter() - t0})
            if val >= result.val_acc:
                result.val_acc, result.test_acc = val, test
            if target_acc is not None and val >= target_acc:
                break
    result.wall_seconds = time.perf_counter() - t0
    result.params = params
    return result


def make_centralized_dataset(data: VFLDataset) -> VFLDataset:
    """M=1 view holding the union graph + full features (paper's Cent.)."""
    return VFLDataset(data.name + "-centralized", [data.full], data.full)
