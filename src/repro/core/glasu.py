"""GLASU: split-model VFL-GNN with lazy aggregation and stale updates.

Implements the paper's Algorithms 1 (training round), 3 (JointInference with
Extract) and 4 (LocalUpdate with stale cross-client representations), plus the
three baselines of §5.2 as special cases (§3.5):

  * centralized            -> M = 1
  * standalone [8]-style   -> agg_layers = () (clients never communicate)
  * simulated centralized [9] -> agg_layers = all layers, Q = 1
  * FedBCD [2]             -> A(E_m) = I (no graph; covered by unit test)

Execution model: the M clients are a stacked leading axis on every parameter
and activation leaf, and client-local compute is ``jax.vmap`` over that axis.
Server aggregation (parameter-free mean/concat, §3.1) is a cross-client
reduction — the only place information crosses the axis, exactly where the
paper places communication. ``CommMeter`` charges bytes for those crossings
using the paper's cost model.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from ..comm import compression
from ..comm.compression import CompressionConfig, Compressor
from ..graph.sampler import SampledBatch
from ..models.gnn import BACKBONES
from ..optim import optimizers as opt_lib


@dataclass(frozen=True)
class GlasuConfig:
    n_clients: int = 3
    n_layers: int = 4
    hidden: int = 64
    n_classes: int = 7
    d_in: int = 478                       # padded per-client feature width
    backbone: str = "gcnii"
    agg: str = "mean"                     # 'mean' | 'concat' (parameter-free, §3.1)
    agg_layers: Sequence[int] = (1, 3)    # lazy aggregation index set I
    n_local_steps: int = 1                # Q (stale updates)
    gcnii_alpha: float = 0.1
    gcnii_beta: float = 0.5
    gat_heads: int = 2
    dp_sigma: float = 0.0                 # §3.6 DP hook (noise on uploads)
    secure_agg: bool = False              # §3.6 SA hook (cancelling masks)
    labels_at_client: Optional[int] = None  # Appendix B.2 (Alg 5-7): one label owner
    use_pallas: bool = False              # fused Pallas kernels (GCN/GCNII/GAT)
    compression: Optional[CompressionConfig] = None  # wire codec at the Agg boundary
    fault_tolerant: bool = False          # deadline rounds + stale-cache fallback

    def __post_init__(self):
        if self.agg_layers:
            assert (self.n_layers - 1) in self.agg_layers, \
                "prediction layer input must be aggregated (paper §3.1)"
        if self.agg == "concat":
            assert self.backbone == "gcn", "concat aggregation implemented for GCN"
        if self.compression is not None and self.compression.active:
            assert not self.secure_agg, \
                "secure_agg masks cancel only exactly; quantized/sparsified " \
                "uploads break the pairwise cancellation (disable one)"
        if self.fault_tolerant:
            assert self.agg_layers, \
                "fault tolerance shapes the aggregation exchange; a " \
                "standalone run has nothing to be tolerant about"
            assert not self.secure_agg and self.dp_sigma == 0.0, \
                "the §3.6 privacy hooks assume every round's uploads are " \
                "fresh; cached substitutes break mask cancellation / the " \
                "noise accounting — disable privacy hooks or faults"
            assert self.labels_at_client is None, \
                "labels_at_client (Alg 6) needs the owner's upload every " \
                "round; not supported with fault injection"

    def layer_in_dim(self, l: int) -> int:
        """Input width of layer l (concat widens post-aggregation layers)."""
        if l == 0:
            return self.hidden
        widened = self.agg == "concat" and (l - 1) in self.agg_layers
        return self.hidden * (self.n_clients if widened else 1)


def init_params(key, cfg: GlasuConfig):
    """Per-client stacked parameters: every leaf has leading dim M."""
    init_layer, _ = BACKBONES[cfg.backbone]
    keys = jax.random.split(key, cfg.n_layers + 2)

    def stack(fn, k):
        return jax.vmap(fn)(jax.random.split(k, cfg.n_clients))

    scale_in = jnp.sqrt(2.0 / cfg.d_in)
    params = {
        "inp": stack(lambda k: {"W": jax.random.normal(k, (cfg.d_in, cfg.hidden)) * scale_in,
                                "b": jnp.zeros((cfg.hidden,))}, keys[0]),
        "layers": [],
        "cls": None,
    }
    for l in range(cfg.n_layers):
        d_in = cfg.layer_in_dim(l)
        kw = {"n_heads": cfg.gat_heads} if cfg.backbone == "gat" else {}
        params["layers"].append(
            stack(lambda k, d=d_in, kw=kw: init_layer(k, d, cfg.hidden, **kw), keys[l + 1]))
    d_cls = cfg.hidden * (cfg.n_clients if cfg.agg == "concat" else 1)
    scale_c = jnp.sqrt(1.0 / d_cls)
    params["cls"] = stack(lambda k: {"W": jax.random.normal(k, (d_cls, cfg.n_classes)) * scale_c,
                                     "b": jnp.zeros((cfg.n_classes,))}, keys[-1])
    return params


# --------------------------------------------------------------------- layers
def _pallas_gcn_layer(p, h, h0, idx, mask):
    """GCN client sub-layer on the fused Pallas graph_agg kernel
    (one-hot gather-matmul + masked mean + MXU matmul in one pallas_call)."""
    from ..kernels import ops as kops
    out = kops.graph_agg(h, idx, mask, p["W"])
    return jax.nn.relu(out + p["b"])


def _pallas_gcnii_layer(p, h, h0, idx, mask, alpha, beta):
    """GCNII client sub-layer fully fused: gather-mean + initial residual +
    identity-map skip + matmul + relu in one pallas_call."""
    from ..kernels import ops as kops
    return kops.gcnii_layer(h, h0, idx, mask, p["W"], p["b"],
                            alpha=alpha, beta=beta)


def _pallas_gat_layer(p, h, h0, idx, mask):
    """GAT client sub-layer fully fused: per-head projection + masked softmax
    attention over the sampled fanout + head mix in one pallas_call."""
    from ..kernels import ops as kops
    return kops.gat_layer(h, idx, mask, p["W"], p["a_src"], p["a_dst"],
                          p["b"])


def _client_layer(cfg: GlasuConfig, l: int):
    """Resolve layer l's sub-layer fn; ``use_pallas=True`` covers all three
    paper backbones (GCN, GCNII, GAT) with fused kernels."""
    _, layer_fn = BACKBONES[cfg.backbone]
    if cfg.backbone == "gcnii":
        beta = cfg.gcnii_beta / (l + 1)   # beta_l = lambda / l decay as in [7]
        if cfg.use_pallas:
            return functools.partial(_pallas_gcnii_layer,
                                     alpha=cfg.gcnii_alpha, beta=beta)
        return functools.partial(layer_fn, alpha=cfg.gcnii_alpha, beta=beta)
    if cfg.backbone == "gcn" and cfg.use_pallas:
        return _pallas_gcn_layer
    if cfg.backbone == "gat" and cfg.use_pallas:
        return _pallas_gat_layer
    return layer_fn


def _aggregate(cfg: GlasuConfig, h_plus, key=None):
    """Server Agg (paper §3.1): parameter-free mean/concat across clients.

    h_plus: (M, n, h). Returns (agg, stale) where
      stale[m] = Extract(H[l+1], H_m^+[l])  — the "all-but-m" buffer (§3.3).
    Optional §3.6 hooks: pairwise-cancelling secure-agg masks and DP noise are
    applied to the *uploads*; the mean is unchanged by SA masks by design.
    """
    m = h_plus.shape[0]
    uploads = h_plus
    if cfg.secure_agg and key is not None:
        # masks and DP noise draw from DISTINCT derived subkeys; sampling
        # with the raw caller key would collide with any other consumer of
        # that key (glint GL002)
        mkey = jax.random.fold_in(key, 0)
        masks = jax.random.normal(mkey, h_plus.shape, h_plus.dtype)
        masks = masks - jnp.mean(masks, axis=0, keepdims=True)  # sum_m mask_m = 0
        uploads = uploads + masks
    if cfg.dp_sigma > 0.0 and key is not None:
        nkey = jax.random.fold_in(key, 1)
        uploads = uploads + cfg.dp_sigma * jax.random.normal(nkey, h_plus.shape, h_plus.dtype)
    if cfg.agg == "mean":
        agg = jnp.mean(uploads, axis=0)                      # (n, h)
        stale = agg[None] - uploads / m                      # Extract: H - H_m^+/M
        return jnp.broadcast_to(agg[None], h_plus.shape), stale
    # concat: (n, M*h); stale keeps other clients' blocks (own block zeroed)
    n, h = h_plus.shape[1], h_plus.shape[2]
    agg = jnp.transpose(uploads, (1, 0, 2)).reshape(n, m * h)
    own_block = jnp.eye(m, dtype=h_plus.dtype)               # (M, M)
    blockmask = jnp.repeat(1.0 - own_block, h, axis=1)       # (M, M*h)
    stale = agg[None] * blockmask[:, None, :]
    return jnp.broadcast_to(agg[None], (m, n, m * h)), stale


def _combine_with_stale(cfg: GlasuConfig, stale_l, h_plus_m, m_index=None,
                        w=None, denom=None):
    """Client-side Agg(H_{-m} (stale), H_m^{+} (fresh)) — Alg 4 line 6.

    ``w``/``denom`` carry the fault-tolerant round's participation weight
    for this client and the weighted-mean denominator; ``None`` (the
    default) is the legacy bit-identical path dividing by M.
    """
    if cfg.agg == "mean":
        if w is None:
            return stale_l + h_plus_m / cfg.n_clients
        return stale_l + w * h_plus_m / denom
    n, h = h_plus_m.shape
    own = jnp.zeros((n, cfg.n_clients, h), h_plus_m.dtype)
    own = own.at[:, m_index, :].set(h_plus_m if w is None else w * h_plus_m)
    return stale_l + own.reshape(n, cfg.n_clients * h)


# ------------------------------------------------------ compressed exchange
def init_comp_state(cfg: GlasuConfig, layer_sizes: Sequence[int],
                    compressor: Optional[Compressor] = None):
    """Error-feedback accumulators for the compressed embedding exchange.

    Returns ``None`` when compression is off (callers take the legacy code
    path), ``{}`` when compression is on without error feedback (stateless
    codecs thread an empty carry), else per aggregation layer one uplink
    accumulator (client-resident, shape ``(M, n_{l+1}, hidden)``) and one
    downlink accumulator (server-resident, ``(n_{l+1}, h_agg)``).
    ``layer_sizes`` is the sampler's static node-set size plan
    (``GlasuSampler.layer_sizes``, length L+1).
    """
    comp = compressor if compressor is not None else \
        compression.make_compressor(cfg.compression)
    if comp is None:
        return None
    if not comp.error_feedback:
        return {}
    down_h = cfg.hidden * (cfg.n_clients if cfg.agg == "concat" else 1)
    state = {}
    for l in cfg.agg_layers:  # glint: disable=GL004 init-time alloc over a static layer set, runs once
        n = layer_sizes[l + 1]
        state[l] = {
            "up": jnp.zeros((cfg.n_clients, n, cfg.hidden), jnp.float32),
            "down": jnp.zeros((n, down_h), jnp.float32)}
    return state


def _payload_msg_bytes(payload, lead_dims: int) -> int:
    """Static wire size of ONE message in a payload whose leaves carry
    ``lead_dims`` leading batch axes (0 = the payload IS one message)."""
    return sum(math.prod(leaf.shape[lead_dims:]) * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree.leaves(payload))


def _compressed_aggregate(cfg: GlasuConfig, comp: Compressor, h_plus, ef_l,
                          key=None, *, gather=None, i0=0, record=None,
                          layer: int = -1, cache_l=None,
                          faults: Optional["RoundFaults"] = None):
    """Server Agg (§3.1) with wire compression on both exchange legs.

    ``h_plus``: ``(m_blk, n, h)`` fresh client uploads — the full client
    stack on the vmapped path, the device-local block under ``shard_map``
    (then ``gather`` stacks payload leaves along the global client axis and
    ``i0`` is the block's global offset). ``ef_l`` is the layer's
    error-feedback entry (``{"up", "down"}``) or ``None``.

    Protocol (what a deployed system would do):
      1. client m adds DP noise (§3.6) and its carried residual, encodes,
         and uploads the wire payload;
      2. the server decodes all uploads, aggregates (`mean`/`concat` on the
         DEQUANTIZED values), adds the downlink residual, encodes, and
         broadcasts the compressed aggregate;
      3. client m decodes the broadcast, subtracts its own dequantized
         upload (Extract — it knows its own wire message exactly) to get
         the stale buffer H_{-m}, and continues forward with
         Agg(H_{-m}, H_m^+) — its exact fresh block plus the compressed
         view of everyone else.

    Composed fault-tolerant mode (``cache_l``/``faults`` given): the server
    keeps a cache of each client's last DELIVERED **decoded** block and
    substitutes it for absent clients, then aggregates with the round's
    participation weights (the same weighted mean as ``_fault_agg_math``,
    on dequantized values). Error feedback is slot-keyed per client and
    updated ONLY for clients whose upload was delivered this round — an
    absent client's residual is frozen, not decayed: it never transmitted,
    so there is nothing new to account for. ``cache_l`` is the full
    ``(M, n, h)`` decoded server view (replicated under ``shard_map``;
    every device recomputes it from the gathered payload).

    Returns ``(h, stale, new_ef_l, new_cache_l, denom)`` with ``h``/
    ``stale`` of shape ``(m_blk, n, h_agg)``; ``new_ef_l`` is ``None`` iff
    ``ef_l`` was, and ``new_cache_l``/``denom`` are ``None`` outside the
    composed mode. Decode is elementwise per row, so slicing the decoded
    global stack equals decoding the local payload — the local EF update
    relies on it.
    """
    m = cfg.n_clients
    m_blk = h_plus.shape[0]
    uploads = h_plus
    if cfg.dp_sigma > 0.0 and key is not None:
        # the global (M, n, h) draw is generated everywhere and sliced so
        # the sharded path adds bit-identical noise to the vmapped one
        nkey = jax.random.fold_in(key, 1)
        noise = cfg.dp_sigma * jax.random.normal(
            nkey, (m,) + h_plus.shape[1:], h_plus.dtype)
        if m_blk != m:
            noise = jax.lax.dynamic_slice_in_dim(noise, i0, m_blk, axis=0)
        uploads = uploads + noise
    ef_up = ef_l["up"] if ef_l is not None else None
    up_in = uploads if ef_up is None else uploads + ef_up
    payload = comp.encode(up_in)                        # client -> server
    wire = payload if gather is None else jax.tree.map(gather, payload)
    up_hat = comp.decode(wire, h_plus.shape[-1])        # (M, n, h) at server
    up_hat_blk = up_hat if m_blk == m else \
        jax.lax.dynamic_slice_in_dim(up_hat, i0, m_blk, axis=0)
    n, h = up_hat.shape[1], up_hat.shape[2]

    if faults is None:
        # the carried residual is decayed: accumulators are slot-keyed
        # while the sampled node set changes every round (not true
        # per-node EF) — see CompressionConfig.ef_decay for why undecayed
        # carry destabilizes
        new_ef_up = None if ef_up is None else \
            comp.ef_decay * (up_in - up_hat_blk)
        new_cache_l = denom = None
        eff_blk = up_hat_blk
        w_blk = None
        if cfg.agg == "mean":
            agg = jnp.mean(up_hat, axis=0)              # (n, h)
        else:
            agg = jnp.transpose(up_hat, (1, 0, 2)).reshape(n, m * h)
    else:
        p_blk = faults.present if m_blk == m else \
            jax.lax.dynamic_slice_in_dim(faults.present, i0, m_blk, axis=0)
        # absent clients never transmitted: their residual is frozen
        new_ef_up = None if ef_up is None else jnp.where(
            p_blk[:, None, None] > 0,
            comp.ef_decay * (up_in - up_hat_blk), ef_up)
        # server view: decoded fresh block where delivered, cache elsewhere
        eff = jnp.where(faults.present[:, None, None] > 0, up_hat, cache_l)
        new_cache_l = eff
        eff_blk = eff if m_blk == m else \
            jax.lax.dynamic_slice_in_dim(eff, i0, m_blk, axis=0)
        w = faults.weight[:, None, None].astype(up_hat.dtype)
        w_blk = faults.weight if m_blk == m else \
            jax.lax.dynamic_slice_in_dim(faults.weight, i0, m_blk, axis=0)
        w_blk = w_blk.astype(up_hat.dtype)
        if cfg.agg == "mean":
            denom = jnp.maximum(jnp.sum(faults.weight),
                                1.0).astype(up_hat.dtype)
            agg = jnp.sum(w * eff, axis=0) / denom      # (n, h)
        else:
            denom = jnp.asarray(1.0, up_hat.dtype)
            agg = jnp.transpose(w * eff, (1, 0, 2)).reshape(n, m * h)

    ef_down = ef_l["down"] if ef_l is not None else None
    down_payload, down_hat, new_ef_down = compression.roundtrip_with_ef(
        comp, agg, ef_down)                             # server -> clients

    if record is not None:
        record(CollectiveRecord(
            layer=layer, n_clients=m, n_rows=n, width_up=h,
            width_down=agg.shape[-1],
            itemsize=jnp.dtype(h_plus.dtype).itemsize,
            up_bytes=_payload_msg_bytes(payload, 1),
            down_bytes=_payload_msg_bytes(down_payload, 0)))

    if cfg.agg == "mean":
        if faults is None:
            stale = down_hat[None] - eff_blk / m        # Extract per client
        else:
            stale = down_hat[None] - w_blk[:, None, None] * eff_blk / denom
    else:
        own_block = jnp.eye(m, dtype=h_plus.dtype)
        blockmask = jnp.repeat(1.0 - own_block, h, axis=1)   # (M, M*h)
        if m_blk != m:
            blockmask = jax.lax.dynamic_slice_in_dim(blockmask, i0, m_blk,
                                                     axis=0)
        stale = down_hat[None] * blockmask[:, None, :]
    g_idx = i0 + jnp.arange(m_blk)
    if faults is None:
        h_out = jax.vmap(lambda s, hp, g: _combine_with_stale(cfg, s, hp, g))(
            stale, h_plus, g_idx)
    else:
        h_out = jax.vmap(
            lambda s, hp, g, wm: _combine_with_stale(cfg, s, hp, g, w=wm,
                                                     denom=denom))(
            stale, h_plus, g_idx, w_blk)
    new_ef_l = None if ef_l is None else {"up": new_ef_up,
                                          "down": new_ef_down}
    return h_out, stale, new_ef_l, new_cache_l, denom


# ------------------------------------------------- fault-tolerant exchange
class RoundFaults(NamedTuple):
    """Device-side view of one round's fault draw (``fed.faults.RoundPlan``).

    Two shape-static ``(M,)`` float32 vectors — the jitted/scanned round
    body never changes shape with the draw. Under ``lax.scan`` the leaves
    carry a leading round axis K and ride in the scan's xs.
    """
    present: Any      # 1.0 = the client's upload arrived before the deadline
    weight: Any       # 1.0 = fresh-or-valid-cache block enters the aggregate


def init_fault_state(cfg: GlasuConfig, layer_sizes: Sequence[int]):
    """Stale-embedding cache for the fault-tolerant exchange.

    ``None`` when fault tolerance is off; else per aggregation layer the
    last *delivered* upload stack, slot-keyed ``(M, n_{l+1}, hidden)``
    exactly like the PR-5 error-feedback accumulators (``layer_sizes`` is
    the sampler's static node-set plan, so the carry is shape-static and
    scan/donation-friendly). Starts at zeros; a never-delivered client's
    slot is excluded from the aggregate by its zero weight, never read.
    """
    if not cfg.fault_tolerant:
        return None
    return {l: jnp.zeros((cfg.n_clients, layer_sizes[l + 1], cfg.hidden),
                         jnp.float32)
            for l in cfg.agg_layers}  # glint: disable=GL004 init-time alloc over a static layer set, runs once


def _fault_agg_math(cfg: GlasuConfig, uploads, weight):
    """Weighted server Agg over effective (fresh-or-cached) uploads.

    ``uploads``: the full (M, n, h) effective stack; ``weight``: (M,)
    participation weights. Returns ``(h, stale, denom)`` with the same
    shapes/semantics as ``_aggregate``. At weight == 1 everywhere this is
    the legacy mean up to summation order (``sum(w*u)/M`` vs ``mean``),
    which is what the degraded-mode conformance rows pin down.
    """
    m = uploads.shape[0]
    w = weight[:, None, None].astype(uploads.dtype)
    if cfg.agg == "mean":
        # an all-zero weight row (every block aged out mid-crash) divides
        # by 1 instead of 0; the aggregate is zeros and weights exclude it
        denom = jnp.maximum(jnp.sum(weight), 1.0).astype(uploads.dtype)
        agg = jnp.sum(w * uploads, axis=0) / denom          # (n, h)
        stale = agg[None] - w * uploads / denom
        return jnp.broadcast_to(agg[None], uploads.shape), stale, denom
    # concat: zero-weight blocks are zeroed in place (documented: no
    # renormalization across the concatenated width)
    n, h = uploads.shape[1], uploads.shape[2]
    denom = jnp.asarray(1.0, uploads.dtype)
    agg = jnp.transpose(w * uploads, (1, 0, 2)).reshape(n, m * h)
    own_block = jnp.eye(m, dtype=uploads.dtype)
    blockmask = jnp.repeat(1.0 - own_block, h, axis=1)       # (M, M*h)
    stale = agg[None] * blockmask[:, None, :]
    return jnp.broadcast_to(agg[None], (m, n, m * h)), stale, denom


# -------------------------------------------------------- execution policy
class ExecPolicy(NamedTuple):
    """How one GLASU round executes — the three orthogonal axes the paper's
    round is invariant to, captured once so a single round body serves
    every builder:

      * aggregation transport: vmapped client stack (``axis_name=None``) vs
        per-device client blocks gathered with ``all_gather`` under
        ``shard_map`` (``axis_name``/``m_loc`` set);
      * exchange codec: identity (``compressor=None``) vs the PR-5 wire
        compressor at the Agg boundary;
      * participation: all-present vs deadline-round ``RoundPlan`` masks
        with the stale-embedding cache (``fault_tolerant``).

    ``record`` is the trace-time :class:`CollectiveRecord` hook of the
    byte meter. The policy is static Python state closed over at build
    time — it never crosses a jit boundary.
    """
    axis_name: Optional[str] = None   # None = vmapped; else shard_map axis
    m_loc: int = 0                    # clients per device (sharded only)
    compressor: Optional[Compressor] = None
    fault_tolerant: bool = False
    record: Any = None

    @property
    def sharded(self) -> bool:
        return self.axis_name is not None


def _policy(cfg: GlasuConfig, axis_name: Optional[str] = None,
            m_loc: int = 0, record=None) -> ExecPolicy:
    """Resolve ``cfg``'s codec/participation axes into an ExecPolicy."""
    return ExecPolicy(axis_name=axis_name, m_loc=m_loc,
                      compressor=compression.make_compressor(cfg.compression),
                      fault_tolerant=cfg.fault_tolerant, record=record)


def _policy_arity(pol: ExecPolicy):
    """Which carries the round threads: (error-feedback, fault-cache).
    Determines the builder signatures — each active carry adds one leading
    state argument and one result, and faults append a mask argument."""
    return pol.compressor is not None, pol.fault_tolerant


def _record_dense(record, l: int, uploads, h_full):
    """Byte-meter record for an UNCOMPRESSED aggregation collective: wire
    size is the dense (n, h) block per message on both legs."""
    isz = jnp.dtype(uploads.dtype).itemsize
    record(CollectiveRecord(
        layer=l, n_clients=uploads.shape[0], n_rows=uploads.shape[1],
        width_up=uploads.shape[2], width_down=h_full.shape[-1],
        itemsize=isz,
        up_bytes=uploads.shape[1] * uploads.shape[2] * isz,
        down_bytes=uploads.shape[1] * h_full.shape[-1] * isz))


def _slice_block(pol: ExecPolicy, x, i0):
    """Device-local client block of a global (M, ...) stack; identity on
    the vmapped path where the block IS the full stack."""
    if not pol.sharded:
        return x
    return jax.lax.dynamic_slice_in_dim(x, i0, pol.m_loc, axis=0)


def _joint_inference_engine(params, batch: SampledBatch, cfg: GlasuConfig,
                            pol: ExecPolicy, key=None, comp_state=None,
                            fault_state=None,
                            faults: Optional[RoundFaults] = None):
    """Alg 3 (JointInference with Extract) under any :class:`ExecPolicy`.

    THE round-forward body — every public entry (``joint_inference``,
    ``fault_joint_inference``, ``sharded_joint_inference``) and every
    builder instantiates this one function; the policy only selects the
    transport (local stack vs gather), the codec (identity vs compressed
    exchange) and the participation rule (all-present vs masked with the
    stale-embedding cache).

    ``params``/``batch`` leaves carry the full client stack on the vmapped
    path and the device-local block under ``shard_map``; ``key``,
    ``faults`` and (with compression) the fault cache are replicated.

    Returns ``(logits, stale, new_comp_state, new_fault_state, denom)``;
    the two carries are ``{}`` when their axis is off, ``denom`` is the
    weighted-mean denominator of the fault aggregation (dtype-cast to the
    uploads exactly once, in ``_fault_agg_math`` /
    ``_compressed_aggregate`` — the vmapped/sharded drift this engine
    retired) and M when faults are off.
    """
    h = jax.vmap(lambda p, x: x @ p["W"] + p["b"])(params["inp"],
                                                   batch.feats)
    h0 = h
    stale: Dict[int, Any] = {}
    new_comp: Dict[int, Any] = {}
    new_cache: Dict[int, Any] = {}
    denom = jnp.asarray(cfg.n_clients, jnp.float32)
    i0 = jax.lax.axis_index(pol.axis_name) * pol.m_loc if pol.sharded else 0
    gather = (lambda x: _gather_clients(x, pol.axis_name)) if pol.sharded \
        else None
    for l in range(cfg.n_layers):  # glint: disable=GL004 static L-layer unroll; per-layer params are heterogeneous (widths change at agg boundaries)
        layer = _client_layer(cfg, l)
        h_plus = jax.vmap(layer)(params["layers"][l], h, h0,
                                 batch.gather_idx[l], batch.gather_mask[l])
        h0 = jax.vmap(lambda a, i: a[i])(h0, batch.self_pos[l])
        if l not in cfg.agg_layers:
            h = h_plus
            continue
        # fault rounds never consume the key: the §3.6 privacy hooks are
        # config-excluded with faults and the legacy fault engines never
        # folded it (trace identity for the golden rows)
        subkey = jax.random.fold_in(key, l) \
            if key is not None and not pol.fault_tolerant else None
        if pol.compressor is not None:
            ef_l = comp_state.get(l) if comp_state else None
            cache_l = fault_state[l] if pol.fault_tolerant else None
            h, stale[l], new_ef, cache, d = _compressed_aggregate(
                cfg, pol.compressor, h_plus, ef_l, subkey, gather=gather,
                i0=i0, record=pol.record, layer=l, cache_l=cache_l,
                faults=faults)
            if new_ef is not None:
                new_comp[l] = new_ef
            if pol.fault_tolerant:
                new_cache[l] = cache
                denom = d
        elif pol.fault_tolerant:
            # fresh where delivered, staleness-bounded cache elsewhere
            p_blk = _slice_block(pol, faults.present, i0)
            eff_blk = jnp.where(p_blk[:, None, None] > 0, h_plus,
                                fault_state[l])
            new_cache[l] = eff_blk
            uploads = eff_blk if gather is None else gather(eff_blk)
            h_full, stale_full, denom = _fault_agg_math(cfg, uploads,
                                                        faults.weight)
            if pol.record is not None:
                _record_dense(pol.record, l, uploads, h_full)
            h = _slice_block(pol, h_full, i0)
            stale[l] = _slice_block(pol, stale_full, i0)
        else:
            uploads = h_plus if gather is None else gather(h_plus)
            h_full, stale_full = _aggregate(cfg, uploads, subkey)
            if pol.record is not None:
                _record_dense(pol.record, l, uploads, h_full)
            h = _slice_block(pol, h_full, i0)
            stale[l] = _slice_block(pol, stale_full, i0)
    logits = jax.vmap(lambda p, x: x @ p["W"] + p["b"])(params["cls"], h)
    return logits, stale, new_comp, new_cache, denom


def fault_joint_inference(params, batch: SampledBatch, cfg: GlasuConfig,
                          fault_state, faults: RoundFaults):
    """Alg 3 under deadline-based partial participation.

    The server aggregates whatever uploads arrived before the deadline
    (``faults.present``) and substitutes each absent client's cached
    embedding; blocks whose cache aged past the staleness bound carry
    weight 0 (``faults.weight``) and are excluded. Returns
    ``(logits, stale, new_fault_state, denom)`` — the refreshed cache is
    threaded through the round carry next to the optimizer state.
    """
    logits, stale, _, new_cache, denom = _joint_inference_engine(
        params, batch, cfg, ExecPolicy(fault_tolerant=True),
        fault_state=fault_state, faults=faults)
    return logits, stale, new_cache, denom


# ------------------------------------------------------------------- forward
def _client_trunk(cfg: GlasuConfig, params_m, feats_m, batch: SampledBatch, m_index,
                  stale: Optional[Dict[int, Any]] = None,
                  return_hidden: bool = False, global_index=None,
                  fault_w=None, fault_denom=None):
    """One client's pass through all layers, aggregating via stale buffers.

    Used by LocalUpdate (Alg 4): server aggregation is replaced by the stored
    H_{-m} plus the client's fresh representation.

    ``m_index`` indexes the client-stacked batch arrays; ``global_index``
    (default: ``m_index``) is the client's position in the GLOBAL client
    order, which concat aggregation needs for its own-block placement. They
    differ only on the sharded backend, where each device holds a local
    block of the client axis and batch arrays are local blocks too.

    ``fault_w``/``fault_denom`` (fault-tolerant rounds only) weight the
    client's fresh block in the combine exactly as the server weighted it
    in the aggregate — a zero-weight client trains against the global
    aggregate with its own block excluded.
    """
    h = feats_m @ params_m["inp"]["W"] + params_m["inp"]["b"]
    h0 = h
    g_index = m_index if global_index is None else global_index
    for l in range(cfg.n_layers):
        layer = _client_layer(cfg, l)
        idx, mask = batch.gather_idx[l][m_index], batch.gather_mask[l][m_index]
        h_plus = layer(params_m["layers"][l], h, h0, idx, mask)
        h0 = h0[batch.self_pos[l][m_index]]
        if l in cfg.agg_layers:
            h = _combine_with_stale(cfg, stale[l], h_plus, g_index,
                                    w=fault_w, denom=fault_denom)
        else:
            h = h_plus
    if return_hidden:
        return h
    logits = h @ params_m["cls"]["W"] + params_m["cls"]["b"]
    return logits


def joint_inference(params, batch: SampledBatch, cfg: GlasuConfig, key=None,
                    compressor: Optional[Compressor] = None, comp_state=None):
    """Alg 3: full split-model forward with server aggregation at l in I.

    Returns (logits (M, S, C), stale {l: (M, n_{l+1}, h_agg)}). With a
    ``compressor``, the embedding exchange at every aggregation layer runs
    through the wire codec (see ``_compressed_aggregate``) and a third
    value — the updated error-feedback state — is returned. Callers that
    probe model math (``Backend.joint_logits``) pass no compressor and get
    the exact uncompressed forward.
    """
    logits, stale, new_state, _, _ = _joint_inference_engine(
        params, batch, cfg, ExecPolicy(compressor=compressor), key=key,
        comp_state=comp_state)
    if compressor is None:
        return logits, stale
    return logits, stale, new_state


def client_loss(params_m, feats_m, batch: SampledBatch, stale_m, labels,
                cfg: GlasuConfig, m_index, global_index=None,
                fault_w=None, fault_denom=None):
    """Client m's local objective (Alg 4 line 11) with stale buffers fixed."""
    logits = _client_trunk(cfg, params_m, feats_m, batch, m_index, stale_m,
                           global_index=global_index, fault_w=fault_w,
                           fault_denom=fault_denom)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.mean(nll)


def label_owner_grad(params, batch: SampledBatch, stale, cfg: GlasuConfig):
    """Alg 6 (modified JointInference): the label owner computes
    grad_{H[L]} of ITS loss; the server broadcasts it to all clients."""
    m0 = cfg.labels_at_client

    def owner_loss(h):
        pm = jax.tree.map(lambda v: v[m0], params)
        logits = h @ pm["cls"]["W"] + pm["cls"]["b"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, batch.labels[:, None], axis=1)[:, 0]
        return jnp.mean(nll)

    pm = jax.tree.map(lambda v: v[m0], params)
    sm = {l: v[m0] for l, v in stale.items()}
    h_l = _client_trunk(cfg, pm, batch.feats[m0], batch, m0, sm,
                        return_hidden=True)
    return jax.grad(owner_loss)(h_l)


def local_update_steps(params, opt_state, batch: SampledBatch, stale,
                       cfg: GlasuConfig, optimizer: opt_lib.Optimizer,
                       g_hl=None, fault_w=None, fault_denom=None,
                       axis_name: Optional[str] = None, m_loc: int = 0):
    """Q iterations of Alg 4 under ``lax.scan`` (same mini-batch, stale H_{-m}).

    With ``labels_at_client`` set (Appendix B.2, Alg 7): only the owner
    evaluates the real loss; every other client trains on the surrogate
    <g_HL, H_m[L]> whose gradient equals the chain-rule product in eq. (3).

    On a fault-tolerant round ``fault_w`` is the participation-weight
    vector and ``fault_denom`` the weighted-mean denominator: each client
    combines its fresh block at the weight the server aggregated it with
    (Alg 4's stale-others + fresh-own structure, weighted).

    With ``axis_name``/``m_loc`` set (shard_map), every stacked input —
    params, opt state, batch, stale buffers, ``fault_w`` — holds the LOCAL
    client block. The update itself is device-local (the stale buffers
    already hold H_{-m}, so no communication — exactly the paper's
    client-side phase); only the reported mean loss crosses devices (an
    all_gather of Q scalars per round; diagnostics, not algorithm traffic,
    hence unmetered). Clients pass their GLOBAL index to the combine,
    which concat aggregation needs for own-block placement.
    """
    labels = batch.labels
    sharded = axis_name is not None
    m_ids = jnp.arange(m_loc if sharded else cfg.n_clients)
    m_global = jax.lax.axis_index(axis_name) * m_loc + m_ids if sharded \
        else None

    def one_step(carry, _):
        p, s = carry

        def per_client(params_m, feats_m, stale_m, m_index, *extra):
            extra = list(extra)
            g_index = extra.pop(0) if sharded else None
            w_m = extra.pop(0) if fault_w is not None else None
            if cfg.labels_at_client is None:
                return client_loss(params_m, feats_m, batch, stale_m, labels,
                                   cfg, m_index, global_index=g_index,
                                   fault_w=w_m, fault_denom=fault_denom)
            own = client_loss(params_m, feats_m, batch, stale_m, labels,
                              cfg, m_index)
            h_l = _client_trunk(cfg, params_m, feats_m, batch, m_index,
                                stale_m, return_hidden=True)
            surrogate = jnp.sum(jax.lax.stop_gradient(g_hl) * h_l)
            is_owner = m_index == cfg.labels_at_client
            # owner optimizes its real loss (incl. classifier); others the
            # broadcast-gradient surrogate (they own no classifier grads)
            return jnp.where(is_owner, own, surrogate)

        args = [p, batch.feats, stale, m_ids]
        if sharded:
            args.append(m_global)
        if fault_w is not None:
            args.append(fault_w)
        loss, grads = jax.vmap(jax.value_and_grad(per_client),
                               in_axes=(0,) * len(args))(*args)
        updates, s = optimizer.update(grads, s, p)
        p = opt_lib.apply_updates(p, updates)
        # sharded: gather to the global (M,) loss row so the reported mean
        # is the same reduction as the vmapped path's mean over all clients
        round_loss = jnp.mean(_gather_clients(loss, axis_name)) if sharded \
            else jnp.mean(loss)
        return (p, s), round_loss

    (params, opt_state), losses = jax.lax.scan(
        one_step, (params, opt_state), None, length=cfg.n_local_steps)
    return params, opt_state, losses


def _round_body(cfg: GlasuConfig, optimizer: opt_lib.Optimizer,
                pol: ExecPolicy, params, opt_state, batch: SampledBatch,
                key, comp_state=None, fault_state=None,
                faults: Optional[RoundFaults] = None):
    """One GLASU round (Alg 1 body): JointInference + Q LocalUpdates.

    THE round body — the only one. Every builder (vmapped / sharded ×
    single / multi-round × any carry combination) instantiates this
    function with its :class:`ExecPolicy`; there is no second copy to
    hand-sync. Always returns the full 5-tuple ``(params, opt_state,
    comp_state, fault_state, losses)`` — inactive carries pass through
    as given (``None``); the builder callers drop them from the public
    signatures.
    """
    if pol.sharded and cfg.labels_at_client is not None:
        raise NotImplementedError(
            "labels_at_client requires indexing the global client axis "
            "(Alg 6 owner gradient); use the vmapped backend")
    fault_w = fault_denom = None
    if cfg.agg_layers:
        _, stale, new_comp, new_cache, denom = _joint_inference_engine(
            params, batch, cfg, pol, key=key, comp_state=comp_state,
            fault_state=fault_state, faults=faults)
        if pol.compressor is not None:
            comp_state = new_comp
        if pol.fault_tolerant:
            fault_state = new_cache
            i0 = jax.lax.axis_index(pol.axis_name) * pol.m_loc \
                if pol.sharded else 0
            fault_w = _slice_block(pol, faults.weight, i0)
            fault_denom = denom
    else:
        # standalone: no communication; zero stale buffers never used
        stale = {}
    g_hl = None
    if cfg.labels_at_client is not None:
        g_hl = label_owner_grad(params, batch, stale, cfg)
    params, opt_state, losses = local_update_steps(
        params, opt_state, batch, stale, cfg, optimizer, g_hl=g_hl,
        fault_w=fault_w, fault_denom=fault_denom,
        axis_name=pol.axis_name, m_loc=pol.m_loc)
    return params, opt_state, comp_state, fault_state, losses


def _round_caller(cfg: GlasuConfig, optimizer: opt_lib.Optimizer,
                  pol: ExecPolicy):
    """Positional adapter from a policy's public round signature to
    ``_round_body``. Argument order: ``params, opt_state, [comp_state,]
    [fault_state,] batch, key[, faults]`` — each active carry adds one
    state argument and one result (same order), faults append the round's
    mask argument. This is the single function every builder wraps (jit /
    shard_map / scan)."""
    has_c, has_f = _policy_arity(pol)

    def round_fn(*args):
        args = list(args)
        params, opt_state = args.pop(0), args.pop(0)
        comp_state = args.pop(0) if has_c else None
        fault_state = args.pop(0) if has_f else None
        batch, key = args.pop(0), args.pop(0)
        faults = args.pop(0) if has_f else None
        p, s, cs, fs, losses = _round_body(
            cfg, optimizer, pol, params, opt_state, batch, key,
            comp_state=comp_state, fault_state=fault_state, faults=faults)
        return (p, s) + ((cs,) if has_c else ()) + \
            ((fs,) if has_f else ()) + (losses,)

    return round_fn


def _multi_round_caller(cfg: GlasuConfig, optimizer: opt_lib.Optimizer,
                        pol: ExecPolicy):
    """K-round scan over ``_round_caller``'s carry layout: active carries
    ride in the scan carry (donated by the builders), batches/keys and the
    (K, M) fault-mask stacks ride in the xs."""
    has_c, has_f = _policy_arity(pol)
    n_carry = 2 + has_c + has_f

    def step_fn(*args):
        carry_in = tuple(args[:n_carry])
        batches, keys = args[n_carry], args[n_carry + 1]
        xs = (batches, keys) + ((args[n_carry + 2],) if has_f else ())

        def body(carry, xs_t):
            p, s = carry[0], carry[1]
            cs = carry[2] if has_c else None
            fs = carry[2 + has_c] if has_f else None
            batch, key = xs_t[0], xs_t[1]
            f = xs_t[2] if has_f else None
            p, s, cs, fs, losses = _round_body(
                cfg, optimizer, pol, p, s, batch, key, comp_state=cs,
                fault_state=fs, faults=f)
            return (p, s) + ((cs,) if has_c else ()) + \
                ((fs,) if has_f else ()), losses

        carry_out, losses = jax.lax.scan(body, carry_in, xs)
        return carry_out + (losses,)             # losses: (K, Q)

    return step_fn


def _checked(step_fn, rounds_per_step: int, what: str):
    """Reject a batch stack whose leading round axis disagrees with the
    static ``rounds_per_step`` hint loudly instead of silently scanning a
    different number of rounds. ``_jit`` exposes cache introspection."""
    def checked(*args):
        batches = next(a for a in args if isinstance(a, SampledBatch))
        k = batches.labels.shape[0]
        if k != rounds_per_step:
            raise ValueError(
                f"{what} built for rounds_per_step={rounds_per_step} "
                f"got a {k}-round batch stack")
        return step_fn(*args)

    checked._jit = step_fn                       # expose cache introspection
    return checked


def make_round_fn(cfg: GlasuConfig, optimizer: opt_lib.Optimizer):
    """One jitted GLASU round; kept for per-round callers (simulation parity
    probes, unit tests). The training hot path is ``make_multi_round_fn``.

    The signature follows the policy's carry layout (``_round_caller``):
    the base ``(params, opt_state, batch, key) -> (params, opt_state,
    losses)``; ``cfg.compression`` threads the error-feedback carry before
    ``batch``; ``cfg.fault_tolerant`` threads the stale-cache carry there
    and appends the round's ``RoundFaults`` masks. Both active (composed
    fault-tolerant compressed rounds): ``(params, opt_state, comp_state,
    fault_state, batch, key, faults) -> (params, opt_state, comp_state,
    fault_state, losses)``.
    """
    return jax.jit(_round_caller(cfg, optimizer, _policy(cfg)))


def make_multi_round_fn(cfg: GlasuConfig, optimizer: opt_lib.Optimizer,
                        rounds_per_step: Optional[int] = None):
    """K GLASU rounds in one dispatch: ``lax.scan`` over round-stacked batches.

    ``batches`` is a ``SampledBatch`` whose every leaf carries a leading
    round axis K (see ``graph.prefetch.stack_batches``) and ``keys`` is the
    matching (K, 2) stack of per-round PRNG keys. The scan compiles ONE
    round body regardless of K and replays it K times device-side — one
    host dispatch per K rounds instead of per round, which is where the
    per-round Python/runtime overhead of the Trainer loop goes.

    Every carry (params, opt state, and any active sidecar) is donated:
    the update is in-place at the XLA level, halving parameter-buffer HBM
    traffic per step. Callers must treat the passed-in trees as consumed
    (the Trainer immediately rebinds them).

    Returns ``(params, opt_state, ..., losses)`` with losses of shape
    (K, Q) — per-round rows, so hook cadence semantics (loss reporting,
    comm metering) are preserved exactly. K is read off the leading axis at
    trace time; distinct K values retrace (the Trainer cuts its schedule so
    a run uses one K, plus at most a tail/cadence remainder).

    ``rounds_per_step`` is an optional static hint: when given, a batch
    whose leading axis disagrees is rejected loudly instead of silently
    scanning a different number of rounds.

    Carry layout per policy (``_round_caller``): ``cfg.compression`` adds
    the error-feedback accumulators to the scan carry, ``cfg.fault_tolerant``
    adds the stale-embedding cache and puts the round-stacked ``RoundFaults``
    of (K, M) leaves in the scan xs — composed configs thread both.
    """
    pol = _policy(cfg)
    has_c, has_f = _policy_arity(pol)
    step_fn = jax.jit(_multi_round_caller(cfg, optimizer, pol),
                      donate_argnums=tuple(range(2 + has_c + has_f)))
    if rounds_per_step is None:
        return step_fn
    return _checked(step_fn, rounds_per_step, "multi-round step")


# ------------------------------------------------------- sharded execution
# Device-sharded client parallelism: each mesh device along the 'clients'
# axis holds an even block of m_loc = M / n_devices clients (params, opt
# state, batch slices) and runs `_client_trunk` device-local under
# ``shard_map``. The ONLY cross-device operation is server aggregation: the
# clients' uploads are ``all_gather``ed along the axis and the identical
# parameter-free Agg of §3.1 (`_aggregate`, including the §3.6 privacy
# hooks — the PRNG key is replicated, so masks/noise match the vmapped path
# bit-for-bit) runs on the gathered stack, exactly where the paper places
# communication. Each collective is recorded at trace time so the byte
# meter reports what the compiled program actually moves, priced under the
# paper's star topology (Fig 1: every client uploads its block, the server
# returns the aggregate).

class CollectiveRecord(NamedTuple):
    """One cross-client collective, recorded while tracing the round body.

    ``up_bytes``/``down_bytes`` are the WIRE sizes of one client upload and
    one server broadcast — equal to ``n_rows * width * itemsize`` for the
    uncompressed exchange, and read off the actual encoded payload leaves
    when a compressor runs (the ``all_gather`` then moves the compressed
    representation, so these are what the compiled collective ships).
    """
    layer: int          # aggregation layer index l
    n_clients: int      # M (global)
    n_rows: int         # n_{l+1} rows per upload
    width_up: int       # per-client upload width (hidden)
    width_down: int     # aggregate width broadcast back (hidden | M*hidden)
    itemsize: int       # logical (pre-compression) payload dtype bytes
    up_bytes: int       # wire bytes of ONE client upload message
    down_bytes: int     # wire bytes of ONE broadcast message

    def star_bytes(self) -> int:
        """Bytes under the paper's client<->server star topology (§3.2):
        M uploads + M downloads at their wire sizes."""
        return self.n_clients * (self.up_bytes + self.down_bytes)


def _gather_clients(x, axis_name: str):
    return jax.lax.all_gather(x, axis_name, axis=0, tiled=True)


def sharded_joint_inference(params, batch: SampledBatch, cfg: GlasuConfig,
                            key=None, *, axis_name: str, m_loc: int,
                            record=None,
                            compressor: Optional[Compressor] = None,
                            comp_state=None, fault_state=None,
                            faults: Optional[RoundFaults] = None):
    """Alg 3 under shard_map: per-device client blocks, collective Agg.

    All array leaves of ``params``/``batch`` carry the LOCAL client block
    (leading dim m_loc); ``batch.labels`` and ``key`` are replicated. At
    every aggregation layer the local uploads are gathered to the full
    (M, n, h) stack and `_aggregate` runs verbatim on it — the same op on
    the same values as the vmapped path — then the device keeps its local
    slice of the broadcast aggregate and the Extract (stale) buffers.

    With a ``compressor``, each device ENCODES its local block first and
    the ``all_gather`` moves the wire payload (int8 codes + scales, fp8,
    or top-k value/index pairs) — the collective itself shrinks, not just
    the metered number. Decode, aggregation, and the compressed downlink
    then run replicated on the gathered payload (``_compressed_aggregate``
    with the device's global block offset), and the device keeps the local
    block of the error-feedback carry. Returns a third value (the updated
    comp state) in that mode.

    Returns (local logits (m_loc, S, C), stale {l: (m_loc, n_{l+1}, h_agg)}).
    ``record``, when given, is called with a ``CollectiveRecord`` per
    aggregation layer at trace time (the byte meter's measurement hook).

    With ``fault_state``/``faults`` (masks replicated) each device
    substitutes cached blocks for absent clients BEFORE the gather, then
    the identical weighted Agg of the vmapped fault path runs on the
    gathered effective stack; a 3rd return value carries the refreshed
    cache. The mesh collective still ships M blocks per layer (the program
    is shape-static); the federated WIRE meter prices only delivered
    uploads — see ``docs/FAULTS.md``. With compression AND faults composed
    the return is the engine's full ``(logits, stale, new_comp_state,
    new_fault_state, denom)`` (the cache then holds the server's decoded
    view, replicated — see ``_fault_state_specs``).
    """
    pol = ExecPolicy(axis_name=axis_name, m_loc=m_loc,
                     compressor=compressor,
                     fault_tolerant=fault_state is not None, record=record)
    logits, stale, new_comp, new_cache, denom = _joint_inference_engine(
        params, batch, cfg, pol, key=key, comp_state=comp_state,
        fault_state=fault_state, faults=faults)
    if compressor is not None and fault_state is not None:
        return logits, stale, new_comp, new_cache, denom
    if compressor is not None:
        return logits, stale, new_comp
    if fault_state is not None:
        return logits, stale, new_cache
    return logits, stale


def _client_axis_check(cfg: GlasuConfig, mesh, axis: str) -> int:
    d = mesh.shape[axis]
    if cfg.n_clients % d:
        raise ValueError(
            f"mesh axis {axis!r} has {d} devices, which does not divide "
            f"n_clients={cfg.n_clients}; build the mesh with "
            "launch.mesh.make_client_mesh (largest dividing axis) or pass "
            "one whose size divides the client count")
    return cfg.n_clients // d


def _sharded_specs(cfg: GlasuConfig, optimizer: opt_lib.Optimizer,
                   axis: str, round_stacked: bool = False):
    """(params, opt_state, batch) shard_map spec trees for the round body.

    These are the EXACT specs of the client-stacked layout (leading client
    dim on the ``clients`` axis); divisibility is enforced by
    `_client_axis_check`, unlike the guarded placement rules in
    launch.sharding which fall back to replication.
    """
    from jax.sharding import PartitionSpec as P

    cspec = P(*((None, axis) if round_stacked else (axis,)))
    params_abs = jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    pspecs = jax.tree.map(lambda _: P(axis), params_abs)
    opt_abs = jax.eval_shape(optimizer.init, params_abs)
    if isinstance(opt_abs, opt_lib.AdamState):
        ospecs = opt_lib.AdamState(P(), pspecs, pspecs)
    elif isinstance(opt_abs, opt_lib.SGDState):
        ospecs = opt_lib.SGDState(
            P(), pspecs if opt_abs.momentum is not None else None)
    else:
        raise ValueError(
            f"sharded GLASU supports sgd/momentum/adam/adamw states, got "
            f"{type(opt_abs).__name__}: factored second moments (adafactor) "
            "reduce across the client-stacked dim and would mix clients")
    per = tuple(cspec for _ in range(cfg.n_layers))
    bspecs = SampledBatch(feats=cspec, gather_idx=per, gather_mask=per,
                          row_valid=per, labels=P(), self_pos=per)
    return pspecs, ospecs, bspecs


def _comp_state_specs(cfg: GlasuConfig, comp: Optional[Compressor],
                      axis: str):
    """shard_map specs for the error-feedback carry: uplink accumulators
    are client-stacked (sharded over ``axis``), the downlink accumulator is
    server state (replicated). ``{}`` for stateless codecs."""
    from jax.sharding import PartitionSpec as P

    if comp is None or not comp.error_feedback:
        return {}
    return {l: {"up": P(axis), "down": P()} for l in cfg.agg_layers}


def _fault_state_specs(cfg: GlasuConfig, axis: str,
                       replicated: bool = False):
    """shard_map specs for the stale-embedding cache carry.

    Plain fault tolerance: each device holds its LOCAL client block of
    every per-layer cache stack (the same layout as the uplink
    error-feedback accumulators). Composed with compression
    (``replicated=True``): the cache holds the server's DECODED view,
    recomputed identically on every device from the gathered wire payload
    — replicated, not client-sharded.
    """
    from jax.sharding import PartitionSpec as P

    return {l: P() if replicated else P(axis) for l in cfg.agg_layers}


def _round_specs(cfg: GlasuConfig, optimizer: opt_lib.Optimizer,
                 pol: ExecPolicy, axis: str, round_stacked: bool = False):
    """(in_specs, out_specs) for shard_mapping a policy's round caller —
    the spec-tree mirror of ``_round_caller``'s argument layout. The PRNG
    key, the fault masks (single (M,) rows and round-stacked (K, M) alike)
    and the loss rows are replicated."""
    from jax.sharding import PartitionSpec as P

    has_c, has_f = _policy_arity(pol)
    pspecs, ospecs, bspecs = _sharded_specs(cfg, optimizer, axis,
                                            round_stacked=round_stacked)
    in_specs, out_specs = [pspecs, ospecs], [pspecs, ospecs]
    if has_c:
        cspecs = _comp_state_specs(cfg, pol.compressor, axis)
        in_specs.append(cspecs)
        out_specs.append(cspecs)
    if has_f:
        fspecs = _fault_state_specs(cfg, axis, replicated=has_c)
        in_specs.append(fspecs)
        out_specs.append(fspecs)
    in_specs += [bspecs, P()]
    if has_f:
        in_specs.append(RoundFaults(present=P(), weight=P()))
    out_specs.append(P())
    return tuple(in_specs), tuple(out_specs)


def make_sharded_round_fn(cfg: GlasuConfig, optimizer: opt_lib.Optimizer,
                          mesh, axis: str = "clients", record=None,
                          jit: bool = True):
    """One GLASU round with clients sharded over ``mesh``'s ``axis``.

    ``record`` (see ``CollectiveRecord``) observes the aggregation
    collectives at trace time; ``jit=False`` returns the bare shard_map'd
    callable, which is what the byte meter abstractly evaluates at bind.
    The signature follows the policy's carry layout exactly as
    ``make_round_fn``'s does: ``cfg.compression`` threads the
    error-feedback carry before ``batch``, ``cfg.fault_tolerant`` threads
    the stale-cache carry there and appends the round's fault masks —
    composed configs thread both: ``(params, opt_state, comp_state,
    fault_state, batch, key, faults)``."""
    from jax.experimental.shard_map import shard_map

    m_loc = _client_axis_check(cfg, mesh, axis)
    pol = _policy(cfg, axis_name=axis, m_loc=m_loc, record=record)
    in_specs, out_specs = _round_specs(cfg, optimizer, pol, axis)
    fn = shard_map(_round_caller(cfg, optimizer, pol), mesh=mesh,
                   in_specs=in_specs, out_specs=out_specs, check_rep=False)
    return jax.jit(fn) if jit else fn


def make_sharded_multi_round_fn(cfg: GlasuConfig,
                                optimizer: opt_lib.Optimizer, mesh,
                                axis: str = "clients",
                                rounds_per_step: Optional[int] = None):
    """K sharded rounds per dispatch: ``lax.scan`` INSIDE the shard_map, so
    one collective program advances all K rounds — same donation,
    carry-layout and round-stacked batch contract as
    ``make_multi_round_fn`` (the (K, M) fault-mask stacks ride the scan
    xs, replicated across devices)."""
    from jax.experimental.shard_map import shard_map

    m_loc = _client_axis_check(cfg, mesh, axis)
    pol = _policy(cfg, axis_name=axis, m_loc=m_loc)
    has_c, has_f = _policy_arity(pol)
    in_specs, out_specs = _round_specs(cfg, optimizer, pol, axis,
                                       round_stacked=True)
    step_fn = jax.jit(
        shard_map(_multi_round_caller(cfg, optimizer, pol), mesh=mesh,
                  in_specs=in_specs, out_specs=out_specs, check_rep=False),
        donate_argnums=tuple(range(2 + has_c + has_f)))
    if rounds_per_step is None:
        return step_fn
    return _checked(step_fn, rounds_per_step, "sharded multi-round step")


def make_sharded_joint_fn(cfg: GlasuConfig, mesh, axis: str = "clients"):
    """JointInference logits with clients sharded over the mesh: returns the
    global (M, S, C) stack (assembled from per-device blocks)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m_loc = _client_axis_check(cfg, mesh, axis)
    # specs don't depend on the optimizer; borrow sgd for the helper
    pspecs, _, bspecs = _sharded_specs(cfg, opt_lib.sgd(0.0), axis)

    def body(params, batch, key):
        logits, _ = sharded_joint_inference(params, batch, cfg, key,
                                            axis_name=axis, m_loc=m_loc)
        return logits

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(pspecs, bspecs, P()),
                             out_specs=P(axis), check_rep=False))


# ---------------------------------------------------------------- evaluation
def full_forward(params, cfg: GlasuConfig, feats, nbr_idx, nbr_mask,
                 chunk: int = 4096, collect_agg: bool = False):
    """Exact full-graph inference, chunked over nodes (eval only).

    feats: (M, N, d); nbr_idx/mask: (M, N, D+1) padded neighbor tables.
    Aggregation across clients happens at the configured layers only — the
    eval-time model is exactly the trained split model.

    The chunk loop is a ``lax.map`` over chunk starts: the jit that wraps
    this (EvalHook) compiles ONE chunk body instead of unrolling
    ceil(N/chunk) copies of it. Destination tables are padded to a chunk
    multiple (pad rows gather node 0 under a zero mask and are sliced off),
    which also makes the chunk tiling exact when chunk does not divide N —
    the previous clamped-dynamic-slice concatenation silently re-read
    earlier rows in that case.

    ``collect_agg=True`` additionally returns the post-aggregation stacks
    ``{l: (M, N, h_agg)}`` per aggregation layer — the serving cache's
    warm-fill source. Pad rows are sliced off BEFORE ``_aggregate`` runs
    (``[:, :n]`` above), so the collected stacks carry exactly the N real
    nodes regardless of whether ``chunk`` divides N; the hot-node cache
    can never be poisoned by chunk padding.
    """
    m, n = feats.shape[0], feats.shape[1]
    pad = (-n) % chunk
    if pad:
        nbr_idx = jnp.pad(nbr_idx, ((0, 0), (0, pad), (0, 0)))
        nbr_mask = jnp.pad(nbr_mask, ((0, 0), (0, pad), (0, 0)))
    n_pad = n + pad
    h = jax.vmap(lambda p, x: x @ p["W"] + p["b"])(params["inp"], feats)
    h0 = h
    aggs: Dict[int, Any] = {}
    for l in range(cfg.n_layers):  # glint: disable=GL004 static L-layer unroll; the node axis is lax.map'd via chunk_fn below
        layer = _client_layer(cfg, l)

        def chunk_fn(lo, h_full=h, h0_full=h0, l=l, layer=layer):
            idx = jax.lax.dynamic_slice_in_dim(nbr_idx, lo, chunk, axis=1)
            mask = jax.lax.dynamic_slice_in_dim(nbr_mask, lo, chunk, axis=1)
            return jax.vmap(layer)(params["layers"][l], h_full, h0_full,
                                   idx, mask)

        if n_pad == chunk:
            h_plus = chunk_fn(0)[:, :n]
        else:
            starts = jnp.arange(0, n_pad, chunk)
            pieces = jax.lax.map(chunk_fn, starts)   # (C, M, chunk, h)
            h_plus = jnp.moveaxis(pieces, 0, 1).reshape(
                m, n_pad, pieces.shape[-1])[:, :n]
        if l in cfg.agg_layers:
            h, _ = _aggregate(cfg, h_plus)
            if collect_agg:
                aggs[l] = h
        else:
            h = h_plus
        # h0 is node-aligned in full-graph mode (no subsetting)
    logits = jax.vmap(lambda p, x: x @ p["W"] + p["b"])(params["cls"], h)
    if collect_agg:
        return logits, aggs
    return logits  # (M, N, C)


# ------------------------------------------------------------------- serving
# Query-path forward for the serving subsystem (repro.serve). Differences
# from joint_inference: no PRNG key (the §3.6 privacy hooks are a training
# protocol — serving answers on the trained model), no error-feedback carry
# (queries are stateless; EF is a training-time variance-reduction loop),
# and a cache-injection hook at every aggregation layer: the session
# overwrites rows whose (node, layer, params_version) aggregate it already
# holds, so those rows skip the cross-client exchange — the serving-path
# analogue of §3.5 stale updates. Injection happens AFTER _aggregate /
# _compressed_aggregate; both aggregations are row-independent (mean /
# concat over clients per node), so garbage in a cached row's freshly
# computed value (its neighbor deps are pruned from the query plan) cannot
# contaminate any other row before it is overwritten.

def serve_forward(params, batch: SampledBatch, cfg: GlasuConfig,
                  compressor: Optional[Compressor] = None,
                  cache_inject: Optional[Dict[int, Any]] = None):
    """Cross-client forward for one served query plan (vmapped clients).

    ``cache_inject`` maps aggregation layer l to ``(keep, rows)``: ``keep``
    is a float (n_{l+1},) mask (1 = use the cached aggregate) and ``rows``
    the (M, n_{l+1}, h_agg) cached per-client stacks. The dict must carry
    the SAME key set on every call of one jitted trace (the session always
    passes all aggregation layers; all-zero masks mean no injection).

    Returns ``(h, aggs)``: the final (M, n_L, h_agg) representation the
    classifier consumes, and the post-injection aggregate stacks
    ``{l: (M, n_{l+1}, h_agg)}`` the session reads its cache fills from.
    """
    h = jax.vmap(lambda p, x: x @ p["W"] + p["b"])(params["inp"],
                                                   batch.feats)
    h0 = h
    aggs: Dict[int, Any] = {}
    for l in range(cfg.n_layers):  # glint: disable=GL004 static L-layer unroll; per-layer params are heterogeneous (widths change at agg boundaries)
        layer = _client_layer(cfg, l)
        h_plus = jax.vmap(layer)(params["layers"][l], h, h0,
                                 batch.gather_idx[l], batch.gather_mask[l])
        h0 = jax.vmap(lambda a, i: a[i])(h0, batch.self_pos[l])
        if l in cfg.agg_layers:
            if compressor is None:
                h, _ = _aggregate(cfg, h_plus)
            else:
                h = _compressed_aggregate(cfg, compressor, h_plus,
                                          None, layer=l)[0]
            if cache_inject is not None and l in cache_inject:
                keep, rows = cache_inject[l]
                h = jnp.where(keep[None, :, None] > 0, rows, h)
            aggs[l] = h
        else:
            h = h_plus
    return h, aggs


def sharded_serve_forward(params, batch: SampledBatch, cfg: GlasuConfig, *,
                          axis_name: str, m_loc: int,
                          compressor: Optional[Compressor] = None,
                          cache_inject: Optional[Dict[int, Any]] = None):
    """``serve_forward`` under shard_map: local client blocks, collective
    Agg (same layout contract as ``sharded_joint_inference``). The
    injection masks/rows arrive replicated; each device overwrites its
    local block of the aggregate."""
    h = jax.vmap(lambda p, x: x @ p["W"] + p["b"])(params["inp"],
                                                   batch.feats)
    h0 = h
    aggs: Dict[int, Any] = {}
    i0 = jax.lax.axis_index(axis_name) * m_loc
    for l in range(cfg.n_layers):  # glint: disable=GL004 static L-layer unroll; per-layer params are heterogeneous (widths change at agg boundaries)
        layer = _client_layer(cfg, l)
        h_plus = jax.vmap(layer)(params["layers"][l], h, h0,
                                 batch.gather_idx[l], batch.gather_mask[l])
        h0 = jax.vmap(lambda a, i: a[i])(h0, batch.self_pos[l])
        if l in cfg.agg_layers:
            if compressor is None:
                uploads = _gather_clients(h_plus, axis_name)
                h_full, _ = _aggregate(cfg, uploads)
                h = jax.lax.dynamic_slice_in_dim(h_full, i0, m_loc, axis=0)
            else:
                h = _compressed_aggregate(
                    cfg, compressor, h_plus, None,
                    gather=lambda x: _gather_clients(x, axis_name),
                    i0=i0, layer=l)[0]
            if cache_inject is not None and l in cache_inject:
                keep, rows = cache_inject[l]
                rows_blk = jax.lax.dynamic_slice_in_dim(rows, i0, m_loc,
                                                        axis=0)
                h = jnp.where(keep[None, :, None] > 0, rows_blk, h)
            aggs[l] = h
        else:
            h = h_plus
    return h, aggs


def make_sharded_serve_fn(cfg: GlasuConfig, mesh, axis: str = "clients",
                          compressor: Optional[Compressor] = None):
    """Jitted serving dispatch with clients sharded over the mesh.

    ``(params, batch, inject) -> (h, aggs)`` with the client axis of every
    output reassembled to the global (M, ...) stack; ``inject`` is the
    replicated ``{l: (keep, rows)}`` cache-injection dict (every
    aggregation layer present)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m_loc = _client_axis_check(cfg, mesh, axis)
    # specs don't depend on the optimizer; borrow sgd for the helper
    pspecs, _, bspecs = _sharded_specs(cfg, opt_lib.sgd(0.0), axis)
    ispecs = {l: (P(), P()) for l in cfg.agg_layers}

    def body(params, batch, inject):
        return sharded_serve_forward(params, batch, cfg, axis_name=axis,
                                     m_loc=m_loc, compressor=compressor,
                                     cache_inject=inject)

    out_specs = (P(axis), {l: P(axis) for l in cfg.agg_layers})
    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(pspecs, bspecs, ispecs),
                             out_specs=out_specs, check_rep=False))


def accuracy_from_logits(logits, labels, idx, mode: str = "ensemble"):
    """'ensemble': average client logits (GLASU eval); 'per_client': mean of
    each client's own accuracy (standalone eval, paper §5.2)."""
    labels = jnp.asarray(labels)
    idx = jnp.asarray(idx)
    if mode == "ensemble":
        pred = jnp.argmax(jnp.mean(logits, axis=0)[idx], axis=-1)
        return jnp.mean((pred == labels[idx]).astype(jnp.float32))
    preds = jnp.argmax(logits[:, idx], axis=-1)
    accs = jnp.mean((preds == labels[idx][None]).astype(jnp.float32), axis=1)
    return jnp.mean(accs)
