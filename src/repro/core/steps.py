"""Train / serve step builders for the architecture zoo.

``make_train_step(cfg)`` -> (init_state, train_step) where train_step is a
pure function (state, batch) -> (state, metrics): CE loss (+ MoE aux), global
grad clip, optimizer from the config. GLASU-split configs run Q microsteps
per call: microstep 0 performs the sync-layer collectives and caches the
gathered activations; microsteps 1..Q-1 are collective-free stale updates
(paper Alg 1/4 transplanted to the transformer).

``make_serve_step(cfg, shape)`` -> (init_serve_state, serve_step): one-token
greedy decode against the per-layer caches (ring buffer under a sliding
window).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, InputShape
from ..models import transformer as tfm
from ..optim import optimizers as opt_lib


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def make_optimizer(cfg: ArchConfig) -> opt_lib.Optimizer:
    """Deprecated shim — the single factory lives in repro.optim.optimizers.

    The transformer zoo historically spelled momentum-SGD as 'sgd' and fell
    back to adamw; normalize the name accordingly.
    """
    name = {"adafactor": "adafactor", "sgd": "momentum"}.get(
        cfg.optimizer, "adamw")
    return opt_lib.make_optimizer(name, cfg.lr)


def cross_entropy(logits, labels, vocab: int):
    """Stable CE in f32, shard-friendly over a vocab-partitioned last axis.

    The gold logit is picked with an iota comparison instead of
    take_along_axis — a cross-shard gather on the 'model'-sharded vocab axis
    would force an all-gather of the full f32 logits (measured: +22 GB temp
    on smollm train_4k).
    """
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    vid = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(vid == labels[..., None], logits, 0.0), axis=-1)
    return jnp.mean(lse - gold)


def chunked_ce_head(unemb, hidden, labels, vocab: int, chunk: int = 512):
    """CE through the unembedding, scanned over sequence chunks.

    Keeps the live f32 logits block at (B, chunk, V) instead of (B, S, V) —
    the unchunked head dominated llama3-405b train_4k temp memory (f32
    (B*S, D) cotangents + (B, S, V) logits).
    """
    from ..models.layers import wcol
    unemb = wcol(unemb)
    b, s, d = hidden.shape
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = hidden.shape[1] // chunk
    hs = jnp.moveaxis(hidden.reshape(b, nc, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(carry, inp):
        h, lab = inp
        logits = (h @ unemb).astype(jnp.float32)
        m = jnp.max(logits, axis=-1, keepdims=True)
        lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
        vid = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.sum(jnp.where(vid == lab[..., None], logits, 0.0), axis=-1)
        valid = (lab >= 0).astype(jnp.float32)
        return (carry[0] + jnp.sum((lse - gold) * valid),
                carry[1] + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ls))
    return tot / jnp.maximum(cnt, 1.0)


def _loss_fn(params, batch, cfg: ArchConfig):
    kwargs = {}
    if cfg.is_encdec:
        kwargs["src_embeds"] = batch["src_embeds"]
        kwargs["tokens"] = batch["tokens"]
    elif cfg.frontend == "vision":
        kwargs["embeds"] = batch["patch_embeds"]
        kwargs["tokens"] = batch["tokens"]
    else:
        kwargs["tokens"] = batch["tokens"]
    hidden, aux = tfm.lm_forward(params, cfg, return_hidden=True, **kwargs)
    loss = chunked_ce_head(params["unemb"], hidden, batch["labels"], cfg.vocab)
    return loss + cfg.router_aux_weight * aux, (loss, aux)


def make_train_step(cfg: ArchConfig):
    optimizer = make_optimizer(cfg)

    def init_state(key) -> TrainState:
        params = tfm.init_lm(key, cfg)
        return TrainState(params, optimizer.init(params),
                          jnp.zeros([], jnp.int32))

    if cfg.glasu is not None and cfg.glasu.local_steps > 1:
        return init_state, _make_glasu_q_step(cfg, optimizer)

    def grads_of(params, batch):
        (_, (loss, aux)), grads = jax.value_and_grad(
            _loss_fn, has_aux=True)(params, batch, cfg)
        return grads, loss, aux

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        if cfg.grad_accum > 1:
            a = cfg.grad_accum
            micro = {k: v.reshape(a, v.shape[0] // a, *v.shape[1:])
                     for k, v in batch.items()}

            def acc(carry, mb):
                g_acc, l_acc, x_acc = carry
                g, l, x = grads_of(state.params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l, x_acc + x), None

            zeros = jax.tree.map(jnp.zeros_like, state.params)
            (grads, loss, aux), _ = jax.lax.scan(
                acc, (zeros, jnp.zeros(()), jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: (g / a).astype(g.dtype), grads)
            loss, aux = loss / a, aux / a
        else:
            grads, loss, aux = grads_of(state.params, batch)
        grads, gnorm = opt_lib.clip_by_global_norm(grads, 1.0)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = opt_lib.apply_updates(state.params, updates)
        return (TrainState(params, opt_state, state.step + 1),
                {"loss": loss, "aux": aux, "grad_norm": gnorm})

    return init_state, train_step


def _make_glasu_q_step(cfg: ArchConfig, optimizer):
    """Alg 1 for the vertical-split transformer: one joint (collective)
    microstep caches sync-layer activations; Q-1 stale local microsteps run
    collective-free on the SAME batch."""
    q_steps = cfg.glasu.local_steps

    def joint_and_stale_loss(params, batch):
        x = params["emb"][batch["tokens"]]
        logits_x, aux, stale = tfm._glasu_trunk(params, x, cfg,
                                                cfg.sliding_window,
                                                collect_stale=True)
        from ..models.layers import rmsnorm
        h = rmsnorm(params["final_norm"], logits_x)
        logits = h @ params["unemb"]
        loss = cross_entropy(logits, batch["labels"], cfg.vocab)
        return loss, (loss, jax.lax.stop_gradient(stale))

    def stale_loss(params, batch, stale):
        x = params["emb"][batch["tokens"]]
        out, aux, _ = tfm._glasu_trunk(params, x, cfg, cfg.sliding_window,
                                       stale=stale)
        from ..models.layers import rmsnorm
        h = rmsnorm(params["final_norm"], out)
        logits = h @ params["unemb"]
        return cross_entropy(logits, batch["labels"], cfg.vocab)

    def train_step(state: TrainState, batch):
        (_, (loss0, stale)), grads = jax.value_and_grad(
            joint_and_stale_loss, has_aux=True)(state.params, batch)
        grads, _ = opt_lib.clip_by_global_norm(grads, 1.0)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = opt_lib.apply_updates(state.params, updates)

        def micro(carry, _):
            p, s = carry
            l, g = jax.value_and_grad(stale_loss)(p, batch, stale)
            g, _ = opt_lib.clip_by_global_norm(g, 1.0)
            u, s = optimizer.update(g, s, p)
            p = opt_lib.apply_updates(p, u)
            return (p, s), l

        (params, opt_state), losses = jax.lax.scan(
            micro, (params, opt_state), None, length=q_steps - 1)
        return (TrainState(params, opt_state, state.step + q_steps),
                {"loss": loss0, "aux": jnp.zeros(()),
                 "grad_norm": jnp.zeros(())})

    return train_step


def make_serve_step(cfg: ArchConfig, shape: InputShape):
    """Decode one token against seq_len-deep caches (prefilled stand-in)."""

    def init_serve_state(key):
        params = tfm.init_lm(key, cfg)
        caches = tfm.init_caches(cfg, shape.global_batch, shape.seq_len,
                                 prefill_len=min(shape.seq_len - 1,
                                                 shape.seq_len))
        return params, caches

    def serve_step(params, caches, token, enc_out=None):
        return tfm.lm_decode_step(params, caches, cfg, token, enc_out=enc_out)

    return init_serve_state, serve_step
