"""Unified experiment API: one config surface, pluggable backends, hooks.

    from repro.api import Trainer, get_preset
    result = Trainer(get_preset("cora-gcnii-glasu").with_(rounds=60)).run()
"""
from ..comm.compression import CompressionConfig
from ..fed.faults import FaultConfig
from ..serve.config import ServeConfig
from .backends import (Backend, RoundResult, ShardedBackend,
                       SimulationBackend, StepResult, VmappedBackend,
                       make_backend)
from .config import ExperimentConfig, agg_layers_for_k
from .presets import get_preset, list_presets, register_preset
from .trainer import (CheckpointHook, CommMeterHook, EarlyStopHook, EvalHook,
                      Hook, ParticipationHook, Trainer, TrainerState,
                      step_schedule)

__all__ = [
    "Backend", "RoundResult", "StepResult", "ShardedBackend",
    "SimulationBackend", "VmappedBackend", "make_backend",
    "CompressionConfig", "FaultConfig", "ServeConfig", "ExperimentConfig",
    "agg_layers_for_k",
    "get_preset", "list_presets", "register_preset", "CheckpointHook",
    "CommMeterHook", "EarlyStopHook", "EvalHook", "Hook",
    "ParticipationHook", "Trainer", "TrainerState", "step_schedule",
]
