"""Pluggable execution backends: same Trainer, swappable substrate.

``VmappedBackend`` is the fast path — clients are a stacked leading axis and
one jitted round function (``core.glasu.make_round_fn``) advances all of them
at once; communication is *metered* analytically via the sampler's cost
model. ``SimulationBackend`` replays the identical round as literal
client/server messages (``fed.simulation``) — the deployment topology of the
paper's Fig. 1 — and *audits* the analytic meter against the message log
every round: a divergence raises instead of silently mis-reporting bytes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Protocol, runtime_checkable

import jax.numpy as jnp

from ..core import glasu
from ..core.glasu import GlasuConfig
from ..fed import simulation
from ..graph.prefetch import unstack_round
from ..graph.sampler import GlasuSampler, SampledBatch
from ..optim import optimizers as opt_lib


@dataclass
class RoundResult:
    """Output of one GLASU round, backend-independent."""
    params: Any
    opt_state: Any
    losses: Any                                   # (Q,) per-microstep losses
    comm_bytes: int                               # bytes this round
    message_log: Optional[simulation.MessageLog] = None


@dataclass
class StepResult:
    """Output of one multi-round step (K rounds in one dispatch)."""
    params: Any
    opt_state: Any
    losses: Any                                   # (K, Q) per-round rows
    comm_bytes_round: int                         # bytes per round (analytic)
    message_logs: Optional[list] = None           # per-round, simulation only


@runtime_checkable
class Backend(Protocol):
    """Execution substrate for one GLASU round (Alg 1 body)."""

    name: str

    def bind(self, model_cfg: GlasuConfig, optimizer: opt_lib.Optimizer,
             sampler: GlasuSampler) -> None:
        """Specialize to a model/optimizer/sampler before the first round."""
        ...

    def run_round(self, params, opt_state, batch: SampledBatch,
                  key) -> RoundResult:
        ...

    def run_step(self, params, opt_state, batches: SampledBatch,
                 keys) -> StepResult:
        """K rounds in one call; ``batches``/``keys`` carry a leading round
        axis. params/opt_state may be donated — callers treat them as
        consumed."""
        ...

    def joint_logits(self, params, batch: SampledBatch, key=None):
        """JointInference logits (M, S, C) — the cross-backend parity probe."""
        ...


def run_step_sequential(backend, params, opt_state, batches: SampledBatch,
                        keys) -> StepResult:
    """K sequential ``run_round`` calls presented as one step.

    Used by ``SimulationBackend`` (message fidelity over throughput) and as
    the Trainer's fallback for backends written against the older
    run_round-only protocol. ``StepResult`` carries ONE per-round byte
    count, so a backend whose rounds diverge raises loudly instead of
    letting ``CommMeterHook`` mis-accumulate.
    """
    losses, logs = [], []
    comm = None
    for i in range(len(keys)):
        out = backend.run_round(params, opt_state,
                                unstack_round(batches, i), keys[i])
        params, opt_state = out.params, out.opt_state
        losses.append(out.losses)
        logs.append(out.message_log)
        if comm is None:
            comm = out.comm_bytes
        elif out.comm_bytes != comm:
            raise RuntimeError(
                "per-round byte counts diverged within a multi-round step; "
                "run this backend with rounds_per_step=1")
    return StepResult(params, opt_state, jnp.stack(losses),
                      comm if comm is not None else 0,
                      message_logs=logs if any(l is not None for l in logs)
                      else None)


def _analytic_bytes(cfg: GlasuConfig, sampler: GlasuSampler) -> int:
    """Paper §3.2/§3.4 cost model; zero when nothing actually crosses clients."""
    if cfg.agg_layers and cfg.n_clients > 1:
        return sampler.comm_bytes_per_joint_inference(cfg.hidden, cfg.agg)
    return 0


class VmappedBackend:
    """Stacked-axis fast path: one jitted scanned step_fn (K rounds per
    dispatch, donated params/opt_state), analytic byte meter."""

    name = "vmapped"

    def bind(self, model_cfg, optimizer, sampler):
        self.cfg = model_cfg
        self.optimizer = optimizer
        self.bytes_per_round = _analytic_bytes(model_cfg, sampler)
        self.step_fn = glasu.make_multi_round_fn(model_cfg, optimizer)
        self._round_fn = None                 # built lazily for run_round

    def run_round(self, params, opt_state, batch, key):
        if self._round_fn is None:
            self._round_fn = glasu.make_round_fn(self.cfg, self.optimizer)
        params, opt_state, losses = self._round_fn(params, opt_state, batch,
                                                   key)
        return RoundResult(params, opt_state, losses, self.bytes_per_round)

    def run_step(self, params, opt_state, batches, keys):
        params, opt_state, losses = self.step_fn(params, opt_state, batches,
                                                 keys)
        return StepResult(params, opt_state, losses, self.bytes_per_round)

    def joint_logits(self, params, batch, key=None):
        logits, _ = glasu.joint_inference(params, batch, self.cfg, key)
        return logits


class SimulationBackend:
    """Explicit message-passing path; audits the meter against the log."""

    name = "simulation"

    def bind(self, model_cfg, optimizer, sampler):
        if model_cfg.agg != "mean":
            raise ValueError("SimulationBackend implements mean aggregation "
                             "only")
        if model_cfg.secure_agg or model_cfg.dp_sigma > 0.0:
            raise ValueError("SimulationBackend does not implement the §3.6 "
                             "privacy hooks")
        self.cfg = model_cfg
        self.optimizer = optimizer
        self.bytes_per_round = _analytic_bytes(model_cfg, sampler)

    def run_round(self, params, opt_state, batch, key):
        params, opt_state, losses, log = simulation.simulate_round(
            params, opt_state, batch, self.cfg, self.optimizer)
        measured = log.total_bytes()
        if self.cfg.n_clients > 1 and self.cfg.agg_layers \
                and measured != self.bytes_per_round:
            raise RuntimeError(
                f"byte-meter audit failed: message log carries {measured} B "
                f"but the sampler cost model predicts {self.bytes_per_round} B")
        comm = measured if self.cfg.n_clients > 1 else 0
        return RoundResult(params, opt_state, losses, comm, message_log=log)

    def run_step(self, params, opt_state, batches, keys):
        """Sequential replay: the simulation path is about message fidelity,
        not throughput, so a step is literally K audited rounds."""
        return run_step_sequential(self, params, opt_state, batches, keys)

    def joint_logits(self, params, batch, key=None):
        logits, _ = simulation.simulate_joint_inference(params, batch,
                                                        self.cfg)
        return logits


_BACKENDS = {"vmapped": VmappedBackend, "simulation": SimulationBackend}


def make_backend(name: str) -> Backend:
    try:
        return _BACKENDS[name]()
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; expected one of "
                         f"{tuple(_BACKENDS)}") from None
