"""Pluggable execution backends: same Trainer, swappable substrate.

``VmappedBackend`` is the fast path — clients are a stacked leading axis and
one jitted round function (``core.glasu.make_round_fn``) advances all of them
at once; communication is *metered* analytically via the sampler's cost
model. ``SimulationBackend`` replays the identical round as literal
client/server messages (``fed.simulation``) — the deployment topology of the
paper's Fig. 1 — and *audits* the analytic meter against the message log
every round: a divergence raises instead of silently mis-reporting bytes.
``ShardedBackend`` places each client (block) on its own mesh device
(``shard_map`` over a 'clients' axis; ``core.glasu.make_sharded_*``):
client compute is device-local, aggregation is a real cross-device
collective, and the byte meter is read off the collectives recorded at
trace time — audited at bind against the message-passing log instead of
trusting the analytic model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from ..comm.compression import make_compressor
from ..core import glasu
from ..core.glasu import GlasuConfig
from ..fed import faults as faults_lib
from ..fed import simulation
from ..graph.prefetch import unstack_round
from ..graph.sampler import GlasuSampler, SampledBatch
from ..optim import optimizers as opt_lib


@dataclass
class RoundResult:
    """Output of one GLASU round, backend-independent."""
    params: Any
    opt_state: Any
    losses: Any                                   # (Q,) per-microstep losses
    comm_bytes: int                               # bytes this round
    message_log: Optional[simulation.MessageLog] = None


@dataclass
class StepResult:
    """Output of one multi-round step (K rounds in one dispatch)."""
    params: Any
    opt_state: Any
    losses: Any                                   # (K, Q) per-round rows
    comm_bytes_round: int                         # bytes per round (analytic)
    message_logs: Optional[list] = None           # per-round, simulation only
    # fault-tolerant steps only: delivered-only bytes for EACH of the K
    # rounds (uploads dropped or late price as zero). ``comm_bytes_round``
    # then still carries the fault-free per-round price for comparison.
    comm_bytes_rounds: Optional[tuple] = None


@runtime_checkable
class Backend(Protocol):
    """Execution substrate for one GLASU round (Alg 1 body).

    ``supports_faults`` is the explicit fault-capability contract: a
    backend that can run deadline rounds (accepting ``faults=`` on
    run_round/run_step) declares it ``True``. The Trainer checks the flag
    at CONFIG time — a fault-tolerant experiment on a backend without it
    fails loudly before the first round instead of silently training
    fault-free (all three built-in backends support faults; the flag
    exists for external/older backends written against the run_round-only
    protocol).
    """

    name: str
    supports_faults: bool

    def bind(self, model_cfg: GlasuConfig, optimizer: opt_lib.Optimizer,
             sampler: GlasuSampler) -> None:
        """Specialize to a model/optimizer/sampler before the first round."""
        ...

    def run_round(self, params, opt_state, batch: SampledBatch,
                  key, faults=None) -> RoundResult:
        """``faults`` (a ``fed.faults.RoundPlan``) runs the fault-tolerant
        exchange; requires a fault-tolerant bind (``cfg.fault_tolerant``)."""
        ...

    def run_step(self, params, opt_state, batches: SampledBatch,
                 keys, faults=None) -> StepResult:
        """K rounds in one call; ``batches``/``keys`` carry a leading round
        axis. params/opt_state may be donated — callers treat them as
        consumed. ``faults``: K ``RoundPlan``s (fault-tolerant binds only).
        """
        ...

    def joint_logits(self, params, batch: SampledBatch, key=None):
        """JointInference logits (M, S, C) — the cross-backend parity probe."""
        ...


def run_step_sequential(backend, params, opt_state, batches: SampledBatch,
                        keys, faults=None) -> StepResult:
    """K sequential ``run_round`` calls presented as one step.

    Used by ``SimulationBackend`` (message fidelity over throughput) and as
    the Trainer's fallback for backends written against the older
    run_round-only protocol. ``StepResult`` carries ONE per-round byte
    count, so a backend whose rounds diverge raises loudly instead of
    letting ``CommMeterHook`` mis-accumulate — EXCEPT under ``faults``
    (K ``RoundPlan``s), where per-round delivered bytes legitimately vary
    with the draw and ride in ``comm_bytes_rounds``.

    Fault contract: ``faults=`` is forwarded only to backends that declare
    ``supports_faults`` — a plan handed to a backend without the flag
    raises here rather than vanishing into a ``**kwargs`` sink (the
    Trainer already rejects that pairing at config time; this guard covers
    direct callers).
    """
    if faults is not None and not getattr(backend, "supports_faults", False):
        raise ValueError(
            f"backend {getattr(backend, 'name', type(backend).__name__)!r} "
            "does not declare supports_faults; it cannot run the "
            "fault-tolerant exchange (the plans would be dropped and the "
            "run would silently train fault-free)")
    losses, logs, per_round = [], [], []
    comm = None
    for i in range(len(keys)):
        # faults= is omitted when no plan is active so run_round-only
        # backends (supports_faults declared or not) keep working fault-free
        kw = {} if faults is None else {"faults": faults[i]}
        out = backend.run_round(params, opt_state,
                                unstack_round(batches, i), keys[i], **kw)
        params, opt_state = out.params, out.opt_state
        losses.append(out.losses)
        logs.append(out.message_log)
        per_round.append(out.comm_bytes)
        if faults is not None:
            continue
        if comm is None:
            comm = out.comm_bytes
        elif out.comm_bytes != comm:
            raise RuntimeError(
                "per-round byte counts diverged within a multi-round step; "
                "run this backend with rounds_per_step=1")
    if faults is not None:
        return StepResult(params, opt_state, jnp.stack(losses),
                          getattr(backend, "bytes_per_round", 0),
                          message_logs=logs
                          if any(l is not None for l in logs) else None,
                          comm_bytes_rounds=tuple(per_round))
    return StepResult(params, opt_state, jnp.stack(losses),
                      comm if comm is not None else 0,
                      message_logs=logs if any(l is not None for l in logs)
                      else None)


def _analytic_bytes(cfg: GlasuConfig, sampler: GlasuSampler,
                    compressor=None, n_uploads: Optional[int] = None) -> int:
    """Paper §3.2/§3.4 cost model; zero when nothing actually crosses
    clients. With a compressor, embedding messages are priced at their
    exact wire size (the int32 index sync is codec-independent). With
    ``n_uploads`` only that many uplink messages are priced (fault rounds:
    dropped/late uploads never reach the server)."""
    if cfg.agg_layers and cfg.n_clients > 1:
        return sampler.comm_bytes_per_joint_inference(cfg.hidden, cfg.agg,
                                                      compressor=compressor,
                                                      n_uploads=n_uploads)
    return 0


def _round_faults(plan) -> "glasu.RoundFaults":
    """Device-side masks for one ``RoundPlan``."""
    return glasu.RoundFaults(present=jnp.asarray(plan.present, jnp.float32),
                             weight=jnp.asarray(plan.weight, jnp.float32))


def _check_fault_args(cfg: GlasuConfig, fault_state, faults):
    if faults is not None and fault_state is None:
        raise ValueError(
            "faults passed to a backend bound without cfg.fault_tolerant; "
            "set the ExperimentConfig 'faults' block (or GlasuConfig."
            "fault_tolerant) before bind")
    if faults is None and fault_state is not None:
        raise ValueError(
            "backend bound fault-tolerant but no fault plan passed: every "
            "round of a fault-tolerant run takes its RoundPlan (a degraded "
            "FaultConfig() draws all-present plans)")


class VmappedBackend:
    """Stacked-axis fast path: one jitted scanned step_fn (K rounds per
    dispatch, donated params/opt_state), analytic byte meter.

    With ``model_cfg.compression`` active the backend owns the
    error-feedback carry (``self.comp_state``): it is threaded (and
    donated) through every round/step alongside the optimizer state, and
    the Trainer checkpoints/restores it via the backend attribute. The
    fault-tolerant stale-embedding cache (``self.fault_state``) is owned
    the same way; composed binds (faults + compression) thread both
    carries in the unified engine's ``(params, opt_state, comp_state,
    fault_state, ...)`` order.
    """

    name = "vmapped"
    supports_faults = True

    def bind(self, model_cfg, optimizer, sampler):
        self.cfg = model_cfg
        self.optimizer = optimizer
        self.sampler = sampler
        self.compressor = make_compressor(model_cfg.compression)
        self.comp_state = glasu.init_comp_state(model_cfg,
                                                sampler.layer_sizes,
                                                self.compressor)
        self.fault_state = glasu.init_fault_state(model_cfg,
                                                  sampler.layer_sizes)
        self.bytes_per_round = _analytic_bytes(model_cfg, sampler,
                                               self.compressor)
        self.step_fn = glasu.make_multi_round_fn(model_cfg, optimizer)
        self._round_fn = None                 # built lazily for run_round

    def _fault_bytes(self, plan) -> int:
        """Delivered-only price of one fault round (uplink × n_present)."""
        return _analytic_bytes(self.cfg, self.sampler, self.compressor,
                               n_uploads=plan.n_present)

    def run_round(self, params, opt_state, batch, key, faults=None):
        _check_fault_args(self.cfg, self.fault_state, faults)
        if self._round_fn is None:
            self._round_fn = glasu.make_round_fn(self.cfg, self.optimizer)
        if self.fault_state is not None:
            masks = _round_faults(faults)
            if self.compressor is not None:
                (params, opt_state, self.comp_state, self.fault_state,
                 losses) = self._round_fn(params, opt_state, self.comp_state,
                                          self.fault_state, batch, key,
                                          masks)
            else:
                params, opt_state, self.fault_state, losses = self._round_fn(
                    params, opt_state, self.fault_state, batch, key, masks)
            return RoundResult(params, opt_state, losses,
                               self._fault_bytes(faults))
        if self.compressor is None:
            params, opt_state, losses = self._round_fn(params, opt_state,
                                                       batch, key)
        else:
            params, opt_state, self.comp_state, losses = self._round_fn(
                params, opt_state, self.comp_state, batch, key)
        return RoundResult(params, opt_state, losses, self.bytes_per_round)

    def run_step(self, params, opt_state, batches, keys, faults=None):
        _check_fault_args(self.cfg, self.fault_state, faults)
        if self.fault_state is not None:
            present, weight = faults_lib.stack_plans(faults)
            masks = glasu.RoundFaults(jnp.asarray(present),
                                      jnp.asarray(weight))
            if self.compressor is not None:
                (params, opt_state, self.comp_state, self.fault_state,
                 losses) = self.step_fn(params, opt_state, self.comp_state,
                                        self.fault_state, batches, keys,
                                        masks)
            else:
                params, opt_state, self.fault_state, losses = self.step_fn(
                    params, opt_state, self.fault_state, batches, keys,
                    masks)
            return StepResult(params, opt_state, losses, self.bytes_per_round,
                              comm_bytes_rounds=tuple(
                                  self._fault_bytes(p) for p in faults))
        if self.compressor is None:
            params, opt_state, losses = self.step_fn(params, opt_state,
                                                     batches, keys)
        else:
            params, opt_state, self.comp_state, losses = self.step_fn(
                params, opt_state, self.comp_state, batches, keys)
        return StepResult(params, opt_state, losses, self.bytes_per_round)

    def joint_logits(self, params, batch, key=None):
        logits, _ = glasu.joint_inference(params, batch, self.cfg, key)
        return logits


class SimulationBackend:
    """Explicit message-passing path; audits the meter against the log."""

    name = "simulation"
    supports_faults = True

    def bind(self, model_cfg, optimizer, sampler):
        if model_cfg.agg != "mean":
            raise ValueError("SimulationBackend implements mean aggregation "
                             "only")
        if model_cfg.secure_agg or model_cfg.dp_sigma > 0.0:
            raise ValueError("SimulationBackend does not implement the §3.6 "
                             "privacy hooks")
        self.cfg = model_cfg
        self.optimizer = optimizer
        self.sampler = sampler
        self.compressor = make_compressor(model_cfg.compression)
        self.comp_state = glasu.init_comp_state(model_cfg,
                                                sampler.layer_sizes,
                                                self.compressor)
        self.fault_state = glasu.init_fault_state(model_cfg,
                                                  sampler.layer_sizes)
        self.bytes_per_round = _analytic_bytes(model_cfg, sampler,
                                               self.compressor)

    def run_round(self, params, opt_state, batch, key, faults=None):
        _check_fault_args(self.cfg, self.fault_state, faults)
        if self.fault_state is not None:
            if self.compressor is not None:
                (params, opt_state, losses, log, self.fault_state,
                 self.comp_state) = simulation.simulate_fault_round(
                    params, opt_state, batch, self.cfg, self.optimizer,
                    self.fault_state, faults, compressor=self.compressor,
                    comp_state=self.comp_state)
            else:
                params, opt_state, losses, log, self.fault_state = \
                    simulation.simulate_fault_round(params, opt_state, batch,
                                                    self.cfg, self.optimizer,
                                                    self.fault_state, faults)
            # delivered-only audit: the log minus dropped messages must
            # price exactly as the analytic model with n_present uploads —
            # compressed payloads priced at their wire size for present
            # clients only
            measured = log.total_bytes(delivered_only=True)
            expected = _analytic_bytes(self.cfg, self.sampler,
                                       compressor=self.compressor,
                                       n_uploads=faults.n_present)
            if measured != expected:
                raise RuntimeError(
                    f"fault-round byte-meter audit failed: delivered "
                    f"messages carry {measured} B but the cost model with "
                    f"{faults.n_present} delivered uploads predicts "
                    f"{expected} B")
            return RoundResult(params, opt_state, losses, measured,
                               message_log=log)
        params, opt_state, losses, log, comp_state = \
            simulation.simulate_round(params, opt_state, batch, self.cfg,
                                      self.optimizer, self.compressor,
                                      self.comp_state)
        if self.compressor is not None:
            self.comp_state = comp_state
        measured = log.total_bytes()
        if self.cfg.n_clients > 1 and self.cfg.agg_layers \
                and measured != self.bytes_per_round:
            raise RuntimeError(
                f"byte-meter audit failed: message log carries {measured} B "
                f"but the sampler cost model predicts {self.bytes_per_round} B")
        comm = measured if self.cfg.n_clients > 1 else 0
        return RoundResult(params, opt_state, losses, comm, message_log=log)

    def run_step(self, params, opt_state, batches, keys, faults=None):
        """Sequential replay: the simulation path is about message fidelity,
        not throughput, so a step is literally K audited rounds."""
        return run_step_sequential(self, params, opt_state, batches, keys,
                                   faults=faults)

    def joint_logits(self, params, batch, key=None):
        logits, _ = simulation.simulate_joint_inference(params, batch,
                                                        self.cfg)
        return logits


class ShardedBackend:
    """Device-sharded client parallelism over a ``('clients',)`` mesh.

    Each device holds an even block of clients (params, optimizer state,
    batch slices, all placed via ``launch.sharding`` client rules) and runs
    the trunk locally; aggregation is an ``all_gather`` collective along the
    client axis — the only cross-device traffic, exactly where the paper
    places communication. ``run_step`` is the same scanned K-round contract
    as the vmapped engine (one collective program, donated buffers).

    Byte metering: the aggregation collectives recorded while tracing the
    round body are priced under the paper's star topology and AUDITED at
    bind against a message-by-message log (``fed.simulation``'s index-sync
    + upload/broadcast replay) — this path never uses the sampler's
    analytic estimate.
    """

    name = "sharded"
    supports_faults = True

    def __init__(self, mesh=None, mesh_devices: Optional[int] = None):
        self._mesh = mesh
        self._mesh_devices = mesh_devices

    def bind(self, model_cfg, optimizer, sampler):
        if model_cfg.labels_at_client is not None:
            raise ValueError(
                "ShardedBackend does not implement labels_at_client (the "
                "Alg 6 owner gradient indexes the global client axis); use "
                "the vmapped backend")
        from ..launch import sharding as shd
        from ..launch.mesh import make_client_mesh

        self.cfg = model_cfg
        self.optimizer = optimizer
        self.sampler = sampler
        self.mesh = self._mesh if self._mesh is not None else \
            make_client_mesh(model_cfg.n_clients,
                             max_devices=self._mesh_devices)
        self.compressor = make_compressor(model_cfg.compression)
        self.comp_state = glasu.init_comp_state(model_cfg,
                                                sampler.layer_sizes,
                                                self.compressor)
        self.fault_state = glasu.init_fault_state(model_cfg,
                                                  sampler.layer_sizes)

        # placement shardings for inputs that arrive from off-mesh (init,
        # checkpoint restore, the host sampler): client-stacked leading dim
        params_abs = jax.eval_shape(
            lambda k: glasu.init_params(k, model_cfg), jax.random.PRNGKey(0))
        opt_abs = jax.eval_shape(optimizer.init, params_abs)
        pspecs = shd.client_param_specs(params_abs, self.mesh)
        self.param_sh = shd.tree_shardings(pspecs, self.mesh)
        self.opt_sh = shd.tree_shardings(
            shd.opt_state_specs(opt_abs, pspecs, self.mesh), self.mesh)
        self.comp_sh = None if self.comp_state is None else \
            shd.tree_shardings(
                shd.client_comp_state_specs(self.comp_state, self.mesh),
                self.mesh)
        # composed with compression the cache holds the server's decoded
        # view, recomputed on every device from the gathered payload —
        # replicated, not client-sharded
        self.fault_sh = None if self.fault_state is None else \
            shd.tree_shardings(
                shd.client_fault_state_specs(
                    self.fault_state, self.mesh,
                    replicated=self.compressor is not None),
                self.mesh)

        # byte meter: record the aggregation collectives from an abstract
        # trace of the round body, then audit them message-by-message.
        # Fault-tolerant binds trace with all-present masks: the mesh
        # collective is shape-static (it always ships M blocks), and the
        # full-participation audit pins the meter; per-round fault prices
        # then come from the SAME audited model with n_present uploads.
        shell = sampler.shape_shell_batch()
        records = []
        trace_fn = glasu.make_sharded_round_fn(
            model_cfg, optimizer, self.mesh, record=records.append,
            jit=False)
        if self.fault_state is not None:
            ones = glasu.RoundFaults(jnp.ones(model_cfg.n_clients),
                                     jnp.ones(model_cfg.n_clients))
            if self.compressor is not None:
                jax.eval_shape(trace_fn, params_abs, opt_abs,
                               self.comp_state, self.fault_state, shell,
                               jax.random.PRNGKey(0), ones)
            else:
                jax.eval_shape(trace_fn, params_abs, opt_abs,
                               self.fault_state, shell,
                               jax.random.PRNGKey(0), ones)
        elif self.compressor is None:
            jax.eval_shape(trace_fn, params_abs, opt_abs, shell,
                           jax.random.PRNGKey(0))
        else:
            jax.eval_shape(trace_fn, params_abs, opt_abs, self.comp_state,
                           shell, jax.random.PRNGKey(0))
        self.collectives = tuple(records)
        self.bytes_per_round = self._audited_bytes(shell)

        self.step_fn = glasu.make_sharded_multi_round_fn(
            model_cfg, optimizer, self.mesh)
        self._round_fn = None
        self._joint_fn = None

    def _audited_bytes(self, shell: SampledBatch) -> int:
        """Collective meter vs message log, or raise. Returns bytes/round."""
        cfg = self.cfg
        measured = sum(r.star_bytes() for r in self.collectives)
        log = simulation.MessageLog()
        simulation.log_index_sync(log, shell, cfg)
        simulation.log_agg_traffic(log, shell, cfg, compressor=self.compressor)
        expected_act = (log.total_bytes("upload")
                        + log.total_bytes("broadcast"))
        if measured != expected_act:
            raise RuntimeError(
                f"collective byte-meter audit failed: traced collectives "
                f"move {measured} B but the message log carries "
                f"{expected_act} B of uploads+broadcasts")
        if not (cfg.agg_layers and cfg.n_clients > 1):
            return 0          # nothing actually crosses clients
        # index-set coordination (Alg 2) runs host-side in the sampler; its
        # traffic comes from the same message log, not the collectives
        return measured + log.total_bytes("index_sync")

    def _place(self, params, opt_state):
        return (jax.device_put(params, self.param_sh),
                jax.device_put(opt_state, self.opt_sh))

    def _place_batch(self, batch, round_stacked: bool):
        from ..launch import sharding as shd
        specs = shd.client_batch_specs(batch, self.mesh,
                                       round_stacked=round_stacked)
        return jax.device_put(batch, shd.tree_shardings(specs, self.mesh))

    def _placed_comp_state(self):
        """EF carry on-mesh: uplink block sharded, downlink replicated.
        (No-op after the first step — outputs already carry the sharding.)"""
        if not self.comp_state:          # None (off) or {} (stateless codec)
            return self.comp_state
        return jax.device_put(self.comp_state, self.comp_sh)

    def _placed_fault_state(self):
        """Stale-cache carry on-mesh: every per-layer stack client-sharded."""
        return jax.device_put(self.fault_state, self.fault_sh)

    def _fault_bytes(self, plan) -> int:
        """Delivered-only price of one fault round on the federated wire.

        The mesh all_gather is shape-static (M blocks regardless of the
        draw), so the TRAFFIC of a fault round is priced by the audited
        cost model with n_present uploads, not re-read off collectives.
        """
        return _analytic_bytes(self.cfg, self.sampler, self.compressor,
                               n_uploads=plan.n_present)

    def run_round(self, params, opt_state, batch, key, faults=None):
        _check_fault_args(self.cfg, self.fault_state, faults)
        if self._round_fn is None:
            self._round_fn = glasu.make_sharded_round_fn(
                self.cfg, self.optimizer, self.mesh)
        params, opt_state = self._place(params, opt_state)
        batch = self._place_batch(batch, round_stacked=False)
        if self.fault_state is not None:
            if self.compressor is not None:
                (params, opt_state, self.comp_state, self.fault_state,
                 losses) = self._round_fn(
                    params, opt_state, self._placed_comp_state(),
                    self._placed_fault_state(), batch, key,
                    _round_faults(faults))
            else:
                params, opt_state, self.fault_state, losses = self._round_fn(
                    params, opt_state, self._placed_fault_state(), batch, key,
                    _round_faults(faults))
            return RoundResult(params, opt_state, losses,
                               self._fault_bytes(faults))
        if self.compressor is None:
            params, opt_state, losses = self._round_fn(params, opt_state,
                                                       batch, key)
        else:
            params, opt_state, self.comp_state, losses = self._round_fn(
                params, opt_state, self._placed_comp_state(), batch, key)
        return RoundResult(params, opt_state, losses, self.bytes_per_round)

    def run_step(self, params, opt_state, batches, keys, faults=None):
        _check_fault_args(self.cfg, self.fault_state, faults)
        params, opt_state = self._place(params, opt_state)
        batches = self._place_batch(batches, round_stacked=True)
        if self.fault_state is not None:
            present, weight = faults_lib.stack_plans(faults)
            masks = glasu.RoundFaults(jnp.asarray(present),
                                      jnp.asarray(weight))
            if self.compressor is not None:
                (params, opt_state, self.comp_state, self.fault_state,
                 losses) = self.step_fn(
                    params, opt_state, self._placed_comp_state(),
                    self._placed_fault_state(), batches, keys, masks)
            else:
                params, opt_state, self.fault_state, losses = self.step_fn(
                    params, opt_state, self._placed_fault_state(), batches,
                    keys, masks)
            return StepResult(params, opt_state, losses, self.bytes_per_round,
                              comm_bytes_rounds=tuple(
                                  self._fault_bytes(p) for p in faults))
        if self.compressor is None:
            params, opt_state, losses = self.step_fn(params, opt_state,
                                                     batches, keys)
        else:
            params, opt_state, self.comp_state, losses = self.step_fn(
                params, opt_state, self._placed_comp_state(), batches, keys)
        return StepResult(params, opt_state, losses, self.bytes_per_round)

    def joint_logits(self, params, batch, key=None):
        if self._joint_fn is None:
            self._joint_fn = glasu.make_sharded_joint_fn(self.cfg, self.mesh)
        params = jax.device_put(params, self.param_sh)
        batch = self._place_batch(batch, round_stacked=False)
        return self._joint_fn(params, batch, key)


_BACKENDS = {"vmapped": VmappedBackend, "simulation": SimulationBackend,
             "sharded": ShardedBackend}


def make_backend(name: str, **kwargs) -> Backend:
    """Instantiate a registered backend. ``kwargs`` (e.g. ``mesh``,
    ``mesh_devices`` for the sharded backend) go to the constructor."""
    try:
        cls = _BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; expected one of "
                         f"{tuple(_BACKENDS)}") from None
    return cls(**kwargs)
