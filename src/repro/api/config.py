"""Unified experiment configuration — one validated surface for a scenario.

``ExperimentConfig`` composes everything a run needs (model, sampler,
training loop, execution backend, checkpointing) and owns the cross-field
invariants that callers previously maintained by hand across ``GlasuConfig``
+ ``SamplerConfig`` + ``TrainConfig``:

  * ``agg_layers`` is derived from ``method``/``k`` (the paper's uniform
    placement) unless given explicitly, and validated to include the
    prediction layer (§3.1).
  * the sampler's ``n_layers``/``agg_layers`` are always consistent with the
    model's — they are the same fields.
  * ``d_in`` / ``n_classes`` are read off the dataset at bind time instead of
    being recomputed at every call site.
  * the paper's baselines (§3.5/§5.2) are first-class ``method`` values:
    centralized (M=1 union view), standalone (no communication),
    simulated-centralized (K=L, Q=1), fedbcd (A(E_m)=I via fanout 0).

``to_dict``/``from_dict`` round-trip exactly, so a config can ride along as
checkpoint metadata and reconstruct the experiment.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

from ..comm.compression import CompressionConfig
from ..core.glasu import GlasuConfig
from ..fed.faults import FaultConfig
from ..serve.config import ServeConfig
from ..core.train import TrainConfig
from ..graph.sampler import SamplerConfig
from ..optim import optimizers as opt_lib

METHODS = ("glasu", "centralized", "standalone", "simulated-centralized",
           "fedbcd")
BACKENDS = ("vmapped", "simulation", "sharded")


def agg_layers_for_k(n_layers: int, k: int) -> Tuple[int, ...]:
    """Paper's 'uniform' placement: K=1 -> last; K=2 -> middle+last; K=L -> all."""
    if k >= n_layers:
        return tuple(range(n_layers))
    step = n_layers // k
    return tuple(sorted({n_layers - 1 - i * step for i in range(k)}))


@dataclass(frozen=True)
class ExperimentConfig:
    # ------------------------------------------------------------- scenario
    name: str = "glasu-experiment"
    dataset: str = "cora"
    method: str = "glasu"
    backend: str = "vmapped"
    mesh_devices: Optional[int] = None    # sharded: cap on client-mesh devices
    # --------------------------------------------------------------- model
    n_clients: int = 3                    # data parties M (model runs M=1 if centralized)
    n_layers: int = 4
    hidden: int = 64
    backbone: str = "gcnii"
    agg: str = "mean"                     # 'mean' | 'concat'
    agg_layers: Optional[Tuple[int, ...]] = None  # None -> derived from method/k
    k: Optional[int] = None               # |I|; used only when agg_layers is None
    n_local_steps: int = 1                # Q (stale updates)
    gcnii_alpha: float = 0.1
    gcnii_beta: float = 0.5
    gat_heads: int = 2
    dp_sigma: float = 0.0
    secure_agg: bool = False
    labels_at_client: Optional[int] = None
    use_pallas: bool = False
    # ---------------------------------------------------------- compression
    # wire codec for the §3.1 embedding exchange (None = full float32).
    # A plain dict {"method": ..., "k": ..., "error_feedback": ...} is
    # coerced to a validated CompressionConfig; resume-mutable — EF
    # accumulators reset when the codec changes across a resume.
    compression: Optional[CompressionConfig] = None
    # -------------------------------------------------------------- serving
    # knobs for the repro.serve joint-inference path (cache size, staleness
    # bound, micro-batcher window). None = library defaults; a plain dict
    # is coerced to a validated ServeConfig. Resume-mutable: serving knobs
    # never affect training state.
    serve: Optional[ServeConfig] = None
    # ---------------------------------------------------------------- faults
    # client fault injection for the federated runtime (None = fault-free
    # synchronous rounds). A plain dict is coerced to a validated
    # FaultConfig; the fault draw is a SEPARATE seeded stream
    # (faults.seed), so the same model seed trains under different fault
    # profiles. Resume-mutable: changing the block across a resume resets
    # the fault schedule and stale caches (fresh sidecar), never the model.
    faults: Optional[FaultConfig] = None
    # -------------------------------------------------------------- sampler
    batch_size: int = 16
    fanout: int = 3
    size_cap: int = 512
    table_cap: int = 64
    # ------------------------------------------------------------- training
    rounds: int = 200
    rounds_per_step: int = 1              # K rounds per scanned device step
    prefetch_buffers: int = 2             # sampler prefetch generations
    lr: float = 0.01
    optimizer: str = "adam"
    eval_every: int = 25
    eval_table_cap: int = 32
    seed: int = 0
    eval_mode: Optional[str] = None       # None -> 'per_client' iff standalone
    target_acc: Optional[float] = None    # early stop (paper Table 4)
    # -------------------------------------------------------- checkpointing
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0                   # rounds between saves (0 = final only)

    # ------------------------------------------------------------ validation
    def __post_init__(self):
        def err(msg):
            raise ValueError(f"ExperimentConfig {self.name!r}: {msg}")

        if self.method not in METHODS:
            err(f"unknown method {self.method!r}; expected one of {METHODS}")
        if self.backend not in BACKENDS:
            err(f"unknown backend {self.backend!r}; expected one of {BACKENDS}")
        if self.optimizer not in opt_lib.OPTIMIZER_NAMES:
            err(f"unknown optimizer {self.optimizer!r}; expected one of "
                f"{opt_lib.OPTIMIZER_NAMES}")
        if self.n_clients < 1 or self.n_layers < 1:
            err("n_clients and n_layers must be positive")
        if self.n_local_steps < 1:
            err("n_local_steps (Q) must be >= 1")
        if self.rounds < 0:
            err("rounds must be >= 0")   # 0 = eval-only run
        if self.eval_every < 0:
            err("eval_every must be >= 0 (0 = no exact eval; the only "
                "option for streamed-store datasets, whose features never "
                "materialize)")
        if self.eval_every == 0 and self.target_acc is not None:
            err("target_acc early stopping needs periodic exact eval; set "
                "eval_every > 0")
        if self.rounds_per_step < 1:
            err("rounds_per_step must be >= 1")
        if self.prefetch_buffers < 1:
            err("prefetch_buffers must be >= 1")
        if self.agg not in ("mean", "concat"):
            err(f"unknown aggregation {self.agg!r}")
        if self.agg == "concat" and self.backbone != "gcn":
            err("concat aggregation is implemented for the gcn backbone only")
        if self.eval_mode not in (None, "ensemble", "per_client"):
            err(f"unknown eval_mode {self.eval_mode!r}")
        if isinstance(self.compression, dict):
            try:
                object.__setattr__(self, "compression",
                                   CompressionConfig(**self.compression))
            except (TypeError, ValueError) as e:
                err(f"invalid compression block: {e}")
        elif not (self.compression is None
                  or isinstance(self.compression, CompressionConfig)):
            err(f"compression must be a CompressionConfig or dict, got "
                f"{type(self.compression).__name__}")
        if isinstance(self.serve, dict):
            try:
                object.__setattr__(self, "serve",
                                   ServeConfig(**self.serve))
            except (TypeError, ValueError) as e:
                err(f"invalid serve block: {e}")
        elif not (self.serve is None or isinstance(self.serve, ServeConfig)):
            err(f"serve must be a ServeConfig or dict, got "
                f"{type(self.serve).__name__}")
        if self.compression is not None and self.compression.active \
                and self.secure_agg:
            err("secure_agg masks cancel only exactly; compressed uploads "
                "break the pairwise cancellation — disable one of "
                "compression / secure_agg")
        if isinstance(self.faults, dict):
            try:
                object.__setattr__(self, "faults", FaultConfig(**self.faults))
            except (TypeError, ValueError) as e:
                err(f"invalid faults block: {e}")
        elif not (self.faults is None or isinstance(self.faults, FaultConfig)):
            err(f"faults must be a FaultConfig or dict, got "
                f"{type(self.faults).__name__}")
        if self.faults is not None:
            # faults × compression compose since the round engines were
            # unified: the server caches each client's last DELIVERED
            # decoded block and EF accumulators freeze for rounds a client
            # never transmitted (core.glasu._compressed_aggregate)
            if self.secure_agg or self.dp_sigma > 0.0:
                err("fault tolerance is incompatible with the §3.6 privacy "
                    "hooks: pairwise masks and per-round DP noise assume "
                    "every client uploads every round")
            if self.labels_at_client is not None:
                err("fault tolerance does not implement labels_at_client "
                    "(the Alg 6 owner gradient assumes a synchronous "
                    "exchange)")
            if self.method == "standalone":
                err("faults model the aggregation exchange; standalone has "
                    "no communication to fault")
            if self.model_clients < 2:
                err("fault tolerance needs >= 2 model clients (a single "
                    "client's absence leaves nothing to aggregate)")

        # method-specific derivations / constraints
        if self.method == "simulated-centralized":
            if self.n_local_steps != 1:
                err("simulated-centralized requires Q == 1 (paper §3.5)")
            want = tuple(range(self.n_layers))
            if self.agg_layers is not None and tuple(self.agg_layers) != want:
                err("simulated-centralized aggregates at every layer; "
                    f"agg_layers must be {want} (or None to derive)")
            object.__setattr__(self, "agg_layers", want)
        elif self.method == "standalone":
            if self.agg_layers:
                err("standalone means no communication; agg_layers must be "
                    "empty (or None to derive)")
            object.__setattr__(self, "agg_layers", ())
        else:
            if self.agg_layers is None:
                k = self.k if self.k is not None else max(self.n_layers // 2, 1)
                object.__setattr__(self, "agg_layers",
                                   agg_layers_for_k(self.n_layers, k))
            else:
                object.__setattr__(self, "agg_layers",
                                   tuple(sorted(set(self.agg_layers))))
        # fedbcd (A(E_m) = I) neutralizes the graph via resolved_fanout == 0;
        # the stored fanout field is untouched so switching method back to a
        # graph-based one restores normal sampling.

        if self.k is not None and self.agg_layers and \
                len(self.agg_layers) != self.k:
            err(f"k={self.k} inconsistent with explicit agg_layers="
                f"{self.agg_layers}")
        if self.agg_layers:
            if any(l < 0 or l >= self.n_layers for l in self.agg_layers):
                err(f"agg_layers {self.agg_layers} out of range for "
                    f"n_layers={self.n_layers}")
            if (self.n_layers - 1) not in self.agg_layers:
                err("missing prediction-layer aggregation: the input of the "
                    f"classifier (layer {self.n_layers - 1}) must be in "
                    "agg_layers (paper §3.1)")
        if self.labels_at_client is not None and not (
                0 <= self.labels_at_client < self.model_clients):
            err(f"labels_at_client={self.labels_at_client} out of range for "
                f"{self.model_clients} model clients")
        if self.backend == "simulation":
            if self.agg != "mean":
                err("SimulationBackend implements mean aggregation only")
            if self.secure_agg or self.dp_sigma > 0.0:
                err("SimulationBackend does not implement the §3.6 privacy "
                    "hooks; use the vmapped backend")
        if self.mesh_devices is not None:
            if self.backend != "sharded":
                err("mesh_devices is only meaningful for the sharded backend")
            if self.mesh_devices < 1:
                err("mesh_devices must be >= 1")
        if self.backend == "sharded":
            if self.labels_at_client is not None:
                err("ShardedBackend does not implement labels_at_client "
                    "(Alg 6 owner gradient indexes the global client axis); "
                    "use the vmapped backend")
            if self.optimizer == "adafactor":
                err("ShardedBackend does not support adafactor: factored "
                    "second moments reduce across the client-stacked dim")

    # --------------------------------------------------------------- derived
    @property
    def model_clients(self) -> int:
        """Number of clients the *model* runs with (centralized => M=1)."""
        return 1 if self.method == "centralized" else self.n_clients

    @property
    def sampler_agg_layers(self) -> Tuple[int, ...]:
        """Standalone still needs a shared mini-batch S[L] (Alg 2)."""
        return self.agg_layers if self.agg_layers else (self.n_layers - 1,)

    @property
    def resolved_fanout(self) -> int:
        """fedbcd keeps only the self loop — A(E_m) = I (§3.5)."""
        return 0 if self.method == "fedbcd" else self.fanout

    @property
    def resolved_eval_mode(self) -> str:
        if self.eval_mode is not None:
            return self.eval_mode
        return "per_client" if self.method == "standalone" else "ensemble"

    def glasu_config(self, data) -> GlasuConfig:
        """Bind to a dataset: derives d_in / n_classes, checks client counts."""
        if data.n_clients != self.model_clients:
            raise ValueError(
                f"ExperimentConfig {self.name!r}: mismatched n_clients — "
                f"config expects {self.model_clients} model clients, dataset "
                f"{data.name!r} has {data.n_clients}")
        return GlasuConfig(
            n_clients=self.model_clients, n_layers=self.n_layers,
            hidden=self.hidden, n_classes=data.n_classes,
            d_in=max(c.feat_dim for c in data.clients),
            backbone=self.backbone, agg=self.agg, agg_layers=self.agg_layers,
            n_local_steps=self.n_local_steps, gcnii_alpha=self.gcnii_alpha,
            gcnii_beta=self.gcnii_beta, gat_heads=self.gat_heads,
            dp_sigma=self.dp_sigma, secure_agg=self.secure_agg,
            labels_at_client=self.labels_at_client,
            use_pallas=self.use_pallas, compression=self.compression,
            fault_tolerant=self.faults is not None)

    def sampler_config(self) -> SamplerConfig:
        return SamplerConfig(
            n_layers=self.n_layers, agg_layers=self.sampler_agg_layers,
            batch_size=self.batch_size, fanout=self.resolved_fanout,
            size_cap=self.size_cap, table_cap=self.table_cap)

    def train_config(self) -> TrainConfig:
        return TrainConfig(
            rounds=self.rounds, lr=self.lr, optimizer=self.optimizer,
            eval_every=self.eval_every, eval_table_cap=self.eval_table_cap,
            seed=self.seed, eval_mode=self.resolved_eval_mode)

    def make_optimizer(self) -> opt_lib.Optimizer:
        return opt_lib.make_optimizer(self.optimizer, self.lr)

    # ------------------------------------------------------------- interface
    def with_(self, **kw) -> "ExperimentConfig":
        """Functional update (re-runs validation).

        Changing ``method``, ``k``, or ``n_layers`` re-derives the
        aggregation schedule unless ``agg_layers`` is given explicitly in
        the same call — otherwise the schedule materialized for the *old*
        scenario would leak into (and usually conflict with) the new one.
        """
        if ({"method", "k", "n_layers"} & kw.keys()) and "agg_layers" not in kw:
            kw["agg_layers"] = None
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)           # nested dataclasses -> dicts
        if d["agg_layers"] is not None:
            d["agg_layers"] = list(d["agg_layers"])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentConfig":
        d = dict(d)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"ExperimentConfig.from_dict: unknown fields "
                             f"{sorted(unknown)}")
        if d.get("agg_layers") is not None:
            d["agg_layers"] = tuple(d["agg_layers"])
        # compression dicts are coerced to CompressionConfig in __post_init__
        return cls(**d)

    @classmethod
    def from_legacy(cls, model_cfg: GlasuConfig, sampler_cfg: SamplerConfig,
                    train_cfg: TrainConfig, target_acc: Optional[float] = None,
                    dataset: str = "custom") -> "ExperimentConfig":
        """Adapt the seed's three-config surface (used by the train_glasu shim)."""
        agg_layers = tuple(sorted(set(model_cfg.agg_layers)))
        sampler_agg = tuple(sorted(set(sampler_cfg.agg_layers)))
        want = agg_layers if agg_layers else (model_cfg.n_layers - 1,)
        if sampler_agg != want:
            # standalone included: the sampler may only share the mini-batch
            raise ValueError(
                f"mismatched agg_layers: model {tuple(model_cfg.agg_layers)} "
                f"implies sampler {want}, got {tuple(sampler_cfg.agg_layers)}")
        if model_cfg.n_layers != sampler_cfg.n_layers:
            raise ValueError(
                f"mismatched n_layers: model {model_cfg.n_layers} vs sampler "
                f"{sampler_cfg.n_layers}")
        # legacy TrainConfig only knew sgd/momentum/adam; preserve its
        # silent-adam fallback for every other name
        optimizer = (train_cfg.optimizer
                     if train_cfg.optimizer in ("sgd", "momentum", "adam")
                     else "adam")
        return cls(
            name=f"legacy-{dataset}", dataset=dataset,
            method="standalone" if not agg_layers else "glasu",
            n_clients=model_cfg.n_clients, n_layers=model_cfg.n_layers,
            hidden=model_cfg.hidden, backbone=model_cfg.backbone,
            agg=model_cfg.agg, agg_layers=agg_layers or None,
            n_local_steps=model_cfg.n_local_steps,
            gcnii_alpha=model_cfg.gcnii_alpha,
            gcnii_beta=model_cfg.gcnii_beta, gat_heads=model_cfg.gat_heads,
            dp_sigma=model_cfg.dp_sigma, secure_agg=model_cfg.secure_agg,
            labels_at_client=model_cfg.labels_at_client,
            use_pallas=model_cfg.use_pallas,
            batch_size=sampler_cfg.batch_size, fanout=sampler_cfg.fanout,
            size_cap=sampler_cfg.size_cap, table_cap=sampler_cfg.table_cap,
            rounds=train_cfg.rounds, lr=train_cfg.lr, optimizer=optimizer,
            eval_every=train_cfg.eval_every,
            eval_table_cap=train_cfg.eval_table_cap, seed=train_cfg.seed,
            eval_mode=train_cfg.eval_mode, target_acc=target_acc)
