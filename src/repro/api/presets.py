"""Named experiment presets — the paper's scenario grid as a registry.

Every §5.2 comparison cell is a preset: {cora, citeseer, pubmed} proxies ×
{gcnii, gcn, gat} backbones × {glasu, centralized, standalone,
simulated-centralized, fedbcd} methods, named ``<dataset>-<backbone>-<method>``
(e.g. ``cora-gcnii-glasu``). Presets are frozen ``ExperimentConfig``s;
customize with ``with_``:

    Trainer(get_preset("cora-gcnii-glasu").with_(rounds=60)).run()
"""
from __future__ import annotations

from typing import Dict, List

from .config import ExperimentConfig

PRESET_DATASETS = ("cora", "citeseer", "pubmed")
PRESET_BACKBONES = ("gcnii", "gcn", "gat")
PRESET_METHODS = ("glasu", "centralized", "standalone",
                  "simulated-centralized", "fedbcd")

_REGISTRY: Dict[str, ExperimentConfig] = {}


def register_preset(cfg: ExperimentConfig, overwrite: bool = False) -> None:
    if cfg.name in _REGISTRY and not overwrite:
        raise ValueError(f"preset {cfg.name!r} already registered")
    _REGISTRY[cfg.name] = cfg


def get_preset(name: str) -> ExperimentConfig:
    try:
        return _REGISTRY[name]
    except KeyError:
        close = [n for n in _REGISTRY if name.split("-")[0] in n][:5]
        hint = f"; similar: {close}" if close else ""
        raise ValueError(f"unknown preset {name!r}{hint}") from None


def list_presets() -> List[str]:
    return sorted(_REGISTRY)


def _register_paper_grid() -> None:
    for dataset in PRESET_DATASETS:
        for backbone in PRESET_BACKBONES:
            for method in PRESET_METHODS:
                # GLASU headline setting: K = L/2 uniform, Q = 4 (Table 2/3)
                q = 4 if method == "glasu" else 1
                register_preset(ExperimentConfig(
                    name=f"{dataset}-{backbone}-{method}",
                    dataset=dataset, method=method, backbone=backbone,
                    n_clients=3, n_layers=4, hidden=64,
                    n_local_steps=q, rounds=200, lr=0.01, eval_every=25))


def _register_scale_profiles() -> None:
    """ROADMAP-scale streamed-store profiles (graph/synth.py POWERLAW_SPECS).

    The 2^20-node power-law graph routes ``graph_agg`` to the CSR
    segment-sum kernel and streams features through ``MemmapFeatureStore``
    column views. Exact full-graph eval would materialize all N feature
    rows, so the preset ships with ``eval_every=0`` (loss-only rounds);
    ``benchmarks/train_bench`` gates the profile's RSS and completion.
    """
    register_preset(ExperimentConfig(
        name="powerlaw1m-gcn-glasu", dataset="powerlaw-1m",
        method="glasu", backbone="gcn", n_clients=2, n_layers=2, hidden=32,
        n_local_steps=1, rounds=50, lr=0.01, eval_every=0, table_cap=8))


_register_paper_grid()
_register_scale_profiles()
