"""Hook-driven training loop replacing the monolithic ``train_glasu``.

The ``Trainer`` owns the dataset binding, the host-side sampler, and the
round loop; everything episodic — periodic exact evaluation, early stopping
at a target accuracy (paper Table 4), communication metering, checkpoint
save/restore — is a ``Hook``. Default hooks reproduce the seed driver's
behavior exactly; callers append their own for logging, sweeps, etc.

    cfg = get_preset("cora-gcnii-glasu")
    result = Trainer(cfg).run()
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from ..core import checkpoint, glasu
from ..core.train import TrainResult, _eval_tables, make_centralized_dataset
from ..graph.sampler import GlasuSampler
from ..graph.synth import make_vfl_dataset
from .backends import Backend, make_backend
from .config import ExperimentConfig


@dataclass
class TrainerState:
    """Mutable run state shared with hooks."""
    params: Any = None
    opt_state: Any = None
    round: int = 0
    comm_bytes: int = 0
    history: List[Dict] = field(default_factory=list)
    val_acc: float = 0.0
    test_acc: float = 0.0
    should_stop: bool = False
    t0: float = 0.0
    wall_seconds: float = 0.0
    last_losses: Any = None


class Hook:
    """Override any subset; hooks run in registration order."""

    def on_train_start(self, trainer: "Trainer"):
        pass

    def on_round_end(self, trainer: "Trainer", metrics: Dict):
        pass

    def on_eval(self, trainer: "Trainer", entry: Dict):
        pass

    def on_train_end(self, trainer: "Trainer"):
        pass


class CommMeterHook(Hook):
    """Accumulates the backend's per-round byte count into the run state."""

    def on_round_end(self, trainer, metrics):
        trainer.state.comm_bytes += metrics["comm_bytes_round"]


class EvalHook(Hook):
    """Periodic exact full-graph evaluation + best-checkpoint bookkeeping.

    Appends a history entry every ``eval_every`` rounds (and at the final
    round) and dispatches ``on_eval`` to every hook — early stopping and
    user hooks key off those entries.
    """

    def on_train_start(self, trainer):
        cfg, data = trainer.cfg, trainer.data
        feats, nbr_idx, nbr_mask = _eval_tables(
            data, cfg.eval_table_cap, cfg.seed)
        mcfg = trainer.model_cfg
        self.eval_fn = jax.jit(lambda p: glasu.full_forward(
            p, mcfg, feats, nbr_idx, nbr_mask,
            chunk=min(4096, data.n_nodes)))

    def _append_entry(self, trainer):
        cfg, st, data = trainer.cfg, trainer.state, trainer.data
        logits = self.eval_fn(st.params)
        mode = cfg.resolved_eval_mode
        val = float(glasu.accuracy_from_logits(
            logits, data.full.labels, data.full.val_idx, mode))
        test = float(glasu.accuracy_from_logits(
            logits, data.full.labels, data.full.test_idx, mode))
        # no round has run yet (rounds == 0, or a resume landing exactly on
        # cfg.rounds): there is no loss to report, not a crash
        loss = (float(st.last_losses[-1]) if st.last_losses is not None
                else float("nan"))
        entry = {"round": st.round, "loss": loss,
                 "val_acc": val, "test_acc": test,
                 "comm_bytes": st.comm_bytes,
                 "seconds": time.perf_counter() - st.t0}
        st.history.append(entry)
        if val >= st.val_acc:
            st.val_acc, st.test_acc = val, test
        for h in trainer.hooks:
            h.on_eval(trainer, entry)

    def on_round_end(self, trainer, metrics):
        cfg, st = trainer.cfg, trainer.state
        if st.round % cfg.eval_every != 0 and st.round != cfg.rounds:
            return
        self._append_entry(trainer)

    def on_train_end(self, trainer):
        """Guarantee a final history entry: covers rounds == 0, a resume
        landing exactly on cfg.rounds, and a hook stopping the run between
        eval cadences (e.g. early stop triggered off round metrics)."""
        st = trainer.state
        if st.history and st.history[-1]["round"] == st.round:
            return
        self._append_entry(trainer)


class EarlyStopHook(Hook):
    """Stop once validation accuracy reaches ``target_acc`` (paper Table 4)."""

    def __init__(self, target_acc: float):
        self.target_acc = target_acc

    def on_eval(self, trainer, entry):
        if entry["val_acc"] >= self.target_acc:
            trainer.state.should_stop = True


class CheckpointHook(Hook):
    """Save/restore (params, opt_state, round, comm_bytes) via core.checkpoint.

    The experiment config is written alongside as ``experiment.json``; on
    resume everything that shapes the state must round-trip equal —
    restoring under a different model/optimizer config is an error, not a
    silent shape mismatch. Loop-schedule fields (rounds, eval cadence,
    early-stop target, ...) may change between resumes.
    """

    RESUME_MUTABLE = ("name", "rounds", "eval_every", "eval_table_cap",
                      "target_acc", "ckpt_every", "ckpt_dir")

    def __init__(self, ckpt_dir: str, every: int = 0, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.every = every
        self.keep = keep

    def _tree(self, st: TrainerState):
        return {"params": st.params, "opt_state": st.opt_state}

    def _sidecar(self, step: int):
        import pathlib
        return pathlib.Path(self.ckpt_dir) / f"state_{step:08d}.json"

    def on_train_start(self, trainer):
        import pathlib
        st = trainer.state
        meta = pathlib.Path(self.ckpt_dir) / "experiment.json"
        step = checkpoint.latest_step(self.ckpt_dir)
        if step is not None:
            if meta.exists():
                saved = ExperimentConfig.from_dict(
                    json.loads(meta.read_text())).to_dict()
                here = trainer.cfg.to_dict()
                for k in self.RESUME_MUTABLE:
                    saved.pop(k, None)
                    here.pop(k, None)
                if saved != here:
                    diff = sorted(k for k in here if saved.get(k) != here[k])
                    raise ValueError(
                        f"checkpoint in {self.ckpt_dir} was written by a "
                        f"different experiment config (fields {diff})")
            tree = checkpoint.restore(self.ckpt_dir, self._tree(st), step)
            st.params = tree["params"]
            st.opt_state = tree["opt_state"]
            st.round = step
            loop = json.loads(self._sidecar(step).read_text())
            st.comm_bytes = loop["comm_bytes"]
            st.val_acc, st.test_acc = loop["val_acc"], loop["test_acc"]
            st.history = loop["history"]
            # restore the wall-clock baseline: offset t0 by the elapsed
            # seconds persisted at save time so 'seconds' in new history
            # entries continues monotonically from the restored ones
            # (older sidecars lack the field — fall back to the last
            # restored entry's timestamp)
            elapsed = loop.get("elapsed_seconds",
                               st.history[-1]["seconds"] if st.history
                               else 0.0)
            st.t0 = time.perf_counter() - elapsed
        else:
            pathlib.Path(self.ckpt_dir).mkdir(parents=True, exist_ok=True)
            meta.write_text(json.dumps(trainer.cfg.to_dict(), indent=1))

    def _save(self, trainer):
        import pathlib
        st = trainer.state
        checkpoint.save(self.ckpt_dir, st.round, self._tree(st))
        self._sidecar(st.round).write_text(json.dumps(
            {"comm_bytes": st.comm_bytes, "val_acc": st.val_acc,
             "test_acc": st.test_acc, "history": st.history,
             "elapsed_seconds": time.perf_counter() - st.t0}))
        checkpoint.cleanup(self.ckpt_dir, keep=self.keep)
        live = {int(f.stem.split("_")[1])
                for f in pathlib.Path(self.ckpt_dir).glob("ckpt_*.npz")}
        for f in pathlib.Path(self.ckpt_dir).glob("state_*.json"):
            if int(f.stem.split("_")[1]) not in live:
                f.unlink()

    def on_round_end(self, trainer, metrics):
        if self.every and trainer.state.round % self.every == 0:
            self._save(trainer)

    def on_train_end(self, trainer):
        if trainer.state.round > 0:
            self._save(trainer)


class Trainer:
    """Run one experiment: dataset binding + backend + hook pipeline."""

    def __init__(self, cfg: ExperimentConfig, data=None,
                 backend: Optional[Backend] = None,
                 hooks: Sequence[Hook] = ()):
        self.cfg = cfg
        self.data = data if data is not None else self._make_data(cfg)
        self.model_cfg = cfg.glasu_config(self.data)
        self.sampler = GlasuSampler(self.data, cfg.sampler_config(),
                                    seed=cfg.seed)
        self.optimizer = cfg.make_optimizer()
        self.backend = backend if backend is not None \
            else make_backend(cfg.backend)
        self.backend.bind(self.model_cfg, self.optimizer, self.sampler)
        self.hooks: List[Hook] = [CommMeterHook(), EvalHook()]
        if cfg.target_acc is not None:
            self.hooks.append(EarlyStopHook(cfg.target_acc))
        if cfg.ckpt_dir is not None:
            self.hooks.append(CheckpointHook(cfg.ckpt_dir, cfg.ckpt_every))
        self.hooks.extend(hooks)
        self.state = TrainerState()

    @staticmethod
    def _make_data(cfg: ExperimentConfig):
        data = make_vfl_dataset(cfg.dataset, n_clients=cfg.n_clients,
                                seed=cfg.seed)
        if cfg.method == "centralized":
            data = make_centralized_dataset(data)
        return data

    def run(self) -> TrainResult:
        cfg, st = self.cfg, self.state
        key = jax.random.PRNGKey(cfg.seed)
        st.params = glasu.init_params(key, self.model_cfg)
        st.opt_state = self.optimizer.init(st.params)
        st.t0 = time.perf_counter()
        for h in self.hooks:
            h.on_train_start(self)          # CheckpointHook may fast-forward
        for _ in range(st.round):
            # replay the consumed sampler stream so a resumed run sees the
            # same batch sequence as an uninterrupted one
            self.sampler.sample_round()
        for t in range(st.round, cfg.rounds):
            # jnp.array (copy) not jnp.asarray: on CPU the latter zero-copy
            # aliases the sampler's reused scratch buffers, which the next
            # sample_round overwrites while this round's async computation
            # may still be reading them
            batch = jax.tree.map(jnp.array, self.sampler.sample_round())
            out = self.backend.run_round(st.params, st.opt_state, batch,
                                         jax.random.fold_in(key, t))
            st.params, st.opt_state = out.params, out.opt_state
            st.last_losses = out.losses
            st.round = t + 1
            metrics = {"round": st.round, "losses": out.losses,
                       "comm_bytes_round": out.comm_bytes,
                       "message_log": out.message_log}
            for h in self.hooks:
                h.on_round_end(self, metrics)
            if st.should_stop:
                break
        st.wall_seconds = time.perf_counter() - st.t0
        for h in self.hooks:
            h.on_train_end(self)
        return TrainResult(
            test_acc=st.test_acc, val_acc=st.val_acc, history=st.history,
            comm_bytes=st.comm_bytes, rounds_run=st.round,
            wall_seconds=st.wall_seconds, params=st.params)
