"""Hook-driven training loop replacing the monolithic ``train_glasu``.

The ``Trainer`` owns the dataset binding, the host-side sampler, and the
round loop; everything episodic — periodic exact evaluation, early stopping
at a target accuracy (paper Table 4), communication metering, checkpoint
save/restore — is a ``Hook``. Default hooks reproduce the seed driver's
behavior exactly; callers append their own for logging, sweeps, etc.

    cfg = get_preset("cora-gcnii-glasu")
    result = Trainer(cfg).run()

The loop itself is a device-resident round engine: ``cfg.rounds_per_step``
rounds advance per jitted dispatch (``lax.scan`` over round-stacked
batches, donated parameter/optimizer buffers) and host-side sampling runs
in a background prefetch thread overlapped with device compute. For the
built-in hooks — and any hook that acts on eval/checkpoint cadence
boundaries — the engine is bit-identical to the historical per-round loop
at every ``rounds_per_step`` (see ``step_schedule``). A custom hook that
inspects ``state.params`` or requests a stop on a round OFF those
cadences sees end-of-step state: the K rounds of a step are one device
dispatch, so mid-step stops take effect once the already-computed step
finishes (up to K-1 rounds later than the per-round loop).
"""
from __future__ import annotations

import copy
import json
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core import checkpoint, glasu
from ..core.train import TrainResult, _eval_tables, make_centralized_dataset
from ..fed.faults import make_schedule
from ..graph.prefetch import PrefetchSampler
from ..graph.sampler import GlasuSampler
from ..graph.synth import make_vfl_dataset
from .backends import Backend, make_backend
from .config import ExperimentConfig


# (K,) per-round keys in ONE dispatch — K sequential fold_in calls would
# hand back a chunk of the per-round host overhead the scan removes
_fold_keys = jax.jit(jax.vmap(jax.random.fold_in, in_axes=(None, 0)))


def step_schedule(start: int, rounds: int, rounds_per_step: int,
                  cadences: Tuple[int, ...] = ()) -> List[int]:
    """Step sizes covering rounds (start, rounds], cut at cadence boundaries.

    Every multiple of every (non-zero) cadence — eval_every, ckpt_every —
    ends a step, so hooks that act on those rounds always see end-of-step
    parameters and the multi-round engine is observationally identical to
    the per-round loop for ANY cadence. Aligned cadences (multiples of
    ``rounds_per_step``) keep the schedule uniform, which keeps the scanned
    step function at a single trace; misaligned ones just add remainder
    steps (extra traces, same results).
    """
    steps: List[int] = []
    t = start
    while t < rounds:
        k = min(rounds_per_step, rounds - t)
        for c in cadences:
            if c:
                k = min(k, (t // c + 1) * c - t)
        steps.append(k)
        t += k
    return steps


@dataclass
class TrainerState:
    """Mutable run state shared with hooks."""
    params: Any = None
    opt_state: Any = None
    round: int = 0
    comm_bytes: int = 0
    history: List[Dict] = field(default_factory=list)
    val_acc: float = 0.0
    test_acc: float = 0.0
    should_stop: bool = False
    t0: float = 0.0
    wall_seconds: float = 0.0
    last_losses: Any = None
    sampler_rng_state: Optional[dict] = None   # after st.round rounds drawn
    virtual_ms: float = 0.0                    # fault runs: simulated clock


class Hook:
    """Override any subset; hooks run in registration order."""

    def on_train_start(self, trainer: "Trainer"):
        pass

    def on_round_end(self, trainer: "Trainer", metrics: Dict):
        pass

    def on_eval(self, trainer: "Trainer", entry: Dict):
        pass

    def on_train_end(self, trainer: "Trainer"):
        pass


class CommMeterHook(Hook):
    """Accumulates the backend's per-round byte count into the run state.

    Fault-tolerant steps report per-round DELIVERED bytes (the Trainer
    threads ``StepResult.comm_bytes_rounds`` into each round's metrics),
    so dropped uploads never accumulate here.
    """

    def on_round_end(self, trainer, metrics):
        trainer.state.comm_bytes += metrics["comm_bytes_round"]


class ParticipationHook(Hook):
    """Fault-run telemetry: participation rate, catch-ups, virtual clock.

    Registered automatically when ``cfg.faults`` is set (before
    ``EvalHook``, so eval entries see the stats through the eval round).
    Each eval entry gains the running mean participation fraction, the
    count of forced catch-up rounds, and the virtual wall-clock.
    """

    def on_train_start(self, trainer):
        self.rounds = 0
        self.presence = 0.0
        self.catch_ups = 0

    def on_round_end(self, trainer, metrics):
        plan = metrics.get("fault_plan")
        if plan is None:
            return
        self.rounds += 1
        self.presence += plan.n_present / len(plan.present)
        self.catch_ups += bool(plan.catch_up)
        trainer.state.virtual_ms = plan.t_end

    def on_eval(self, trainer, entry):
        if self.rounds:
            entry["participation"] = self.presence / self.rounds
            entry["catch_up_rounds"] = self.catch_ups
            entry["virtual_ms"] = trainer.state.virtual_ms


class EvalHook(Hook):
    """Periodic exact full-graph evaluation + best-checkpoint bookkeeping.

    Appends a history entry every ``eval_every`` rounds (and at the final
    round) and dispatches ``on_eval`` to every hook — early stopping and
    user hooks key off those entries.
    """

    def on_train_start(self, trainer):
        cfg, data = trainer.cfg, trainer.data
        feats, nbr_idx, nbr_mask = _eval_tables(
            data, cfg.eval_table_cap, cfg.seed)
        mcfg = trainer.model_cfg
        self.eval_fn = jax.jit(lambda p: glasu.full_forward(
            p, mcfg, feats, nbr_idx, nbr_mask,
            chunk=min(4096, data.n_nodes)))

    def _append_entry(self, trainer):
        cfg, st, data = trainer.cfg, trainer.state, trainer.data
        logits = self.eval_fn(st.params)
        mode = cfg.resolved_eval_mode
        val = float(glasu.accuracy_from_logits(
            logits, data.full.labels, data.full.val_idx, mode))
        test = float(glasu.accuracy_from_logits(
            logits, data.full.labels, data.full.test_idx, mode))
        # no round has run yet (rounds == 0, or a resume landing exactly on
        # cfg.rounds): there is no loss to report, not a crash. One
        # device_get here — at eval cadence — is the only host sync the
        # loss reporting pays; non-eval rounds never block on device.
        loss = (float(jax.device_get(st.last_losses)[-1])
                if st.last_losses is not None else float("nan"))
        entry = {"round": st.round, "loss": loss,
                 "val_acc": val, "test_acc": test,
                 "comm_bytes": st.comm_bytes,
                 "seconds": time.perf_counter() - st.t0}
        st.history.append(entry)
        if val >= st.val_acc:
            st.val_acc, st.test_acc = val, test
        for h in trainer.hooks:
            h.on_eval(trainer, entry)

    def on_round_end(self, trainer, metrics):
        cfg, st = trainer.cfg, trainer.state
        if st.round % cfg.eval_every != 0 and st.round != cfg.rounds:
            return
        self._append_entry(trainer)

    def on_train_end(self, trainer):
        """Guarantee a final history entry: covers rounds == 0, a resume
        landing exactly on cfg.rounds, and a hook stopping the run between
        eval cadences (e.g. early stop triggered off round metrics)."""
        st = trainer.state
        if st.history and st.history[-1]["round"] == st.round:
            return
        self._append_entry(trainer)


class EarlyStopHook(Hook):
    """Stop once validation accuracy reaches ``target_acc`` (paper Table 4)."""

    def __init__(self, target_acc: float):
        self.target_acc = target_acc

    def on_eval(self, trainer, entry):
        if entry["val_acc"] >= self.target_acc:
            trainer.state.should_stop = True


class CheckpointHook(Hook):
    """Save/restore (params, opt_state, round, comm_bytes) via core.checkpoint.

    The experiment config is written alongside as ``experiment.json``; on
    resume everything that shapes the state must round-trip equal —
    restoring under a different model/optimizer config is an error, not a
    silent shape mismatch. Loop-schedule fields (rounds, eval cadence,
    early-stop target, ...) may change between resumes.
    """

    RESUME_MUTABLE = ("name", "rounds", "eval_every", "eval_table_cap",
                      "target_acc", "ckpt_every", "ckpt_dir",
                      "rounds_per_step", "prefetch_buffers", "mesh_devices",
                      "compression", "serve", "faults")

    def __init__(self, ckpt_dir: str, every: int = 0, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.every = every
        self.keep = keep

    def _tree(self, st: TrainerState):
        return {"params": st.params, "opt_state": st.opt_state}

    def _sidecar(self, step: int):
        import pathlib
        return pathlib.Path(self.ckpt_dir) / f"state_{step:08d}.json"

    def on_train_start(self, trainer):
        import pathlib
        st = trainer.state
        meta = pathlib.Path(self.ckpt_dir) / "experiment.json"
        step = checkpoint.latest_step(self.ckpt_dir)
        if step is not None:
            saved_comp = saved_faults = None
            if meta.exists():
                saved = ExperimentConfig.from_dict(
                    json.loads(meta.read_text())).to_dict()
                here = trainer.cfg.to_dict()
                saved_comp = saved.get("compression")
                saved_faults = saved.get("faults")
                for k in self.RESUME_MUTABLE:
                    saved.pop(k, None)
                    here.pop(k, None)
                if saved != here:
                    diff = sorted(k for k in here if saved.get(k) != here[k])
                    raise ValueError(
                        f"checkpoint in {self.ckpt_dir} was written by a "
                        f"different experiment config (fields {diff})")
            tree = checkpoint.restore(self.ckpt_dir, self._tree(st), step)
            st.params = tree["params"]
            st.opt_state = tree["opt_state"]
            st.round = step
            self._restore_comp_state(trainer, step, saved_comp)
            loop = json.loads(self._sidecar(step).read_text())
            self._restore_fault_state(trainer, step, saved_faults, loop)
            st.comm_bytes = loop["comm_bytes"]
            st.val_acc, st.test_acc = loop["val_acc"], loop["test_acc"]
            st.history = loop["history"]
            # restore the wall-clock baseline: offset t0 by the elapsed
            # seconds persisted at save time so 'seconds' in new history
            # entries continues monotonically from the restored ones
            # (older sidecars lack the field — fall back to the last
            # restored entry's timestamp)
            elapsed = loop.get("elapsed_seconds",
                               st.history[-1]["seconds"] if st.history
                               else 0.0)
            st.t0 = time.perf_counter() - elapsed
            # new sidecars carry the sampler's exact bit-generator state at
            # save time: restore it directly instead of the O(rounds)
            # sample_round() replay (the Trainer falls back to replay for
            # sidecars written before the field existed)
            rng_state = loop.get("sampler_rng")
            if rng_state is not None:
                trainer.sampler.rng.bit_generator.state = rng_state
                trainer.sampler_restored = True
        else:
            pathlib.Path(self.ckpt_dir).mkdir(parents=True, exist_ok=True)
            meta.write_text(json.dumps(trainer.cfg.to_dict(), indent=1))

    def _restore_comp_state(self, trainer, step: int, saved_comp):
        """Restore the compressed-exchange EF accumulators (resume-mutable).

        The ``compression`` block may change between resumes; accumulators
        are only restored when (a) the current run keeps one (EF enabled),
        (b) a ``comp_<step>.npz`` sidecar exists, and (c) the codec that
        wrote it is KNOWN to match the current one (``experiment.json``
        comparison — different codecs produce identically-shaped state
        trees, so a residual restored across a codec change would load
        silently and mean nothing). Otherwise — including a missing or
        unreadable meta file, i.e. unknown provenance — error feedback
        restarts from zeros, which is always a valid EF state.
        """
        import dataclasses
        import pathlib
        comp_state = getattr(trainer.backend, "comp_state", None)
        if not comp_state:               # compression off or stateless codec
            return
        comp_file = pathlib.Path(self.ckpt_dir) / f"comp_{step:08d}.npz"
        if not comp_file.exists():
            return                       # EF newly enabled: start from zeros
        if saved_comp != dataclasses.asdict(trainer.cfg.compression):
            return                       # codec changed/unknown: reset
        trainer.backend.comp_state = checkpoint.restore(
            self.ckpt_dir, comp_state, step, name="comp")

    def _restore_fault_state(self, trainer, step: int, saved_faults, loop):
        """Restore the stale-embedding caches + fault schedule (resume-mutable).

        Same provenance contract as the EF sidecar: the caches and the
        schedule's rng state are restored only when a ``fault_<step>.npz``
        sidecar AND a persisted schedule state exist and the fault block
        that wrote them matches the current one. A changed/unknown block —
        or a run that just turned faults on — starts a fresh schedule with
        zero caches, which is always a valid fault state (never-delivered
        slots carry weight 0). The sidecar follows ``core.checkpoint``'s
        loud-corruption contract; a truncated/garbled file raises rather
        than silently training against partial caches.
        """
        import dataclasses
        import pathlib
        fault_state = getattr(trainer.backend, "fault_state", None)
        if fault_state is None or trainer.fault_sched is None:
            return
        if saved_faults != dataclasses.asdict(trainer.cfg.faults):
            return                       # fault block changed/unknown: reset
        sched_state = loop.get("fault_sched")
        fault_file = pathlib.Path(self.ckpt_dir) / f"fault_{step:08d}.npz"
        if sched_state is None or not fault_file.exists():
            return                       # pre-fault sidecar: reset
        trainer.backend.fault_state = checkpoint.restore(
            self.ckpt_dir, fault_state, step, name="fault")
        trainer.fault_sched.load_state(sched_state)
        trainer.fault_sched_restored = True

    def _save(self, trainer):
        import pathlib
        st = trainer.state
        checkpoint.save(self.ckpt_dir, st.round, self._tree(st))
        comp_state = getattr(trainer.backend, "comp_state", None)
        if comp_state:                   # EF accumulators ride as a sidecar
            checkpoint.save(self.ckpt_dir, st.round, comp_state, name="comp")
        fault_state = getattr(trainer.backend, "fault_state", None)
        if fault_state is not None:      # stale caches ride as a sidecar
            checkpoint.save(self.ckpt_dir, st.round, fault_state,
                            name="fault")
        # the meta file records the config that WROTE the latest state —
        # updated at save time (not resume start), so a resume that dies
        # before its first save can't relabel an older codec's EF sidecar
        # as its own for the next resume's provenance comparison
        (pathlib.Path(self.ckpt_dir) / "experiment.json").write_text(
            json.dumps(trainer.cfg.to_dict(), indent=1))
        self._sidecar(st.round).write_text(json.dumps(
            {"comm_bytes": st.comm_bytes, "val_acc": st.val_acc,
             "test_acc": st.test_acc, "history": st.history,
             "elapsed_seconds": time.perf_counter() - st.t0,
             # exact resume point for the sampler stream: the generator bit
             # state after st.round rounds were drawn (json handles the
             # arbitrary-precision ints PCG64 carries)
             "sampler_rng": st.sampler_rng_state,
             # fault schedule after st.round rounds drawn (saves land on
             # step ends, where the host draw is exactly st.round deep)
             "fault_sched": trainer.fault_sched.state()
             if trainer.fault_sched is not None else None}))
        checkpoint.cleanup(self.ckpt_dir, keep=self.keep)
        live = {int(f.stem.split("_")[1])
                for f in pathlib.Path(self.ckpt_dir).glob("ckpt_*.npz")}
        for f in list(pathlib.Path(self.ckpt_dir).glob("state_*.json")) + \
                list(pathlib.Path(self.ckpt_dir).glob("comp_*.npz")) + \
                list(pathlib.Path(self.ckpt_dir).glob("fault_*.npz")):
            if int(f.stem.split("_")[1]) not in live:
                f.unlink()

    def on_round_end(self, trainer, metrics):
        if self.every and trainer.state.round % self.every == 0:
            self._save(trainer)

    def on_train_end(self, trainer):
        if trainer.state.round > 0:
            self._save(trainer)


class Trainer:
    """Run one experiment: dataset binding + backend + hook pipeline."""

    def __init__(self, cfg: ExperimentConfig, data=None,
                 backend: Optional[Backend] = None,
                 hooks: Sequence[Hook] = ()):
        self.cfg = cfg
        self.data = data if data is not None else self._make_data(cfg)
        self.model_cfg = cfg.glasu_config(self.data)
        self.sampler = GlasuSampler(self.data, cfg.sampler_config(),
                                    seed=cfg.seed)
        self.optimizer = cfg.make_optimizer()
        backend_kw = {"mesh_devices": cfg.mesh_devices} \
            if cfg.backend == "sharded" and cfg.mesh_devices else {}
        self.backend = backend if backend is not None \
            else make_backend(cfg.backend, **backend_kw)
        self.backend.bind(self.model_cfg, self.optimizer, self.sampler)
        # host-side fault schedule (None for fault-free runs): the Trainer
        # owns the sequential draw; backends only ever see per-round plans
        self.fault_sched = make_schedule(cfg.faults, self.model_cfg.n_clients)
        if self.fault_sched is not None and \
                not getattr(self.backend, "supports_faults", False):
            # fail at config time: a backend without the fault contract
            # would otherwise silently train fault-free (the faults kwarg
            # only reaches backends through the run_round/run_step protocol)
            raise ValueError(
                f"backend {self.backend.name!r} does not support the "
                "fault-tolerance protocol (supports_faults); drop the "
                "faults block or pick a fault-capable backend")
        self.hooks: List[Hook] = [CommMeterHook()]
        if self.fault_sched is not None:
            self.hooks.append(ParticipationHook())
        if cfg.eval_every > 0:
            # eval_every == 0 skips exact full-graph eval entirely — the
            # contract for streamed-store datasets (powerlaw-* profiles),
            # where _eval_tables would materialize all N feature rows
            self.hooks.append(EvalHook())
        if cfg.target_acc is not None:
            self.hooks.append(EarlyStopHook(cfg.target_acc))
        if cfg.ckpt_dir is not None:
            self.hooks.append(CheckpointHook(cfg.ckpt_dir, cfg.ckpt_every))
        self.hooks.extend(hooks)
        self.state = TrainerState()
        # set by CheckpointHook when a sidecar restored the sampler's rng
        # bit state directly (skips the O(rounds) replay loop on resume)
        self.sampler_restored = False
        # set by CheckpointHook when the fault sidecar restored the
        # schedule's rng/clock state (skips the O(rounds) draw replay)
        self.fault_sched_restored = False

    def _run_step(self, params, opt_state, batches, keys, faults=None):
        """Dispatch one multi-round step; backends written against the
        older run_round-only protocol fall back to K audited sequential
        rounds (same helper the simulation backend uses)."""
        run_step = getattr(self.backend, "run_step", None)
        if run_step is not None:
            if faults is not None:
                return run_step(params, opt_state, batches, keys,
                                faults=faults)
            return run_step(params, opt_state, batches, keys)
        from .backends import run_step_sequential
        return run_step_sequential(self.backend, params, opt_state,
                                   batches, keys, faults=faults)

    @staticmethod
    def _make_data(cfg: ExperimentConfig):
        data = make_vfl_dataset(cfg.dataset, n_clients=cfg.n_clients,
                                seed=cfg.seed)
        if cfg.method == "centralized":
            data = make_centralized_dataset(data)
        return data

    def run(self) -> TrainResult:
        """Drive the device-resident round engine.

        Rounds advance in multi-round *steps*: ``cfg.rounds_per_step``
        pre-sampled rounds are stacked on a leading axis and dispatched as
        ONE jitted ``lax.scan`` (``Backend.run_step``) with params/opt_state
        donated. The step schedule is cut at every eval/checkpoint cadence
        boundary, so every hook that inspects ``state.params`` fires at a
        step end and sees exactly what the per-round loop would have shown
        it; mid-step rounds still dispatch ``on_round_end`` with their own
        loss row and byte count. Sampling runs in a ``PrefetchSampler``
        worker thread that fills round-stacked generation buffers while the
        device computes the previous step (a hook requesting a stop
        mid-step takes effect once the already-computed step finishes
        dispatching its round metrics).
        """
        cfg, st = self.cfg, self.state
        key = jax.random.PRNGKey(cfg.seed)
        st.params = glasu.init_params(key, self.model_cfg)
        st.opt_state = self.optimizer.init(st.params)
        st.t0 = time.perf_counter()
        for h in self.hooks:
            h.on_train_start(self)          # CheckpointHook may fast-forward
        if st.round and not self.sampler_restored:
            # replay the consumed sampler stream so a resumed run sees the
            # same batch sequence as an uninterrupted one — fallback for
            # sidecars that predate the persisted rng bit state
            for _ in range(st.round):
                self.sampler.sample_round()
        if st.round and self.fault_sched is not None \
                and not self.fault_sched_restored:
            # same replay for the fault draw: a resume without a restored
            # schedule state (fresh/changed fault block keeps zero caches,
            # but the DRAW must stay aligned with the round counter)
            for _ in range(st.round):
                self.fault_sched.next_round()
        st.sampler_rng_state = copy.deepcopy(
            self.sampler.rng.bit_generator.state)
        # every CheckpointHook's cadence cuts the schedule — a save must
        # land on a step end so its sidecar's rng state matches st.round
        ckpt_cadences = tuple(h.every for h in self.hooks
                              if isinstance(h, CheckpointHook))
        schedule = step_schedule(st.round, cfg.rounds, cfg.rounds_per_step,
                                 (cfg.eval_every,) + ckpt_cadences)
        prefetch = PrefetchSampler(self.sampler, schedule,
                                   n_buffers=cfg.prefetch_buffers) \
            if schedule else None
        try:
            t = st.round
            for _ in schedule:
                step = prefetch.get()
                k = step.rounds
                keys = _fold_keys(key, jnp.arange(t, t + k))
                batches = jax.device_put(step.data)
                plans = self.fault_sched.draw_step(k) \
                    if self.fault_sched is not None else None
                out = self._run_step(st.params, st.opt_state, batches, keys,
                                     faults=plans)
                st.params, st.opt_state = out.params, out.opt_state
                st.sampler_rng_state = step.rng_state_after
                # recycles the oldest generation, blocking on ITS compute
                # only — the step just dispatched keeps running
                prefetch.retire(step, out.losses)
                logs = out.message_logs
                per_round_bytes = out.comm_bytes_rounds
                for i in range(k):
                    st.round = t + i + 1
                    # a device row, not a host value: nothing blocks until
                    # EvalHook pulls it at eval cadence
                    st.last_losses = out.losses[i]
                    metrics = {"round": st.round, "losses": out.losses[i],
                               "comm_bytes_round":
                                   per_round_bytes[i]
                                   if per_round_bytes is not None
                                   else out.comm_bytes_round,
                               "message_log": logs[i] if logs else None,
                               "fault_plan": plans[i] if plans else None}
                    for h in self.hooks:
                        h.on_round_end(self, metrics)
                t += k
                if st.should_stop:
                    break
        finally:
            if prefetch is not None:
                prefetch.close()
        st.wall_seconds = time.perf_counter() - st.t0
        for h in self.hooks:
            h.on_train_end(self)
        return TrainResult(
            test_acc=st.test_acc, val_acc=st.val_acc, history=st.history,
            comm_bytes=st.comm_bytes, rounds_run=st.round,
            wall_seconds=st.wall_seconds, params=st.params)
