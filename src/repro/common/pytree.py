"""Small pytree utilities used across the framework (no flax/optax in env)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_axpy(alpha, x, y):
    """alpha * x + y."""
    return jax.tree.map(lambda a, b: alpha * a + b, x, y)


def tree_dot(a, b):
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.asarray(0.0))


def tree_norm(tree):
    return jnp.sqrt(tree_dot(tree, tree))


def tree_count_params(tree) -> int:
    return int(sum(x.size for x in jax.tree.leaves(tree)))


def tree_bytes(tree) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def tree_cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


def tree_any_nan(tree):
    leaves = [jnp.any(jnp.isnan(x)) for x in jax.tree.leaves(tree)
              if jnp.issubdtype(x.dtype, jnp.floating)]
    if not leaves:
        return jnp.asarray(False)
    return jnp.any(jnp.stack(leaves))
