"""Joint-inference serving subsystem (see ``docs/SERVING.md``).

Restores trained params from a checkpoint and answers node-classification
queries through the split-model forward, with a hot-node aggregate cache
(the serving analogue of the paper's §3.5 stale updates), optional wire
codecs on the embedding exchange, audited per-query byte metering, and a
deadline micro-batcher in front of bucketed jit dispatches.
"""
from .batcher import MicroBatcher
from .cache import HotNodeCache
from .config import ServeConfig
from .metrics import ServeAnswer, ServeMetrics
from .session import InferenceSession

__all__ = ["InferenceSession", "HotNodeCache", "MicroBatcher",
           "ServeAnswer", "ServeConfig", "ServeMetrics"]
