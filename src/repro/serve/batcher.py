"""Request micro-batcher: coalesce concurrent queries into one dispatch.

Callers submit node-id lists and get a ``Future``; a background worker
drains the queue, waits up to ``batch_deadline_ms`` from the FIRST queued
request (or until ``max_batch`` ids accumulate), concatenates the ids into
one ``InferenceSession.answer`` call — a single padded, bucketed, jitted
dispatch — and splits the answer back per request. Padding to bucket sizes
means coalescing never retraces: the jit cache is keyed on the bucket, not
on how many requests happened to share a window.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import List, Tuple

import numpy as np

from .metrics import ServeAnswer


class MicroBatcher:
    def __init__(self, session, max_batch: int = None,
                 deadline_ms: float = None):
        self.session = session
        self.max_batch = (max_batch if max_batch is not None
                          else session.serve.max_batch)
        self.deadline_s = (deadline_ms if deadline_ms is not None
                           else session.serve.batch_deadline_ms) / 1e3
        self._queue: List[Tuple[np.ndarray, Future]] = []
        self._cv = threading.Condition()
        self._closed = False
        self.batches = 0          # dispatches issued
        self.coalesced = 0        # requests that shared a dispatch
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def submit(self, nodes) -> "Future[ServeAnswer]":
        nodes = np.asarray(nodes, dtype=np.int32).ravel()
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("batcher is closed")
            self._queue.append((nodes, fut))
            self._cv.notify()
        return fut

    def query(self, nodes, timeout: float = None) -> ServeAnswer:
        """Blocking convenience wrapper around ``submit``."""
        return self.submit(nodes).result(timeout=timeout)

    def _take_batch(self):
        """Wait for work, then hold the window open until the deadline or
        ``max_batch`` ids — whichever comes first."""
        with self._cv:
            while not self._queue and not self._closed:
                self._cv.wait()
            if not self._queue:
                return []
            deadline = time.monotonic() + self.deadline_s
            while (sum(len(n) for n, _ in self._queue) < self.max_batch):
                left = deadline - time.monotonic()
                if left <= 0 or self._closed:
                    break
                self._cv.wait(timeout=left)
            out, self._queue = self._queue, []
            return out

    def _run(self):
        while True:
            batch = self._take_batch()
            if not batch:
                if self._closed:
                    return
                continue
            self.batches += 1
            self.coalesced += len(batch) - 1
            all_nodes = np.concatenate([n for n, _ in batch])
            try:
                ans = self.session.answer(all_nodes)
            except Exception as e:           # noqa: BLE001 — fan the
                for _, fut in batch:         # failure out to every waiter
                    fut.set_exception(e)
                continue
            off = 0
            for nodes, fut in batch:
                sl = slice(off, off + len(nodes))
                off += len(nodes)
                fut.set_result(ServeAnswer(
                    nodes=nodes, logits=ans.logits[sl],
                    per_client=ans.per_client[:, sl, :],
                    preds=ans.preds[sl], fresh_rows=ans.fresh_rows,
                    upload_bytes=ans.upload_bytes,
                    broadcast_bytes=ans.broadcast_bytes,
                    index_bytes=ans.index_bytes,
                    cache_hits=ans.cache_hits,
                    cache_misses=ans.cache_misses,
                    latency_s=ans.latency_s, cold=ans.cold,
                    params_version=ans.params_version, log=ans.log))

    def close(self):
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._worker.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
