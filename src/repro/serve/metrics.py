"""Serving metrics: per-answer records + session-level aggregation."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np


@dataclass
class ServeAnswer:
    """One ``InferenceSession.answer`` result.

    ``logits`` is the ensemble (mean over clients) head output, the
    quantity §5 evaluates; ``per_client`` keeps the M individual heads.
    Byte fields price exactly the FRESH rows exchanged at each aggregation
    layer — cached rows ship nothing (see ``docs/SERVING.md``).
    """

    nodes: np.ndarray                  # (b,) queried node ids, caller order
    logits: np.ndarray                 # (b, C) ensemble logits
    per_client: np.ndarray             # (M, b, C)
    preds: np.ndarray                  # (b,) argmax labels
    fresh_rows: Dict[int, int]         # agg layer -> rows exchanged fresh
    upload_bytes: int                  # client -> server embedding legs
    broadcast_bytes: int               # server -> client aggregate legs
    index_bytes: int                   # fresh-row id lists (int32, 1 leg)
    cache_hits: int
    cache_misses: int
    latency_s: float
    cold: bool                         # False = all-hit fast path (no plan)
    params_version: int
    log: Optional[Any] = None          # MessageLog replay (record_log=True)

    @property
    def wire_bytes(self) -> int:
        return self.upload_bytes + self.broadcast_bytes + self.index_bytes


@dataclass
class ServeMetrics:
    """Running counters over a session's lifetime (thread-safe under the
    session's dispatch lock — mutated only while it is held)."""

    queries: int = 0
    answers: int = 0
    upload_bytes: int = 0
    broadcast_bytes: int = 0
    index_bytes: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    warm_answers: int = 0
    fresh_rows: Dict[int, int] = field(default_factory=dict)
    latencies_s: List[float] = field(default_factory=list)

    def record(self, ans: ServeAnswer):
        self.queries += len(ans.nodes)
        self.answers += 1
        self.upload_bytes += ans.upload_bytes
        self.broadcast_bytes += ans.broadcast_bytes
        self.index_bytes += ans.index_bytes
        self.cache_hits += ans.cache_hits
        self.cache_misses += ans.cache_misses
        self.warm_answers += int(not ans.cold)
        for l, n in ans.fresh_rows.items():
            self.fresh_rows[l] = self.fresh_rows.get(l, 0) + n
        self.latencies_s.append(ans.latency_s)

    @property
    def wire_bytes(self) -> int:
        return self.upload_bytes + self.broadcast_bytes + self.index_bytes

    def latency_percentiles(self) -> Dict[str, float]:
        if not self.latencies_s:
            return {"p50": 0.0, "p99": 0.0}
        arr = np.asarray(self.latencies_s)
        return {"p50": float(np.percentile(arr, 50)),
                "p99": float(np.percentile(arr, 99))}

    def summary(self) -> Dict[str, Any]:
        pct = self.latency_percentiles()
        return {
            "queries": self.queries, "answers": self.answers,
            "upload_bytes": self.upload_bytes,
            "broadcast_bytes": self.broadcast_bytes,
            "index_bytes": self.index_bytes,
            "wire_bytes": self.wire_bytes,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "warm_answers": self.warm_answers,
            "fresh_rows": {str(k): v for k, v in
                           sorted(self.fresh_rows.items())},
            "latency_p50_s": pct["p50"], "latency_p99_s": pct["p99"],
        }
