"""Joint-inference serving session.

``InferenceSession`` holds the trained per-client parameter stack (restored
via ``core.checkpoint.load_for_inference`` — params only, no optimizer or
error-feedback state), the per-client feature stores and neighbor tables,
and answers node-classification queries through the same split-model
forward the trainer evaluates with.

Query path, per dispatch:

1. **Cache probe** at the top aggregation layer (L-1). If every queried
   node hits, the answer is assembled straight from cached aggregates and
   one tiny classifier matmul — no receptive field, no cross-client
   exchange, zero wire bytes (the warm fast path the serve benchmark's
   >= 2x throughput gate measures).
2. Otherwise a **receptive-field plan** is built on the host (numpy):
   walking layers top-down, rows already cached at an aggregation layer
   are pruned — their neighbors are never materialized — and the
   remaining rows expand through the SAME padded neighbor tables the
   evaluation path uses (``core.train._eval_tables``), so a cold
   uncompressed answer matches ``core/glasu.py`` ``full_forward`` at the
   query rows. Plans are padded to bucketed static shapes: one jit trace
   per (bucket, engine), never per query.
3. One jitted dispatch (``serve_forward`` or its shard_map twin) runs the
   plan with cached rows injected after each aggregation; freshly
   computed aggregates are written back to the cache keyed on
   (node, layer) at the current ``params_version``.

Byte accounting prices exactly the FRESH rows at each aggregation layer —
each client uploads its (n_fresh, h) block and receives the aggregate
back, at the wire size of the session codec (``comm.compression``) — plus
the int32 fresh-row id lists. ``fed.simulation.log_query_traffic`` replays
the same bill into a ``MessageLog``; the serve benchmark audits the two
term-by-term.

Why injection after aggregation is exact: both ``mean`` and ``concat``
aggregation and every PR 5 codec decode per-row-independently, so a row's
served value does not depend on which other rows share its padded batch —
computing a pruned row's garbage and overwriting it cannot contaminate a
fresh row, and a fresh row's value is bitwise what shipping only the fresh
rows would produce.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, NamedTuple, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..comm.compression import make_compressor
from ..core import checkpoint, glasu
from ..core.train import _eval_neighbor_tables, _eval_tables
from ..graph.feature_store import is_streamed
from ..graph.sampler import SampledBatch
from .cache import HotNodeCache
from .config import ServeConfig
from .metrics import ServeAnswer, ServeMetrics

_UNSET = object()


class QueryPlan(NamedTuple):
    batch: SampledBatch          # jnp arrays, bucket-static shapes
    inject: Dict[int, Any]       # agg layer -> (keep (n,), rows (M,n,h_agg))
    fresh: Dict[int, int]        # agg layer -> rows exchanged fresh
    fills: Dict[int, Any]        # agg layer -> (ids (n,), compute mask (n,))


class InferenceSession:
    """Answer node-classification queries on a trained GLASU model."""

    def __init__(self, params, config, data=None, *, serve=None,
                 compression=_UNSET, params_version: int = 0):
        if compression is not _UNSET:
            config = config.with_(compression=compression)
        if serve is None:
            serve = getattr(config, "serve", None) or ServeConfig()
        elif isinstance(serve, dict):
            serve = ServeConfig(**serve)
        self.config = config
        self.serve = serve
        if data is None:
            from ..graph.synth import make_vfl_dataset
            data = make_vfl_dataset(config.dataset,
                                    n_clients=config.n_clients,
                                    seed=config.seed)
            if config.method == "centralized":
                from ..core.train import make_centralized_dataset
                data = make_centralized_dataset(data)
        self.data = data
        self.mcfg = config.glasu_config(data)
        self.params = params
        self.params_version = int(params_version)
        self._comp = make_compressor(self.mcfg.compression)

        m = self.mcfg
        self.M, self.L, self.N = m.n_clients, m.n_layers, data.n_nodes
        self.h_agg = m.hidden * (self.M if m.agg == "concat" else 1)
        self._down_h = self.h_agg
        self._d_pad = max(c.feat_dim for c in data.clients)
        self._streamed = any(is_streamed(c.features) for c in data.clients)
        if self._streamed:
            # streamed store: neighbor tables only; level-0 features are
            # gathered per plan through the store's LRU (never all N rows)
            nbr_idx, nbr_mask = _eval_neighbor_tables(
                data, config.eval_table_cap, config.seed)
            self._feats_dev = None
            self._np_feats = None
        else:
            feats, nbr_idx, nbr_mask = _eval_tables(
                data, config.eval_table_cap, config.seed)
            self._feats_dev = feats                   # (M, N, d_pad) device
            self._np_feats = np.asarray(feats)
        self._nbr_idx = np.asarray(nbr_idx)           # (M, N, W)
        self._nbr_mask = np.asarray(nbr_mask)
        self._nbr_idx_dev = nbr_idx
        self._nbr_mask_dev = nbr_mask
        self.W = self._nbr_idx.shape[-1]
        self._identity = np.arange(self.N, dtype=np.int32)

        self.cache = HotNodeCache(serve.cache_entries, serve.max_staleness)
        self.metrics = ServeMetrics()
        self._lock = threading.Lock()
        self._sizes: Dict[int, list] = {}
        self._zero_labels: Dict[int, Any] = {}   # bucket -> device zeros

        if serve.engine == "sharded":
            from ..launch.mesh import make_client_mesh
            mesh = make_client_mesh(self.M,
                                    max_devices=config.mesh_devices)
            self._fwd = glasu.make_sharded_serve_fn(
                self.mcfg, mesh, compressor=self._comp)
        else:
            self._fwd = jax.jit(
                lambda p, b, inj: glasu.serve_forward(
                    p, b, self.mcfg, compressor=self._comp,
                    cache_inject=inj))

        def _cls(params, rows, real):
            # zero pad rows BEFORE the head so warm/cold assembly of the
            # same real rows is bitwise identical regardless of pad junk
            rows = rows * real[None, :, None]
            per = jax.vmap(lambda p, x: x @ p["W"] + p["b"])(params["cls"],
                                                             rows)
            return per, per.mean(axis=0)

        self._cls = jax.jit(_cls)

    # ------------------------------------------------------------ factory
    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, step: Optional[int] = None,
                        data=None, *, serve=None, compression=_UNSET):
        """Build a session from a training checkpoint directory (params
        only; optimizer / error-feedback sidecars are never read).
        ``params_version`` starts at the restored training step."""
        r = checkpoint.load_for_inference(ckpt_dir, step=step, data=data)
        return cls(r.params, r.config, r.data, serve=serve,
                   compression=compression, params_version=r.step)

    # ------------------------------------------------------- plan builder
    def _plan_sizes(self, bucket: int) -> list:
        """Static per-level set sizes for one bucket: level L holds the
        padded queries; each level below can add at most M*(W-1) table
        neighbors per computed row, capped at N (identity set)."""
        if bucket not in self._sizes:
            sizes = [0] * (self.L + 1)
            sizes[self.L] = bucket
            grow = 1 + self.M * (self.W - 1)
            for l in range(self.L - 1, -1, -1):
                sizes[l] = min(self.N, sizes[l + 1] * grow)
            self._sizes[bucket] = sizes
        return self._sizes[bucket]

    def _bucket(self, b: int) -> int:
        for bk in self.serve.resolved_buckets():
            if bk >= b:
                return bk
        raise ValueError(f"batch of {b} exceeds largest bucket "
                         f"{self.serve.resolved_buckets()[-1]}")

    def _build_plan(self, q_ids: np.ndarray, bucket: int,
                    top_hit: np.ndarray, top_rows: np.ndarray) -> QueryPlan:
        """Receptive-field plan for one padded query batch (host numpy).

        Top-down: decide per level which rows must be computed (needed,
        real, not cache-hit), expand only those rows' neighbors into the
        level below, and keep EVERY real row's self-chain so the backbone's
        h0/self_pos bookkeeping stays node-aligned (GCNII reads h0 at the
        self position of every layer). ``top_hit``/``top_rows`` are the
        already-probed cache state at layer L-1 (probing again would
        double-count cache statistics).
        """
        M, L, N, W = self.M, self.L, self.N, self.W
        agg_layers = self.mcfg.agg_layers
        sizes = self._plan_sizes(bucket)
        b = len(q_ids)

        sets = [None] * (L + 1)
        needs = [None] * (L + 1)
        computes = [None] * L
        inject: Dict[int, Any] = {}
        fresh: Dict[int, int] = {}
        fills: Dict[int, Any] = {}

        ids = np.full(bucket, -1, dtype=np.int32)
        ids[:b] = q_ids
        sets[L] = ids
        needs[L] = ids >= 0

        for l in range(L - 1, -1, -1):
            cur, need = sets[l + 1], needs[l + 1]
            real = cur >= 0
            if l in agg_layers:
                n_out = sizes[l + 1]
                if l == L - 1:
                    hit = np.zeros(n_out, dtype=np.float32)
                    hit[:len(top_hit)] = top_hit
                    rows = np.zeros((n_out, M, self.h_agg),
                                    dtype=np.float32)
                    rows[:len(top_rows)] = top_rows
                else:
                    hit, rows = self.cache.lookup(
                        l, np.where(need & real, cur, -1),
                        self.params_version, (M, self.h_agg))
                hitb = (hit > 0) & real & need
                compute = need & real & ~hitb
                inject[l] = (hitb.astype(np.float32),
                             np.ascontiguousarray(rows.transpose(1, 0, 2)))
                fresh[l] = int(compute.sum())
                fills[l] = (cur.copy(), compute.copy())
            else:
                compute = need & real
            computes[l] = compute

            n_in = sizes[l]
            cnodes = cur[compute]
            if len(cnodes):
                nb = self._nbr_idx[:, cnodes, :]
                nbr_ids = nb[self._nbr_mask[:, cnodes, :] > 0]
                need_ids = np.unique(np.concatenate([cnodes, nbr_ids]))
            else:
                need_ids = cnodes
            if n_in == N:
                sets[l] = self._identity
                nmask = np.zeros(N, dtype=bool)
                nmask[need_ids] = True
                needs[l] = nmask
            else:
                self_ids = np.unique(cur[real])
                src_ids = np.union1d(self_ids, need_ids)
                ids_l = np.full(n_in, -1, dtype=np.int32)
                ids_l[:len(src_ids)] = src_ids
                sets[l] = ids_l
                nmask = np.zeros(n_in, dtype=bool)
                nmask[:len(src_ids)] = np.isin(src_ids, need_ids)
                needs[l] = nmask

        gi_t, gm_t, rv_t, sp_t = [], [], [], []
        lut = np.full(N, -1, dtype=np.int32)
        for l in range(L):  # glint: disable=GL004 host-side numpy plan building; jnp.asarray only stages the finished tables
            src, dst = sets[l], sets[l + 1]
            n_in, n_out = sizes[l], sizes[l + 1]
            safe_dst = np.maximum(dst, 0)
            ti = self._nbr_idx[:, safe_dst, :]           # (M, n_out, W)
            tm = self._nbr_mask[:, safe_dst, :]
            if n_in == N:
                pos, selfpos = ti, safe_dst
            else:
                srcr = src[src >= 0]
                lut[srcr] = np.arange(len(srcr), dtype=np.int32)
                pos, selfpos = lut[ti], lut[safe_dst]
                lut[srcr] = -1                           # reusable buffer
            gm = (tm * (pos >= 0)
                  * computes[l][None, :, None]).astype(np.float32)
            gi = np.maximum(pos, 0).astype(np.int32)
            # force column 0 = the row's own position: every row (cached,
            # chain-only, padding) gathers at least one valid entry, so
            # every h_plus is finite (gather_mean clamps its denominator,
            # GAT's masked softmax needs >= 1 live logit)
            sp = np.maximum(selfpos, 0).astype(np.int32)
            gi[:, :, 0] = sp[None, :]
            gm[:, :, 0] = 1.0
            gi_t.append(jnp.asarray(gi))
            gm_t.append(jnp.asarray(gm))
            rv_t.append(jnp.asarray(np.ascontiguousarray(
                np.broadcast_to((dst >= 0).astype(np.float32),
                                (M, n_out)))))
            sp_t.append(jnp.asarray(np.ascontiguousarray(
                np.broadcast_to(sp, (M, n_out)))))

        src0 = sets[0]
        if sizes[0] == N:
            if self._streamed:
                raise RuntimeError(
                    "query plan reached the identity set at level 0, which "
                    "a streamed feature store cannot materialize; lower the "
                    "serve buckets / eval_table_cap for this graph scale")
            feats = self._feats_dev          # resident; no per-query copy
        else:
            feats = jnp.asarray(self._gather_feats(src0))
        # labels are a dead input on the serve path; stage one zeros vector
        # per bucket explicitly (jnp.zeros here would upload its scalar
        # fill constant on every cold dispatch — transfer_guard trips on it)
        labels = self._zero_labels.get(bucket)
        if labels is None:
            labels = jnp.asarray(np.zeros(bucket, np.int32))
            self._zero_labels[bucket] = labels
        batch = SampledBatch(
            feats=feats, gather_idx=tuple(gi_t), gather_mask=tuple(gm_t),
            row_valid=tuple(rv_t), labels=labels, self_pos=tuple(sp_t))
        inject_dev = {l: (jnp.asarray(k), jnp.asarray(r))
                      for l, (k, r) in inject.items()}
        return QueryPlan(batch=batch, inject=inject_dev, fresh=fresh,
                         fills=fills)

    def _gather_feats(self, src0: np.ndarray) -> np.ndarray:
        """(M, n, d_pad) level-0 feature block for one plan: resident-array
        slice on small graphs, per-client store row gather when streamed
        (only the plan's rows ever leave disk)."""
        valid = (src0 >= 0).astype(np.float32)[None, :, None]
        if not self._streamed:
            return self._np_feats[:, np.maximum(src0, 0), :] * valid
        safe = np.maximum(src0, 0)
        f = np.zeros((self.M, len(src0), self._d_pad), np.float32)
        for m, c in enumerate(self.data.clients):
            rows = c.features[safe]
            f[m, :, :rows.shape[1]] = rows
        return f * valid

    # ----------------------------------------------------------- serving
    def _wire(self, n: int, d: int) -> int:
        if self._comp is None:
            return n * d * 4
        return self._comp.wire_bytes(n, d)

    def _price(self, fresh: Dict[int, int]) -> Tuple[int, int, int]:
        """(upload, broadcast, index) bytes for one query's fresh rows —
        the same per-layer bill ``fed.simulation.log_query_traffic``
        replays into a MessageLog."""
        m = self.mcfg
        up = down = idx = 0
        for l in m.agg_layers:
            n = fresh.get(l, 0)
            if n == 0:
                continue
            up += self.M * self._wire(n, m.hidden)
            down += self.M * self._wire(n, self._down_h)
            idx += self.M * n * 4
        return up, down, idx

    def answer(self, nodes) -> ServeAnswer:
        """Answer a node-classification query for ``nodes`` (any order,
        duplicates fine). Requests beyond ``max_batch`` are split into
        sequential dispatches and recombined."""
        nodes = np.asarray(nodes, dtype=np.int32).ravel()
        if nodes.size == 0:
            raise ValueError("empty query")
        if nodes.min() < 0 or nodes.max() >= self.N:
            raise ValueError(
                f"query ids must be in [0, {self.N}), got range "
                f"[{nodes.min()}, {nodes.max()}]")
        mb = self.serve.max_batch
        chunks = [nodes[i:i + mb] for i in range(0, len(nodes), mb)]
        answers = []
        with self._lock:
            for c in chunks:
                ans = self._answer_locked(c)
                self.metrics.record(ans)
                answers.append(ans)
        if len(answers) == 1:
            return answers[0]
        return ServeAnswer(
            nodes=nodes,
            logits=np.concatenate([a.logits for a in answers]),
            per_client=np.concatenate([a.per_client for a in answers],
                                      axis=1),
            preds=np.concatenate([a.preds for a in answers]),
            fresh_rows={l: sum(a.fresh_rows.get(l, 0) for a in answers)
                        for l in self.mcfg.agg_layers},
            upload_bytes=sum(a.upload_bytes for a in answers),
            broadcast_bytes=sum(a.broadcast_bytes for a in answers),
            index_bytes=sum(a.index_bytes for a in answers),
            cache_hits=sum(a.cache_hits for a in answers),
            cache_misses=sum(a.cache_misses for a in answers),
            latency_s=sum(a.latency_s for a in answers),
            cold=any(a.cold for a in answers),
            params_version=self.params_version,
            log=answers[0].log)

    def _answer_locked(self, nodes: np.ndarray) -> ServeAnswer:
        t0 = time.perf_counter()
        m = self.mcfg
        uniq, inv = np.unique(nodes, return_inverse=True)
        b = len(uniq)
        bucket = self._bucket(b)
        top = self.L - 1 if self.mcfg.agg_layers else None

        if top is not None:
            top_hit, top_rows = self.cache.lookup(
                top, uniq, self.params_version, (self.M, self.h_agg))
        else:
            top_hit = np.zeros(b, dtype=np.float32)
            top_rows = np.zeros((b, self.M, self.h_agg), dtype=np.float32)

        if top is not None and bool(top_hit.all()):
            # warm fast path: no plan, no layer stack, zero wire bytes
            rows = np.zeros((bucket, self.M, self.h_agg), dtype=np.float32)
            rows[:b] = top_rows
            fresh = {l: 0 for l in m.agg_layers}
            cold = False
        else:
            plan = self._build_plan(uniq, bucket, top_hit, top_rows)
            h, aggs = self._fwd(self.params, plan.batch, plan.inject)
            # numpy roundtrip on purpose: the warm path assembles the same
            # f32 rows from cache, so both paths feed the classifier
            # bitwise-identical arrays
            rows = np.ascontiguousarray(
                np.asarray(h).transpose(1, 0, 2)).astype(
                    np.float32, copy=False)
            for l, (ids_l, comp) in plan.fills.items():
                if comp.any():
                    stack = np.asarray(aggs[l])        # (M, n, h_agg)
                    self.cache.insert(
                        l, ids_l[comp], self.params_version,
                        np.ascontiguousarray(
                            stack[:, comp, :].transpose(1, 0, 2)))
            fresh = plan.fresh
            cold = True

        real = np.zeros(bucket, dtype=np.float32)
        real[:b] = 1.0
        per, ens = self._cls(self.params,
                             jnp.asarray(np.ascontiguousarray(
                                 rows.transpose(1, 0, 2))),
                             jnp.asarray(real))
        per = np.asarray(per)[:, :b, :][:, inv, :]
        ens = np.asarray(ens)[:b][inv]
        up, down, idx = self._price(fresh)
        # hit/miss on the answer are the top-layer probe's outcome — the
        # decision that picks warm vs cold; inner-layer hits show up in
        # self.cache stats and in the smaller fresh_rows bill
        n_hit = int((top_hit > 0).sum())
        n_miss = b - n_hit
        log = None
        if self.serve.record_log:
            from ..fed.simulation import MessageLog, log_query_traffic
            log = MessageLog()
            log_query_traffic(log, fresh, m, compressor=self._comp)
        return ServeAnswer(
            nodes=np.array(nodes), logits=ens, per_client=per,
            preds=np.argmax(ens, axis=-1).astype(np.int32),
            fresh_rows=dict(fresh), upload_bytes=up, broadcast_bytes=down,
            index_bytes=idx, cache_hits=n_hit, cache_misses=n_miss,
            latency_s=time.perf_counter() - t0, cold=cold,
            params_version=self.params_version, log=log)

    # -------------------------------------------------------- management
    def update_params(self, params, version: Optional[int] = None):
        """Swap in new parameters (e.g. from a newer checkpoint) and bump
        ``params_version``; cache entries outside the staleness bound are
        evicted immediately."""
        with self._lock:
            self.params = params
            self.params_version = (int(version) if version is not None
                                   else self.params_version + 1)
            self.cache.drop_older_than(self.params_version)

    def precompute(self, chunk: int = 4096) -> np.ndarray:
        """Warm the cache for EVERY node from one exact chunked
        ``full_forward`` sweep; returns the (M, N, C) full-graph logits.
        The collected aggregate stacks carry exactly the N real nodes
        (pad rows are sliced off before aggregation), so chunk padding
        can never enter the cache."""
        if self._streamed:
            raise RuntimeError(
                "precompute() sweeps full_forward over all N nodes with "
                "resident features; a streamed-store session warms its "
                "cache through served queries instead")
        with self._lock:
            logits, aggs = glasu.full_forward(
                self.params, self.mcfg, self._feats_dev,
                self._nbr_idx_dev, self._nbr_mask_dev, chunk=chunk,
                collect_agg=True)
            for l, stack in aggs.items():
                self.cache.insert(
                    l, self._identity, self.params_version,
                    np.ascontiguousarray(
                        np.asarray(stack).transpose(1, 0, 2)))
            return np.asarray(logits)
