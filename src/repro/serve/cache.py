"""Hot-node aggregate cache for the serving path.

Caches POST-aggregation embedding stacks per (node, aggregation layer):
the (M, h_agg) block every client holds after the server broadcast. A hit
at layer l means that node's row needs no fresh cross-client exchange at
that layer — its upload + broadcast legs (and the index-sync entry for it)
drop out of the query's byte bill, and the plan builder prunes the node's
receptive field below l. This is the serving-path analogue of the paper's
§3.5 stale updates: a bounded-staleness reuse of cross-client state.

Keyed on (node, layer); the params_version the entry was computed at is
stored alongside and checked on lookup against the session's current
version under the configured ``max_staleness`` bound (0 = exact match).
Entries that fail the bound are evicted on sight. Eviction is LRU over an
``OrderedDict`` — lookups refresh recency, inserts evict from the cold end.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Tuple

import numpy as np


class HotNodeCache:
    def __init__(self, capacity: int, max_staleness: int = 0):
        self.capacity = int(capacity)
        self.max_staleness = int(max_staleness)
        self._store: "OrderedDict[Tuple[int, int], Tuple[int, np.ndarray]]" \
            = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._store)

    def lookup(self, layer: int, nodes: np.ndarray, version: int,
               row_shape: Tuple[int, int]):
        """Batched lookup at one layer.

        nodes: (n,) int array; entries < 0 are padding and are neither
        counted nor looked up. Returns ``(hit, rows)``: ``hit`` float32
        (n,) and ``rows`` float32 (n, M, h_agg) with zeros at misses —
        exactly the ``(keep, rows)`` injection mask `serve_forward` takes
        (after a transpose to (M, n, h_agg) by the caller).
        """
        n = len(nodes)
        hit = np.zeros(n, dtype=np.float32)
        rows = np.zeros((n,) + tuple(row_shape), dtype=np.float32)
        if self.capacity == 0:
            self.misses += int((np.asarray(nodes) >= 0).sum())
            return hit, rows
        for i, node in enumerate(np.asarray(nodes).tolist()):
            if node < 0:
                continue
            key = (int(node), int(layer))
            entry = self._store.get(key)
            if entry is None:
                self.misses += 1
                continue
            ver, row = entry
            if version - ver > self.max_staleness or ver > version:
                # too stale (or from a future version after a rollback):
                # unusable now and forever — drop it
                del self._store[key]
                self.evictions += 1
                self.misses += 1
                continue
            self._store.move_to_end(key)
            hit[i] = 1.0
            rows[i] = row
            self.hits += 1
        return hit, rows

    def insert(self, layer: int, nodes: np.ndarray, version: int,
               rows: np.ndarray):
        """Store freshly computed aggregates. rows: (n, M, h_agg) float32,
        aligned with ``nodes``; negative node ids (padding) are skipped."""
        if self.capacity == 0:
            return
        for i, node in enumerate(np.asarray(nodes).tolist()):
            if node < 0:
                continue
            key = (int(node), int(layer))
            self._store[key] = (int(version), np.array(rows[i],
                                                       dtype=np.float32))
            self._store.move_to_end(key)
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1

    def drop_older_than(self, version: int):
        """Evict everything below the staleness bound for ``version`` —
        called on ``update_params`` so a version bump frees memory
        immediately instead of lazily on lookup."""
        dead = [k for k, (ver, _) in self._store.items()
                if version - ver > self.max_staleness or ver > version]
        for k in dead:
            del self._store[k]
        self.evictions += len(dead)

    def clear(self):
        self.evictions += len(self._store)
        self._store.clear()
