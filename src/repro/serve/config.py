"""Validated serving configuration block.

Stdlib-only on purpose: ``api.config.ExperimentConfig`` embeds a
``ServeConfig`` (dict-coerced, like ``CompressionConfig``), so this module
must import neither jax nor any repro package — it sits below everything.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

ENGINES = ("vmapped", "sharded")


@dataclass(frozen=True)
class ServeConfig:
    """Knobs for the joint-inference serving path (``repro.serve``).

    cache_entries     hot-node aggregate cache capacity in (node, layer)
                      entries; 0 disables the cache entirely
    max_staleness     how many params_version bumps a cached aggregate may
                      survive and still be served (0 = exact-version only)
                      — the serving analogue of the paper's §3.5 stale-
                      update tolerance Q
    max_batch         hard cap on queries answered in one dispatch; larger
                      requests are split
    batch_deadline_ms micro-batcher coalescing window, measured from the
                      first queued request
    buckets           padded batch sizes the jitted dispatch is traced at;
                      None -> powers of two up to max_batch
    engine            'vmapped' (stacked clients + jit) or 'sharded'
                      (shard_map over the client mesh)
    record_log        keep a per-query ``fed.simulation.MessageLog`` replay
                      on every answer (audit/debug; costs host time)
    """

    cache_entries: int = 4096
    max_staleness: int = 0
    max_batch: int = 16
    batch_deadline_ms: float = 2.0
    buckets: Optional[Sequence[int]] = None
    engine: str = "vmapped"
    record_log: bool = False

    def __post_init__(self):
        def err(msg):
            raise ValueError(f"ServeConfig: {msg}")

        if self.engine not in ENGINES:
            err(f"engine must be one of {ENGINES}, got {self.engine!r}")
        if self.cache_entries < 0:
            err(f"cache_entries must be >= 0, got {self.cache_entries}")
        if self.max_staleness < 0:
            err(f"max_staleness must be >= 0, got {self.max_staleness}")
        if self.max_batch < 1:
            err(f"max_batch must be >= 1, got {self.max_batch}")
        if self.batch_deadline_ms < 0:
            err(f"batch_deadline_ms must be >= 0, got "
                f"{self.batch_deadline_ms}")
        if self.buckets is not None:
            bk = tuple(int(b) for b in self.buckets)
            if not bk or any(b < 1 for b in bk):
                err(f"buckets must be a non-empty list of sizes >= 1, "
                    f"got {self.buckets}")
            if sorted(bk) != list(bk):
                err(f"buckets must be sorted ascending, got {self.buckets}")
            if bk[-1] < self.max_batch:
                err(f"largest bucket ({bk[-1]}) must cover max_batch "
                    f"({self.max_batch})")
            object.__setattr__(self, "buckets", bk)

    def resolved_buckets(self) -> Tuple[int, ...]:
        """Padded batch sizes, smallest first. Default: powers of two up
        to (and including) ``max_batch`` — each bucket is one jit trace."""
        if self.buckets is not None:
            return tuple(self.buckets)
        out = []
        b = 1
        while b < self.max_batch:
            out.append(b)
            b *= 2
        out.append(self.max_batch)
        return tuple(out)
