"""Infrastructure tests: checkpointing, sharding-spec inference, HLO walker."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import checkpoint
from repro.launch import hlo_cost


# ------------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(4, 3)),
                             jnp.bfloat16),
            "b": jnp.arange(5, dtype=jnp.int32),
            "nested": {"s": jnp.asarray(3.5, jnp.float32)}}
    checkpoint.save(str(tmp_path), 7, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 7
    back = checkpoint.restore(str(tmp_path), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_restore_missing_file_raises_filenotfound(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    with pytest.raises(FileNotFoundError, match="no checkpoint"):
        checkpoint.restore(str(tmp_path), tree)
    checkpoint.save(str(tmp_path), 1, tree)
    # a step-aligned sidecar that was never written must fail loudly too,
    # listing what exists — not fall back to zeros or the main ckpt
    with pytest.raises(FileNotFoundError, match="comp"):
        checkpoint.restore(str(tmp_path), tree, name="comp")


@pytest.mark.parametrize("name", ["ckpt", "comp", "fault"])
def test_restore_truncated_npz_raises_loud(tmp_path, name):
    """A half-written file (killed mid-save) must raise RuntimeError naming
    the file — the main checkpoint and every sidecar kind (EF accumulators,
    the fault-tolerant stale-embedding cache) share the contract."""
    from pathlib import Path
    tree = {"x": jnp.arange(512, dtype=jnp.float32)}
    fn = Path(checkpoint.save(str(tmp_path), 3, tree, name=name))
    raw = fn.read_bytes()
    fn.write_bytes(raw[:len(raw) // 2])
    with pytest.raises(RuntimeError, match="corrupt checkpoint"):
        checkpoint.restore(str(tmp_path), tree, step=3, name=name)


def test_restore_garbled_npz_raises_loud(tmp_path):
    from pathlib import Path
    tree = {"x": jnp.zeros((4,))}
    fn = Path(checkpoint.save(str(tmp_path), 2, tree))
    fn.write_bytes(b"\x89not-a-zip" * 64)
    with pytest.raises(RuntimeError, match="corrupt checkpoint"):
        checkpoint.restore(str(tmp_path), tree)


def test_restore_leaf_count_mismatch_raises_runtime(tmp_path):
    checkpoint.save(str(tmp_path), 1, {"x": jnp.zeros((2,))})
    like = {"x": jnp.zeros((2,)), "y": jnp.zeros((3,))}
    with pytest.raises(RuntimeError, match="leaves"):
        checkpoint.restore(str(tmp_path), like)


def test_checkpoint_cleanup(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in range(5):
        checkpoint.save(str(tmp_path), s, tree)
    checkpoint.cleanup(str(tmp_path), keep=2)
    import glob
    assert len(glob.glob(str(tmp_path / "ckpt_*.npz"))) == 2
    assert checkpoint.latest_step(str(tmp_path)) == 4


# -------------------------------------------------------- sharding inference
def test_param_specs_rules():
    from jax.sharding import PartitionSpec as P
    from repro.launch import sharding as shd
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    # stand-in leaves (ShapeDtypeStruct is enough for the rule engine)
    sds = jax.ShapeDtypeStruct
    params = {
        "emb": sds((1024, 64), jnp.float32),
        "blocks": {"attn": {"wq": sds((8, 64, 128), jnp.float32),
                            "wo": sds((8, 128, 64), jnp.float32)},
                   "moe": {"w_gate": sds((8, 4, 64, 32), jnp.float32),
                           "router": sds((64, 4), jnp.float32)}},
        "final_norm": {"g": sds((64,), jnp.float32)},
    }
    specs = shd.param_specs(params, mesh)
    # mesh axes have size 1 -> guard strips everything to None; use a fake
    # 4-device mesh shape instead via the internal rule function
    raw = jax.tree_util.tree_map_with_path(
        lambda p, l: shd._leaf_spec(p, l, FakeMesh()), params)
    # small leaves: pure TP rules, no FSDP (below the 16 MB threshold)
    assert raw["emb"] == P("model", None)
    assert raw["blocks"]["attn"]["wq"] == P(None, None, "model")
    assert raw["blocks"]["attn"]["wo"] == P(None, "model", None)
    assert raw["blocks"]["moe"]["w_gate"][1] == "model"   # expert axis
    assert raw["blocks"]["moe"]["router"] == P(None, None)
    assert raw["final_norm"]["g"] == P(None)
    # large leaf: FSDP adds 'data' on the biggest free dim
    big = jax.ShapeDtypeStruct((32, 8192, 8192), jnp.float32)
    spec = shd._leaf_spec(
        (jax.tree_util.DictKey("blocks"), jax.tree_util.DictKey("wq")),
        big, FakeMesh())
    assert spec == P(None, "data", "model")


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 2, "model": 2}


def test_opt_state_specs_structural():
    from jax.sharding import PartitionSpec as P
    from repro.launch import sharding as shd
    from repro.optim import optimizers as opt_lib
    params = {"w": jnp.zeros((8, 4)), "g": jnp.zeros((4,))}
    pspecs = {"w": P("data", "model"), "g": P(None)}
    for make in (lambda: opt_lib.adamw(1e-3), lambda: opt_lib.adafactor(1e-3),
                 lambda: opt_lib.sgd(1e-3, momentum=0.9)):
        opt = make()
        state = jax.eval_shape(opt.init, params)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        specs = shd.opt_state_specs(state, pspecs, mesh)
        # structurally mappable onto the state (would raise otherwise)
        jax.tree.flatten(specs)


# ----------------------------------------------------------------- HLO walker
def test_hlo_walker_counts_scan_trips():
    def ten(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jnp.zeros((128, 128))
    r = hlo_cost.analyze(jax.jit(ten).lower(x).compile().as_text())
    assert r["flops"] == pytest.approx(10 * 2 * 128 ** 3, rel=0.01)


def test_hlo_walker_nested_and_collect_bytes():
    def nested(x):
        def outer(c, _):
            def inner(cc, _):
                return cc @ cc, None
            y, _ = jax.lax.scan(inner, c, None, length=5)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jnp.zeros((64, 64))
    r = hlo_cost.analyze(jax.jit(nested).lower(x).compile().as_text())
    assert r["flops"] == pytest.approx(15 * 2 * 64 ** 3, rel=0.01)
    assert r["hbm_bytes"] > 15 * 2 * 64 * 64 * 4  # at least the carrier traffic


# ------------------------------------------------------------ dryrun env hygiene
@pytest.mark.slow
def test_dryrun_appends_to_user_xla_flags():
    """Importing launch.dryrun must append its host-device-count flag to any
    user-set XLA_FLAGS (it used to clobber the variable), and must respect a
    user-chosen device count."""
    import os
    import subprocess
    import sys

    code = ("import os, repro.launch.dryrun; "
            "print(os.environ['XLA_FLAGS'])")
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_cpu_enable_fast_math=false")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=".",
                         capture_output=True, text=True, check=True).stdout
    assert "--xla_cpu_enable_fast_math=false" in out
    assert "--xla_force_host_platform_device_count=512" in out

    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=".",
                         capture_output=True, text=True, check=True).stdout
    assert out.strip() == "--xla_force_host_platform_device_count=4"
