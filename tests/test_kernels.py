"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
allclose against the pure-jnp oracles in kernels/ref.py (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.graph_agg import graph_agg_pallas


# ------------------------------------------------------------------ graph_agg
@pytest.mark.parametrize("n_src,n_dst,fanout,d,d_out", [
    (64, 32, 4, 16, 8),
    (300, 128, 4, 64, 32),
    (512, 200, 8, 128, 64),     # non-multiple of 128 dst
    (1000, 384, 3, 96, 48),
    (256, 77, 5, 96, 192),      # tiled d_out (> DOUT_BLOCK), ragged dst
    (256, 130, 5, 64, 320),     # tiled d_out, non-multiple-of-128 tiles
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_graph_agg_matches_ref(n_src, n_dst, fanout, d, d_out, dtype):
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(n_src, d)), dtype)
    idx = jnp.asarray(rng.integers(0, n_src, size=(n_dst, fanout)), jnp.int32)
    mask = jnp.asarray(rng.random((n_dst, fanout)) < 0.8, jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, d_out)), dtype)
    got = graph_agg_pallas(h, idx, mask, w, interpret=True)
    want = ref.graph_agg_ref(h, idx, mask, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------- fused backbone kernels
@pytest.mark.parametrize("n_src,n_dst,fanout1,d", [
    (64, 32, 5, 16),
    (300, 130, 4, 64),          # non-multiple-of-128 dst
    (256, 77, 5, 160),          # tiled d_out (d > DOUT_BLOCK)
])
def test_gcnii_kernel_matches_ref(n_src, n_dst, fanout1, d):
    from repro.kernels.graph_agg import gcnii_layer_pallas
    rng = np.random.default_rng(1)
    h = jnp.asarray(rng.normal(size=(n_src, d)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(n_src, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n_src, size=(n_dst, fanout1)), jnp.int32)
    mask = jnp.asarray(rng.random((n_dst, fanout1)) < 0.8, jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, d)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    got = gcnii_layer_pallas(h, h0, idx, mask, w, b, alpha=0.1, beta=0.5,
                             interpret=True)
    want = ref.gcnii_layer_ref(h, h0, idx, mask, w, b, 0.1, 0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n_src,n_dst,fanout1,d,heads,dh", [
    (64, 32, 5, 16, 2, 8),
    (300, 130, 4, 64, 2, 32),   # non-multiple-of-128 dst
    (256, 77, 5, 96, 4, 16),    # 4 heads
    (200, 129, 9, 48, 1, 64),   # single head, wide fanout
])
def test_gat_kernel_matches_ref(n_src, n_dst, fanout1, d, heads, dh):
    from repro.kernels.graph_agg import gat_layer_pallas
    rng = np.random.default_rng(2)
    h = jnp.asarray(rng.normal(size=(n_src, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n_src, size=(n_dst, fanout1)), jnp.int32)
    mask = np.asarray(rng.random((n_dst, fanout1)) < 0.8, np.float32)
    mask[:, 0] = 1.0                                   # self loop always on
    mask = jnp.asarray(mask)
    w = jnp.asarray(rng.normal(size=(d, heads, dh)) * 0.2, jnp.float32)
    a_src = jnp.asarray(rng.normal(size=(heads, dh)) * 0.1, jnp.float32)
    a_dst = jnp.asarray(rng.normal(size=(heads, dh)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.normal(size=(heads * dh,)), jnp.float32)
    got = gat_layer_pallas(h, idx, mask, w, a_src, a_dst, b, interpret=True)
    want = ref.gat_layer_ref(h, idx, mask, w, a_src, a_dst, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_fused_ops_gradients_match_ref():
    """Training differentiates through the fused layers — the custom_vjp
    (Pallas forward, ref backward) must match end-to-end ref gradients."""
    rng = np.random.default_rng(3)
    h = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 64, size=(40, 5)), jnp.int32)
    mask = jnp.asarray(rng.random((40, 5)) < 0.8, jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(16,)), jnp.float32)

    g1 = jax.grad(lambda h, w: jnp.sum(ops.graph_agg(h, idx, mask, w) ** 2)
                  )(h, w)
    g2 = jax.grad(lambda h, w: jnp.sum(ref.graph_agg_ref(h, idx, mask, w) ** 2)
                  )(h, w)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-5, atol=2e-5)

    g1 = jax.grad(lambda h, w: jnp.sum(ops.gcnii_layer(
        h, h0, idx, mask, w, b, alpha=0.1, beta=0.5) ** 2))(h, w)
    g2 = jax.grad(lambda h, w: jnp.sum(ref.gcnii_layer_ref(
        h, h0, idx, mask, w, b, 0.1, 0.5) ** 2))(h, w)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-5, atol=2e-5)

    wg = jnp.asarray(rng.normal(size=(16, 2, 8)) * 0.2, jnp.float32)
    a_src = jnp.asarray(rng.normal(size=(2, 8)) * 0.1, jnp.float32)
    a_dst = jnp.asarray(rng.normal(size=(2, 8)) * 0.1, jnp.float32)
    bg = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    g1 = jax.grad(lambda h, w: jnp.sum(ops.gat_layer(
        h, idx, mask, w, a_src, a_dst, bg) ** 2))(h, wg)
    g2 = jax.grad(lambda h, w: jnp.sum(ref.gat_layer_ref(
        h, idx, mask, w, a_src, a_dst, bg) ** 2))(h, wg)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=3e-5, atol=3e-5)


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(n_src=st.integers(8, 200), n_dst=st.integers(1, 150),
       fanout=st.integers(1, 6), d=st.sampled_from([8, 24, 64]),
       seed=st.integers(0, 2**31 - 1))
def test_graph_agg_property(n_src, n_dst, fanout, d, seed):
    """Property: all-masked rows give exactly zero; result is permutation-
    equivariant in destination rows."""
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(n_src, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n_src, size=(n_dst, fanout)), jnp.int32)
    mask = jnp.asarray(rng.random((n_dst, fanout)) < 0.7, jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, d)), jnp.float32)
    got = graph_agg_pallas(h, idx, mask, w, interpret=True)
    want = ref.graph_agg_ref(h, idx, mask, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    # all-masked row -> zero output
    mask0 = mask.at[0].set(0.0)
    got0 = graph_agg_pallas(h, idx, mask0, w, interpret=True)
    np.testing.assert_allclose(np.asarray(got0[0]), 0.0, atol=1e-6)
    # permutation equivariance
    perm = rng.permutation(n_dst)
    got_p = graph_agg_pallas(h, idx[perm], mask[perm], w, interpret=True)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(got)[perm],
                               rtol=3e-5, atol=3e-5)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("b,s,t,h,kv,dh", [
    (1, 128, 128, 4, 4, 32),      # MHA, single block
    (2, 256, 256, 8, 2, 64),      # GQA 4:1, multi-block
    (1, 200, 200, 4, 1, 64),      # MQA, ragged seq (padding path)
    (2, 96, 320, 4, 2, 32),       # cross-length (t > s)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(b, s, t, h, kv, dh, causal):
    if causal and s != t:
        pytest.skip("causal requires square for this ref")
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kv, dh)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [32, 128, 511])
def test_flash_sliding_window(window):
    rng = np.random.default_rng(2)
    b, s, h, dh = 1, 512, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    rng = np.random.default_rng(3)
    b, s, h, dh = 1, 128, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)), dtype)
    got = flash_attention_pallas(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
    assert got.dtype == dtype


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(s=st.integers(16, 257), h=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2]), dh=st.sampled_from([16, 32]),
       seed=st.integers(0, 2**31 - 1))
def test_flash_property(s, h, g, dh, seed):
    """Property: rows of the attention matrix sum to 1 -> constant-v gives
    constant output; causal first row attends only to itself."""
    if h % g:
        g = 1
    kv = h // g
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, s, kv, dh)), jnp.float32)
    v = jnp.ones((1, s, kv, dh), jnp.float32) * 3.25
    got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), 3.25, rtol=1e-5, atol=1e-5)


def test_ops_wrappers_jit():
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.float32)
    out = ops.flash_attention(q, q, q, causal=True)
    assert out.shape == q.shape
    h = jnp.asarray(rng.normal(size=(50, 16)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 50, size=(20, 4)), jnp.int32)
    mask = jnp.ones((20, 4), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    out = ops.graph_agg(h, idx, mask, w)
    assert out.shape == (20, 8)
