"""PRNG key-hygiene regression tests for the §3.6 privacy hooks.

Pre-fix, ``_aggregate`` drew the secure-agg masks with the *raw* caller key
(``jax.random.normal(key, ...)``) while the DP path derived its own subkey
via ``fold_in(key, 1)``. Any other consumer of that raw key — including the
caller splitting it again — would replay the exact mask stream, which is
precisely the key-reuse hazard glint's GL002 rule exists to catch. The fix
derives a dedicated mask subkey (``fold_in(key, 0)``); these tests pin both
the derivation and the algebraic properties the paper requires of the masks.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.glasu import GlasuConfig, _aggregate


def _cfg(**kw):
    return GlasuConfig(n_clients=4, n_layers=4, hidden=8, backbone="gcn",
                       agg="mean", agg_layers=(1, 3), **kw)


def _centered_normal(key, shape):
    masks = jax.random.normal(key, shape, jnp.float32)
    return masks - jnp.mean(masks, axis=0, keepdims=True)


def test_secure_agg_masks_use_derived_subkey_not_raw_key():
    """With zero uploads the stale buffers ARE the (scaled) masks:
    stale = -masks/M. Recover them and check the sampling key."""
    cfg = _cfg(secure_agg=True)
    m, n, h = cfg.n_clients, 6, cfg.hidden
    key = jax.random.PRNGKey(42)
    agg, stale = _aggregate(cfg, jnp.zeros((m, n, h), jnp.float32), key)

    # masks are zero-mean across clients, so the mean aggregate is exactly 0
    np.testing.assert_allclose(np.asarray(agg), 0.0, atol=1e-6)

    recovered = -np.asarray(stale) * m
    # regression: the raw caller key must NOT be the mask sampling key
    raw_draw = np.asarray(_centered_normal(key, (m, n, h)))
    assert not np.allclose(recovered, raw_draw, atol=1e-5), \
        "masks drawn with the raw caller key (GL002 key-reuse regression)"
    # the fix pins masks to the fold_in(key, 0) derived subkey
    derived_draw = np.asarray(_centered_normal(jax.random.fold_in(key, 0),
                                               (m, n, h)))
    np.testing.assert_allclose(recovered, derived_draw, atol=1e-5)


def test_secure_agg_masks_cancel_in_mean():
    """§3.6: pairwise-cancelling masks must leave the mean aggregate
    bit-for-bit unchanged up to float tolerance."""
    m, n, h = 4, 6, 8
    h_plus = jax.random.normal(jax.random.PRNGKey(0), (m, n, h), jnp.float32)
    agg_plain, _ = _aggregate(_cfg(), h_plus)
    agg_masked, _ = _aggregate(_cfg(secure_agg=True), h_plus,
                               jax.random.PRNGKey(7))
    np.testing.assert_allclose(np.asarray(agg_masked), np.asarray(agg_plain),
                               atol=1e-5)


def test_mask_and_dp_noise_streams_are_distinct():
    """Masks (fold_in 0) and DP noise (fold_in 1) must come from different
    streams — with both hooks on, the aggregate equals plain-mean + noise-mean
    where the noise matches an independent redraw from the DP subkey."""
    cfg = _cfg(secure_agg=True, dp_sigma=0.5)
    m, n, h = cfg.n_clients, 6, cfg.hidden
    key = jax.random.PRNGKey(3)
    agg, _ = _aggregate(cfg, jnp.zeros((m, n, h), jnp.float32), key)

    noise = cfg.dp_sigma * jax.random.normal(jax.random.fold_in(key, 1),
                                             (m, n, h), jnp.float32)
    np.testing.assert_allclose(np.asarray(agg[0]),
                               np.asarray(jnp.mean(noise, axis=0)),
                               atol=1e-5)
