"""Statistical + structural sampler tests for the vectorized fast path and
the two bias fixes (truncation order, bounded neighbor draw)."""
import numpy as np
import pytest

from repro.graph.graph import Graph, edges_to_csr
from repro.graph.sampler import GlasuSampler, SamplerConfig, _padded_tables
from repro.graph.synth import make_vfl_dataset


def _star_graph(n_leaves: int, extra_feat: int = 4) -> Graph:
    """Node 0 connected to nodes 1..n_leaves."""
    edges = np.stack([np.zeros(n_leaves, np.int32),
                      np.arange(1, n_leaves + 1)], axis=1)
    n = n_leaves + 1
    indptr, indices = edges_to_csr(n, edges)
    rng = np.random.default_rng(0)
    return Graph(n, indptr, indices,
                 rng.normal(size=(n, extra_feat)).astype(np.float32),
                 np.zeros(n, np.int32), np.arange(n), np.arange(n),
                 np.arange(n))


def _tiny_sampler(seed=0, **kw):
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = SamplerConfig(n_layers=4, agg_layers=(1, 3), batch_size=8,
                        fanout=3, size_cap=96, **kw)
    return GlasuSampler(data, cfg, seed=seed)


# ------------------------------------------------------------ bias fixes
def test_build_set_truncation_is_unbiased():
    """Pre-fix, _build_set kept the lowest candidate ids under truncation —
    high-id neighbors were dropped in every round. Post-fix every candidate
    must survive at a roughly uniform rate."""
    s = _tiny_sampler()
    n_cand = 200
    size = 110                           # 10 centers + room for 100 of 200
    centers = np.arange(10, dtype=np.int32)
    others = np.arange(10, 10 + n_cand, dtype=np.int32)
    counts = np.zeros(10 + n_cand)
    trials = 400
    for _ in range(trials):
        out = s._build_set([centers], [others.reshape(1, -1)], size)
        kept = out[out >= 0]
        counts[kept] += 1
    # centers never dropped
    assert np.all(counts[:10] == trials)
    keep_rate = counts[10:] / trials     # expected 100/200 = 0.5 each
    assert keep_rate.mean() == pytest.approx(0.5, abs=0.01)
    # the seed behavior pins the top half at 0.0 and the bottom at 1.0
    assert keep_rate.min() > 0.3
    assert keep_rate.max() < 0.7
    # high-id half survives as often as the low-id half (seed: 0 vs 1)
    lo, hi = keep_rate[:n_cand // 2].mean(), keep_rate[n_cand // 2:].mean()
    assert abs(lo - hi) < 0.05


def test_neighbor_draw_is_uniform():
    """The bounded per-row draw must hit every neighbor of a node at a
    uniform rate (and only actual neighbors)."""
    deg = 7                              # not a power of two
    g = _star_graph(deg)
    data = make_vfl_dataset("tiny", n_clients=1, seed=0)
    data.clients[0] = g
    data = type(data)(data.name, [g], g)
    cfg = SamplerConfig(n_layers=2, agg_layers=(1,), batch_size=4, fanout=3,
                        size_cap=32, table_cap=16)
    s = GlasuSampler(data, cfg, seed=1)
    centers = np.zeros(64, np.int32)     # node 0, deg 7
    counts = np.zeros(deg + 1)
    trials = 200
    for _ in range(trials):
        nb = s._sample_neighbors(0, centers)
        assert nb.min() >= 1 and nb.max() <= deg   # neighbors only
        counts += np.bincount(nb.ravel(), minlength=deg + 1)
    freq = counts[1:] / counts[1:].sum()           # expected 1/7 each
    assert np.all(np.abs(freq - 1 / deg) < 0.01)


def test_sampler_reproducible_under_seed():
    a, b = _tiny_sampler(seed=7), _tiny_sampler(seed=7)
    for _ in range(3):
        ba, bb = a.sample_round(), b.sample_round()
        for xa, xb in zip(ba.gather_idx, bb.gather_idx):
            np.testing.assert_array_equal(xa, xb)
        for xa, xb in zip(ba.gather_mask, bb.gather_mask):
            np.testing.assert_array_equal(xa, xb)
        np.testing.assert_array_equal(ba.labels, bb.labels)
        np.testing.assert_array_equal(ba.feats, bb.feats)


# ------------------------------------------------- vectorized table build
def test_padded_tables_keeps_all_neighbors_under_cap():
    data = make_vfl_dataset("tiny", n_clients=2, seed=1)
    g = data.clients[0]
    cap = int(np.diff(g.indptr).max()) + 1      # nothing truncated
    table, deg = _padded_tables(g, cap, np.random.default_rng(0))
    for i in range(g.n_nodes):
        want = set(map(int, g.neighbors(i)))
        got = set(map(int, table[i, :deg[i]]))
        assert got == want
        assert np.all(table[i, deg[i]:] == -1)


def test_padded_tables_hub_subsample_uniform_without_replacement():
    deg, cap = 100, 10
    g = _star_graph(deg)
    counts = np.zeros(deg + 1)
    trials = 300
    for t in range(trials):
        table, d = _padded_tables(g, cap, np.random.default_rng(t))
        row = table[0, :cap]
        assert d[0] == cap
        assert len(set(row.tolist())) == cap     # without replacement
        assert row.min() >= 1
        counts += np.bincount(row, minlength=deg + 1)
    rate = counts[1:] / trials                   # expected cap/deg = 0.1
    assert rate.mean() == pytest.approx(cap / deg, abs=0.01)
    assert rate.min() > 0.02 and rate.max() < 0.25


def test_padded_neighbor_table_vectorized_structure():
    data = make_vfl_dataset("tiny", n_clients=2, seed=2)
    g = data.full
    idx, mask = g.padded_neighbor_table(8, np.random.default_rng(0))
    deg = np.minimum(np.diff(g.indptr), 8)
    np.testing.assert_array_equal(mask.sum(axis=1), deg + 1)  # self + nbrs
    np.testing.assert_array_equal(idx[:, 0], np.arange(g.n_nodes))
    for i in range(0, g.n_nodes, 37):
        nbrs = set(map(int, g.neighbors(i)))
        got = idx[i, 1:][mask[i, 1:] > 0]
        assert set(map(int, got)) <= nbrs


# ------------------------------------------------------- scratch + lookup
def test_sample_round_reuses_scratch_buffers():
    s = _tiny_sampler()
    b1 = s.sample_round()
    b2 = s.sample_round()
    for a, b in zip(b1.gather_idx, b2.gather_idx):
        assert a is b                    # same buffer, overwritten in place
    assert b1.feats is b2.feats


def test_positions_matches_searchsorted_reference():
    s = _tiny_sampler()
    rng = np.random.default_rng(3)
    node_set = np.full(64, -1, np.int32)
    ids = rng.choice(s.data.n_nodes, size=40, replace=False).astype(np.int32)
    node_set[:40] = ids
    query = rng.integers(0, s.data.n_nodes, size=(17, 5)).astype(np.int32)
    query[0, 0] = -1
    got = s._positions(node_set, query)

    order = np.argsort(node_set, kind="stable")
    ss = node_set[order]
    q = query.ravel()
    loc = np.clip(np.searchsorted(ss, q), 0, len(ss) - 1)
    hit = (ss[loc] == q) & (q >= 0)
    want = np.where(hit, order[loc], -1).reshape(query.shape)
    np.testing.assert_array_equal(got, want)
    # lookup table left clean for the next call
    assert np.all(s._pos_lut == -1)
    assert np.all(s._mark == 0)
