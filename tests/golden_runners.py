"""Golden-parity harness for the unified round engine.

Each combo below drives ONE engine/policy pair (vmapped | sharded |
simulation  x  plain | compressed | fault-tolerant) on a tiny fixed
problem and returns a flat dict of numpy arrays (final params leaves,
per-round losses, and any carried sidecar state). The fixtures under
``tests/golden/`` were generated from the three legacy hand-synced
engines immediately BEFORE they were unified into the single
policy-parameterized round body; ``tests/test_golden_parity.py`` replays
every combo against the stored arrays so the unified body provably
reproduces each legacy engine (bitwise for the vmapped and simulation
paths, float32-ULP for the sharded lowering).

Regenerate (only when the numerical contract is INTENTIONALLY changed)::

    PYTHONPATH=src python tests/golden_runners.py --write
"""
import os

import numpy as np

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

SEED = 0
ROUNDS = 3


def _base(**kw):
    from repro.core.glasu import GlasuConfig
    from repro.graph.sampler import GlasuSampler, SamplerConfig
    from repro.graph.synth import make_vfl_dataset

    data = make_vfl_dataset("tiny", n_clients=3, seed=SEED)
    d_in = max(c.feat_dim for c in data.clients)
    mcfg = GlasuConfig(n_clients=3, n_layers=4, hidden=16, backbone="gcn",
                       n_classes=data.n_classes, d_in=d_in,
                       agg_layers=(1, 3), n_local_steps=2, **kw)
    scfg = SamplerConfig(n_layers=4, agg_layers=(1, 3), batch_size=8,
                         fanout=3, size_cap=96)
    sampler = GlasuSampler(data, scfg, seed=SEED)
    return mcfg, sampler


def _rounds_and_keys(sampler, n=ROUNDS, as_numpy=True):
    import jax
    import jax.numpy as jnp

    # snapshot with np.array FIRST: the sampler reuses its internal numpy
    # buffers across draws and jnp.asarray is zero-copy on CPU, so a
    # device view of the live buffers would alias the NEXT round's draw
    rounds = [jax.tree.map(np.array, sampler.sample_round())
              for _ in range(n)]
    if not as_numpy:
        rounds = [jax.tree.map(jnp.asarray, r) for r in rounds]
    keys = [jax.random.fold_in(jax.random.PRNGKey(7), t) for t in range(n)]
    return rounds, keys


def _init(mcfg, lr=0.05, opt="sgd"):
    import jax

    from repro.core import glasu
    from repro.optim import optimizers as opt_lib

    optimizer = opt_lib.make_optimizer(opt, lr)
    params = glasu.init_params(jax.random.PRNGKey(SEED), mcfg)
    return optimizer, params, optimizer.init(params)


def _flat(prefix, tree):
    import jax

    leaves = jax.tree.leaves(tree)
    return {f"{prefix}_{i:03d}": np.asarray(x)
            for i, x in enumerate(leaves)}


def _plans(mcfg, n=ROUNDS):
    """A fixed, reproducible fault draw with drops, deadline kills and
    catch-up pressure (every plan shape the engine branches on)."""
    from repro.fed import faults as faults_lib

    fcfg = faults_lib.FaultConfig(seed=11, participation=0.8, drop_prob=0.25,
                                  deadline_ms=40.0, base_latency_ms=10.0,
                                  straggler_prob=0.3, straggler_scale=6.0,
                                  max_staleness=2)
    sched = faults_lib.make_schedule(fcfg, mcfg.n_clients)
    return [sched.next_round() for _ in range(n)]


def _masks(plans):
    import jax.numpy as jnp

    from repro.core import glasu
    from repro.fed import faults as faults_lib

    present, weight = faults_lib.stack_plans(plans)
    return glasu.RoundFaults(jnp.asarray(present), jnp.asarray(weight))


def _round_masks(plan):
    import jax.numpy as jnp

    from repro.core import glasu

    return glasu.RoundFaults(jnp.asarray(plan.present, jnp.float32),
                             jnp.asarray(plan.weight, jnp.float32))


# --------------------------------------------------------------- combos

def vmapped_plain_multi():
    from repro.core import glasu
    from repro.graph.prefetch import stack_rounds

    mcfg, sampler = _base()
    optimizer, params, opt_state = _init(mcfg)
    rounds, keys = _rounds_and_keys(sampler)
    step = glasu.make_multi_round_fn(mcfg, optimizer, rounds_per_step=ROUNDS)
    import jax.numpy as jnp
    params, opt_state, losses = step(params, opt_state, stack_rounds(rounds),
                                     jnp.stack(keys))
    return {**_flat("params", params), "losses": np.asarray(losses)}


def vmapped_privacy_round():
    from repro.core import glasu

    mcfg, sampler = _base(secure_agg=True, dp_sigma=0.01)
    optimizer, params, opt_state = _init(mcfg)
    rounds, keys = _rounds_and_keys(sampler)
    rf = glasu.make_round_fn(mcfg, optimizer)
    losses = []
    for t in range(ROUNDS):
        params, opt_state, l = rf(params, opt_state, rounds[t], keys[t])
        losses.append(np.asarray(l))
    return {**_flat("params", params), "losses": np.stack(losses)}


def vmapped_concat_labels_round():
    from repro.core import glasu

    mcfg, sampler = _base(agg="concat", labels_at_client=1)
    optimizer, params, opt_state = _init(mcfg)
    rounds, keys = _rounds_and_keys(sampler)
    rf = glasu.make_round_fn(mcfg, optimizer)
    losses = []
    for t in range(ROUNDS):
        params, opt_state, l = rf(params, opt_state, rounds[t], keys[t])
        losses.append(np.asarray(l))
    return {**_flat("params", params), "losses": np.stack(losses)}


def vmapped_int8_ef_round():
    from repro.comm import compression
    from repro.core import glasu

    mcfg, sampler = _base(compression=compression.CompressionConfig(
        method="int8", error_feedback=True))
    optimizer, params, opt_state = _init(mcfg)
    comp = glasu.init_comp_state(mcfg, sampler.layer_sizes,
                                 compression.make_compressor(mcfg.compression))
    rounds, keys = _rounds_and_keys(sampler)
    rf = glasu.make_round_fn(mcfg, optimizer)
    losses = []
    for t in range(ROUNDS):
        params, opt_state, comp, l = rf(params, opt_state, comp,
                                        rounds[t], keys[t])
        losses.append(np.asarray(l))
    return {**_flat("params", params), **_flat("comp", comp),
            "losses": np.stack(losses)}


def vmapped_topk_multi():
    import jax.numpy as jnp

    from repro.comm import compression
    from repro.core import glasu
    from repro.graph.prefetch import stack_rounds

    mcfg, sampler = _base(compression=compression.CompressionConfig(
        method="topk_ef", k=4))
    optimizer, params, opt_state = _init(mcfg)
    comp = glasu.init_comp_state(mcfg, sampler.layer_sizes,
                                 compression.make_compressor(mcfg.compression))
    rounds, keys = _rounds_and_keys(sampler)
    step = glasu.make_multi_round_fn(mcfg, optimizer, rounds_per_step=ROUNDS)
    params, opt_state, comp, losses = step(params, opt_state, comp,
                                           stack_rounds(rounds),
                                           jnp.stack(keys))
    return {**_flat("params", params), **_flat("comp", comp),
            "losses": np.asarray(losses)}


def vmapped_fault_multi():
    import jax.numpy as jnp

    from repro.core import glasu
    from repro.graph.prefetch import stack_rounds

    mcfg, sampler = _base(fault_tolerant=True)
    optimizer, params, opt_state = _init(mcfg)
    cache = glasu.init_fault_state(mcfg, sampler.layer_sizes)
    rounds, keys = _rounds_and_keys(sampler)
    step = glasu.make_multi_round_fn(mcfg, optimizer, rounds_per_step=ROUNDS)
    params, opt_state, cache, losses = step(params, opt_state, cache,
                                            stack_rounds(rounds),
                                            jnp.stack(keys),
                                            _masks(_plans(mcfg)))
    return {**_flat("params", params), **_flat("cache", cache),
            "losses": np.asarray(losses)}


def sim_plain():
    from repro.fed import simulation

    mcfg, sampler = _base()
    optimizer, params, opt_state = _init(mcfg)
    rounds, _ = _rounds_and_keys(sampler, n=2, as_numpy=False)
    losses = []
    for t in range(2):
        params, opt_state, l, _, _ = simulation.simulate_round(
            params, opt_state, rounds[t], mcfg, optimizer, None, None)
        losses.append(np.asarray(l))
    return {**_flat("params", params), "losses": np.stack(losses)}


def sim_fault():
    from repro.core import glasu
    from repro.fed import simulation

    mcfg, sampler = _base(fault_tolerant=True)
    optimizer, params, opt_state = _init(mcfg)
    cache = glasu.init_fault_state(mcfg, sampler.layer_sizes)
    plans = _plans(mcfg, n=2)
    rounds, _ = _rounds_and_keys(sampler, n=2, as_numpy=False)
    losses = []
    for t in range(2):
        params, opt_state, l, _, cache = simulation.simulate_fault_round(
            params, opt_state, rounds[t], mcfg, optimizer, cache, plans[t])
        losses.append(np.asarray(l))
    return {**_flat("params", params), **_flat("cache", cache),
            "losses": np.stack(losses)}


def _sharded(mcfg):
    from repro.launch.mesh import make_client_mesh

    return make_client_mesh(mcfg.n_clients)


def sharded_plain_multi():
    import jax.numpy as jnp

    from repro.core import glasu
    from repro.graph.prefetch import stack_rounds

    mcfg, sampler = _base()
    optimizer, params, opt_state = _init(mcfg)
    rounds, keys = _rounds_and_keys(sampler)
    step = glasu.make_sharded_multi_round_fn(mcfg, optimizer, _sharded(mcfg),
                                             rounds_per_step=ROUNDS)
    params, opt_state, losses = step(params, opt_state, stack_rounds(rounds),
                                     jnp.stack(keys))
    return {**_flat("params", params), "losses": np.asarray(losses)}


def sharded_fault_round():
    from repro.core import glasu

    mcfg, sampler = _base(fault_tolerant=True)
    optimizer, params, opt_state = _init(mcfg)
    cache = glasu.init_fault_state(mcfg, sampler.layer_sizes)
    plans = _plans(mcfg)
    rounds, keys = _rounds_and_keys(sampler)
    rf = glasu.make_sharded_round_fn(mcfg, optimizer, _sharded(mcfg))
    losses = []
    for t in range(ROUNDS):
        params, opt_state, cache, l = rf(params, opt_state, cache, rounds[t],
                                         keys[t], _round_masks(plans[t]))
        losses.append(np.asarray(l))
    return {**_flat("params", params), **_flat("cache", cache),
            "losses": np.stack(losses)}


def sharded_int8_ef_round():
    from repro.comm import compression
    from repro.core import glasu

    mcfg, sampler = _base(compression=compression.CompressionConfig(
        method="int8", error_feedback=True))
    optimizer, params, opt_state = _init(mcfg)
    comp = glasu.init_comp_state(mcfg, sampler.layer_sizes,
                                 compression.make_compressor(mcfg.compression))
    rounds, keys = _rounds_and_keys(sampler)
    rf = glasu.make_sharded_round_fn(mcfg, optimizer, _sharded(mcfg))
    losses = []
    for t in range(ROUNDS):
        params, opt_state, comp, l = rf(params, opt_state, comp, rounds[t],
                                        keys[t])
        losses.append(np.asarray(l))
    return {**_flat("params", params), **_flat("comp", comp),
            "losses": np.stack(losses)}


# bitwise: same engine lowering replayed on the same host
EXACT = ("vmapped_plain_multi", "vmapped_privacy_round",
         "vmapped_concat_labels_round", "vmapped_int8_ef_round",
         "vmapped_topk_multi", "vmapped_fault_multi", "sim_plain",
         "sim_fault")
# float32-ULP: the sharded shard_map lowering fuses differently per build
CLOSE = ("sharded_plain_multi", "sharded_fault_round",
         "sharded_int8_ef_round")

COMBOS = {name: globals()[name] for name in EXACT + CLOSE}


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true")
    args = ap.parse_args()
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for name, fn in COMBOS.items():
        out = fn()
        path = os.path.join(GOLDEN_DIR, f"{name}.npz")
        if args.write:
            np.savez_compressed(path, **out)
            print(f"wrote {path} ({len(out)} arrays)")
        else:
            print(f"{name}: {len(out)} arrays (dry run)")


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
