"""ServeMetrics/ServeAnswer accounting + MicroBatcher error fan-out, driven
under glint's layer-3 runtime guards (``retrace_guard`` / ``transfer_guard``
from ``tools/glint/pytest_plugin.py``).

The batcher tests use stub sessions — the fan-out contract (every waiter of
a failed dispatch gets the exception; the worker survives and serves the
next window) is independent of the model. The session-level test drives a
real ``InferenceSession`` and checks the running counters equal the sum of
the per-answer records while the jit caches stay frozen.
"""
import json

import numpy as np
import pytest

from repro.api import ExperimentConfig, Trainer
from repro.serve import InferenceSession, MicroBatcher, ServeConfig
from repro.serve.metrics import ServeAnswer, ServeMetrics

ROUNDS = 2


@pytest.fixture(scope="module")
def session(tmp_path_factory):
    d = tmp_path_factory.mktemp("serve-metrics-ckpt")
    cfg = ExperimentConfig(
        name="serve-metrics-test", dataset="tiny", backbone="gcnii",
        hidden=16, batch_size=8, size_cap=96, rounds=ROUNDS, lr=0.05,
        optimizer="sgd", eval_every=ROUNDS, ckpt_dir=str(d),
        ckpt_every=ROUNDS)
    Trainer(cfg).run()
    return InferenceSession.from_checkpoint(
        d, serve=ServeConfig(max_batch=8))


def _answer(n, *, cold, latency, fresh=None, **bytes_kw):
    b = dict(upload_bytes=100, broadcast_bytes=40, index_bytes=8)
    b.update(bytes_kw)
    return ServeAnswer(
        nodes=np.arange(n, dtype=np.int32),
        logits=np.zeros((n, 3), np.float32),
        per_client=np.zeros((2, n, 3), np.float32),
        preds=np.zeros(n, np.int32), fresh_rows=fresh or {},
        cache_hits=1, cache_misses=2, latency_s=latency, cold=cold,
        params_version=7, **b)


# ------------------------------------------------------------- ServeAnswer
def test_serve_answer_wire_bytes_sums_all_legs():
    ans = _answer(4, cold=True, latency=0.01,
                  upload_bytes=10, broadcast_bytes=20, index_bytes=3)
    assert ans.wire_bytes == 33


# ------------------------------------------------------------ ServeMetrics
def test_metrics_record_accumulates_and_merges_fresh_rows():
    m = ServeMetrics()
    m.record(_answer(4, cold=True, latency=0.2, fresh={1: 10, 3: 4}))
    m.record(_answer(2, cold=False, latency=0.1, fresh={3: 6}))
    assert m.queries == 6 and m.answers == 2
    assert m.upload_bytes == 200 and m.broadcast_bytes == 80
    assert m.index_bytes == 16 and m.wire_bytes == 296
    assert m.cache_hits == 2 and m.cache_misses == 4
    assert m.warm_answers == 1                       # only the cold=False one
    assert m.fresh_rows == {1: 10, 3: 10}
    assert m.latencies_s == [0.2, 0.1]


def test_metrics_empty_percentiles_are_zero():
    m = ServeMetrics()
    assert m.latency_percentiles() == {"p50": 0.0, "p99": 0.0}
    assert m.summary()["latency_p99_s"] == 0.0


def test_metrics_percentiles_and_summary_roundtrip():
    m = ServeMetrics()
    for i, lat in enumerate([0.010, 0.020, 0.030, 0.500]):
        m.record(_answer(1, cold=bool(i % 2), latency=lat))
    pct = m.latency_percentiles()
    assert pct["p50"] <= pct["p99"]
    assert pct["p50"] == pytest.approx(0.025)
    s = m.summary()
    # the summary is what benchmarks/CI serialize — it must be pure JSON
    assert json.loads(json.dumps(s)) == s
    assert s["queries"] == 4 and s["wire_bytes"] == m.wire_bytes
    assert s["fresh_rows"] == {}


# -------------------------------------------- session counters under guard
def test_session_metrics_match_sum_of_answers(session, retrace_guard,
                                              transfer_guard):
    s = session
    warm = s.answer([0, 1])                          # compile + cold plan
    base = dict(s.metrics.summary())
    retrace_guard.watch(s._cls, "session._cls")
    answers = []
    with transfer_guard():
        for i in range(3):
            answers.append(s.answer([2 * i, 2 * i + 1]))
    got = s.metrics.summary()
    assert got["answers"] == base["answers"] + 3
    assert got["queries"] == base["queries"] + 6
    want_wire = base["wire_bytes"] + sum(a.wire_bytes for a in answers)
    assert got["wire_bytes"] == want_wire
    assert warm.wire_bytes > 0


# ------------------------------------------------------ batcher error paths
class _BoomSession:
    calls = 0

    def answer(self, nodes):
        raise RuntimeError("kaboom")


class _FlakySession:
    """First dispatch explodes, later ones succeed."""

    def __init__(self):
        self.calls = 0

    def answer(self, nodes):
        self.calls += 1
        if self.calls == 1:
            raise RuntimeError("first-call kaboom")
        return _answer(len(nodes), cold=True, latency=0.01)


def test_batcher_fans_error_out_to_every_waiter():
    with MicroBatcher(_BoomSession(), max_batch=64,
                      deadline_ms=100.0) as mb:
        futs = [mb.submit([i]) for i in range(4)]
        errs = []
        for f in futs:
            with pytest.raises(RuntimeError, match="kaboom"):
                f.result(timeout=30)
            errs.append(f.exception())
        # one failed dispatch -> the SAME exception instance everywhere
        assert all(e is errs[0] for e in errs)


def test_batcher_worker_survives_failed_dispatch():
    s = _FlakySession()
    with MicroBatcher(s, max_batch=64, deadline_ms=20.0) as mb:
        with pytest.raises(RuntimeError, match="first-call"):
            mb.submit([0, 1]).result(timeout=30)
        ok = mb.submit([5, 6, 7]).result(timeout=30)
        assert isinstance(ok, ServeAnswer)
        np.testing.assert_array_equal(ok.nodes, [5, 6, 7])
        assert ok.logits.shape == (3, 3)
        assert s.calls == 2 and mb.batches == 2


def test_batcher_rejects_submit_after_close():
    mb = MicroBatcher(_BoomSession(), max_batch=4, deadline_ms=1.0)
    mb.close()
    with pytest.raises(RuntimeError, match="closed"):
        mb.submit([1])
    mb.close()                                       # idempotent
