"""Per-architecture smoke tests (mandate f).

Each assigned architecture instantiates a REDUCED variant of the same family
(2 layers, d_model <= 512, <= 4 experts) and runs one forward + one train
step + one decode step on CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, InputShape, get_reduced
from repro.core.steps import make_serve_step, make_train_step
from repro.data.pipeline import input_specs, synth_train_batch

SMOKE_SHAPE = InputShape("smoke_train", seq_len=64, global_batch=2, mode="train")
DECODE_SHAPE = InputShape("smoke_decode", seq_len=96, global_batch=2, mode="decode")


def _tree_no_nan(tree):
    for leaf in jax.tree.leaves(tree):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert not bool(jnp.any(jnp.isnan(leaf))), "NaN in tree"


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_train_step(arch_id):
    cfg = get_reduced(arch_id)
    assert cfg.n_layers <= 2 or cfg.enc_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    init_state, train_step = make_train_step(cfg)
    state = init_state(jax.random.PRNGKey(0))
    batch = synth_train_batch(cfg, SMOKE_SHAPE, seed=1)
    step = jax.jit(train_step)
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0.0
    _tree_no_nan(state2.params)
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(state2.params)))
    assert delta > 0.0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_step(arch_id):
    cfg = get_reduced(arch_id)
    init_serve, serve_step = make_serve_step(cfg, DECODE_SHAPE)
    params, caches = init_serve(jax.random.PRNGKey(0))
    token = jnp.zeros((DECODE_SHAPE.global_batch, 1), jnp.int32)
    kwargs = {}
    if cfg.is_encdec:
        kwargs["enc_out"] = jnp.asarray(
            np.random.default_rng(0).normal(size=(DECODE_SHAPE.global_batch, 8,
                                                  cfg.d_model)),
            jnp.dtype(cfg.dtype))
    step = jax.jit(serve_step)
    nxt, new_caches = step(params, caches, token, **kwargs)
    assert nxt.shape == (DECODE_SHAPE.global_batch, 1)
    assert nxt.dtype == jnp.int32
    assert 0 <= int(nxt[0, 0]) < cfg.vocab
    _tree_no_nan(new_caches)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_input_specs_cover_model_inputs(arch_id):
    cfg = get_reduced(arch_id)
    specs = input_specs(cfg, SMOKE_SHAPE)
    assert "tokens" in specs and "labels" in specs
    for s in specs.values():
        assert isinstance(s, jax.ShapeDtypeStruct)


def test_decode_loss_decreases_with_training_smollm():
    """Tiny end-to-end sanity: a few train steps reduce CE on a fixed batch."""
    cfg = get_reduced("smollm_360m")
    init_state, train_step = make_train_step(cfg)
    state = init_state(jax.random.PRNGKey(0))
    batch = synth_train_batch(cfg, SMOKE_SHAPE, seed=3)
    step = jax.jit(train_step)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
