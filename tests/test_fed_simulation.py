"""The explicit message-passing simulation must agree with the vmapped
runtime AND with the byte meter — three implementations of the same algebra."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import glasu
from repro.core.glasu import GlasuConfig
from repro.fed.simulation import MessageLog, simulate_joint_inference
from repro.graph.sampler import GlasuSampler, SamplerConfig
from repro.graph.synth import make_vfl_dataset


def _setup(m=3, agg_layers=(1, 3)):
    data = make_vfl_dataset("tiny", n_clients=m, seed=0)
    d_in = max(c.feat_dim for c in data.clients)
    mcfg = GlasuConfig(n_clients=m, n_layers=4, hidden=16,
                       n_classes=data.n_classes, d_in=d_in, backbone="gcnii",
                       agg_layers=agg_layers)
    scfg = SamplerConfig(n_layers=4, agg_layers=agg_layers, batch_size=8,
                         fanout=3, size_cap=96)
    sampler = GlasuSampler(data, scfg, seed=0)
    params = glasu.init_params(jax.random.PRNGKey(0), mcfg)
    batch = jax.tree.map(jnp.asarray, sampler.sample_round())
    return mcfg, sampler, params, batch


@pytest.mark.slow
def test_simulation_matches_vmapped_runtime():
    cfg, _, params, batch = _setup()
    want, _ = glasu.joint_inference(params, batch, cfg)
    got, _ = simulate_joint_inference(params, batch, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_simulation_bytes_match_meter():
    cfg, sampler, params, batch = _setup()
    _, log = simulate_joint_inference(params, batch, cfg)
    measured = log.total_bytes("upload") + log.total_bytes("broadcast")
    meter = sampler.comm_bytes_per_joint_inference(cfg.hidden, cfg.agg)
    # meter additionally charges index-union sync; payload bytes must match
    index_sync = sum(
        2 * cfg.n_clients * sampler.layer_sizes[j] * 4
        for j in range(cfg.n_layers + 1) if sampler._shared(j))
    assert meter - index_sync == measured


def test_simulation_message_pattern():
    cfg, _, params, batch = _setup(agg_layers=(3,))
    _, log = simulate_joint_inference(params, batch, cfg)
    # K=1: exactly M uploads + M broadcasts, all at the final layer
    assert len(log.messages) == 2 * cfg.n_clients
    assert all(m.layer == 3 for m in log.messages)
    # the fault-free path logs nothing dropped and carries zero timestamps
    assert log.dropped_messages() == []
    assert all(m.t == 0.0 for m in log.messages)


def test_meter_excludes_dropped_messages_by_default():
    """``total_bytes`` defaults to delivered-only: a lost or past-deadline
    upload never reaches the server and must not count toward the audited
    communication cost — ``delivered_only=False`` prices the sent traffic."""
    log = MessageLog()
    log.send_nbytes("client0", "server", "upload", 0, 100, t=3.0,
                    dropped=True)
    log.send_nbytes("client1", "server", "upload", 0, 100, t=5.0)
    log.send_nbytes("server", "client0", "broadcast", 0, 40, t=9.0)
    assert log.total_bytes() == 140
    assert log.total_bytes("upload") == 100
    assert log.total_bytes(delivered_only=False) == 240
    assert log.total_bytes("upload", delivered_only=False) == 200
    dropped = log.dropped_messages()
    assert len(dropped) == 1 and dropped[0].sender == "client0"
    assert dropped[0].t == 3.0
