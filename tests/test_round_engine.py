"""Device-resident round engine: scan parity, compile stability, prefetch.

The engine's contract is that ``rounds_per_step=K`` is *observationally
identical* to K sequential rounds — same final params (bit-exact), same
loss history, same comm-byte accounting — while compiling one round body
and dispatching once per K rounds.
"""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentConfig, Trainer
from repro.api.trainer import step_schedule
from repro.core import glasu
from repro.core.glasu import GlasuConfig
from repro.graph.prefetch import PrefetchSampler, stack_rounds, unstack_round
from repro.graph.sampler import GlasuSampler, SamplerConfig
from repro.graph.synth import make_vfl_dataset
from repro.optim import optimizers as opt_lib

TINY = dict(name="engine", dataset="tiny", hidden=16, batch_size=8,
            size_cap=96, lr=0.02)


def _setup(seed=0):
    data = make_vfl_dataset("tiny", n_clients=3, seed=seed)
    d_in = max(c.feat_dim for c in data.clients)
    mcfg = GlasuConfig(n_clients=3, n_layers=4, hidden=16,
                       n_classes=data.n_classes, d_in=d_in,
                       agg_layers=(1, 3))
    scfg = SamplerConfig(n_layers=4, agg_layers=(1, 3), batch_size=8,
                         fanout=3, size_cap=96)
    sampler = GlasuSampler(data, scfg, seed=seed)
    params = glasu.init_params(jax.random.PRNGKey(seed), mcfg)
    return data, mcfg, sampler, params


def _copy(tree):
    return jax.tree.map(jnp.array, tree)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_trees_close(a, b, rtol=1e-5, atol=1e-6):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ----------------------------------------------------------- core scan fn
def test_multi_round_fn_matches_sequential_rounds():
    """One scanned K-round dispatch == K make_round_fn calls.

    Across the scan/non-scan compilation boundary XLA fuses differently, so
    this is ULP-close rather than bit-equal; the engine's bit-exact
    contract (rounds_per_step=K vs K steps of the engine at K=1) is covered
    by the Trainer parity tests below."""
    _, mcfg, sampler, params = _setup()
    opt = opt_lib.make_optimizer("adam", 0.02)
    rounds = [jax.tree.map(np.array, sampler.sample_round())
              for _ in range(3)]
    key = jax.random.PRNGKey(7)
    keys = jnp.stack([jax.random.fold_in(key, t) for t in range(3)])

    p_seq, s_seq = _copy(params), opt.init(params)
    round_fn = glasu.make_round_fn(mcfg, opt)
    seq_losses = []
    for t in range(3):
        p_seq, s_seq, losses = round_fn(p_seq, s_seq, rounds[t], keys[t])
        seq_losses.append(losses)

    step_fn = glasu.make_multi_round_fn(mcfg, opt)
    p_k, s_k, losses_k = step_fn(_copy(params), opt.init(params),
                                 stack_rounds(rounds), keys)
    assert losses_k.shape == (3, mcfg.n_local_steps)
    _assert_trees_close(p_k, p_seq)
    _assert_trees_close(s_k, s_seq)
    _assert_trees_close(losses_k, jnp.stack(seq_losses))


def test_multi_round_fn_rejects_mismatched_k():
    _, mcfg, sampler, params = _setup()
    opt = opt_lib.make_optimizer("adam", 0.02)
    step_fn = glasu.make_multi_round_fn(mcfg, opt, rounds_per_step=4)
    rounds = [jax.tree.map(np.array, sampler.sample_round())
              for _ in range(2)]
    keys = jnp.stack([jax.random.PRNGKey(0)] * 2)
    with pytest.raises(ValueError, match="rounds_per_step"):
        step_fn(_copy(params), opt.init(params), stack_rounds(rounds), keys)


# ------------------------------------------------------------- scheduling
def test_step_schedule_cuts_at_cadence_boundaries():
    # uniform when everything divides
    assert step_schedule(0, 16, 4, (8, 0)) == [4, 4, 4, 4]
    # eval cadence 5 cuts each K=4 run short at multiples of 5
    assert step_schedule(0, 12, 4, (5,)) == [4, 1, 4, 1, 2]
    # resume from a mid-grid round realigns at the next boundary
    assert step_schedule(3, 10, 4, (4,)) == [1, 4, 2]
    # K=1 degenerates to the per-round loop
    assert step_schedule(0, 3, 1, (2,)) == [1, 1, 1]
    assert step_schedule(5, 5, 4, (2,)) == []
    # every boundary of every cadence ends a step
    for steps, cads in [((0, 40, 8), (6, 10)), ((7, 31, 16), (5,))]:
        sched = step_schedule(*steps, cads)
        t, ends = steps[0], []
        for k in sched:
            t += k
            ends.append(t)
        assert t == steps[1]
        for c in cads:
            for b in range(steps[0] + 1, steps[1] + 1):
                if c and b % c == 0:
                    assert b in ends


# ------------------------------------------------------- trainer parity
@pytest.mark.parametrize("k", [2, 4])
def test_trainer_rounds_per_step_bit_exact(k):
    """K-round steps vs per-round loop: params, losses, history, bytes."""
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = ExperimentConfig(**TINY, rounds=8, eval_every=4)
    r1 = Trainer(cfg, data=data).run()
    rk = Trainer(cfg.with_(rounds_per_step=k), data=data).run()
    _assert_trees_equal(rk.params, r1.params)
    assert rk.comm_bytes == r1.comm_bytes
    assert [h["round"] for h in rk.history] == [h["round"] for h in r1.history]
    assert [h["loss"] for h in rk.history] == [h["loss"] for h in r1.history]
    assert [h["comm_bytes"] for h in rk.history] == \
        [h["comm_bytes"] for h in r1.history]


@pytest.mark.slow
def test_trainer_parity_with_misaligned_cadence():
    """eval_every that does not divide rounds_per_step still evaluates the
    exact same rounds with the exact same state (remainder steps)."""
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = ExperimentConfig(**TINY, rounds=7, eval_every=3)
    r1 = Trainer(cfg, data=data).run()
    rk = Trainer(cfg.with_(rounds_per_step=4), data=data).run()
    assert [h["round"] for h in rk.history] == [3, 6, 7]
    _assert_trees_equal(rk.params, r1.params)
    assert [h["loss"] for h in rk.history] == [h["loss"] for h in r1.history]


@pytest.mark.slow
def test_resume_mid_step_bit_exact(tmp_path):
    """A checkpoint landing mid-K-grid (ckpt_every cuts the step) resumes
    into the scanned engine bit-exact with an uninterrupted sequential run."""
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = ExperimentConfig(**TINY, rounds=3, rounds_per_step=2, eval_every=2,
                           ckpt_dir=str(tmp_path), ckpt_every=3)
    Trainer(cfg, data=data).run()        # steps [2, 1] -> ckpt at round 3
    assert (tmp_path / "LATEST").read_text().strip() == "3"
    res = Trainer(cfg.with_(rounds=7), data=data).run()   # resumes mid-grid
    seq = Trainer(ExperimentConfig(**TINY, rounds=7, eval_every=2),
                  data=data).run()
    _assert_trees_equal(res.params, seq.params)
    assert res.comm_bytes == seq.comm_bytes
    # the first run's end-of-run eval at round 3 rides along in the restored
    # history; every cadence entry matches the uninterrupted run exactly
    assert [h["round"] for h in res.history] == [2, 3, 4, 6, 7]
    seq_by_round = {h["round"]: h["loss"] for h in seq.history}
    for h in res.history:
        if h["round"] in seq_by_round:
            assert h["loss"] == seq_by_round[h["round"]]


def test_rng_sidecar_skips_replay_on_resume(tmp_path):
    """New sidecars restore the sampler bit state directly: the resumed run
    draws only the remaining rounds instead of replaying the whole stream."""
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = ExperimentConfig(**TINY, rounds=4, eval_every=2,
                           ckpt_dir=str(tmp_path))
    Trainer(cfg, data=data).run()
    sidecar = json.loads((tmp_path / "state_00000004.json").read_text())
    assert sidecar["sampler_rng"] is not None

    tr = Trainer(cfg.with_(rounds=6), data=data)
    calls = []
    orig = tr.sampler.sample_round
    tr.sampler.sample_round = lambda: calls.append(1) or orig()
    res = tr.run()
    assert tr.sampler_restored
    assert len(calls) == 2               # rounds 5..6 only, no 1..4 replay
    assert res.rounds_run == 6


def test_rng_sidecar_fallback_to_replay(tmp_path):
    """Old sidecars (no sampler_rng field) keep the replay fallback and
    still produce the uninterrupted stream."""
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = ExperimentConfig(**TINY, rounds=3, eval_every=3,
                           ckpt_dir=str(tmp_path))
    Trainer(cfg, data=data).run()
    sc = tmp_path / "state_00000003.json"
    legacy = json.loads(sc.read_text())
    legacy.pop("sampler_rng")
    sc.write_text(json.dumps(legacy))

    tr = Trainer(cfg.with_(rounds=5), data=data)
    calls = []
    orig = tr.sampler.sample_round
    tr.sampler.sample_round = lambda: calls.append(1) or orig()
    res = tr.run()
    assert not tr.sampler_restored
    assert len(calls) == 5               # 3 replayed + 2 new
    seq = Trainer(ExperimentConfig(**TINY, rounds=5, eval_every=3),
                  data=data).run()
    _assert_trees_equal(res.params, seq.params)


def test_run_round_only_backend_falls_back_to_sequential_step():
    """A backend implementing only the pre-engine protocol (bind/run_round/
    joint_logits) still trains: the Trainer falls back to K sequential
    audited rounds per step."""
    from repro.api.backends import VmappedBackend

    class LegacyBackend:
        name = "legacy"

        def bind(self, mcfg, opt, sampler):
            self._v = VmappedBackend()
            self._v.bind(mcfg, opt, sampler)

        def run_round(self, p, s, b, key):
            return self._v.run_round(p, s, b, key)

        def joint_logits(self, p, b, key=None):
            return self._v.joint_logits(p, b, key)

    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = ExperimentConfig(**TINY, rounds=4, eval_every=2, rounds_per_step=2)
    res = Trainer(cfg, data=data, backend=LegacyBackend()).run()
    ref = Trainer(cfg, data=data).run()
    assert res.rounds_run == 4
    assert res.comm_bytes == ref.comm_bytes
    assert [h["round"] for h in res.history] == \
        [h["round"] for h in ref.history]


def test_extra_checkpoint_hook_cadence_cuts_steps(tmp_path):
    """Every CheckpointHook's cadence ends a step — not just the config-owned
    one — so a user hook's sidecar rng state matches st.round exactly."""
    from repro.api import CheckpointHook

    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = ExperimentConfig(**TINY, rounds=5, rounds_per_step=4, eval_every=5)
    tr = Trainer(cfg, data=data,
                 hooks=[CheckpointHook(str(tmp_path), every=3)])
    tr.run()
    sidecar = json.loads((tmp_path / "state_00000003.json").read_text())
    ref = GlasuSampler(data, cfg.sampler_config(), seed=cfg.seed)
    for _ in range(3):
        ref.sample_round()
    assert sidecar["sampler_rng"] == ref.rng.bit_generator.state


# -------------------------------------------------------- compile stability
def test_multi_round_fn_traces_once_across_run():
    """Aligned cadences -> a uniform step schedule -> exactly one trace of
    the scanned step function for the whole training run."""
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = ExperimentConfig(**TINY, rounds=12, rounds_per_step=4, eval_every=4)
    tr = Trainer(cfg, data=data)
    tr.run()
    assert tr.backend.step_fn._cache_size() == 1


def test_remainder_steps_add_at_most_one_retrace():
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = ExperimentConfig(**TINY, rounds=10, rounds_per_step=4, eval_every=5)
    tr = Trainer(cfg, data=data)
    tr.run()                              # schedule [4, 1, 4, 1] -> K in {4, 1}
    assert tr.backend.step_fn._cache_size() == 2


# ---------------------------------------------------------------- prefetch
def test_prefetch_reproduces_sequential_stream():
    _, _, ref_sampler, _ = _setup(seed=3)
    want = [jax.tree.map(np.array, ref_sampler.sample_round())
            for _ in range(5)]
    _, _, sampler, _ = _setup(seed=3)
    schedule = [2, 2, 1]
    pf = PrefetchSampler(sampler, schedule, n_buffers=2)
    try:
        got, states = [], []
        for _ in schedule:
            step = pf.get()
            for i in range(step.rounds):
                got.append(jax.tree.map(np.array,
                                        unstack_round(step.data, i)))
            states.append(step.rng_state_after)
            pf.retire(step, None)
    finally:
        pf.close()
    assert len(got) == 5
    for a, b in zip(got, want):
        _assert_trees_equal(a, b)
    # the final carried state is exactly the sequential sampler's state
    assert states[-1] == ref_sampler.rng.bit_generator.state


def test_prefetch_generation_not_reused_before_release():
    """The worker must not refill a generation until retire() released it:
    batches from consecutive steps live in distinct buffers."""
    _, _, sampler, _ = _setup()
    pf = PrefetchSampler(sampler, [1, 1, 1], n_buffers=2)
    try:
        s0 = pf.get()
        s1 = pf.get()                    # both generations filled
        assert s0.gen != s1.gen
        assert s0.data.labels.base is not s1.data.labels.base
        first = np.array(s0.data.labels)
        pf.retire(s0, None)
        pf.retire(s1, None)              # releases gen of s0 -> worker refills
        s2 = pf.get()
        assert s2.gen == s0.gen          # buffer recycled ...
        np.testing.assert_array_equal(first, np.asarray(first))
        pf.retire(s2, None)
    finally:
        pf.close()


def test_prefetch_worker_error_propagates():
    _, _, sampler, _ = _setup()
    sampler.sample_round = lambda: (_ for _ in ()).throw(
        RuntimeError("boom"))
    pf = PrefetchSampler(sampler, [1, 1], n_buffers=2)
    try:
        with pytest.raises(RuntimeError, match="prefetch worker failed"):
            pf.get()
    finally:
        pf.close()


def test_prefetch_close_mid_run_joins_worker():
    _, _, sampler, _ = _setup()
    pf = PrefetchSampler(sampler, [1] * 50, n_buffers=2)
    pf.get()                              # consume one, leave the rest
    pf.close()
    assert not pf._thread.is_alive()


def test_prefetch_worker_error_while_consumer_blocked():
    """A worker that dies MID-STREAM (after a successful step) must wake the
    consumer blocked in get() with the error — not leave it deadlocked."""
    _, _, sampler, _ = _setup()
    real = sampler.sample_round
    calls = []

    def failing_round():
        calls.append(1)
        if len(calls) > 1:
            time.sleep(0.2)         # let the consumer block in get() first
            raise RuntimeError("boom mid-stream")
        return real()

    sampler.sample_round = failing_round
    pf = PrefetchSampler(sampler, [1, 1], n_buffers=1)
    try:
        first = pf.get()
        pf.retire(first, None)      # frees the generation -> worker refills
        with pytest.raises(RuntimeError, match="prefetch worker failed"):
            pf.get()
    finally:
        pf.close()
    assert not pf._thread.is_alive()


def test_prefetch_close_mid_fill_joins_promptly():
    """close() while the worker is inside a long multi-round fill must cut
    the fill at the next round boundary, not sample the whole step out."""
    _, _, sampler, _ = _setup()
    real = sampler.sample_round

    def slow_round():
        time.sleep(0.15)
        return real()

    sampler.sample_round = slow_round
    pf = PrefetchSampler(sampler, [40], n_buffers=1)
    time.sleep(0.4)                       # worker is a few rounds into the fill
    t0 = time.monotonic()
    pf.close()
    assert not pf._thread.is_alive()
    # a full fill is 40 * 0.15s = 6s; the stop-check exits within one round
    assert time.monotonic() - t0 < 2.0


# ------------------------------------------------------------- validation
@pytest.mark.parametrize("kw", [dict(rounds_per_step=0),
                                dict(prefetch_buffers=0)])
def test_engine_config_validation(kw):
    with pytest.raises(ValueError):
        ExperimentConfig(**TINY, **kw)


# ------------------------------------------------------------ full_forward
def test_full_forward_chunked_matches_unchunked():
    """lax.map chunking is exact, including chunk sizes that do not divide
    the node count (the old clamped-slice concatenation misaligned rows
    there)."""
    rng = np.random.default_rng(0)
    m, n, d_in, cap = 2, 75, 12, 5
    cfg = GlasuConfig(n_clients=m, n_layers=4, hidden=16, n_classes=4,
                      d_in=d_in, agg_layers=(1, 3))
    params = glasu.init_params(jax.random.PRNGKey(0), cfg)
    feats = jnp.asarray(rng.normal(size=(m, n, d_in)), jnp.float32)
    idx = rng.integers(0, n, size=(m, n, cap + 1)).astype(np.int32)
    idx[..., 0] = np.arange(n)[None]
    mask = (rng.random((m, n, cap + 1)) < 0.8).astype(np.float32)
    mask[..., 0] = 1.0
    idx, mask = jnp.asarray(idx), jnp.asarray(mask)

    full = glasu.full_forward(params, cfg, feats, idx, mask, chunk=n)
    for chunk in (32, 25, 75):            # 32 does not divide 75
        out = glasu.full_forward(params, cfg, feats, idx, mask, chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                                   rtol=1e-5, atol=1e-5)


# -------------------------------------------- glint layer-3 runtime guards
def test_round_fn_hot_path_is_transfer_free_and_trace_stable(
        retrace_guard, transfer_guard):
    """After the warmup compile, same-signature round dispatches must
    neither recompile (retrace_guard) nor move data implicitly between host
    and device (transfer_guard) — batches and keys are staged explicitly,
    everything else lives on device for the whole run."""
    _, mcfg, sampler, params = _setup()
    opt = opt_lib.make_optimizer("adam", 0.02)
    round_fn = glasu.make_round_fn(mcfg, opt)
    rounds = [jax.device_put(jax.tree.map(np.array, sampler.sample_round()))
              for _ in range(4)]
    # pre-staged per-round keys: eager `keys[t]` indexing inside the guard
    # would upload its index scalar and (correctly) trip it
    keys = [jax.random.fold_in(jax.random.PRNGKey(0), t) for t in range(4)]
    p, s = _copy(params), opt.init(_copy(params))
    p, s, _ = round_fn(p, s, rounds[0], keys[0])      # the one compile
    retrace_guard.watch(round_fn, "make_round_fn")
    with transfer_guard():
        for t in range(1, 4):
            p, s, _ = round_fn(p, s, rounds[t], keys[t])
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(p))


def test_multi_round_fn_k_change_is_the_only_retrace(retrace_guard):
    """The scanned step fn compiles once per K; driving a second batch at
    the same K must hit the cache (max_new=0 after the K=2 warmup)."""
    _, mcfg, sampler, params = _setup()
    opt = opt_lib.make_optimizer("adam", 0.02)
    step_fn = glasu.make_multi_round_fn(mcfg, opt)
    rounds = [jax.tree.map(np.array, sampler.sample_round())
              for _ in range(4)]
    keys = jnp.stack([jax.random.PRNGKey(t) for t in range(2)])
    p, s = _copy(params), opt.init(_copy(params))
    p, s, _ = step_fn(p, s, stack_rounds(rounds[:2]), keys)
    retrace_guard.watch(step_fn, "make_multi_round_fn")
    p, s, _ = step_fn(p, s, stack_rounds(rounds[2:]), keys)
