"""Unified-engine golden parity: every legacy engine, frozen.

The fixtures under ``tests/golden/`` hold final parameters, loss
histories and sidecar carries produced by the three legacy hand-synced
round engines (vmapped, sharded, simulation — each with its plain /
compressed / fault-tolerant variants) immediately before they were
unified into the single policy-parameterized round body. Replaying each
combo through the unified body and comparing against the stored arrays
pins the refactor: the vmapped and simulation paths must be BITWISE
identical (same ops in the same order on the same host), the sharded
path float32-ULP close (its shard_map lowering fuses differently across
XLA builds — the same tolerance class as ``SHARD_TOL`` in the
conformance suite).
"""
import os

import numpy as np
import pytest

import golden_runners as gr

SHARD_TOL = dict(rtol=5e-5, atol=5e-5)


def _load(name):
    path = os.path.join(gr.GOLDEN_DIR, f"{name}.npz")
    if not os.path.exists(path):
        pytest.fail(f"golden fixture missing: {path} — regenerate with "
                    f"`PYTHONPATH=src python tests/golden_runners.py --write`")
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


@pytest.mark.parametrize("name", gr.EXACT)
def test_golden_bitwise(name):
    got = gr.COMBOS[name]()
    want = _load(name)
    assert sorted(got) == sorted(want)
    for k in sorted(want):
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


@pytest.mark.parametrize("name", gr.CLOSE)
def test_golden_ulp(name):
    got = gr.COMBOS[name]()
    want = _load(name)
    assert sorted(got) == sorted(want)
    for k in sorted(want):
        np.testing.assert_allclose(got[k], want[k], err_msg=k, **SHARD_TOL)
