"""Cross-backend parity: the explicit message-passing backend must agree
with the vmapped fast path — logits, trained parameters, AND bytes (the
message log audits the sampler's analytic cost model every round)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ExperimentConfig, SimulationBackend, Trainer,
                       VmappedBackend)
from repro.core import glasu
from repro.graph.sampler import GlasuSampler
from repro.graph.synth import make_vfl_dataset

CFG = ExperimentConfig(name="parity", dataset="tiny", hidden=16, batch_size=8,
                       size_cap=96, rounds=2, eval_every=2, optimizer="sgd",
                       lr=0.05)


def _bind_both(cfg):
    data = make_vfl_dataset(cfg.dataset, n_clients=cfg.n_clients,
                            seed=cfg.seed)
    mcfg = cfg.glasu_config(data)
    sampler = GlasuSampler(data, cfg.sampler_config(), seed=cfg.seed)
    vb, sb = VmappedBackend(), SimulationBackend()
    vb.bind(mcfg, cfg.make_optimizer(), sampler)
    sb.bind(mcfg, cfg.make_optimizer(), sampler)
    params = glasu.init_params(jax.random.PRNGKey(cfg.seed), mcfg)
    batch = jax.tree.map(jnp.asarray, sampler.sample_round())
    return mcfg, sampler, vb, sb, params, batch


@pytest.mark.slow
def test_joint_logits_parity():
    _, _, vb, sb, params, batch = _bind_both(CFG)
    np.testing.assert_allclose(np.asarray(sb.joint_logits(params, batch)),
                               np.asarray(vb.joint_logits(params, batch)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_round_parity_params_and_bytes():
    cfg = CFG
    mcfg, sampler, vb, sb, params, batch = _bind_both(cfg)
    opt = cfg.make_optimizer()
    state_v = opt.init(params)
    state_s = opt.init(params)
    pv, ps = params, params
    analytic = sampler.comm_bytes_per_joint_inference(mcfg.hidden, mcfg.agg)
    key = jax.random.PRNGKey(0)
    for t in range(2):
        out_v = vb.run_round(pv, state_v, batch, jax.random.fold_in(key, t))
        out_s = sb.run_round(ps, state_s, batch, jax.random.fold_in(key, t))
        pv, state_v = out_v.params, out_v.opt_state
        ps, state_s = out_s.params, out_s.opt_state
        # bytes: measured message log == analytic meter == vmapped estimate
        assert out_s.message_log is not None
        assert out_s.message_log.total_bytes() == analytic
        assert out_s.comm_bytes == out_v.comm_bytes == analytic
        for a, b in zip(jax.tree.leaves(ps), jax.tree.leaves(pv)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)


def test_message_log_breakdown_matches_cost_model_terms():
    """Per-kind audit: uploads+broadcasts = activation term, index_sync =
    index-union term of §3.2's cost model."""
    mcfg, sampler, _, sb, params, batch = _bind_both(CFG)
    out = sb.run_round(params, sb.optimizer.init(params), batch,
                       jax.random.PRNGKey(0))
    log = out.message_log
    act = sum(2 * mcfg.n_clients * sampler.layer_sizes[l + 1] * mcfg.hidden * 4
              for l in mcfg.agg_layers)
    idx = sum(2 * mcfg.n_clients * sampler.layer_sizes[j] * 4
              for j in range(mcfg.n_layers + 1) if sampler._shared(j))
    assert log.total_bytes("upload") + log.total_bytes("broadcast") == act
    assert log.total_bytes("index_sync") == idx


@pytest.mark.slow
def test_trainer_runs_on_simulation_backend():
    res = Trainer(CFG.with_(backend="simulation")).run()
    assert res.rounds_run == 2
    assert res.comm_bytes > 0
    assert np.isfinite(res.history[-1]["loss"])


@pytest.mark.slow
def test_standalone_simulation_has_no_traffic():
    cfg = CFG.with_(method="standalone", agg_layers=None, backend="simulation")
    res = Trainer(cfg).run()
    assert res.comm_bytes == 0


def test_simulation_backend_rejects_privacy_hooks():
    cfg = CFG.with_(secure_agg=True)
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    sb = SimulationBackend()
    with pytest.raises(ValueError, match="privacy"):
        sb.bind(cfg.glasu_config(data), cfg.make_optimizer(),
                GlasuSampler(data, cfg.sampler_config(), seed=0))
