"""Serving subsystem: checkpoint restore, cache, batcher, byte metering.

Conformance of served answers against the training-path evaluators across
engines/codecs lives in ``test_backend_conformance.py``; this module covers
the serving-specific machinery — params-only checkpoint restore
(``load_for_inference``), hot-node cache semantics (LRU, staleness,
version bumps), the query-path byte bill vs its message-log replay, the
micro-batcher, and the chunk-padding guarantee of ``full_forward``'s
aggregate collection (pad rows must never reach the cache or the served
logits when ``chunk`` does not divide N).
"""
import json

import jax
import numpy as np
import pytest

from repro.api import ExperimentConfig, Trainer
from repro.core import checkpoint, glasu
from repro.core.train import _eval_tables
from repro.fed.simulation import MessageLog, log_query_traffic
from repro.serve import (HotNodeCache, InferenceSession, MicroBatcher,
                         ServeAnswer, ServeConfig)

ROUNDS = 4


def _cfg(**kw):
    kw.setdefault("optimizer", "sgd")
    kw.setdefault("eval_every", ROUNDS)
    return ExperimentConfig(
        name="serve-test", dataset="tiny", backbone="gcnii", hidden=16,
        batch_size=8, size_cap=96, rounds=ROUNDS, lr=0.05, **kw)


@pytest.fixture(scope="module")
def ckpt(tmp_path_factory):
    """A mid-training checkpoint: ckpt_every=2 leaves steps 2 and 4."""
    d = tmp_path_factory.mktemp("serve-ckpt")
    cfg = _cfg(ckpt_dir=str(d), ckpt_every=2)
    res = Trainer(cfg).run()
    return str(d), cfg, res


# ------------------------------------------------------- load_for_inference
def test_load_for_inference_params_only(ckpt):
    d, cfg, res = ckpt
    r = checkpoint.load_for_inference(d)
    assert r.step == ROUNDS
    # exactly the params tree — no opt_state leaves tag along
    assert jax.tree_util.tree_structure(r.params) \
        == jax.tree_util.tree_structure(res.params)
    for a, b in zip(jax.tree.leaves(r.params), jax.tree.leaves(res.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert r.config.to_dict() == cfg.to_dict()


def test_load_for_inference_mid_training_step_into_session(ckpt):
    d, _, res = ckpt
    r = checkpoint.load_for_inference(d, step=2)   # not the final params
    assert r.step == 2
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(r.params),
                               jax.tree.leaves(res.params)))
    s = InferenceSession.from_checkpoint(d, step=2,
                                         serve=ServeConfig(max_batch=8))
    assert s.params_version == 2
    ans = s.answer([1, 2, 3])
    assert ans.logits.shape == (3, s.mcfg.n_classes)
    assert np.isfinite(ans.logits).all()


def test_load_for_inference_loud_errors(ckpt, tmp_path):
    d, _, _ = ckpt
    with pytest.raises(FileNotFoundError, match="experiment.json"):
        checkpoint.load_for_inference(str(tmp_path))
    with pytest.raises(FileNotFoundError, match="no checkpoint for step"):
        checkpoint.load_for_inference(d, step=77)
    # corrupt npz: truncate a copy of the checkpoint directory
    import shutil
    bad = tmp_path / "bad"
    shutil.copytree(d, bad)
    fn = bad / f"ckpt_{ROUNDS:08d}.npz"
    fn.write_bytes(fn.read_bytes()[:100])
    with pytest.raises(RuntimeError, match="corrupt"):
        checkpoint.load_for_inference(str(bad))


def test_load_for_inference_rejects_mismatched_model(ckpt, tmp_path):
    d, cfg, _ = ckpt
    import shutil
    bad = tmp_path / "swapped"
    shutil.copytree(d, bad)
    # claim a different optimizer: the leaf count no longer matches
    meta = json.loads((bad / "experiment.json").read_text())
    meta["optimizer"] = "adam"
    (bad / "experiment.json").write_text(json.dumps(meta))
    with pytest.raises(RuntimeError, match="leaves"):
        checkpoint.load_for_inference(str(bad))


# ------------------------------------------------------------ HotNodeCache
def test_cache_lru_eviction_order():
    c = HotNodeCache(capacity=2)
    row = np.ones((1, 3, 4), np.float32)
    c.insert(0, np.array([10]), 0, row)
    c.insert(0, np.array([11]), 0, row)
    c.lookup(0, np.array([10]), 0, (3, 4))       # refresh 10 -> 11 is LRU
    c.insert(0, np.array([12]), 0, row)          # evicts 11
    hit, _ = c.lookup(0, np.array([10, 11, 12]), 0, (3, 4))
    assert hit.tolist() == [1.0, 0.0, 1.0]
    assert c.evictions == 1


def test_cache_staleness_bound_and_version_bump():
    c = HotNodeCache(capacity=8, max_staleness=1)
    row = np.full((1, 2, 2), 7.0, np.float32)
    c.insert(1, np.array([5]), 10, row)
    hit, rows = c.lookup(1, np.array([5]), 11, (2, 2))   # 1 version old: ok
    assert hit[0] == 1.0 and rows[0, 0, 0] == 7.0
    hit, _ = c.lookup(1, np.array([5]), 12, (2, 2))      # 2 old: evicted
    assert hit[0] == 0.0 and len(c) == 0
    # exact-version cache: any bump invalidates
    c0 = HotNodeCache(capacity=8, max_staleness=0)
    c0.insert(1, np.array([5]), 10, row)
    assert c0.lookup(1, np.array([5]), 11, (2, 2))[0][0] == 0.0


def test_cache_disabled_and_padding_ids():
    c = HotNodeCache(capacity=0)
    c.insert(0, np.array([1]), 0, np.ones((1, 2, 2), np.float32))
    hit, _ = c.lookup(0, np.array([1, -1]), 0, (2, 2))
    assert hit.sum() == 0 and len(c) == 0
    assert c.misses == 1          # the pad id (-1) is not counted


def test_session_update_params_invalidates_cache(ckpt):
    d, _, _ = ckpt
    s = InferenceSession.from_checkpoint(d, serve=ServeConfig(max_batch=8))
    q = [1, 2, 3]
    s.answer(q)
    assert not s.answer(q).cold                   # warm at fixed version
    s.update_params(s.params)                     # version bump, stale=0
    a = s.answer(q)
    assert a.cold and a.params_version == s.params_version


# ------------------------------------------------- byte metering / answers
def test_query_bytes_match_message_log_replay(ckpt):
    d, _, _ = ckpt
    s = InferenceSession.from_checkpoint(
        d, serve=ServeConfig(max_batch=8, record_log=True))
    a1 = s.answer([0, 1, 2, 3])
    a2 = s.answer([2, 3, 4, 5])                   # overlap: fewer fresh rows
    for a in (a1, a2):
        log = MessageLog()
        log_query_traffic(log, a.fresh_rows, s.mcfg, compressor=s._comp)
        assert a.upload_bytes == log.total_bytes("upload") \
            == a.log.total_bytes("upload")
        assert a.broadcast_bytes == log.total_bytes("broadcast")
        assert a.index_bytes == log.total_bytes("index_sync")
    top = s.L - 1
    assert a2.fresh_rows[top] < a1.fresh_rows[top]
    assert a2.wire_bytes < a1.wire_bytes
    # warm repeat ships nothing and is bitwise stable
    a3 = s.answer([2, 3, 4, 5])
    assert a3.wire_bytes == 0 and not a3.cold
    np.testing.assert_array_equal(a3.logits, a2.logits)


def test_answer_validates_and_splits(ckpt):
    d, _, _ = ckpt
    s = InferenceSession.from_checkpoint(d, serve=ServeConfig(max_batch=4))
    with pytest.raises(ValueError, match="empty"):
        s.answer([])
    with pytest.raises(ValueError, match="query ids"):
        s.answer([10_000])
    big = list(range(10))                          # > max_batch: split
    a = s.answer(big)
    assert a.logits.shape[0] == 10
    assert s.metrics.answers == 3 and s.metrics.queries == 10
    # duplicate + shuffled queries map back to caller order
    a2 = s.answer([3, 3, 1])
    np.testing.assert_array_equal(a2.logits[0], a2.logits[1])
    np.testing.assert_array_equal(a2.logits[2],
                                  s.answer([1]).logits[0])


def test_serve_config_validation_and_roundtrip():
    with pytest.raises(ValueError, match="engine"):
        ServeConfig(engine="warp")
    with pytest.raises(ValueError, match="max_batch"):
        ServeConfig(max_batch=0)
    with pytest.raises(ValueError, match="buckets"):
        ServeConfig(buckets=[4, 2])
    with pytest.raises(ValueError, match="cover max_batch"):
        ServeConfig(buckets=[2, 4], max_batch=16)
    assert ServeConfig(max_batch=12).resolved_buckets() == (1, 2, 4, 8, 12)
    with pytest.raises(ValueError, match="serve block"):
        _cfg(serve={"engine": "warp"})
    cfg = _cfg(serve={"max_batch": 4, "buckets": [2, 4]})
    assert cfg.serve == ServeConfig(max_batch=4, buckets=(2, 4))
    rt = ExperimentConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert rt == cfg


# ------------------------------------------------------------ micro-batcher
def test_batcher_coalesces_and_splits(ckpt):
    d, _, _ = ckpt
    s = InferenceSession.from_checkpoint(d, serve=ServeConfig(max_batch=8))
    ref = {i: s.answer([i]).logits[0] for i in range(4)}
    with MicroBatcher(s, deadline_ms=200.0) as mb:
        futs = [mb.submit([i, i + 1]) for i in range(3)]
        outs = [f.result(timeout=30) for f in futs]
        assert mb.batches == 1 and mb.coalesced == 2
    for i, o in enumerate(outs):
        assert isinstance(o, ServeAnswer) and o.logits.shape[0] == 2
        np.testing.assert_array_equal(o.logits[0], ref[i])
        np.testing.assert_array_equal(o.logits[1], ref[i + 1])


def test_batcher_propagates_errors_and_closes(ckpt):
    d, _, _ = ckpt
    s = InferenceSession.from_checkpoint(d, serve=ServeConfig(max_batch=8))
    mb = MicroBatcher(s, deadline_ms=1.0)
    with pytest.raises(ValueError, match="query ids"):
        mb.submit([99_999]).result(timeout=30)
    mb.close()
    with pytest.raises(RuntimeError, match="closed"):
        mb.submit([1])


# ------------------------------------- satellite 6: chunk padding vs cache
def test_full_forward_chunk_padding_cannot_poison_cache(ckpt):
    """chunk=100 does not divide N=256: ``full_forward`` pads the last
    chunk under ``lax.map``. The collected aggregate stacks must carry
    exactly the N real rows, the warmed cache exactly N entries per layer,
    and logits served from that cache must match the unpadded forward."""
    d, _, _ = ckpt
    s = InferenceSession.from_checkpoint(d, serve=ServeConfig(max_batch=8))
    assert s.N % 100 != 0
    logits_pad = s.precompute(chunk=100)
    feats, nbr_idx, nbr_mask = _eval_tables(s.data, s.config.eval_table_cap,
                                            s.config.seed)
    logits_whole = np.asarray(glasu.full_forward(
        s.params, s.mcfg, feats, nbr_idx, nbr_mask, chunk=s.N))
    np.testing.assert_allclose(logits_pad, logits_whole,
                               rtol=2e-4, atol=2e-4)
    assert len(s.cache) == len(s.mcfg.agg_layers) * s.N
    assert all(0 <= node < s.N for node, _ in s.cache._store)
    # every query is now a cache hit and matches the exact evaluator
    q = np.array([0, 99, 100, 255])               # straddle chunk edges
    ans = s.answer(q)
    assert not ans.cold and ans.wire_bytes == 0
    np.testing.assert_allclose(ans.logits, logits_whole.mean(0)[q],
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(ans.per_client, logits_whole[:, q],
                               rtol=2e-4, atol=2e-4)
