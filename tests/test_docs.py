"""Docs integrity in tier-1: every link and file:line anchor resolves.

The full doctest (README quickstart execution) runs in the CI docs job
via ``tools/check_docs.py --run-quickstart``; here we keep the cheap
structural checks in the main suite so a refactor that moves an anchored
symbol fails immediately, not only on the docs job.
"""
import importlib.util
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
_spec = importlib.util.spec_from_file_location(
    "check_docs", ROOT / "tools" / "check_docs.py")
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)

DOCS = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]


@pytest.mark.parametrize("md", DOCS, ids=lambda p: p.name)
def test_links_and_anchors_resolve(md):
    errors = check_docs.check_file(md)
    assert not errors, "\n".join(errors)


def test_docs_suite_exists():
    for name in ("ARCHITECTURE.md", "BACKENDS.md", "BENCHMARKS.md"):
        assert (ROOT / "docs" / name).is_file(), f"docs/{name} missing"


def test_readme_quickstart_fence_present():
    text = (ROOT / "README.md").read_text()
    assert "## Quickstart" in text
    assert "```python" in text.split("## Quickstart", 1)[1]
