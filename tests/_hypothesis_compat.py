"""``hypothesis`` if installed, else a tiny deterministic fallback.

The fallback implements exactly the subset this suite uses —
``@settings(max_examples=..., deadline=...)`` + ``@given`` with
``st.integers`` and ``st.sampled_from`` — by looping the test body over
seeded draws. Property tests therefore still *run* (deterministically, no
shrinking) in containers without the dependency.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect as _inspect

    import numpy as _np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(
                lambda rng: elems[int(rng.integers(0, len(elems)))])

    st = _Strategies()

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def runner(*args, **kwargs):
                n = getattr(runner, "_max_examples", 10)
                rng = _np.random.default_rng(0)
                for _ in range(n):
                    draw = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **draw)
            # hide the drawn parameters from pytest's fixture resolution
            del runner.__wrapped__
            runner.__signature__ = _inspect.Signature()
            return runner
        return deco

# re-exported surface (the try-import above is the real definition site)
__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
