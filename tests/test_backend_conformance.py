"""Cross-backend conformance: vmapped / simulation / sharded must agree.

One parametrized grid — backends x backbones {gcn, gcnii, gat} x aggregation
{mean, concat} x rounds-per-step K in {1, 4} — asserting that trained
parameters, per-round losses, and per-round byte counts agree after N
rounds, plus checkpoint save/resume under sharded placement and agreement
between the sharded collective byte meter and the message-passing log.

Numerical contract (measured, documented): the sharded backend runs the
SAME ops on the SAME values as the vmapped path (aggregation happens on the
all_gathered full client stack), but XLA compiles the per-device trunk at a
different batch width than the vmapped one, and CPU fusion differs at the
last ULP between those lowerings. Agreement is therefore pinned to a few
float32 ULPs per round (``SHARD_TOL``) rather than bitwise equality —
roughly 1000x tighter than any real cross-client bug (wrong index, wrong
reduction) would produce, and tighter than the simulation backend's
independent-implementation tolerance (``SIM_TOL``). Checkpoint resume IS
bitwise (same program replayed on restored state).

The suite adapts to the device count: with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI multi-device
job) the client mesh places one client per device and aggregation is a real
cross-device collective; on one device the same shard_map program runs with
a single shard (m_loc = M), so the tier-1 run exercises the identical code
path everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ExperimentConfig, ShardedBackend, SimulationBackend,
                       Trainer, VmappedBackend, make_backend)
from repro.comm.compression import make_compressor
from repro.core import glasu
from repro.fed import faults as faults_lib
from repro.fed import simulation
from repro.graph.prefetch import stack_rounds
from repro.graph.sampler import GlasuSampler
from repro.graph.synth import make_vfl_dataset
from repro.launch import sharding as shd
from repro.launch.mesh import client_mesh_size, make_client_mesh

# sharded vs vmapped: float32-ULP class (see module docstring)
SHARD_TOL = dict(rtol=5e-5, atol=5e-5)
# simulation vs vmapped: independent per-client implementation (existing
# tolerance class from test_backend_parity)
SIM_TOL = dict(rtol=2e-4, atol=2e-5)

ROUNDS = 4

# (backbone, agg): concat aggregation is implemented for gcn only
MODEL_GRID = [("gcn", "mean"), ("gcn", "concat"),
              ("gcnii", "mean"), ("gat", "mean")]


def _cfg(backbone, agg, **kw):
    # the grid trains with plain SGD: updates are LINEAR in the gradients,
    # so implementation-level ULP noise stays ULP-sized in the parameters
    # and the tolerances below pin algebraic equivalence. (Adam's
    # m/sqrt(v) normalization turns a last-ULP sign flip on a near-zero
    # gradient element into a full +/-lr step — an optimizer property, not
    # a backend divergence; Adam-driven conformance is covered by the
    # gcnii privacy/trainer/checkpoint tests below, where it is stable.)
    kw.setdefault("optimizer", "sgd")
    kw.setdefault("eval_every", ROUNDS)
    return ExperimentConfig(
        name=f"conf-{backbone}-{agg}", dataset="tiny", backbone=backbone,
        agg=agg, hidden=16, batch_size=8, size_cap=96, rounds=ROUNDS,
        lr=0.05, **kw)


def _setup(cfg):
    data = make_vfl_dataset(cfg.dataset, n_clients=cfg.n_clients,
                            seed=cfg.seed)
    mcfg = cfg.glasu_config(data)
    sampler = GlasuSampler(data, cfg.sampler_config(), seed=cfg.seed)
    return data, mcfg, sampler


def _sample_rounds(sampler, n):
    # copy each round out of the sampler's scratch before the next draw
    return [jax.tree.map(np.array, sampler.sample_round()) for _ in range(n)]


def _run(backend, opt, params, rounds, keys, k):
    """Drive ``rounds`` through run_step in chunks of k; fresh param copy
    (run_step may donate its inputs)."""
    p = jax.tree.map(jnp.array, params)
    s = opt.init(p)
    losses, comm = [], None
    for t in range(0, len(rounds), k):
        out = backend.run_step(p, s,
                               jax.tree.map(jnp.asarray,
                                            stack_rounds(rounds[t:t + k])),
                               keys[t:t + k])
        p, s = out.params, out.opt_state
        losses.append(np.asarray(out.losses))
        assert comm is None or comm == out.comm_bytes_round
        comm = out.comm_bytes_round
    return p, np.concatenate(losses, axis=0), comm


def _assert_trees_close(a, b, **tol):
    for (pa, la), (_, lb) in zip(jax.tree_util.tree_leaves_with_path(a),
                                 jax.tree_util.tree_leaves_with_path(b)):
        np.testing.assert_allclose(
            np.asarray(la), np.asarray(lb),
            err_msg=jax.tree_util.keystr(pa), **tol)


# ------------------------------------------------------------------ the grid
@pytest.mark.parametrize("k", [1, pytest.param(4, marks=pytest.mark.slow)])
@pytest.mark.parametrize("backbone,agg", MODEL_GRID)
def test_trained_params_losses_and_bytes_conform(backbone, agg, k):
    cfg = _cfg(backbone, agg)
    data, mcfg, sampler = _setup(cfg)
    opt = cfg.make_optimizer()
    params = glasu.init_params(jax.random.PRNGKey(cfg.seed), mcfg)
    rounds = _sample_rounds(sampler, ROUNDS)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(1), jnp.arange(ROUNDS))
    analytic = sampler.comm_bytes_per_joint_inference(mcfg.hidden, mcfg.agg)

    vb = VmappedBackend()
    vb.bind(mcfg, opt, sampler)
    p_ref, losses_ref, comm_ref = _run(vb, opt, params, rounds, keys, k)
    assert comm_ref == analytic

    sb = make_backend("sharded")
    sb.bind(mcfg, opt, sampler)
    p_sh, losses_sh, comm_sh = _run(sb, opt, params, rounds, keys, k)
    assert comm_sh == comm_ref          # collective meter == analytic model
    np.testing.assert_allclose(losses_sh, losses_ref, rtol=1e-5, atol=1e-6)
    _assert_trees_close(p_sh, p_ref, **SHARD_TOL)

    if agg == "mean":                   # simulation implements mean only
        # the simulation path is an independent per-client implementation:
        # its ULP-level noise amplifies through training (visibly so for
        # GAT's softmax attention), so parity is pinned over 2 rounds —
        # same depth as the historical backend-parity test. Multi-round
        # scan semantics are covered by the vmapped/sharded comparison
        # above (the simulation step is sequential by construction).
        p_ref2, losses_ref2, _ = _run(vb, opt, params, rounds[:2],
                                      keys[:2], 1)
        mb = SimulationBackend()
        mb.bind(mcfg, opt, sampler)
        p_sim, losses_sim, comm_sim = _run(mb, opt, params, rounds[:2],
                                           keys[:2], 1)
        assert comm_sim == comm_ref     # message log == both meters
        np.testing.assert_allclose(losses_sim, losses_ref2, **SIM_TOL)
        _assert_trees_close(p_sim, p_ref2, **SIM_TOL)


@pytest.mark.parametrize("backbone,agg", MODEL_GRID)
def test_joint_logits_conform(backbone, agg):
    cfg = _cfg(backbone, agg)
    data, mcfg, sampler = _setup(cfg)
    opt = cfg.make_optimizer()
    params = glasu.init_params(jax.random.PRNGKey(cfg.seed), mcfg)
    batch = jax.tree.map(jnp.array, sampler.sample_round())

    vb = VmappedBackend()
    vb.bind(mcfg, opt, sampler)
    ref = np.asarray(vb.joint_logits(params, batch))

    sb = make_backend("sharded")
    sb.bind(mcfg, opt, sampler)
    got = np.asarray(sb.joint_logits(params, batch))
    assert got.shape == ref.shape == (mcfg.n_clients, cfg.batch_size,
                                      mcfg.n_classes)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)

    if agg == "mean":
        mb = SimulationBackend()
        mb.bind(mcfg, opt, sampler)
        np.testing.assert_allclose(np.asarray(mb.joint_logits(params, batch)),
                                   ref, **SIM_TOL)


@pytest.mark.slow
def test_privacy_hooks_conform_on_sharded():
    """§3.6 secure-agg masks + DP noise: the replicated PRNG key makes the
    sharded aggregation draw the same masks as the vmapped path (the
    simulation backend rejects these hooks — the sharded one need not)."""
    cfg = _cfg("gcnii", "mean", secure_agg=True, dp_sigma=0.01,
               optimizer="adam")
    data, mcfg, sampler = _setup(cfg)
    opt = cfg.make_optimizer()
    params = glasu.init_params(jax.random.PRNGKey(cfg.seed), mcfg)
    rounds = _sample_rounds(sampler, 2)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(1), jnp.arange(2))
    vb = VmappedBackend()
    vb.bind(mcfg, opt, sampler)
    sb = make_backend("sharded")
    sb.bind(mcfg, opt, sampler)
    p_ref, losses_ref, _ = _run(vb, opt, params, rounds, keys, 1)
    p_sh, losses_sh, _ = _run(sb, opt, params, rounds, keys, 1)
    np.testing.assert_allclose(losses_sh, losses_ref, rtol=1e-5, atol=1e-6)
    _assert_trees_close(p_sh, p_ref, **SHARD_TOL)


# ------------------------------------------------- checkpointing under shards
def test_sharded_checkpoint_save_resume_bit_exact(tmp_path):
    """Interrupt/resume on the sharded backend replays the identical
    program on restored state: bitwise-equal parameters, continuous comm
    accounting, and the restored sampler rng stream."""
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    base = _cfg("gcnii", "mean", optimizer="adam").with_(
        backend="sharded", eval_every=2)
    cfg = base.with_(ckpt_dir=str(tmp_path), ckpt_every=2, rounds=2)
    Trainer(cfg, data=data).run()
    assert (tmp_path / "LATEST").read_text().strip() == "2"
    res = Trainer(cfg.with_(rounds=ROUNDS), data=data).run()  # resume 2 -> 4
    straight = Trainer(base, data=data).run()
    for (pa, la), (_, lb) in zip(
            jax.tree_util.tree_leaves_with_path(res.params),
            jax.tree_util.tree_leaves_with_path(straight.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=jax.tree_util.keystr(pa))
    assert res.comm_bytes == straight.comm_bytes
    straight_by_round = {h["round"]: h["loss"] for h in straight.history}
    for h in res.history:
        if h["round"] in straight_by_round:
            assert h["loss"] == straight_by_round[h["round"]]


# ------------------------------------------------------- byte-meter vs log
def test_sharded_collective_meter_agrees_with_message_log():
    """The sharded path's bytes come from trace-time collective records
    (star-topology priced), not the analytic model — and they must agree
    with the literal message log of one simulated round, term by term."""
    cfg = _cfg("gcnii", "mean")
    data, mcfg, sampler = _setup(cfg)
    opt = cfg.make_optimizer()
    sb = make_backend("sharded")
    sb.bind(mcfg, opt, sampler)
    assert len(sb.collectives) == len(mcfg.agg_layers)

    params = glasu.init_params(jax.random.PRNGKey(cfg.seed), mcfg)
    batch = jax.tree.map(jnp.array, sampler.sample_round())
    mb = SimulationBackend()
    mb.bind(mcfg, opt, sampler)
    out = mb.run_round(params, opt.init(params), batch,
                       jax.random.PRNGKey(0))
    log = out.message_log
    # activation term: recorded collectives == uploads + broadcasts
    assert sum(r.star_bytes() for r in sb.collectives) == \
        log.total_bytes("upload") + log.total_bytes("broadcast")
    # full round: collectives + host-side index sync == the whole log
    assert sb.bytes_per_round == log.total_bytes()


def test_shape_shell_replay_matches_live_round_log():
    """``log_agg_traffic``/``log_index_sync`` on the sampler's shape shells
    reconstruct exactly the message log a computed round emits."""
    cfg = _cfg("gcnii", "mean")
    data, mcfg, sampler = _setup(cfg)
    shell = sampler.shape_shell_batch()
    log = simulation.MessageLog()
    simulation.log_index_sync(log, shell, mcfg)
    simulation.log_agg_traffic(log, shell, mcfg)

    params = glasu.init_params(jax.random.PRNGKey(cfg.seed), mcfg)
    batch = jax.tree.map(jnp.array, sampler.sample_round())
    live = simulation.MessageLog()
    simulation.log_index_sync(live, batch, mcfg)
    simulation.simulate_joint_inference(params, batch, mcfg, log=live)
    for kind in ("upload", "broadcast", "index_sync"):
        assert log.total_bytes(kind) == live.total_bytes(kind)


# --------------------------------------------------- mesh + sharding guards
def test_client_mesh_size_divisor_selection():
    assert client_mesh_size(3, 8) == 3
    assert client_mesh_size(4, 8) == 4
    assert client_mesh_size(6, 4) == 3      # largest divisor that fits
    assert client_mesh_size(5, 3) == 1      # prime M, too few devices
    assert client_mesh_size(8, 8) == 8
    assert client_mesh_size(1, 8) == 1
    with pytest.raises(ValueError):
        client_mesh_size(0, 8)


def test_make_client_mesh_uses_available_devices():
    mesh = make_client_mesh(3)
    want = client_mesh_size(3, len(jax.devices()))
    assert mesh.axis_names == ("clients",)
    assert mesh.shape["clients"] == want
    capped = make_client_mesh(3, max_devices=1)
    assert capped.shape["clients"] == 1


def test_client_param_specs_shard_the_client_axis():
    from jax.sharding import PartitionSpec as P
    cfg = _cfg("gcnii", "mean")
    data, mcfg, sampler = _setup(cfg)
    params = jax.eval_shape(
        lambda k: glasu.init_params(k, mcfg), jax.random.PRNGKey(0))
    mesh = make_client_mesh(mcfg.n_clients)
    specs = shd.client_param_specs(params, mesh)
    d = mesh.shape["clients"]
    want = P("clients") if d > 1 else P(None)   # 1-device mesh replicates
    for path, spec in jax.tree_util.tree_leaves_with_path(
            specs, is_leaf=lambda x: isinstance(x, P)):
        assert spec[0] == want[0], jax.tree_util.keystr(path)

    batch_specs = shd.client_batch_specs(sampler.shape_shell_batch(), mesh)
    assert batch_specs.labels == P()
    assert batch_specs.feats[0] == want[0]


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices for a non-dividing mesh")
def test_divisibility_guard_falls_back_to_replication():
    """n_clients = 3 on a 2-way client axis: the guarded placement specs
    must replicate every client-stacked leaf instead of producing ragged
    shards — and the shard_map round body must refuse the mesh loudly."""
    from jax.sharding import PartitionSpec as P
    cfg = _cfg("gcnii", "mean")
    data, mcfg, sampler = _setup(cfg)
    bad_mesh = jax.make_mesh((2,), ("clients",), devices=jax.devices()[:2])

    params = jax.eval_shape(
        lambda k: glasu.init_params(k, mcfg), jax.random.PRNGKey(0))
    for path, spec in jax.tree_util.tree_leaves_with_path(
            shd.client_param_specs(params, bad_mesh),
            is_leaf=lambda x: isinstance(x, P)):
        assert all(s is None for s in spec), jax.tree_util.keystr(path)
    shell = sampler.shape_shell_batch()
    for spec in jax.tree.leaves(shd.client_batch_specs(shell, bad_mesh),
                                is_leaf=lambda x: isinstance(x, P)):
        assert all(s is None for s in spec)

    sb = ShardedBackend(mesh=bad_mesh)
    with pytest.raises(ValueError, match="does not divide"):
        sb.bind(mcfg, cfg.make_optimizer(), sampler)


# ------------------------------------------------------------ config guards
def test_sharded_config_guards():
    with pytest.raises(ValueError, match="adafactor"):
        _cfg("gcnii", "mean", backend="sharded", optimizer="adafactor")
    with pytest.raises(ValueError, match="labels_at_client"):
        _cfg("gcnii", "mean", backend="sharded", labels_at_client=0)
    with pytest.raises(ValueError, match="mesh_devices"):
        _cfg("gcnii", "mean", mesh_devices=2)       # vmapped backend
    with pytest.raises(ValueError, match="mesh_devices"):
        _cfg("gcnii", "mean", backend="sharded", mesh_devices=0)
    cfg = _cfg("gcnii", "mean", backend="sharded", mesh_devices=1)
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg


def test_sharded_multi_round_shape_guard():
    cfg = _cfg("gcnii", "mean")
    data, mcfg, sampler = _setup(cfg)
    opt = cfg.make_optimizer()
    mesh = make_client_mesh(mcfg.n_clients)
    fn = glasu.make_sharded_multi_round_fn(mcfg, opt, mesh,
                                           rounds_per_step=2)
    params = glasu.init_params(jax.random.PRNGKey(0), mcfg)
    batches = stack_rounds(_sample_rounds(sampler, 3))
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(0), jnp.arange(3))
    with pytest.raises(ValueError, match="rounds_per_step"):
        fn(params, opt.init(params), batches, keys)


# -------------------------------------------------- fault-tolerant rows
# Degraded-mode conformance: the fault-tolerant round path with the default
# FaultConfig (every client present, zero latency, no drops) must match the
# fault-free engine at the established tolerance classes. The weighted Agg
# reduces algebraically to the plain mean at weight == 1, but its summation
# order differs from the legacy reduction, so agreement is the same
# float32-ULP class as the sharded rows — not bitwise.

def _degraded_plans(n_clients, n):
    return faults_lib.FaultSchedule(faults_lib.FaultConfig(),
                                    n_clients).draw_step(n)


def _run_f(backend, opt, params, rounds, keys, k, plans):
    """_run with per-round fault plans threaded through run_step."""
    p = jax.tree.map(jnp.array, params)
    s = opt.init(p)
    losses, per_round = [], []
    for t in range(0, len(rounds), k):
        out = backend.run_step(p, s,
                               jax.tree.map(jnp.asarray,
                                            stack_rounds(rounds[t:t + k])),
                               keys[t:t + k], faults=plans[t:t + k])
        p, s = out.params, out.opt_state
        losses.append(np.asarray(out.losses))
        per_round.extend(out.comm_bytes_rounds)
    return p, np.concatenate(losses, axis=0), per_round


@pytest.mark.parametrize("k", [1, pytest.param(4, marks=pytest.mark.slow)])
@pytest.mark.parametrize("backbone,agg", MODEL_GRID)
def test_degraded_fault_path_conforms_to_legacy_engine(backbone, agg, k):
    cfg = _cfg(backbone, agg, faults={})        # default block = degraded
    data, mcfg, sampler = _setup(cfg)
    assert mcfg.fault_tolerant and not cfg.faults.active
    mcfg_legacy = _cfg(backbone, agg).glasu_config(data)
    opt = cfg.make_optimizer()
    params = glasu.init_params(jax.random.PRNGKey(cfg.seed), mcfg)
    rounds = _sample_rounds(sampler, ROUNDS)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(1), jnp.arange(ROUNDS))
    plans = _degraded_plans(mcfg.n_clients, ROUNDS)
    analytic = sampler.comm_bytes_per_joint_inference(mcfg.hidden, mcfg.agg)

    vb0 = VmappedBackend()
    vb0.bind(mcfg_legacy, opt, sampler)
    p_ref, losses_ref, _ = _run(vb0, opt, params, rounds, keys, k)

    vb = VmappedBackend()
    vb.bind(mcfg, opt, sampler)
    p_f, losses_f, per_round = _run_f(vb, opt, params, rounds, keys, k, plans)
    # full participation: every delivered-only round prices the dense cost
    assert per_round == [analytic] * ROUNDS
    np.testing.assert_allclose(losses_f, losses_ref, **SHARD_TOL)
    _assert_trees_close(p_f, p_ref, **SHARD_TOL)

    sb = make_backend("sharded")
    sb.bind(mcfg, opt, sampler)
    p_sh, losses_sh, per_round_sh = _run_f(sb, opt, params, rounds, keys, k,
                                           plans)
    assert per_round_sh == per_round
    np.testing.assert_allclose(losses_sh, losses_ref, **SHARD_TOL)
    _assert_trees_close(p_sh, p_ref, **SHARD_TOL)

    if agg == "mean":                   # simulation implements mean only
        p_ref2, losses_ref2, _ = _run(vb0, opt, params, rounds[:2],
                                      keys[:2], 1)
        mb = SimulationBackend()
        mb.bind(mcfg, opt, sampler)
        p_sim, losses_sim, per_round_sim = _run_f(
            mb, opt, params, rounds[:2], keys[:2], 1, plans[:2])
        assert per_round_sim == per_round[:2]
        np.testing.assert_allclose(losses_sim, losses_ref2, **SIM_TOL)
        _assert_trees_close(p_sim, p_ref2, **SIM_TOL)


# ------------------------------------------------ compressed exchange rows
# Quantization amplifies compilation-level ULP noise (a last-ULP input
# difference can flip a round-to-nearest bucket and move the decoded value
# by a whole quantization step), so compressed cross-backend rows are
# pinned at a tolerance one class looser than SHARD_TOL — still far
# tighter than any protocol bug (wrong index/reduction) would produce.
COMP_TOL = dict(rtol=2e-4, atol=2e-4)

COMP_GRID = [("int8", {}), ("fp8", {}), ("topk_ef", {"k": 2})]


@pytest.mark.parametrize("method,kw", COMP_GRID)
@pytest.mark.parametrize("k", [1, pytest.param(4, marks=pytest.mark.slow)])
def test_compressed_sharded_conforms_to_vmapped(method, kw, k):
    """Compressed rows of the backend grid: trained params, losses, and
    byte meters agree between the vmapped engine and the sharded engine
    (which encodes BEFORE its all_gather — the collective itself moves the
    wire payload), with the EF carry threaded through both scans."""
    cfg = _cfg("gcnii", "mean", compression=dict(method=method, **kw))
    data, mcfg, sampler = _setup(cfg)
    opt = cfg.make_optimizer()
    params = glasu.init_params(jax.random.PRNGKey(cfg.seed), mcfg)
    rounds = _sample_rounds(sampler, ROUNDS)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(1), jnp.arange(ROUNDS))
    comp = make_compressor(mcfg.compression)
    analytic = sampler.comm_bytes_per_joint_inference(
        mcfg.hidden, mcfg.agg, compressor=comp)
    dense = sampler.comm_bytes_per_joint_inference(mcfg.hidden, mcfg.agg)
    assert analytic < dense

    vb = VmappedBackend()
    vb.bind(mcfg, opt, sampler)
    p_ref, losses_ref, comm_ref = _run(vb, opt, params, rounds, keys, k)
    assert comm_ref == analytic

    sb = make_backend("sharded")
    sb.bind(mcfg, opt, sampler)      # bind-time audit vs the message log
    p_sh, losses_sh, comm_sh = _run(sb, opt, params, rounds, keys, k)
    assert comm_sh == comm_ref
    np.testing.assert_allclose(losses_sh, losses_ref, **COMP_TOL)
    _assert_trees_close(p_sh, p_ref, **COMP_TOL)


def test_compressed_concat_sharded_conforms_to_vmapped():
    """concat aggregation compresses the widened (n, M*h) broadcast too."""
    cfg = _cfg("gcn", "concat", compression={"method": "int8"})
    data, mcfg, sampler = _setup(cfg)
    opt = cfg.make_optimizer()
    params = glasu.init_params(jax.random.PRNGKey(cfg.seed), mcfg)
    rounds = _sample_rounds(sampler, 2)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(1), jnp.arange(2))
    vb = VmappedBackend()
    vb.bind(mcfg, opt, sampler)
    p_ref, losses_ref, comm_ref = _run(vb, opt, params, rounds, keys, 1)
    sb = make_backend("sharded")
    sb.bind(mcfg, opt, sampler)
    p_sh, losses_sh, comm_sh = _run(sb, opt, params, rounds, keys, 1)
    assert comm_sh == comm_ref > 0
    np.testing.assert_allclose(losses_sh, losses_ref, **COMP_TOL)
    _assert_trees_close(p_sh, p_ref, **COMP_TOL)


def test_compressed_collective_meter_agrees_with_message_log():
    """Compressed sharded byte meter: the trace-recorded collectives carry
    the WIRE sizes of the encoded payloads and still audit term-by-term
    against the simulation backend's compressed message log."""
    cfg = _cfg("gcnii", "mean", compression={"method": "topk_ef", "k": 2})
    data, mcfg, sampler = _setup(cfg)
    opt = cfg.make_optimizer()
    sb = make_backend("sharded")
    sb.bind(mcfg, opt, sampler)
    assert len(sb.collectives) == len(mcfg.agg_layers)
    dense_star = sum(r.n_clients * r.n_rows * (r.width_up + r.width_down)
                     * r.itemsize for r in sb.collectives)
    assert sum(r.star_bytes() for r in sb.collectives) < dense_star

    params = glasu.init_params(jax.random.PRNGKey(cfg.seed), mcfg)
    batch = jax.tree.map(jnp.array, sampler.sample_round())
    mb = SimulationBackend()
    mb.bind(mcfg, opt, sampler)
    out = mb.run_round(params, opt.init(params), batch,
                       jax.random.PRNGKey(0))
    log = out.message_log
    assert sum(r.star_bytes() for r in sb.collectives) == \
        log.total_bytes("upload") + log.total_bytes("broadcast")
    assert sb.bytes_per_round == log.total_bytes()


@pytest.mark.slow
def test_compressed_trainer_sharded_matches_vmapped_run():
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = _cfg("gcnii", "mean", eval_every=2, optimizer="adam",
               compression={"method": "int8", "error_feedback": True})
    res_v = Trainer(cfg, data=data).run()
    res_s = Trainer(cfg.with_(backend="sharded"), data=data).run()
    assert res_s.comm_bytes == res_v.comm_bytes > 0
    np.testing.assert_allclose(
        [h["loss"] for h in res_s.history],
        [h["loss"] for h in res_v.history], **COMP_TOL)
    _assert_trees_close(res_s.params, res_v.params, **COMP_TOL)


# ----------------------------------------------------------- trainer E2E
@pytest.mark.slow
def test_trainer_sharded_matches_vmapped_run():
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = _cfg("gcnii", "mean", eval_every=2, optimizer="adam")
    res_v = Trainer(cfg, data=data).run()
    res_s = Trainer(cfg.with_(backend="sharded"), data=data).run()
    assert res_s.rounds_run == res_v.rounds_run == ROUNDS
    assert res_s.comm_bytes == res_v.comm_bytes > 0
    assert [h["round"] for h in res_s.history] == \
        [h["round"] for h in res_v.history]
    np.testing.assert_allclose(
        [h["loss"] for h in res_s.history],
        [h["loss"] for h in res_v.history], rtol=1e-5, atol=1e-6)
    _assert_trees_close(res_s.params, res_v.params, **SHARD_TOL)


@pytest.mark.slow
def test_trainer_sharded_multi_round_step():
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = _cfg("gcnii", "mean", eval_every=2,
               optimizer="adam").with_(backend="sharded",
                                                    rounds_per_step=4)
    res = Trainer(cfg, data=data).run()
    assert res.rounds_run == ROUNDS
    assert np.isfinite(res.history[-1]["loss"])


# --------------------------------------------------------------- serving
# Served answers must agree with the direct full_forward evaluator at the
# query rows, across engines and codecs, and with/without the hot-node
# cache in the path. int8 quantization of the embedding exchange moves
# logits by ~1e-2 on these shapes (a codec property, not an engine
# divergence), so compressed-vs-EXACT rows get their own tolerance class;
# compressed engine-vs-engine stays at COMP_TOL.
SERVE_CODEC_TOL = dict(rtol=5e-2, atol=5e-2)


@pytest.fixture(scope="module")
def serve_ckpt(tmp_path_factory):
    from repro.serve import InferenceSession, ServeConfig  # noqa: F401
    d = tmp_path_factory.mktemp("serve-conf")
    cfg = _cfg("gcnii", "mean", optimizer="adam",
               ckpt_dir=str(d), ckpt_every=0)
    data = make_vfl_dataset(cfg.dataset, n_clients=cfg.n_clients,
                            seed=cfg.seed)
    Trainer(cfg, data=data).run()
    from repro.core.train import _eval_tables
    feats, nbr_idx, nbr_mask = _eval_tables(data, cfg.eval_table_cap,
                                            cfg.seed)
    from repro.core import checkpoint
    r = checkpoint.load_for_inference(str(d), data=data)
    full = np.asarray(glasu.full_forward(r.params, cfg.glasu_config(data),
                                         feats, nbr_idx, nbr_mask))
    return str(d), data, full


SERVE_ENGINES = ["vmapped",
                 pytest.param("sharded", marks=pytest.mark.slow)]


@pytest.mark.parametrize("engine", SERVE_ENGINES)
def test_served_answers_conform_to_full_forward(serve_ckpt, engine):
    from repro.serve import InferenceSession, ServeConfig
    d, data, full = serve_ckpt
    q = np.array([3, 7, 50, 200])
    s = InferenceSession.from_checkpoint(
        d, data=data, serve=ServeConfig(max_batch=8, engine=engine))
    cold = s.answer(q)                       # uncached: fresh exchange
    assert cold.cold and cold.wire_bytes > 0
    np.testing.assert_allclose(cold.per_client, full[:, q], **COMP_TOL)
    np.testing.assert_allclose(cold.logits, full.mean(0)[q], **COMP_TOL)
    cached = s.answer(q)                     # cached: no exchange at all
    assert not cached.cold and cached.wire_bytes == 0
    np.testing.assert_allclose(cached.logits, full.mean(0)[q], **COMP_TOL)
    # partial overlap exercises cache injection mid-plan
    q2 = np.array([7, 50, 99, 123])
    mixed = s.answer(q2)
    np.testing.assert_allclose(mixed.logits, full.mean(0)[q2], **COMP_TOL)


@pytest.mark.parametrize("engine", SERVE_ENGINES)
def test_served_compressed_answers_conform(serve_ckpt, engine):
    from repro.serve import InferenceSession, ServeConfig
    d, data, full = serve_ckpt
    q = np.array([3, 7, 50, 200])
    s = InferenceSession.from_checkpoint(
        d, data=data, serve=ServeConfig(max_batch=8, engine=engine),
        compression={"method": "int8"})
    ans = s.answer(q)
    np.testing.assert_allclose(ans.logits, full.mean(0)[q],
                               **SERVE_CODEC_TOL)
    assert (ans.preds == np.argmax(full.mean(0)[q], -1)).all()
    dense = InferenceSession.from_checkpoint(
        d, data=data, serve=ServeConfig(max_batch=8, engine=engine))
    dense_ans = dense.answer(q)
    assert dict(ans.fresh_rows) == dict(dense_ans.fresh_rows)
    assert ans.wire_bytes < dense_ans.wire_bytes / 2   # codec actually paid


@pytest.mark.slow
def test_served_compressed_engines_agree(serve_ckpt):
    from repro.serve import InferenceSession, ServeConfig
    d, data, _ = serve_ckpt
    q = np.array([3, 7, 50, 200])
    outs = {}
    for engine in ("vmapped", "sharded"):
        s = InferenceSession.from_checkpoint(
            d, data=data, serve=ServeConfig(max_batch=8, engine=engine),
            compression={"method": "int8"})
        outs[engine] = s.answer(q)
    np.testing.assert_allclose(outs["sharded"].per_client,
                               outs["vmapped"].per_client, **COMP_TOL)


@pytest.mark.parametrize("engine", SERVE_ENGINES)
def test_served_repeat_query_bitwise(serve_ckpt, engine):
    from repro.serve import InferenceSession, ServeConfig
    d, data, _ = serve_ckpt
    q = np.array([5, 6, 7])
    s = InferenceSession.from_checkpoint(
        d, data=data, serve=ServeConfig(max_batch=8, engine=engine))
    first, second, third = s.answer(q), s.answer(q), s.answer(q)
    # cold -> warm and warm -> warm: bitwise at fixed params_version
    np.testing.assert_array_equal(first.logits, second.logits)
    np.testing.assert_array_equal(second.logits, third.logits)
    np.testing.assert_array_equal(first.per_client, second.per_client)


# -------------------------------------------- glint layer-3 runtime guards
def test_backend_step_dispatch_guarded(retrace_guard, transfer_guard):
    """One compile per (K, shapes) signature and zero implicit host traffic
    on the warm run_step path (inputs staged explicitly up front)."""
    cfg = _cfg("gcn", "mean")
    data, mcfg, sampler = _setup(cfg)
    opt = cfg.make_optimizer()
    params = glasu.init_params(jax.random.PRNGKey(cfg.seed), mcfg)
    rounds = _sample_rounds(sampler, 3)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(1), jnp.arange(3))
    vb = VmappedBackend()
    vb.bind(mcfg, opt, sampler)
    staged = [jax.device_put(jax.tree.map(jnp.asarray, stack_rounds([r])))
              for r in rounds]
    # pre-sliced key stacks: eager slicing inside the guard would upload
    # its index scalars and (correctly) trip it
    key_slices = [keys[t:t + 1] for t in range(3)]
    p = jax.tree.map(jnp.array, params)
    out = vb.run_step(p, opt.init(p), staged[0], key_slices[0])   # warmup
    retrace_guard.watch(vb.step_fn, "vmapped.step_fn")
    with transfer_guard():
        for t in range(1, 3):
            out = vb.run_step(out.params, out.opt_state, staged[t],
                              key_slices[t])
    assert np.asarray(out.losses).shape[0] == 1
