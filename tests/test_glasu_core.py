"""GLASU algorithm invariants (unit + integration + hypothesis property)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import glasu
from repro.core.glasu import GlasuConfig
from repro.core.train import TrainConfig, make_centralized_dataset, train_glasu
from repro.graph.sampler import GlasuSampler, SamplerConfig
from repro.graph.synth import make_vfl_dataset
from repro.optim import optimizers as opt_lib


def _setup(backbone="gcnii", agg="mean", agg_layers=(1, 3), m=3, q=1, seed=0):
    data = make_vfl_dataset("tiny", n_clients=m, seed=seed)
    d_in = max(c.feat_dim for c in data.clients)
    mcfg = GlasuConfig(n_clients=m, n_layers=4, hidden=16,
                       n_classes=data.n_classes, d_in=d_in, backbone=backbone,
                       agg=agg, agg_layers=agg_layers, n_local_steps=q)
    scfg = SamplerConfig(n_layers=4, agg_layers=agg_layers, batch_size=8,
                         fanout=3, size_cap=96)
    sampler = GlasuSampler(data, scfg, seed=seed)
    params = glasu.init_params(jax.random.PRNGKey(seed), mcfg)
    batch = jax.tree.map(jnp.asarray, sampler.sample_round())
    return data, mcfg, sampler, params, batch


def test_extract_consistency_mean():
    """Alg 3/4 core algebra: Agg(Extract(H, H_m+), H_m+) == H for every m.

    The local forward at q=0 (fresh own representation + stale others) must
    exactly reconstruct the joint-inference activations and logits.
    """
    _, cfg, _, params, batch = _setup()
    joint_logits, stale = glasu.joint_inference(params, batch, cfg)
    for m in range(cfg.n_clients):
        pm = jax.tree.map(lambda v: v[m], params)
        sm = {l: v[m] for l, v in stale.items()}
        local_logits = glasu._client_trunk(cfg, pm, batch.feats[m], batch, m, sm)
        np.testing.assert_allclose(np.asarray(local_logits),
                                   np.asarray(joint_logits[m]),
                                   rtol=1e-5, atol=1e-5)


def test_extract_consistency_concat():
    _, cfg, _, params, batch = _setup(backbone="gcn", agg="concat")
    joint_logits, stale = glasu.joint_inference(params, batch, cfg)
    for m in range(cfg.n_clients):
        pm = jax.tree.map(lambda v: v[m], params)
        sm = {l: v[m] for l, v in stale.items()}
        local_logits = glasu._client_trunk(cfg, pm, batch.feats[m], batch, m, sm)
        np.testing.assert_allclose(np.asarray(local_logits),
                                   np.asarray(joint_logits[m]),
                                   rtol=1e-5, atol=1e-5)


def test_secure_agg_masks_cancel():
    """§3.6: pairwise-cancelling masks leave the mean aggregate unchanged."""
    _, cfg, _, params, batch = _setup()
    cfg_sa = GlasuConfig(**{**cfg.__dict__, "secure_agg": True})
    logits, _ = glasu.joint_inference(params, batch, cfg)
    logits_sa, _ = glasu.joint_inference(params, batch, cfg_sa,
                                         key=jax.random.PRNGKey(7))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_sa),
                               rtol=2e-4, atol=2e-4)


def test_dp_noise_changes_aggregate():
    _, cfg, _, params, batch = _setup()
    cfg_dp = GlasuConfig(**{**cfg.__dict__, "dp_sigma": 0.5})
    logits, _ = glasu.joint_inference(params, batch, cfg)
    logits_dp, _ = glasu.joint_inference(params, batch, cfg_dp,
                                         key=jax.random.PRNGKey(7))
    assert float(jnp.max(jnp.abs(logits - logits_dp))) > 1e-3


@pytest.mark.slow
def test_fedbcd_special_case_no_graph():
    """§3.5: with A(E_m) = I (no edges) GLASU reduces to FedBCD — the layer
    aggregation sees only the self loop."""
    data = make_vfl_dataset("tiny", n_clients=2, seed=3)
    # erase edges: keep only self-loops via empty neighbor tables
    for c in data.clients:
        c.indptr = np.zeros(c.n_nodes + 1, np.int32)
        c.indices = np.zeros(0, np.int32)
    d_in = max(c.feat_dim for c in data.clients)
    mcfg = GlasuConfig(n_clients=2, n_layers=2, hidden=16,
                       n_classes=data.n_classes, d_in=d_in, backbone="gcn",
                       agg_layers=(1,), n_local_steps=2)
    scfg = SamplerConfig(n_layers=2, agg_layers=(1,), batch_size=8, fanout=2,
                         size_cap=64)
    res = train_glasu(data, mcfg, scfg,
                      TrainConfig(rounds=10, eval_every=5, lr=0.02))
    assert res.history[-1]["loss"] < 2.0   # trains without graph structure


def test_q_steps_update_params_q_times():
    _, cfg, sampler, params, batch = _setup(q=3)
    opt = opt_lib.sgd(0.1)
    state = opt.init(params)
    round_fn = glasu.make_round_fn(cfg, opt)
    p2, state, losses = round_fn(params, state, batch, jax.random.PRNGKey(0))
    assert losses.shape == (3,)
    assert int(state.step) == 3


def test_stale_updates_match_paper_semantics():
    """During q>0 the OTHER clients' contribution stays frozen: client m's
    local update changes only its own slice of the next joint aggregate."""
    _, cfg, _, params, batch = _setup()
    _, stale = glasu.joint_inference(params, batch, cfg)
    # perturb client 0's params; stale buffers for client 1 must be unchanged
    params2 = jax.tree.map(lambda v: v, params)
    params2["inp"]["W"] = params2["inp"]["W"].at[0].add(1.0)
    _, stale2 = glasu.joint_inference(params2, batch, cfg)
    # At the FIRST aggregation layer: stale_0 = mean_m(h_m) - h_0/M contains
    # no h_0 term, so perturbing client 0 leaves stale_0 unchanged while
    # stale_1 (which includes h_0/M) must change. At later aggregation layers
    # client 0 leaks into everyone through the earlier shared aggregate.
    l = min(stale.keys())
    d0 = float(jnp.max(jnp.abs(stale[l][0] - stale2[l][0])))
    d1 = float(jnp.max(jnp.abs(stale[l][1] - stale2[l][1])))
    assert d0 < 1e-5 and d1 > 1e-4


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(2, 4),
       k=st.sampled_from([1, 2, 4]))
def test_sampler_invariants(seed, m, k):
    data = make_vfl_dataset("tiny", n_clients=m, seed=seed % 5)
    agg = {1: (3,), 2: (1, 3), 4: (0, 1, 2, 3)}[k]
    scfg = SamplerConfig(n_layers=4, agg_layers=agg, batch_size=8, fanout=2,
                         size_cap=96)
    sampler = GlasuSampler(data, scfg, seed=seed)
    b = sampler.sample_round()
    for l in range(4):
        n_next = sampler.layer_sizes[l + 1]
        assert b.gather_idx[l].shape == (m, n_next, 3)
        # indices always in range of layer-l set
        assert int(b.gather_idx[l].max()) < sampler.layer_sizes[l]
        assert int(b.gather_idx[l].min()) >= 0
        # masked entries -> zero weight; valid rows have a valid self column
        valid = b.row_valid[l] > 0
        assert np.all(b.gather_mask[l][valid][:, 0] == 1.0)
    # shared node sets at aggregation boundaries: gather targets of layer l+1
    # use identical position spaces across clients — verified structurally by
    # equality of layer sizes (padding identical) and identical batch labels
    assert b.labels.shape == (8,)


def test_comm_meter_matches_qlk_formula():
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    hidden = 16
    byts = {}
    for k, agg in [(4, (0, 1, 2, 3)), (2, (1, 3)), (1, (3,))]:
        scfg = SamplerConfig(n_layers=4, agg_layers=agg, batch_size=8,
                             fanout=2, size_cap=96)
        byts[k] = GlasuSampler(data, scfg, seed=0) \
            .comm_bytes_per_joint_inference(hidden)
    # more aggregation layers => strictly more bytes, roughly linear in K
    assert byts[4] > byts[2] > byts[1]
    ratio = byts[4] / byts[2]
    assert 1.3 < ratio < 3.5


def test_centralized_equals_m1():
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cdata = make_centralized_dataset(data)
    assert cdata.n_clients == 1
    assert cdata.clients[0].feat_dim == data.full.feat_dim
    assert cdata.clients[0].n_edges == data.full.n_edges


@pytest.mark.slow
def test_label_at_one_client_gradient_equivalence():
    """Appendix B.2 eq.(3): the broadcast-gradient surrogate gives every
    non-owner client EXACTLY the gradient of the owner's end-to-end loss."""
    _, cfg, _, params, batch = _setup()
    cfg1 = GlasuConfig(**{**cfg.__dict__, "labels_at_client": 0})
    _, stale = glasu.joint_inference(params, batch, cfg)
    g_hl = glasu.label_owner_grad(params, batch, stale, cfg1)

    # surrogate gradient for client 1
    def surrogate(params_m):
        h = glasu._client_trunk(cfg1, params_m, batch.feats[1], batch, 1,
                                {l: v[1] for l, v in stale.items()},
                                return_hidden=True)
        return jnp.sum(jax.lax.stop_gradient(g_hl) * h)

    p1 = jax.tree.map(lambda v: v[1], params)
    g_sur = jax.grad(surrogate)(p1)

    # reference: end-to-end grad of client-0's loss wrt client-1's weights,
    # holding the stale buffers fixed (the local-update computational graph)
    def owner_loss_via_client1(p1_vars):
        h1 = glasu._client_trunk(cfg1, p1_vars, batch.feats[1], batch, 1,
                                 {l: v[1] for l, v in stale.items()},
                                 return_hidden=True)
        # client 1's fresh H[L]; owner's classifier applied to it (shared
        # final representation per Appendix B.2 requirement K includes L-1)
        p0 = jax.tree.map(lambda v: v[0], params)
        logits = h1 @ p0["cls"]["W"] + p0["cls"]["b"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        return jnp.mean(-jnp.take_along_axis(logp, batch.labels[:, None],
                                             axis=1)[:, 0])

    g_ref = jax.grad(owner_loss_via_client1)(p1)
    for a, b in zip(jax.tree.leaves(g_sur), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


@pytest.mark.slow
def test_label_at_one_client_trains():
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    d_in = max(c.feat_dim for c in data.clients)
    mcfg = GlasuConfig(n_clients=3, n_layers=4, hidden=16,
                       n_classes=data.n_classes, d_in=d_in, backbone="gcnii",
                       agg_layers=(1, 3), n_local_steps=2, labels_at_client=0)
    scfg = SamplerConfig(n_layers=4, agg_layers=(1, 3), batch_size=8,
                         fanout=3, size_cap=96)
    res = train_glasu(data, mcfg, scfg,
                      TrainConfig(rounds=25, eval_every=25, lr=0.02))
    assert res.test_acc > 0.5


@pytest.mark.parametrize("backbone", ["gcn", "gcnii", "gat"])
def test_pallas_backed_backbone_matches_jnp(backbone):
    """use_pallas=True swaps the client sub-layer onto the fused Pallas
    kernels for ALL three paper backbones; joint inference must match the
    pure-jnp path."""
    _, cfg, _, params, batch = _setup(backbone=backbone)
    cfg_k = GlasuConfig(**{**cfg.__dict__, "use_pallas": True})
    logits, _ = glasu.joint_inference(params, batch, cfg)
    logits_k, _ = glasu.joint_inference(params, batch, cfg_k)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_k),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.slow
@pytest.mark.parametrize("backbone", ["gcn", "gcnii", "gat"])
def test_pallas_backed_training_round_matches_jnp(backbone):
    """A full training round (JointInference + LocalUpdate gradients) through
    the fused kernels stays on the jnp trajectory — the custom_vjp backward
    is exact up to float32 reassociation."""
    _, cfg, _, params, batch = _setup(backbone=backbone)
    cfg_k = GlasuConfig(**{**cfg.__dict__, "use_pallas": True})
    opt = opt_lib.sgd(0.05)              # sgd: no adaptive noise amplification
    state = opt.init(params)
    p_j, _, loss_j = glasu.make_round_fn(cfg, opt)(
        params, state, batch, jax.random.PRNGKey(0))
    p_k, _, loss_k = glasu.make_round_fn(cfg_k, opt)(
        params, state, batch, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(loss_j), np.asarray(loss_k),
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(p_j), jax.tree.leaves(p_k)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
