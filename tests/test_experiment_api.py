"""Unified experiment API: config validation, derived fields, round-trip,
presets, optimizer consolidation, and checkpoint-hook resume."""
import json

import pytest

from repro.api import ExperimentConfig, Trainer, get_preset, list_presets
from repro.api.config import agg_layers_for_k
from repro.configs.base import GNN_ARCH_IDS, get_gnn_arch, get_gnn_reduced
from repro.core.steps import make_optimizer as steps_make_optimizer
from repro.core.train import TrainConfig
from repro.core.train import make_optimizer as train_make_optimizer
from repro.graph.synth import make_vfl_dataset
from repro.optim import optimizers as opt_lib

TINY = ExperimentConfig(name="tiny-exp", dataset="tiny", hidden=16,
                        batch_size=8, size_cap=96, rounds=4, eval_every=2,
                        lr=0.02)


# ------------------------------------------------------------- validation
def test_missing_prediction_layer_aggregation_rejected():
    with pytest.raises(ValueError, match="prediction-layer"):
        ExperimentConfig(n_layers=4, agg_layers=(0, 2))


def test_mismatched_n_clients_rejected_at_bind():
    data = make_vfl_dataset("tiny", n_clients=2, seed=0)
    with pytest.raises(ValueError, match="mismatched n_clients"):
        TINY.glasu_config(data)  # TINY expects 3 model clients


@pytest.mark.parametrize("kw,msg", [
    (dict(method="nope"), "unknown method"),
    (dict(backend="grpc"), "unknown backend"),
    (dict(optimizer="lion"), "unknown optimizer"),
    (dict(agg="concat", backbone="gcnii"), "concat"),
    (dict(method="simulated-centralized", agg_layers=None, n_local_steps=4),
     "Q == 1"),
    (dict(method="standalone", agg_layers=(1, 3)), "no communication"),
    (dict(labels_at_client=7), "out of range"),
    (dict(backend="simulation", dp_sigma=0.5), "privacy"),
    (dict(agg_layers=(1, 5)), "out of range"),
    (dict(n_local_steps=0), "Q"),
])
def test_cross_field_validation(kw, msg):
    with pytest.raises(ValueError, match=msg):
        TINY.with_(**kw)


def test_explicit_k_must_match_explicit_agg_layers():
    with pytest.raises(ValueError, match="inconsistent"):
        ExperimentConfig(dataset="tiny", k=3, agg_layers=(1, 3))


# ---------------------------------------------------------- derived fields
def test_with_rederives_agg_layers_on_scenario_change():
    glasu = get_preset("cora-gcnii-glasu")
    assert glasu.with_(k=1).agg_layers == (3,)
    assert glasu.with_(method="standalone").agg_layers == ()
    assert glasu.with_(n_layers=6).agg_layers == agg_layers_for_k(6, 3)
    # explicit agg_layers in the same call wins over re-derivation
    assert glasu.with_(n_layers=2, agg_layers=(1,)).agg_layers == (1,)


def test_agg_layers_derived_by_method():
    assert TINY.agg_layers == (1, 3)                        # K = L/2 uniform
    assert TINY.with_(agg_layers=None, k=1).agg_layers == (3,)
    assert TINY.with_(method="standalone", agg_layers=None).agg_layers == ()
    sim = TINY.with_(method="simulated-centralized", agg_layers=None)
    assert sim.agg_layers == (0, 1, 2, 3)
    assert agg_layers_for_k(6, 3) == (1, 3, 5)


def test_method_specific_derivations():
    fedbcd = TINY.with_(method="fedbcd")
    assert fedbcd.resolved_fanout == 0                      # A(E_m) = I
    assert fedbcd.sampler_config().fanout == 0
    assert fedbcd.fanout == TINY.fanout                     # field preserved...
    assert fedbcd.with_(method="glasu").resolved_fanout == TINY.fanout  # ...so
    # switching back to a graph-based method restores real sampling
    cent = TINY.with_(method="centralized")
    assert cent.model_clients == 1 and cent.n_clients == 3
    assert TINY.resolved_eval_mode == "ensemble"
    stal = TINY.with_(method="standalone", agg_layers=None)
    assert stal.resolved_eval_mode == "per_client"
    assert stal.sampler_agg_layers == (3,)      # shared mini-batch S[L] only


def test_sampler_and_model_configs_are_consistent():
    scfg = TINY.sampler_config()
    assert scfg.n_layers == TINY.n_layers
    assert tuple(scfg.agg_layers) == TINY.agg_layers
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    mcfg = TINY.glasu_config(data)
    assert mcfg.d_in == max(c.feat_dim for c in data.clients)
    assert mcfg.n_classes == data.n_classes
    assert mcfg.agg_layers == TINY.agg_layers
    assert TINY.train_config().eval_mode == "ensemble"


def test_from_legacy_accepts_unsorted_and_rejects_mismatch():
    from repro.core.glasu import GlasuConfig
    from repro.graph.sampler import SamplerConfig

    mk = dict(n_clients=3, n_layers=4, hidden=16, n_classes=4, d_in=16)
    # unsorted but equal schedules are fine (membership-only semantics)
    cfg = ExperimentConfig.from_legacy(
        GlasuConfig(**mk, agg_layers=(3, 1)),
        SamplerConfig(n_layers=4, agg_layers=(3, 1)), TrainConfig())
    assert cfg.agg_layers == (1, 3)
    # standalone with a sampler that shares more than the mini-batch is loud
    with pytest.raises(ValueError, match="mismatched agg_layers"):
        ExperimentConfig.from_legacy(
            GlasuConfig(**mk, agg_layers=()),
            SamplerConfig(n_layers=4, agg_layers=(1, 3)), TrainConfig())


# --------------------------------------------------------------- round-trip
def test_to_dict_from_dict_roundtrip():
    for cfg in (TINY, TINY.with_(method="standalone", agg_layers=None),
                get_preset("pubmed-gat-fedbcd")):
        d = json.loads(json.dumps(cfg.to_dict()))   # must be JSON-serializable
        assert ExperimentConfig.from_dict(d) == cfg


def test_from_dict_rejects_unknown_fields():
    d = TINY.to_dict()
    d["n_epochs"] = 10
    with pytest.raises(ValueError, match="unknown fields"):
        ExperimentConfig.from_dict(d)


# ------------------------------------------------------------------ presets
def test_preset_grid_complete():
    names = list_presets()
    # 3 datasets x 3 backbones x 5 methods + the powerlaw-1m scale profile
    assert len(names) == 46
    assert "cora-gcnii-glasu" in names
    assert "powerlaw1m-gcn-glasu" in names
    scale = get_preset("powerlaw1m-gcn-glasu")
    assert scale.eval_every == 0 and scale.dataset == "powerlaw-1m"
    glasu = get_preset("cora-gcnii-glasu")
    assert glasu.n_local_steps == 4 and glasu.agg_layers == (1, 3)
    assert get_preset("citeseer-gcn-standalone").agg_layers == ()
    with pytest.raises(ValueError, match="unknown preset"):
        get_preset("cora-gcnii-magic")


def test_gnn_arch_ids_resolve_to_real_modules():
    for arch_id in GNN_ARCH_IDS:
        cfg = get_gnn_arch(arch_id)
        assert isinstance(cfg, ExperimentConfig) and cfg.name == arch_id
        red = get_gnn_reduced(arch_id)
        assert red.dataset == "tiny" and red.hidden < cfg.hidden


# ------------------------------------------------- optimizer consolidation
def test_make_optimizer_union_of_names():
    for name in opt_lib.OPTIMIZER_NAMES:
        opt = opt_lib.make_optimizer(name, 0.1)
        assert isinstance(opt, opt_lib.Optimizer)
    with pytest.raises(ValueError, match="unknown optimizer"):
        opt_lib.make_optimizer("lion", 0.1)


def test_legacy_factories_delegate():
    # legacy lenient behavior preserved: unknown names fall back
    assert isinstance(train_make_optimizer(TrainConfig(optimizer="mystery")),
                      opt_lib.Optimizer)

    class _ArchStub:
        optimizer = "sgd"
        lr = 0.1

    assert isinstance(steps_make_optimizer(_ArchStub()), opt_lib.Optimizer)


# ------------------------------------------------------- checkpoint resume
@pytest.mark.slow
def test_trainer_checkpoint_save_and_resume(tmp_path):
    import jax
    import numpy as np

    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = TINY.with_(rounds=2, ckpt_dir=str(tmp_path))
    res = Trainer(cfg, data=data).run()
    assert res.rounds_run == 2
    assert (tmp_path / "experiment.json").exists()
    assert (tmp_path / "LATEST").read_text().strip() == "2"

    # resume with extended schedule: fast-forwards past round 2 and must be
    # indistinguishable from an uninterrupted 4-round run (same sampler
    # stream, same keys, history carried over)
    res2 = Trainer(cfg.with_(rounds=4), data=data).run()
    assert res2.rounds_run == 4
    assert (tmp_path / "LATEST").read_text().strip() == "4"
    assert [h["round"] for h in res2.history] == [2, 4]
    uninterrupted = Trainer(TINY.with_(rounds=4), data=data).run()
    for a, b in zip(jax.tree.leaves(res2.params),
                    jax.tree.leaves(uninterrupted.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)

    # a state-shaping field may NOT change across a resume
    with pytest.raises(ValueError, match="different experiment config"):
        Trainer(cfg.with_(rounds=6, hidden=32), data=data).run()


@pytest.mark.slow
def test_resume_restores_wall_clock_baseline(tmp_path):
    """Post-restore history entries must continue the restored wall clock:
    'seconds' stays monotonic across the resume boundary instead of
    resetting to ~0."""
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = TINY.with_(rounds=2, eval_every=1, ckpt_dir=str(tmp_path))
    res = Trainer(cfg, data=data).run()
    assert len(res.history) == 2
    res2 = Trainer(cfg.with_(rounds=4), data=data).run()
    secs = [h["seconds"] for h in res2.history]
    assert [h["round"] for h in res2.history] == [1, 2, 3, 4]
    assert all(a <= b for a, b in zip(secs, secs[1:])), secs
    # the first post-resume entry includes the restored elapsed time
    assert secs[2] >= secs[1]
    sidecar = json.loads((tmp_path / "state_00000004.json").read_text())
    assert sidecar["elapsed_seconds"] >= secs[-1] > 0.0


def test_rounds_zero_is_eval_only(tmp_path):
    """rounds == 0 must not crash on the missing loss: the run evaluates the
    initial parameters and reports a single history entry."""
    import math
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    res = Trainer(TINY.with_(rounds=0), data=data).run()
    assert res.rounds_run == 0
    assert len(res.history) == 1
    assert res.history[0]["round"] == 0
    assert math.isnan(res.history[0]["loss"])
    assert 0.0 <= res.history[0]["val_acc"] <= 1.0


def test_resume_landing_on_final_round_does_not_crash(tmp_path):
    """A resume that fast-forwards exactly to cfg.rounds runs zero new
    rounds; st.last_losses is None and the final history entry must already
    exist (no duplicate, no crash)."""
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = TINY.with_(rounds=2, ckpt_dir=str(tmp_path))
    Trainer(cfg, data=data).run()
    res = Trainer(cfg, data=data).run()    # resumes at round 2 == rounds
    assert res.rounds_run == 2
    assert [h["round"] for h in res.history] == [2]


def test_final_history_entry_when_stopped_between_cadences():
    """A hook stopping the run off the eval cadence still yields a final
    history entry for the round the run actually stopped at."""
    from repro.api.trainer import Hook

    class StopAtRound1(Hook):
        def on_round_end(self, trainer, metrics):
            if trainer.state.round >= 1:
                trainer.state.should_stop = True

    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = TINY.with_(rounds=4, eval_every=10)
    res = Trainer(cfg, data=data, hooks=[StopAtRound1()]).run()
    assert res.rounds_run == 1
    assert res.history[-1]["round"] == 1
    assert res.history[-1]["loss"] == res.history[-1]["loss"]  # not NaN
