"""Serving correctness: token-by-token decode through the per-layer caches
must reproduce the prefill (full-forward) predictions for every architecture
family — KV cache (GQA), latent cache (MLA), recurrent state (Mamba2/RWKV6),
hybrid group caches (Zamba2).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_reduced
from repro.models import transformer as tfm

DECODE_ARCHS = ["smollm_360m", "deepseek_v2_lite_16b", "zamba2_1p2b",
                "rwkv6_7b", "granite_20b", "phi35_moe_42b"]


def _greedy_from_prefill(params, cfg, tokens):
    logits, _ = tfm.lm_forward(params, cfg, tokens=tokens)
    return jnp.argmax(logits, axis=-1)      # (B, T) next-token at each pos


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", DECODE_ARCHS)
def test_decode_matches_prefill(arch_id):
    # ample MoE capacity so routing drops cannot differ between the prefill
    # and decode token populations (capacity semantics are tested separately)
    cfg = get_reduced(arch_id).with_(capacity_factor=8.0)
    t = 24
    b = 2
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(b, t)), jnp.int32)
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)

    want = _greedy_from_prefill(params, cfg, tokens)

    caches = tfm.init_caches(cfg, b, t)
    step = jax.jit(lambda c, tok: tfm.lm_decode_step(params, c, cfg, tok))
    got = []
    for i in range(t):
        nxt, caches = step(caches, tokens[:, i:i + 1])
        got.append(nxt)
    got = jnp.concatenate(got, axis=1)
    # argmax can differ on near-ties in f32; require >=90% agreement and
    # exact agreement on the final position
    agree = float(jnp.mean((got == want).astype(jnp.float32)))
    assert agree >= 0.9, f"decode/prefill agreement {agree:.2f}"
    np.testing.assert_array_equal(np.asarray(got[:, -1]),
                                  np.asarray(want[:, -1]))


@pytest.mark.slow
def test_decode_matches_prefill_encdec():
    cfg = get_reduced("seamless_m4t_large_v2")
    b, t, src = 2, 12, 8
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(b, t)), jnp.int32)
    src_emb = jnp.asarray(rng.normal(size=(b, src, cfg.d_model)), jnp.float32)
    params = tfm.init_lm(jax.random.PRNGKey(1), cfg)

    logits, _ = tfm.lm_forward(params, cfg, tokens=tokens, src_embeds=src_emb)
    want = jnp.argmax(logits, axis=-1)

    # encoder output (same path as lm_forward's encoder branch)
    from repro.models.layers import rmsnorm
    enc, _ = tfm._scan_stack(lambda p, h: (tfm.dense_block_bidir(p, h, cfg),),
                             params["enc"], src_emb, False)
    enc = rmsnorm(params["final_norm"], enc)

    caches = tfm.init_caches(cfg, b, t)
    got = []
    for i in range(t):
        nxt, caches = tfm.lm_decode_step(params, caches, cfg,
                                         tokens[:, i:i + 1], enc_out=enc)
        got.append(nxt)
    got = jnp.concatenate(got, axis=1)
    agree = float(jnp.mean((got == want).astype(jnp.float32)))
    assert agree >= 0.9


@pytest.mark.slow
def test_ring_cache_equals_full_cache_within_window():
    """Sliding-window ring buffer must agree with a full cache + window mask."""
    cfg = get_reduced("smollm_360m").with_(sliding_window=8)
    b, t = 1, 20
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(b, t)), jnp.int32)
    params = tfm.init_lm(jax.random.PRNGKey(2), cfg)

    ring = tfm.init_caches(cfg, b, t)            # capacity = window = 8 (ring)
    assert ring["blocks"].k.shape[2] == 8
    full_cfg = cfg.with_(sliding_window=None)
    full = tfm.init_caches(full_cfg, b, t)

    # reference: prefill logits with explicit window mask
    logits, _ = tfm.lm_forward(params, cfg, tokens=tokens)
    want = jnp.argmax(logits, axis=-1)

    got = []
    caches = ring
    for i in range(t):
        nxt, caches = tfm.lm_decode_step(params, caches, cfg,
                                         tokens[:, i:i + 1])
        got.append(nxt)
    got = jnp.concatenate(got, axis=1)
    agree = float(jnp.mean((got == want).astype(jnp.float32)))
    assert agree >= 0.9


@pytest.mark.slow
def test_glasu_split_decode_matches_prefill():
    """The vertical-split transformer's decode path (per-client KV caches for
    block-diagonal layers + full caches for sync layers) must agree with its
    prefill forward."""
    from repro.configs.base import ArchConfig, GlasuSplit
    cfg = ArchConfig(name="t", kind="dense", n_layers=4, d_model=64,
                     n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=128,
                     dtype="float32", remat=False,
                     glasu=GlasuSplit(n_clients=2, sync_every=2, local_steps=1))
    b, t = 2, 16
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(b, t)), jnp.int32)
    params = tfm.init_lm(jax.random.PRNGKey(3), cfg)
    logits, _ = tfm.lm_forward(params, cfg, tokens=tokens)
    want = jnp.argmax(logits, axis=-1)

    caches = tfm.init_caches(cfg, b, t)
    got = []
    for i in range(t):
        nxt, caches = tfm.lm_decode_step(params, caches, cfg,
                                         tokens[:, i:i + 1])
        got.append(nxt)
    got = jnp.concatenate(got, axis=1)
    agree = float(jnp.mean((got == want).astype(jnp.float32)))
    assert agree >= 0.9, agree
