"""Streamed feature store + power-law profile properties.

The million-node path has three contracts, each pinned here at the
``powerlaw-tiny`` scale (same code path, 4096 nodes):

  * ``MemmapFeatureStore`` gathers are bitwise-equal to the backing file,
    the LRU stays bounded, and whole-matrix materialization fails loudly;
  * sampler invariants on power-law graphs — sampled neighbor sets are
    subsets of the true neighborhoods, ``_build_set`` emits no duplicates,
    and the position LUT round-trips;
  * a streamed-store training round is bitwise-identical to the same round
    on fully materialized features, and runs under ``transfer_guard`` with
    no implicit host transfer inside the jitted round body.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.graph.feature_store import (MemmapFeatureStore, create_store,
                                       is_streamed)
from repro.graph.sampler import GlasuSampler, SamplerConfig
from repro.graph.synth import POWERLAW_SPECS, make_vfl_dataset


@pytest.fixture(scope="module")
def tiny_powerlaw(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("powerlaw"))
    return make_vfl_dataset("powerlaw-tiny", n_clients=2, seed=0), root


def _materialized_twin(data):
    """Same dataset with every streamed store replaced by the resident
    column block it views — the bitwise ground truth."""
    raw = np.load(data.full.features.path)
    def swap(g):
        lo, hi = g.features._cols
        return dataclasses.replace(g, features=raw[:, lo:hi].copy())
    return dataclasses.replace(
        data, clients=[swap(c) for c in data.clients], full=swap(data.full))


# ------------------------------------------------------------------ store
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), chunk_rows=st.integers(3, 40))
def test_store_gather_bitwise_equals_backing_file(seed, chunk_rows):
    # NOTE: the _hypothesis_compat fallback @given cannot compose with
    # pytest fixtures, so the temp dir comes from tempfile directly
    import tempfile
    rng = np.random.default_rng(seed)
    n, d = 257, 6                       # non-multiple of any chunk size
    path = os.path.join(tempfile.mkdtemp(prefix="repro_store_"),
                        f"s{seed}.npy")
    mm = create_store(path, n, d)
    ref = rng.normal(size=(n, d)).astype(np.float32)
    mm[:] = ref
    mm.flush()
    del mm
    store = MemmapFeatureStore(path, chunk_rows=chunk_rows, cache_chunks=3)
    rows = rng.integers(0, n, size=50)
    np.testing.assert_array_equal(store[rows], ref[rows])
    # repeated + shuffled gathers hit the LRU, stay bitwise
    np.testing.assert_array_equal(store[rows[::-1]], ref[rows[::-1]])
    # column views slice the same file without copying it
    lo, hi = 2, 5
    np.testing.assert_array_equal(store.view(lo, hi)[rows], ref[rows, lo:hi])
    # scalar + 2-D id gathers keep their shapes
    np.testing.assert_array_equal(store[int(rows[0])], ref[rows[0]])
    np.testing.assert_array_equal(store[rows.reshape(10, 5)],
                                  ref[rows].reshape(10, 5, d))


def test_store_lru_stays_bounded(tmp_path):
    path = os.path.join(str(tmp_path), "lru.npy")
    mm = create_store(path, 1000, 4)
    mm[:] = np.arange(4000, dtype=np.float32).reshape(1000, 4)
    mm.flush()
    del mm
    store = MemmapFeatureStore(path, chunk_rows=10, cache_chunks=3)
    for r0 in range(0, 1000, 10):       # touch all 100 chunks
        store[np.arange(r0, r0 + 10)]
    assert len(store._cache) <= store.cache_chunks == 3
    assert store.cache_misses == 100
    hits0 = store.cache_hits
    store[np.arange(990, 1000)]         # resident chunk: pure hit
    assert store.cache_hits == hits0 + 1 and store.cache_misses == 100
    store.drop_cache()
    assert len(store._cache) == 0


def test_store_fails_loudly_instead_of_materializing(tmp_path):
    path = os.path.join(str(tmp_path), "loud.npy")
    mm = create_store(path, 64, 4)
    mm[:] = 1.0
    mm.flush()
    del mm
    store = MemmapFeatureStore(path, chunk_rows=8, cache_chunks=2)
    with pytest.raises(TypeError, match="refusing to materialize"):
        np.asarray(store)
    with pytest.raises(IndexError, match="out of range"):
        store[np.array([0, 64])]
    with pytest.raises(IndexError, match="out of range"):
        store[np.array([-1])]
    # the sanctioned whole-matrix path reconstructs the file exactly
    full = np.concatenate([c for _, c in store.iter_chunks()])
    np.testing.assert_array_equal(full, np.load(path))


# -------------------------------------------------------------- power law
def test_powerlaw_graph_structural_invariants(tiny_powerlaw):
    data, _ = tiny_powerlaw
    spec = POWERLAW_SPECS["powerlaw-tiny"]
    g = data.full
    assert g.n_nodes == spec.n_nodes
    deg = g.degrees()
    assert deg.sum() == len(g.indices)
    assert g.indices.min() >= 0 and g.indices.max() < g.n_nodes
    assert deg.max() <= spec.max_deg + 1
    # undirected: the edge-key multiset is symmetric
    src = np.repeat(np.arange(g.n_nodes), deg)
    fwd = np.sort(src.astype(np.int64) * g.n_nodes + g.indices)  # glint: disable=GL003 edge-key packing needs 64-bit headroom; host-only
    rev = np.sort(g.indices.astype(np.int64) * g.n_nodes + src)  # glint: disable=GL003 edge-key packing needs 64-bit headroom; host-only
    np.testing.assert_array_equal(fwd, rev)
    # heavy-tailed: top-1% of nodes carry well above a uniform share
    top = np.sort(deg)[-(g.n_nodes // 100):]
    assert top.sum() > 3 * deg.sum() // 100
    assert is_streamed(g.features) and is_streamed(data.clients[0].features)
    # rebuild with the same seed is bitwise deterministic
    twin = make_vfl_dataset("powerlaw-tiny", n_clients=2, seed=0)
    np.testing.assert_array_equal(twin.full.indptr, g.indptr)
    np.testing.assert_array_equal(twin.full.indices, g.indices)
    np.testing.assert_array_equal(twin.full.labels, g.labels)


@pytest.mark.parametrize("seed", [0, 7, 1234, 8507])
def test_sampler_invariants_on_powerlaw(tiny_powerlaw, seed):
    """Alg-2 sampler on a power-law graph: per-client sampled neighbors are
    true neighbors, node sets are duplicate-free with centers first, and
    the position LUT round-trips."""
    data, _ = tiny_powerlaw
    cfg = SamplerConfig(n_layers=2, agg_layers=(1,), batch_size=8,
                        fanout=3, size_cap=96, table_cap=8)
    s = GlasuSampler(data, cfg, seed=seed)
    centers = np.tile(s.rng.choice(data.full.train_idx, size=8), (s.M, 1))
    nbrs = s._sample_neighbors_all(centers.astype(np.int32))
    for m in range(s.M):
        true = [set(data.clients[m].neighbors(int(c))) for c in centers[m]]
        for i in range(centers.shape[1]):
            drawn = set(int(v) for v in nbrs[m, i] if v >= 0)
            assert drawn <= true[i], \
                f"client {m} drew non-neighbors {drawn - true[i]}"
            # -1 only for isolated nodes in this client's edge subsample
            if not true[i]:
                assert (nbrs[m, i] == -1).all()
    sset = s._build_set([centers[0]], [nbrs[0]], cfg.size_cap)
    valid = sset[sset >= 0]
    assert len(valid) == len(np.unique(valid)), "duplicate ids after dedup"
    assert set(np.unique(centers[0])) <= set(valid), "center dropped"
    pos = s._positions(sset, valid)
    np.testing.assert_array_equal(sset[pos], valid)     # LUT round-trip
    assert (s._pos_lut == -1).all() and (s._mark == 0).all()  # scratch reset


def test_streamed_round_bitwise_equals_materialized(tiny_powerlaw):
    """The whole point of the store: a sampled round gathered through the
    LRU chunks must be byte-identical to the same round on resident
    features."""
    data, _ = tiny_powerlaw
    twin = _materialized_twin(data)
    cfg = SamplerConfig(n_layers=2, agg_layers=(1,), batch_size=8,
                        fanout=3, size_cap=96, table_cap=8)
    s_stream = GlasuSampler(data, cfg, seed=3)
    s_resident = GlasuSampler(twin, cfg, seed=3)
    for _ in range(3):
        a, b = s_stream.sample_round(), s_resident.sample_round()
        np.testing.assert_array_equal(a.feats, b.feats)
        np.testing.assert_array_equal(a.labels, b.labels)
        for l in range(a.n_layers):
            np.testing.assert_array_equal(a.gather_idx[l], b.gather_idx[l])
            np.testing.assert_array_equal(a.gather_mask[l], b.gather_mask[l])
            np.testing.assert_array_equal(a.row_valid[l], b.row_valid[l])
            np.testing.assert_array_equal(a.self_pos[l], b.self_pos[l])


# ------------------------------------------------------- training contracts
def test_streamed_round_has_no_implicit_transfers(tiny_powerlaw,
                                                  transfer_guard):
    """Store gathers happen on host BEFORE staging; the jitted round body
    must not smuggle a host->device copy (the GL-contract behind the 1M
    train_bench smoke)."""
    from repro.api.backends import make_backend
    from repro.api.config import ExperimentConfig
    from repro.core import glasu

    data, _ = tiny_powerlaw
    cfg = ExperimentConfig(
        name="streamed-guard", dataset="powerlaw-tiny", n_clients=2,
        n_layers=2, hidden=16, backbone="gcn", batch_size=8, fanout=3,
        size_cap=96, table_cap=8, rounds=0, eval_every=0)
    mcfg = cfg.glasu_config(data)
    optimizer = cfg.make_optimizer()
    sampler = GlasuSampler(data, cfg.sampler_config(), seed=0)
    backend = make_backend("vmapped")
    backend.bind(mcfg, optimizer, sampler)
    params = glasu.init_params(jax.random.PRNGKey(0), mcfg)
    opt_state = optimizer.init(params)
    key = jax.random.PRNGKey(1)
    # warmup OUTSIDE the guard: compilation may stage closure constants
    batch = jax.tree.map(jnp.array, sampler.sample_round())
    out = backend.run_round(params, opt_state, batch, key)
    jax.block_until_ready(out.losses)
    keys = [jax.random.fold_in(key, t) for t in range(2)]  # pre-staged
    with transfer_guard():
        for t in range(2):
            batch = jax.tree.map(np.array, sampler.sample_round())
            out = backend.run_round(out.params, out.opt_state,
                                    jax.device_put(batch), keys[t])
        jax.block_until_ready(out.losses)
    assert np.isfinite(float(jax.device_get(out.losses)[-1]))


def test_trainer_end_to_end_on_streamed_profile(tiny_powerlaw):
    """Full Trainer run with eval_every=0 (the streamed-store contract):
    completes, loss finite, and the exact-eval path refuses to run."""
    from repro.api.config import ExperimentConfig
    from repro.api.trainer import Trainer
    from repro.core.train import _eval_tables

    data, _ = tiny_powerlaw
    cfg = ExperimentConfig(
        name="streamed-e2e", dataset="powerlaw-tiny", n_clients=2,
        n_layers=2, hidden=16, backbone="gcn", batch_size=8, fanout=3,
        size_cap=96, table_cap=8, rounds=3, eval_every=0, lr=0.02)
    tr = Trainer(cfg, data=data)
    res = tr.run()
    assert res.rounds_run == 3
    assert res.history == []            # no EvalHook registered
    assert np.isfinite(float(jax.device_get(tr.state.last_losses)[-1]))
    with pytest.raises(RuntimeError, match="streamed feature store"):
        _eval_tables(data, cap=8, seed=0)


def test_eval_every_zero_validation():
    from repro.api.config import ExperimentConfig
    with pytest.raises(ValueError, match="eval_every"):
        ExperimentConfig(name="bad", eval_every=-1)
    with pytest.raises(ValueError, match="target_acc"):
        ExperimentConfig(name="bad", eval_every=0, target_acc=0.5)
