"""glint self-tests: every rule fires on a seeded-violation corpus and stays
quiet on a clean twin; the jaxpr contracts catch seeded f64 / broken-donation
/ meter-drift cases; the committed repo baseline is zero unsuppressed
findings; and the runtime guards actually guard.

The snippet corpus lives in string literals — the linter parses them as
stand-alone modules with repo-relative paths chosen to land inside (or
outside) the traced/hot prefixes each rule is gated on.
"""
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tools.glint import REPO, parse_suppressions, run_lint
from tools.glint import contracts
from tools.glint import rules as rules_mod
from tools.glint.pytest_plugin import RetraceGuard, jit_cache_size

TRACED = "src/repro/core/glasu.py"     # inside TRACED_PREFIXES
HOT = "src/repro/serve/hot.py"         # inside HOT_PREFIXES, not traced
COLD = "src/repro/launch/cold.py"      # neither


def lint(code, rule, rel=TRACED):
    """Run one rule over one dedented snippet; return its findings."""
    code = textwrap.dedent(code)
    active = rules_mod.resolve([rule])
    return rules_mod.check_file(Path("/snippet.py"), rel, code, active,
                                repo=REPO, all_files=())


def fired(code, rule, rel=TRACED):
    return [f for f in lint(code, rule, rel) if f.rule == rule]


# ================================================================ layer 1
# -------------------------------------------------------- GL000 + engine
def test_gl000_bare_suppression_is_a_finding(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "src/a.py").write_text(
        "def f(x=[]):  # glint: disable=GL008\n    return x\n")
    findings, report = run_lint(roots=("src",), repo=tmp_path,
                                rules=["GL008"])
    assert [f.rule for f in findings] == ["GL000"]
    # the bare comment still suppresses — GL008 itself is NOT reported
    assert report["suppressed_findings"] == 1


def test_reasoned_suppression_silences_and_is_counted(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "src/a.py").write_text(
        "def f(x=[]):  # glint: disable=GL008 shared sentinel, never mutated\n"
        "    return x\n")
    findings, report = run_lint(roots=("src",), repo=tmp_path,
                                rules=["GL008"])
    assert findings == []
    assert report["suppressed_findings"] == 1
    assert report["suppression_sites"] == 1


def test_file_level_suppression_covers_any_line():
    sup = parse_suppressions(
        "# glint: disable-file=GL009 corpus fixture\n\nx = 1\n")
    assert sup.covers("GL009", 3)
    assert not sup.covers("GL008", 3)


def test_suppression_on_wrong_line_does_not_cover():
    sup = parse_suppressions("x = 1  # glint: disable=GL008 why\ny = 2\n")
    assert sup.covers("GL008", 1)
    assert not sup.covers("GL008", 2)


# ---------------------------------------------------------------- GL001
def test_gl001_numpy_and_item_in_traced_module():
    code = """
    def round_body(h):
        a = np.sum(h)
        b = h.item()
        c = float(a)
        return a, b, c
    """
    lines = {f.line for f in fired(code, "GL001")}
    assert len(lines) == 3


def test_gl001_clean_statics_and_untraced_modules():
    code = """
    def round_body(h):
        dt = np.dtype("float32")
        n = int(h.shape[0])
        x = float(2.0)
        return dt, n, x
    """
    assert not fired(code, "GL001")
    assert not fired("def f(h):\n    return np.sum(h)\n", "GL001", rel=COLD)


# ---------------------------------------------------------------- GL002
def test_gl002_sample_then_reuse():
    code = """
    def f(key):
        a = jax.random.normal(key, (3,))
        b = jax.random.uniform(key, (3,))
        return a + b
    """
    assert fired(code, "GL002")


def test_gl002_sample_after_derive_and_double_split():
    consumed_after_derive = """
    def f(key):
        sub = jax.random.split(key, 2)
        x = jax.random.normal(key, (3,))
        return sub, x
    """
    assert fired(consumed_after_derive, "GL002")
    double_split = """
    def f(key):
        a = jax.random.split(key, 2)
        b = jax.random.split(key, 2)
        return a, b
    """
    # NOTE: assignment to a/b does not reset `key` tracking, only `key = ...`
    assert fired(double_split, "GL002")
    dup_fold = """
    def f(key):
        a = jax.random.fold_in(key, 0)
        b = jax.random.fold_in(key, 0)
        return a, b
    """
    assert fired(dup_fold, "GL002")


def test_gl002_clean_patterns():
    clean = """
    def f(key):
        mkey = jax.random.fold_in(key, 0)
        nkey = jax.random.fold_in(key, 1)
        a = jax.random.normal(mkey, (3,))
        return a, nkey

    def g(key):
        key, sub = jax.random.split(key)
        a = jax.random.normal(sub, (3,))
        key = jax.random.fold_in(key, 7)
        b = jax.random.normal(key, (3,))
        return a + b

    def h(key):
        stack = jax.vmap(lambda key: jax.random.normal(key, ()))(key)
        return jax.random.split(key, 2), stack
    """
    assert not fired(clean, "GL002")


# ---------------------------------------------------------------- GL003
def test_gl003_x64_attrs_strings_and_toggle():
    code = """
    import jax
    A = np.float64
    B = jnp.int64
    def f(x):
        return x.astype("float64")
    jax.config.update("jax_enable_x64", True)
    """
    assert len(fired(code, "GL003", rel=COLD)) == 4


def test_gl003_clean_32bit():
    code = "A = np.float32\nB = jnp.int32\nC = 'float32'\n"
    assert not fired(code, "GL003", rel=COLD)


# ---------------------------------------------------------------- GL004
def test_gl004_device_op_in_loop_in_hot_module():
    code = """
    def serve_step(xs):
        out = []
        for x in xs:
            out.append(jnp.dot(x, x))
        return out
    """
    assert fired(code, "GL004", rel=HOT)
    # same code outside the hot prefixes is fine
    assert not fired(code, "GL004", rel=COLD)


def test_gl004_clean_nested_def_and_host_loop():
    code = """
    def serve_step(xs):
        def body(c, x):
            return c, jnp.dot(x, x)
        total = 0
        for x in xs:
            total += len(x)
        return body, total
    """
    assert not fired(code, "GL004", rel=HOT)


# ---------------------------------------------------------------- GL005
def test_gl005_program_id():
    code = """
    def kernel(o_ref):
        i = pl.program_id(0)
        o_ref[i] = i
    """
    assert fired(code, "GL005", rel="src/repro/kernels/k.py")
    assert not fired("def kernel(o_ref):\n    o_ref[0] = 1\n", "GL005",
                     rel="src/repro/kernels/k.py")


# ---------------------------------------------------------------- GL006
_GL006_BAD = """
def call(x, block):
    return pl.pallas_call(kern, grid=(x.shape[0] // block,))(x)
"""


def test_gl006_floordiv_grid_without_guard():
    assert fired(_GL006_BAD, "GL006", rel="src/repro/kernels/k.py")


def test_gl006_clean_with_assert_or_pad():
    with_assert = """
    def call(x, block):
        assert x.shape[0] % block == 0
        return pl.pallas_call(kern, grid=(x.shape[0] // block,))(x)
    """
    assert not fired(with_assert, "GL006", rel="src/repro/kernels/k.py")
    with_pad = """
    def call(x, block):
        x = jnp.pad(x, ((0, (-x.shape[0]) % block), (0, 0)))
        return pl.pallas_call(kern, grid=(x.shape[0] // block,))(x)
    """
    assert not fired(with_pad, "GL006", rel="src/repro/kernels/k.py")


# ---------------------------------------------------------------- GL007
def test_gl007_blockspec_memory_space():
    bare = "spec = pl.BlockSpec((8, 8), lambda i: (i, 0))\n"
    assert fired(bare, "GL007", rel="src/repro/kernels/k.py")
    annotated = ("spec = pl.BlockSpec((8, 8), lambda i: (i, 0), "
                 "memory_space=pltpu.VMEM)\n")
    assert not fired(annotated, "GL007", rel="src/repro/kernels/k.py")


# ---------------------------------------------------------------- GL008
def test_gl008_mutable_defaults():
    code = """
    def f(a=[], b={}, *, c=set()):
        return a, b, c
    """
    assert len(fired(code, "GL008", rel=COLD)) == 3
    assert not fired("def f(a=None, b=()):\n    return a, b\n", "GL008",
                     rel=COLD)


# ---------------------------------------------------------------- GL009
def test_gl009_global_rng_and_unseeded():
    code = """
    import random
    def f():
        a = np.random.normal(size=3)
        rng = np.random.default_rng()
        b = random.randint(0, 9)
        return a, rng, b
    """
    assert len(fired(code, "GL009", rel=COLD)) == 3


def test_gl009_clean_seeded_generator():
    code = "rng = np.random.default_rng(0)\nx = rng.normal(size=3)\n"
    assert not fired(code, "GL009", rel=COLD)


# ---------------------------------------------------------------- GL010
def _write(root: Path, rel: str, text: str):
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)


def test_gl010_dead_module_flagged_imported_module_not(tmp_path):
    _write(tmp_path, "src/repro/dead.py", "X = 1\n")
    _write(tmp_path, "src/repro/used.py", "Y = 2\n")
    _write(tmp_path, "tests/test_t.py",
           "from repro.used import Y\nassert Y == 2\n")
    findings, _ = run_lint(roots=("src", "tests"), repo=tmp_path,
                           rules=["GL010"])
    assert [f.path for f in findings] == ["src/repro/dead.py"]


def test_gl010_entry_points_and_registry_suppressions_exempt(tmp_path):
    _write(tmp_path, "src/repro/cli.py",
           "def main():\n    pass\n\nif __name__ == '__main__':\n"
           "    main()\n")
    _write(tmp_path, "src/repro/plugin.py",
           "# glint: disable-file=GL010 loaded dynamically via registry\n"
           "X = 1\n")
    _write(tmp_path, "src/repro/__init__.py", "")
    findings, report = run_lint(roots=("src",), repo=tmp_path,
                                rules=["GL010"])
    assert findings == []
    assert report["suppressed_findings"] == 1


# ---------------------------------------------------------------- GL011
def test_gl011_unused_import():
    code = "import os\nimport sys\n\nprint(sys.argv)\n"
    got = fired(code, "GL011", rel=COLD)
    assert len(got) == 1 and "`os`" in got[0].message


def test_gl011_all_exports_and_doc_references_exempt():
    code = ('import os\nimport io\n\n__all__ = ["os"]\n\n'
            '"""uses ``io.BytesIO`` in doctests"""\n')
    assert not fired(code, "GL011", rel=COLD)
    init = "from .mod import thing\n"
    assert not fired(init, "GL011", rel="src/repro/pkg/__init__.py")


# ---------------------------------------------------------------- GL012
def test_gl012_swallowed_exception_fires():
    code = """
    def f():
        try:
            risky()
        except Exception:
            pass

    def g():
        try:
            risky()
        except:
            return {}
    """
    got = fired(code, "GL012", rel=COLD)
    assert len(got) == 2
    assert "swallows" in got[0].message
    assert "bare `except:`" in got[1].message


def test_gl012_clean_on_handled_exceptions():
    code = """
    import logging

    def reraise():
        try:
            risky()
        except Exception as e:
            raise RuntimeError("ctx") from e

    def logged():
        try:
            risky()
        except Exception:
            logging.warning("recoverable; continuing")

    def propagated(q):
        try:
            risky()
        except BaseException as e:
            q.put(e)            # exception object forwarded, not dropped

    def narrow():
        try:
            risky()
        except (ValueError, KeyError):
            return None         # narrow catch is deliberate handling
    """
    assert not fired(code, "GL012", rel=COLD)
    # rule is scoped to src/ — the same swallow in tests/tools is fine
    swallow = "try:\n    risky()\nexcept Exception:\n    pass\n"
    assert not fired(swallow, "GL012", rel="tests/test_x.py")


# ----------------------------------------------------- committed baseline
def test_repo_lint_baseline_is_clean():
    """The whole point: src/ + tests/ carry zero unsuppressed findings."""
    findings, report = run_lint()
    assert findings == [], "\n".join(f.format() for f in findings)
    assert report["files"] > 50


# ================================================================ layer 2
def test_gl201_catches_seeded_f64_trace():
    jax.config.update("jax_enable_x64", True)  # glint: disable=GL003 deliberately seeding an f64 trace for the checker-under-test
    try:
        closed = jax.make_jaxpr(lambda x: x * 2.0)(np.zeros(3, np.float64))  # glint: disable=GL003 the seeded f64 violation itself
    finally:
        jax.config.update("jax_enable_x64", False)  # glint: disable=GL003 restoring the repo-wide x64-off contract
    got = contracts._check_no_x64("seeded", closed, "x.py")
    assert got and got[0].rule == "GL201"


def test_gl201_clean_on_f32_trace():
    closed = jax.make_jaxpr(lambda x: x * 2.0)(np.zeros(3, np.float32))
    assert not contracts._check_no_x64("clean", closed, "x.py")


def test_gl202_catches_callback_primitives():
    def with_cb(x):
        return jax.pure_callback(
            lambda a: np.asarray(a), jax.ShapeDtypeStruct((3,), np.float32),
            x)
    closed = jax.make_jaxpr(with_cb)(np.zeros(3, np.float32))
    got = contracts._check_no_callbacks("seeded", closed, "x.py")
    assert got and got[0].rule == "GL202"
    clean = jax.make_jaxpr(lambda x: x + 1)(np.zeros(3, np.float32))
    assert not contracts._check_no_callbacks("clean", clean, "x.py")


def test_gl203_catches_broken_donation():
    def f(a, b):
        return a + 1.0, b * 2.0
    args = (jnp.ones((4,)), jnp.ones((4,)))
    undonated = jax.jit(f)
    got = contracts._check_donation("seeded", undonated, args, 2, "x.py")
    assert got and got[0].rule == "GL203"
    donated = jax.jit(f, donate_argnums=(0, 1))
    assert not contracts._check_donation("clean", donated, args, 2, "x.py")


def test_gl204_catches_meter_drift(monkeypatch):
    """Double every up_bytes the meter reports: the traced all_gather set no
    longer matches and the contract must fire."""
    glasu = contracts._fixture()["glasu"]
    orig = glasu.make_sharded_round_fn

    def skewed(cfg, opt, mesh, axis="clients", record=None, jit=True):
        wrapped = None if record is None else \
            (lambda r: record(r._replace(up_bytes=r.up_bytes * 2)))
        return orig(cfg, opt, mesh, axis=axis, record=wrapped, jit=jit)

    monkeypatch.setattr(glasu, "make_sharded_round_fn", skewed)
    got = contracts._check_collectives_vs_meter()
    assert any(f.rule == "GL204" and "drifted" in f.message for f in got)


def test_gl204_catches_silent_meter(monkeypatch):
    glasu = contracts._fixture()["glasu"]
    orig = glasu.make_sharded_round_fn

    def mute(cfg, opt, mesh, axis="clients", record=None, jit=True):
        return orig(cfg, opt, mesh, axis=axis, record=None, jit=jit)

    monkeypatch.setattr(glasu, "make_sharded_round_fn", mute)
    got = contracts._check_collectives_vs_meter()
    assert any(f.rule == "GL204" and "no collectives" in f.message
               for f in got)


def test_entry_point_registry_covers_public_builders():
    """Adding a public round/serve builder or Pallas kernel without
    registering it for contract checks is itself a failure."""
    import ast
    tree = ast.parse((REPO / "src/repro/core/glasu.py").read_text())
    public = {n.name for n in tree.body if isinstance(n, ast.FunctionDef)
              and n.name.startswith("make_") and n.name.endswith("_fn")}
    public |= {"serve_forward", "full_forward"}
    for p in sorted((REPO / "src/repro/kernels").glob("*.py")):
        kt = ast.parse(p.read_text())
        public |= {n.name for n in kt.body if isinstance(n, ast.FunctionDef)
                   and n.name.endswith("_pallas")
                   and not n.name.startswith("_")}
    missing = public - set(contracts.ENTRY_POINTS)
    assert not missing, f"unregistered entry points: {sorted(missing)}"


def test_repo_contracts_are_clean():
    findings, report = contracts.run_contracts()
    assert findings == [], "\n".join(f.format() for f in findings)
    assert "collectives-vs-meter" in report["entry_points"]


# ================================================================ layer 3
def test_retrace_guard_passes_on_stable_signature():
    fn = jax.jit(lambda x: x * 2.0)
    fn(jnp.ones((4,)))                       # warmup compile
    guard = RetraceGuard()
    guard.watch(fn, "stable")
    fn(jnp.ones((4,)))                       # cache hit
    guard.check()


def test_retrace_guard_fails_on_retrace():
    fn = jax.jit(lambda x: x * 3.0)
    fn(jnp.ones((4,)))
    guard = RetraceGuard()
    guard.watch(fn, "hot")
    fn(jnp.ones((5,)))                       # new shape -> recompile
    with pytest.raises(pytest.fail.Exception, match="retraced"):
        guard.check()


def test_retrace_guard_max_new_budget():
    fn = jax.jit(lambda x: x - 1.0)
    fn(jnp.ones((4,)))
    guard = RetraceGuard()
    guard.watch(fn, "warming", max_new=1)
    fn(jnp.ones((6,)))                       # one allowed recompile
    guard.check()


def test_jit_cache_size_rejects_plain_functions():
    with pytest.raises(TypeError, match="_cache_size"):
        jit_cache_size(lambda x: x)


def test_transfer_guard_blocks_implicit_transfers(transfer_guard):
    x = jnp.ones((4,), jnp.float32)
    host = np.arange(4, dtype=np.float32)
    with transfer_guard():
        with pytest.raises(Exception, match="[Dd]isallowed"):
            _ = x + host                     # implicit host->device upload
    _ = x + host                             # fine outside the scope


def test_transfer_guard_allows_explicit_staging(transfer_guard):
    host = np.ones(3, np.float32)
    with transfer_guard():
        staged = jnp.asarray(host)           # explicit stage-in
        _ = np.asarray(staged)               # explicit readback
