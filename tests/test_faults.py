"""Fault-model tests: config validation, schedule determinism/replay,
catch-up semantics, the timestamped message replay with its term-by-term
byte audit under dropped uploads, trainer participation telemetry, and the
fixed-seed chaos matrix (fault profiles x backends must produce the same
trace, losses, and delivered-byte meters).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentConfig, FaultConfig, Trainer
from repro.api.backends import VmappedBackend
from repro.core import glasu
from repro.fed import simulation
from repro.fed.faults import FaultSchedule, make_schedule, stack_plans
from repro.graph.sampler import GlasuSampler
from repro.graph.synth import make_vfl_dataset

# independent-implementation tolerance class (test_backend_conformance)
SIM_TOL = dict(rtol=2e-4, atol=2e-5)


def _cfg(**kw):
    kw.setdefault("optimizer", "sgd")
    kw.setdefault("eval_every", 4)
    kw.setdefault("rounds", 8)
    return ExperimentConfig(name="faults-t", dataset="tiny", backbone="gcn",
                            agg="mean", hidden=16, batch_size=8, size_cap=96,
                            lr=0.05, **kw)


# -------------------------------------------------------- config validation
@pytest.mark.parametrize("kw", [
    dict(participation=0.0), dict(participation=1.5),
    dict(drop_prob=1.0), dict(drop_prob=-0.1),
    dict(deadline_ms=-1.0), dict(deadline_ms=float("inf")),
    dict(base_latency_ms=-1.0), dict(latency_sigma=-0.5),
    dict(straggler_prob=1.5), dict(straggler_scale=0.0),
    dict(crash_prob=1.0), dict(rejoin_after=0), dict(max_staleness=0),
    # a drop can only resolve against a deadline
    dict(drop_prob=0.2),
])
def test_fault_config_rejects_bad_values(kw):
    with pytest.raises(ValueError, match="FaultConfig"):
        FaultConfig(**kw)


def test_fault_config_active_property():
    assert not FaultConfig().active                    # degraded block
    assert not FaultConfig(base_latency_ms=5.0).active  # latency, no deadline
    assert FaultConfig(participation=0.5).active
    assert FaultConfig(drop_prob=0.1, deadline_ms=10.0).active
    assert FaultConfig(crash_prob=0.1).active
    assert FaultConfig(deadline_ms=10.0, base_latency_ms=5.0).active


@pytest.mark.parametrize("kw,msg", [
    (dict(secure_agg=True), "privacy hooks"),
    (dict(dp_sigma=0.1), "privacy hooks"),
    (dict(labels_at_client=0), "labels_at_client"),
    (dict(method="standalone"), "standalone"),
])
def test_experiment_config_fault_exclusions(kw, msg):
    with pytest.raises(ValueError, match=msg):
        _cfg(faults={"seed": 1}, **kw)


def test_experiment_config_coerces_and_roundtrips_faults():
    cfg = _cfg(faults={"seed": 3, "drop_prob": 0.2, "deadline_ms": 50.0})
    assert isinstance(cfg.faults, FaultConfig)
    assert cfg.glasu_config(make_vfl_dataset(
        "tiny", n_clients=cfg.n_clients, seed=0)).fault_tolerant
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg


def test_experiment_config_accepts_faults_with_compression():
    """faults x compression compose since the round-engine unification:
    the codec runs on the fault exchange (the server caches each client's
    last DELIVERED decoded block; EF freezes for absent clients)."""
    cfg = _cfg(faults={"seed": 1, "participation": 0.67},
               compression={"method": "int8", "error_feedback": True})
    mcfg = cfg.glasu_config(make_vfl_dataset(
        "tiny", n_clients=cfg.n_clients, seed=0))
    assert mcfg.fault_tolerant and mcfg.compression.active
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg


# ------------------------------------------------------------- the schedule
CHAOTIC = FaultConfig(seed=5, participation=0.67, drop_prob=0.2,
                      deadline_ms=40.0, base_latency_ms=10.0,
                      straggler_prob=0.2, straggler_scale=8.0,
                      crash_prob=0.1, rejoin_after=2, max_staleness=3)


def _trace(sched, n):
    return [sched.next_round() for _ in range(n)]


def test_schedule_fixed_seed_replays_identically():
    a = _trace(FaultSchedule(CHAOTIC, 3), 20)
    b = _trace(FaultSchedule(CHAOTIC, 3), 20)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(pa.present, pb.present)
        np.testing.assert_array_equal(pa.weight, pb.weight)
        np.testing.assert_array_equal(pa.latency_ms, pb.latency_ms)
        assert pa.t_end == pb.t_end and pa.catch_up == pb.catch_up


def test_schedule_state_json_roundtrip_resumes_exactly():
    ref = FaultSchedule(CHAOTIC, 3)
    _trace(ref, 5)
    snap = json.loads(json.dumps(ref.state()))   # through the sidecar format
    want = _trace(ref, 5)

    resumed = FaultSchedule(CHAOTIC, 3)
    resumed.load_state(snap)
    assert resumed.round == 5
    got = _trace(resumed, 5)
    for pa, pb in zip(got, want):
        np.testing.assert_array_equal(pa.present, pb.present)
        np.testing.assert_array_equal(pa.weight, pb.weight)
        assert pa.t_start == pb.t_start and pa.t_end == pb.t_end


def test_schedule_catch_up_bounds_staleness():
    """Partial participation ages the unselected clients; when any live
    client's cache reaches max_staleness the next round is a synchronous
    catch-up: every live client is waited for, and ages reset."""
    cfg = FaultConfig(seed=0, participation=0.34, max_staleness=2)
    sched = FaultSchedule(cfg, 3)
    plans = _trace(sched, 12)
    assert any(p.catch_up for p in plans)
    for p in plans:
        if p.catch_up:
            # the server waits for every live client (no deadline, no drops)
            np.testing.assert_array_equal(p.present,
                                          p.active.astype(np.float32))
    # the bound holds throughout: no live client's cache ever exceeds it
    chk = FaultSchedule(cfg, 3)
    for _ in range(12):
        p = chk.next_round()
        assert int(chk.age[p.active].max(initial=0)) <= cfg.max_staleness


def test_schedule_weight_excludes_aged_out_and_never_delivered():
    """weight[m] = fresh or valid cache; a client that has never delivered
    (or whose cache aged out) is excluded from the aggregate entirely."""
    cfg = FaultConfig(seed=2, participation=0.34, max_staleness=5)
    sched = FaultSchedule(cfg, 3)
    p0 = sched.next_round()
    # round 0: no caches exist yet, so weight == present exactly
    np.testing.assert_array_equal(p0.weight, p0.present)
    for p in _trace(sched, 10):
        assert ((p.weight == 0) | (p.weight == 1)).all()
        # fresh blocks always carry weight
        assert (p.weight >= p.present).all()


def test_schedule_virtual_clock_and_deadline_duration():
    cfg = FaultConfig(seed=4, drop_prob=0.4, deadline_ms=25.0,
                      base_latency_ms=5.0)
    sched = FaultSchedule(cfg, 3)
    t = 0.0
    saw_wait = False
    for p in _trace(sched, 15):
        assert p.t_start == t and p.t_end >= p.t_start
        t = p.t_end
        if not p.catch_up and p.n_present < int(p.attempted.sum()):
            # a drop/straggler forces the server to wait out the deadline
            assert p.duration_ms == cfg.deadline_ms
            saw_wait = True
        elif not p.catch_up:
            assert p.duration_ms <= cfg.deadline_ms
    assert saw_wait


def test_stack_plans_shapes_and_make_schedule():
    plans = _trace(FaultSchedule(CHAOTIC, 3), 4)
    present, weight = stack_plans(plans)
    assert present.shape == weight.shape == (4, 3)
    assert present.dtype == weight.dtype == np.float32
    assert make_schedule(None, 3) is None
    assert make_schedule(CHAOTIC, 3).m == 3


# ------------------------------------------ timestamped replay + byte audit
def _fault_setup(fcfg_kw):
    cfg = _cfg(faults=fcfg_kw)
    data = make_vfl_dataset(cfg.dataset, n_clients=cfg.n_clients, seed=0)
    mcfg = cfg.glasu_config(data)
    sampler = GlasuSampler(data, cfg.sampler_config(), seed=0)
    params = glasu.init_params(jax.random.PRNGKey(0), mcfg)
    return cfg, mcfg, sampler, params


def test_fault_round_byte_audit_term_by_term():
    """Under dropped uploads the delivered-only meter must equal the
    analytic model term by term: index sync (everyone coordinates) +
    n_present uploads + M broadcasts per aggregation layer — and the
    sent-traffic meter prices the attempted uploads instead."""
    cfg, mcfg, sampler, params = _fault_setup(
        {"seed": 9, "drop_prob": 0.5, "deadline_ms": 30.0,
         "base_latency_ms": 5.0})
    sched = make_schedule(cfg.faults, mcfg.n_clients)
    opt = cfg.make_optimizer()
    opt_state = opt.init(params)
    fstate = glasu.init_fault_state(mcfg, sampler.layer_sizes)
    audited_partial = False
    for _ in range(6):
        plan = sched.next_round()
        batch = jax.tree.map(jnp.asarray, sampler.sample_round())
        params, opt_state, _, log, fstate = simulation.simulate_fault_round(
            params, opt_state, batch, mcfg, opt, fstate, plan)
        m, h = mcfg.n_clients, mcfg.hidden
        index_sync = sum(2 * m * sampler.layer_sizes[j] * 4
                         for j in range(mcfg.n_layers + 1)
                         if sampler._shared(j))
        per_layer = {l: sampler.layer_sizes[l + 1] * h * 4
                     for l in mcfg.agg_layers}
        n_att = int(plan.attempted.sum())
        want_delivered = index_sync + sum(
            plan.n_present * b + m * b for b in per_layer.values())
        want_sent = index_sync + sum(
            n_att * b + m * b for b in per_layer.values())
        assert log.total_bytes() == want_delivered
        assert log.total_bytes(delivered_only=False) == want_sent
        assert len(log.dropped_messages()) == \
            (n_att - plan.n_present) * len(mcfg.agg_layers)
        assert all(msg.kind == "upload" for msg in log.dropped_messages())
        audited_partial |= plan.n_present < n_att
    assert audited_partial      # the profile actually dropped something


def test_fault_round_message_timestamps():
    cfg, mcfg, sampler, params = _fault_setup(
        {"seed": 1, "drop_prob": 0.3, "deadline_ms": 25.0,
         "base_latency_ms": 8.0, "latency_sigma": 0.8})
    sched = make_schedule(cfg.faults, mcfg.n_clients)
    plan = sched.next_round()
    batch = jax.tree.map(jnp.asarray, sampler.sample_round())
    fstate = glasu.init_fault_state(mcfg, sampler.layer_sizes)
    *_, log, _ = simulation.simulate_fault_round(
        params, opt_state=cfg.make_optimizer().init(params), batch=batch,
        cfg=mcfg, optimizer=cfg.make_optimizer(), fault_state=fstate,
        plan=plan)
    for msg in log.messages:
        if msg.kind == "index_sync":
            assert msg.t == plan.t_start       # round opens with coordination
        elif msg.kind == "broadcast":
            assert msg.t == plan.t_end         # server closes the round
        elif not msg.dropped:
            assert plan.t_start <= msg.t <= plan.t_end  # arrived in time


# ------------------------------------------------------- trainer telemetry
def test_trainer_participation_telemetry_and_virtual_clock():
    cfg = _cfg(faults={"seed": 3, "participation": 0.67, "drop_prob": 0.2,
                       "deadline_ms": 50.0, "base_latency_ms": 10.0})
    res = Trainer(cfg).run()
    entries = [h for h in res.history if "participation" in h]
    assert entries, "eval entries must carry participation telemetry"
    for e in entries:
        assert 0.0 <= e["participation"] <= 1.0
        assert e["catch_up_rounds"] >= 0
    clocks = [e["virtual_ms"] for e in entries]
    assert clocks == sorted(clocks) and clocks[-1] > 0.0
    # partial participation must actually have priced fewer delivered bytes
    dense = Trainer(_cfg()).run()
    assert 0 < res.comm_bytes < dense.comm_bytes


# -------------------------------------- degraded mode / mixed precision
@pytest.mark.parametrize("agg", ["mean", "concat"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fault_agg_math_preserves_upload_dtype(agg, dtype):
    """Degraded-mode conformance row (mixed-precision uploads under
    partial participation): the weighted-mean denominator is cast to the
    uploads dtype exactly once, inside ``_fault_agg_math`` — the sharded
    engine previously divided by an f32 weak type, silently upcasting
    bf16 exchanges (the dtype drift the unified round body retired)."""
    cfg = _cfg(faults={"seed": 0, "participation": 0.67}).with_(agg=agg)
    mcfg = cfg.glasu_config(make_vfl_dataset(
        "tiny", n_clients=cfg.n_clients, seed=0))
    rng = np.random.default_rng(0)
    m, n, h = mcfg.n_clients, 8, mcfg.hidden
    uploads = jnp.asarray(rng.normal(size=(m, n, h)), dtype)
    weight = jnp.asarray([1.0, 0.0, 1.0][:m])     # partial participation
    agg_out, stale, denom = glasu._fault_agg_math(mcfg, uploads, weight)
    assert agg_out.dtype == uploads.dtype
    assert stale.dtype == uploads.dtype
    assert denom.dtype == uploads.dtype
    if agg == "mean":
        # value check against a host-side f64 reference of the weighted
        # mean (NumPy only — never crosses into a trace)
        w = np.asarray(weight, np.float64)[:, None, None]  # glint: disable=GL003 host-side reference math
        u = np.asarray(uploads.astype(jnp.float32), np.float64)  # glint: disable=GL003 host-side reference math
        ref = (w * u).sum(axis=0) / max(float(w.sum()), 1.0)
        tol = 1e-6 if dtype == jnp.float32 else 0.05
        np.testing.assert_allclose(
            np.asarray(agg_out[0].astype(jnp.float32), np.float64), ref,  # glint: disable=GL003 host-side reference math
            rtol=tol, atol=tol)


# ----------------------- composed faults x compression (unified engine)
def test_composed_round_vmapped_matches_simulation_and_audits_bytes():
    """One faults+int8 exchange through the unified round body (vmapped)
    and the independent NumPy replay must agree within SIM_TOL — and the
    delivered-only meter must equal the analytic cost model TERM BY TERM
    against the simulation message log: compressed wire size for present
    clients' uploads only, all-M compressed broadcasts, and the
    codec-independent int32 index sync."""
    from repro.comm.compression import make_compressor

    cfg, mcfg, sampler, params = _fault_setup(
        {"seed": 7, "drop_prob": 0.4, "deadline_ms": 40.0,
         "base_latency_ms": 5.0})
    cfg = cfg.with_(compression={"method": "int8", "error_feedback": True})
    data = make_vfl_dataset(cfg.dataset, n_clients=cfg.n_clients, seed=0)
    mcfg = cfg.glasu_config(data)
    comp = make_compressor(mcfg.compression)
    opt = cfg.make_optimizer()
    sched = make_schedule(cfg.faults, mcfg.n_clients)
    round_fn = glasu.make_round_fn(mcfg, opt)

    pv, ov = params, opt.init(params)
    cs_v = glasu.init_comp_state(mcfg, sampler.layer_sizes, comp)
    fs_v = glasu.init_fault_state(mcfg, sampler.layer_sizes)
    ps, os_ = params, opt.init(params)
    cs_s, fs_s = cs_v, fs_v
    saw_partial = False
    for r in range(5):
        plan = sched.next_round()
        batch = jax.tree.map(jnp.asarray, sampler.sample_round())
        masks = glasu.RoundFaults(jnp.asarray(plan.present),
                                  jnp.asarray(plan.weight))
        pv, ov, cs_v, fs_v, losses_v = round_fn(
            pv, ov, cs_v, fs_v, batch, jax.random.PRNGKey(r), masks)
        (ps, os_, losses_s, log, fs_s,
         cs_s) = simulation.simulate_fault_round(
            ps, os_, batch, mcfg, opt, fs_s, plan,
            compressor=comp, comp_state=cs_s)
        np.testing.assert_allclose(np.asarray(losses_v),
                                   np.asarray(losses_s), **SIM_TOL)
        # term-by-term audit: analytic model == message log
        m, h = mcfg.n_clients, mcfg.hidden
        index_sync = sum(2 * m * sampler.layer_sizes[j] * 4
                         for j in range(mcfg.n_layers + 1)
                         if sampler._shared(j))
        up = {l: comp.wire_bytes(sampler.layer_sizes[l + 1], h)
              for l in mcfg.agg_layers}
        down = up                     # mean agg: downlink width == hidden
        n_att = int(plan.attempted.sum())
        assert log.total_bytes("index_sync") == index_sync
        assert log.total_bytes("upload") == \
            plan.n_present * sum(up.values())
        assert log.total_bytes("upload", delivered_only=False) == \
            n_att * sum(up.values())
        assert log.total_bytes("broadcast") == m * sum(down.values())
        want = index_sync + sum(plan.n_present * up[l] + m * down[l]
                                for l in mcfg.agg_layers)
        assert log.total_bytes() == want
        assert want == sampler.comm_bytes_per_joint_inference(
            h, agg=mcfg.agg, compressor=comp, n_uploads=plan.n_present)
        assert len(log.dropped_messages()) == \
            (n_att - plan.n_present) * len(mcfg.agg_layers)
        saw_partial |= plan.n_present < n_att
    assert saw_partial          # the profile actually dropped something
    for (pa, la), (_, lb) in zip(
            jax.tree_util.tree_leaves_with_path(pv),
            jax.tree_util.tree_leaves_with_path(ps)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   **SIM_TOL,
                                   err_msg=jax.tree_util.keystr(pa))


def test_composed_e2e_trainer_resume_bitwise(tmp_path):
    """Faults + int8 EF compose end-to-end: an interrupted run restores
    BOTH sidecars (comp_<step>.npz EF accumulators, fault_<step>.npz
    stale caches + schedule state) bitwise, so the resumed run reproduces
    the uninterrupted one exactly."""
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    base = _cfg(faults={"seed": 5, "drop_prob": 0.3, "deadline_ms": 40.0,
                        "base_latency_ms": 5.0},
                compression={"method": "int8", "error_feedback": True},
                rounds=4, eval_every=2)
    cfg = base.with_(ckpt_dir=str(tmp_path), ckpt_every=2, rounds=2)
    Trainer(cfg, data=data).run()
    assert (tmp_path / "comp_00000002.npz").exists()
    assert (tmp_path / "fault_00000002.npz").exists()

    res = Trainer(cfg.with_(rounds=4), data=data).run()   # resume 2 -> 4
    straight = Trainer(base, data=data).run()
    for (pa, la), (_, lb) in zip(
            jax.tree_util.tree_leaves_with_path(res.params),
            jax.tree_util.tree_leaves_with_path(straight.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=jax.tree_util.keystr(pa))
    assert res.comm_bytes == straight.comm_bytes


@pytest.mark.slow
def test_cora_profile_composed_faults_compression_trains_and_audits():
    """Acceptance row: the cora preset with faults + int8 compression
    TRAINS under the simulation backend, whose per-round delivered-only
    byte audit (analytic model vs message log) runs on every round."""
    from repro.api import get_preset

    cfg = get_preset("cora-gcn-glasu").with_(
        rounds=4, eval_every=2, backend="simulation",
        batch_size=16, size_cap=256,
        faults={"seed": 3, "drop_prob": 0.3, "deadline_ms": 40.0,
                "base_latency_ms": 5.0},
        compression={"method": "int8", "error_feedback": True})
    res = Trainer(cfg).run()
    assert res.rounds_run == 4
    losses = [h["loss"] for h in res.history]
    assert losses and np.isfinite(losses).all()
    assert res.comm_bytes > 0


# ------------------------------------------------ fault-support contract
def test_run_step_sequential_rejects_backend_without_fault_support():
    """A backend that never declared the fault contract must fail loudly
    when handed plans instead of silently training fault-free."""
    from repro.api.backends import run_step_sequential

    class LegacyBackend:
        name = "legacy"

        def run_round(self, params, opt_state, batch, key, **kw):
            raise AssertionError("must not be reached")

    plans = _trace(FaultSchedule(CHAOTIC, 3), 2)
    with pytest.raises(ValueError, match="supports_faults"):
        run_step_sequential(LegacyBackend(), None, None, None,
                            keys=[None, None], faults=plans)


def test_trainer_rejects_fault_schedule_on_unsupporting_backend(monkeypatch):
    """Satellite of the same contract: the Trainer refuses the pairing at
    config time, before any round runs."""
    from repro.api import backends as backends_mod

    monkeypatch.setattr(backends_mod.VmappedBackend, "supports_faults",
                        False)
    with pytest.raises(ValueError, match="supports_faults"):
        Trainer(_cfg(faults={"seed": 1, "participation": 0.67}))


def test_backend_rejects_faults_on_fault_free_bind():
    cfg = _cfg()
    data = make_vfl_dataset(cfg.dataset, n_clients=cfg.n_clients, seed=0)
    mcfg = cfg.glasu_config(data)
    sampler = GlasuSampler(data, cfg.sampler_config(), seed=0)
    vb = VmappedBackend()
    vb.bind(mcfg, cfg.make_optimizer(), sampler)
    params = glasu.init_params(jax.random.PRNGKey(0), mcfg)
    plan = FaultSchedule(FaultConfig(), mcfg.n_clients).next_round()
    batch = jax.tree.map(jnp.asarray, sampler.sample_round())
    with pytest.raises(ValueError, match="fault_tolerant"):
        vb.run_round(params, cfg.make_optimizer().init(params), batch,
                     jax.random.PRNGKey(0), faults=plan)


# ---------------------------------------------------------- chaos matrix
# Three fixed-seed fault profiles; every backend must replay the identical
# host-side trace, agree on losses within the independent-implementation
# tolerance, and price the identical delivered-only bytes.
CHAOS_PROFILES = {
    "drops": {"seed": 11, "drop_prob": 0.3, "deadline_ms": 50.0,
              "base_latency_ms": 5.0},
    "stragglers": {"seed": 12, "deadline_ms": 30.0, "base_latency_ms": 10.0,
                   "straggler_prob": 0.3, "straggler_scale": 20.0,
                   "client_speed_sigma": 0.3},
    "crashes": {"seed": 13, "participation": 0.67, "crash_prob": 0.2,
                "rejoin_after": 2, "max_staleness": 3},
}


@pytest.mark.slow
@pytest.mark.parametrize("profile", sorted(CHAOS_PROFILES))
def test_chaos_matrix_backends_agree(profile):
    cfg = _cfg(faults=CHAOS_PROFILES[profile])
    res_v = Trainer(cfg).run()
    res_s = Trainer(cfg.with_(backend="simulation")).run()
    assert res_s.comm_bytes == res_v.comm_bytes > 0
    assert [h["round"] for h in res_s.history] == \
        [h["round"] for h in res_v.history]
    np.testing.assert_allclose([h["loss"] for h in res_s.history],
                               [h["loss"] for h in res_v.history], **SIM_TOL)
    tv = [h["virtual_ms"] for h in res_v.history if "virtual_ms" in h]
    ts = [h["virtual_ms"] for h in res_s.history if "virtual_ms" in h]
    assert tv == ts                     # identical replayed fault trace


@pytest.mark.slow
@pytest.mark.parametrize("profile", sorted(CHAOS_PROFILES))
def test_chaos_matrix_sharded_agrees_with_vmapped(profile):
    cfg = _cfg(faults=CHAOS_PROFILES[profile])
    res_v = Trainer(cfg).run()
    res_h = Trainer(cfg.with_(backend="sharded")).run()
    assert res_h.comm_bytes == res_v.comm_bytes
    np.testing.assert_allclose([h["loss"] for h in res_h.history],
                               [h["loss"] for h in res_v.history],
                               rtol=5e-5, atol=5e-5)
