"""Unit + property tests for the model substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import apply_rope
from repro.optim import optimizers as opt_lib


# -------------------------------------------------------------------- rope
def test_rope_preserves_norm():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, 4, 32)), jnp.float32)
    pos = jnp.arange(16)[None]
    r = apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(r), axis=-1),
                               rtol=1e-5)


def test_rope_relative_property():
    """<R(q,m), R(k,n)> depends only on m-n (per head dim pair)."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)

    def dot_at(m, n):
        qm = apply_rope(q, jnp.array([[m]], jnp.float32))
        kn = apply_rope(k, jnp.array([[n]], jnp.float32))
        return float(jnp.sum(qm * kn))

    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
    assert abs(dot_at(0, 0) - dot_at(7, 7)) < 1e-4


# --------------------------------------------------------------------- MoE
def test_moe_no_drops_with_large_capacity():
    rng = np.random.default_rng(2)
    p = moe_lib.moe_init(jax.random.PRNGKey(0), 32, 64, 4, 0, 0)
    x = jnp.asarray(rng.normal(size=(2, 16, 32)), jnp.float32)
    y, stats = moe_lib.moe_apply(p, x, 4, 2, capacity_factor=8.0)
    assert y.shape == x.shape
    assert float(stats.dropped_frac) == 0.0
    assert np.isfinite(float(stats.aux_loss))


def test_moe_capacity_drops_counted():
    rng = np.random.default_rng(3)
    p = moe_lib.moe_init(jax.random.PRNGKey(1), 16, 32, 8, 0, 0)
    x = jnp.asarray(rng.normal(size=(1, 64, 16)), jnp.float32)
    # skewed router -> force collisions at tiny capacity
    p["router"] = p["router"] * 0.0 + jnp.eye(16, 8) * 10.0
    y, stats = moe_lib.moe_apply(p, x, 8, 2, capacity_factor=0.25)
    assert float(stats.dropped_frac) > 0.0


@pytest.mark.slow
def test_moe_gradients_flow_to_all_parts():
    rng = np.random.default_rng(4)
    p = moe_lib.moe_init(jax.random.PRNGKey(2), 16, 32, 4, 1, 32)

    def loss(p, x):
        y, stats = moe_lib.moe_apply(p, x, 4, 2, capacity_factor=4.0)
        return jnp.sum(y ** 2) + 0.01 * stats.aux_loss

    x = jnp.asarray(rng.normal(size=(1, 32, 16)), jnp.float32)
    g = jax.grad(loss)(p, x)
    for name in ("router", "w_gate", "w_down", "shared"):
        leaves = jax.tree.leaves(g[name])
        assert any(float(jnp.max(jnp.abs(l))) > 0 for l in leaves), name


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(t=st.integers(4, 40), e=st.sampled_from([2, 4, 8]),
       k=st.sampled_from([1, 2]), seed=st.integers(0, 1000))
def test_moe_is_weighted_average_of_expert_outputs(t, e, k, seed):
    """With no drops, output == sum_k gate_k * expert_k(x) per token."""
    if k > e:
        k = e
    d, f = 8, 16
    rng = np.random.default_rng(seed)
    p = moe_lib.moe_init(jax.random.PRNGKey(seed), d, f, e, 0, 0)
    x = jnp.asarray(rng.normal(size=(1, t, d)), jnp.float32)
    y, stats = moe_lib.moe_apply(p, x, e, k, capacity_factor=float(e * 2))
    assert float(stats.dropped_frac) == 0.0

    # dense reference
    logits = x.reshape(t, d) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, k)
    gv = gv / jnp.maximum(gv.sum(-1, keepdims=True), 1e-9)
    xt = x.reshape(t, d)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["w_gate"])) \
        * jnp.einsum("td,edf->tef", xt, p["w_up"])
    all_out = jnp.einsum("tef,efd->ted", h, p["w_down"])   # (t, e, d)
    want = jnp.zeros((t, d))
    for kk in range(k):
        want = want + gv[:, kk, None] * jnp.take_along_axis(
            all_out, ei[:, kk, None, None].repeat(d, -1), axis=1)[:, 0]
    np.testing.assert_allclose(np.asarray(y.reshape(t, d)), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------- Mamba2
def test_ssd_chunk_scan_matches_naive_recurrence():
    """Chunked SSD == step-by-step h' = exp(dt a) h + dt B x; y = C h."""
    b, s, h, p, n = 1, 16, 2, 4, 3
    rng = np.random.default_rng(5)
    xh = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.5, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 1.5, size=(h,)), jnp.float32)

    got = ssm_lib._ssd_chunk_scan(xh, bm, cm, dt, a, chunk=4)

    state = np.zeros((b, h, p, n), np.float32)
    want = np.zeros((b, s, h, p), np.float32)
    for t in range(s):
        dec = np.exp(np.asarray(dt[:, t]) * np.asarray(a))      # (b, h)
        upd = np.einsum("bhp,bn,bh->bhpn", xh[:, t], bm[:, t], dt[:, t])
        state = state * dec[:, :, None, None] + upd
        want[:, t] = np.einsum("bhpn,bn->bhp", state, cm[:, t])
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------- optimizers
@pytest.mark.parametrize("make", [
    lambda: opt_lib.sgd(0.1), lambda: opt_lib.sgd(0.1, momentum=0.9),
    lambda: opt_lib.adam(0.1), lambda: opt_lib.adamw(0.1),
    lambda: opt_lib.adafactor(0.5),
])
def test_optimizers_minimize_quadratic(make):
    opt = make()
    params = {"w": jnp.asarray(np.ones((4, 3)), jnp.float32),
              "b": jnp.ones((3,), jnp.float32)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = opt_lib.apply_updates(params, upd)
    assert float(loss(params)) < 0.2 * l0


def test_clip_by_global_norm_preserves_dtype_and_norm():
    g = {"a": jnp.ones((8, 8), jnp.bfloat16) * 10}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert clipped["a"].dtype == jnp.bfloat16
    total = float(jnp.sqrt(jnp.sum(jnp.square(
        clipped["a"].astype(jnp.float32)))))
    assert total <= 1.05


def test_schedules():
    s = opt_lib.linear_warmup_cosine(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) < 0.01
    inv = opt_lib.inverse_sqrt(1.0, 16)
    assert abs(float(inv(16)) - 1.0) < 1e-6
    assert float(inv(64)) == pytest.approx(0.5, rel=1e-3)
