"""Property tests for the CSR segment-sum aggregation path.

Random ragged degree sequences (including zero-degree rows, empty graphs,
and non-multiple-of-tile destination counts) driven through
``ops.graph_agg_csr`` / ``ops._graph_agg_sparse``, checked forward AND
gradient against the ``kernels/ref.py`` oracles, plus CSR-vs-dense-path
equivalence on the same graph.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.graph.csr_plan import plan_csr_slabs
from repro.kernels import ops, ref
from repro.kernels.graph_agg import CSR_PAD_ROW, DST_BLOCK


def _rand_csr(seed: int, n_dst: int, n_src: int, max_deg: int = 6,
              p_zero: float = 0.3):
    """Ragged host CSR: ~p_zero of the rows have NO neighbors."""
    rng = np.random.default_rng(seed)
    deg = rng.integers(1, max_deg + 1, size=n_dst)
    deg[rng.random(n_dst) < p_zero] = 0
    indptr = np.zeros(n_dst + 1, np.int32)
    indptr[1:] = np.cumsum(deg, dtype=np.int32)
    indices = rng.integers(0, n_src, size=int(indptr[-1])).astype(np.int32)
    return indptr.astype(np.int32), indices


def _rand_inputs(seed: int, n_src: int, d: int, d_out: int, nnz: int):
    rng = np.random.default_rng(seed + 1)
    h = jnp.asarray(rng.normal(size=(n_src, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, d_out)) * 0.3, jnp.float32)
    ew = jnp.asarray(rng.random(nnz) + 0.25, jnp.float32)
    return h, w, ew


# ------------------------------------------------------- forward properties
@settings(max_examples=12, deadline=None)
@given(n_dst=st.integers(1, 300), seed=st.integers(0, 10_000))
def test_csr_forward_matches_oracle(n_dst, seed):
    """Ragged/zero-degree/non-tile-aligned CSR: kernel == segment-sum ref."""
    n_src, d, d_out = 64, 16, 8
    indptr, indices = _rand_csr(seed, n_dst, n_src)
    h, w, ew = _rand_inputs(seed, n_src, d, d_out, len(indices))
    got = ops.graph_agg_csr(h, indptr, indices, w)
    want = ref.graph_agg_csr_ref(h, indptr, indices, w)
    assert got.shape == (n_dst, d_out)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # weighted-edge variant (traced edge weights through the slot scatter)
    got_w = ops.graph_agg_csr(h, indptr, indices, w, edge_weight=ew)
    want_w = ref.graph_agg_csr_ref(h, indptr, indices, w, edge_weight=ew)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(n_dst=st.integers(1, 200), seed=st.integers(0, 10_000))
def test_csr_zero_degree_rows_are_exactly_zero(n_dst, seed):
    n_src, d, d_out = 32, 8, 8
    indptr, indices = _rand_csr(seed, n_dst, n_src, p_zero=0.6)
    h, w, _ = _rand_inputs(seed, n_src, d, d_out, len(indices))
    out = np.asarray(ops.graph_agg_csr(h, indptr, indices, w))
    zero_rows = np.flatnonzero(np.diff(indptr) == 0)
    assert (out[zero_rows] == 0.0).all()


def test_csr_empty_graph_all_zero():
    """Every row isolated: the whole output is exactly zero."""
    n_dst, n_src, d, d_out = 130, 16, 8, 8
    indptr = np.zeros(n_dst + 1, np.int32)
    indices = np.zeros(0, np.int32)
    h, w, _ = _rand_inputs(0, n_src, d, d_out, 0)
    out = np.asarray(ops.graph_agg_csr(h, indptr, indices, w))
    assert out.shape == (n_dst, d_out) and (out == 0.0).all()


def test_csr_slab_planner_invariants():
    """Slab layout: 128-multiple slabs, local seg ids, zeroed padding."""
    indptr, indices = _rand_csr(3, 300, 64)
    idx_s, seg_s, ew_s, n_dst = plan_csr_slabs(indptr, indices)
    n_tiles = -(-n_dst // DST_BLOCK)
    assert n_dst == 300 and idx_s.shape == seg_s.shape == ew_s.shape
    assert idx_s.shape[0] % (n_tiles * DST_BLOCK) == 0 or \
        idx_s.shape[0] % n_tiles == 0
    slab = idx_s.shape[0] // n_tiles
    assert slab % DST_BLOCK == 0
    seg = seg_s[:, 0]
    real = seg < CSR_PAD_ROW
    assert real.sum() == len(indices)
    assert seg.max() <= CSR_PAD_ROW
    assert (ew_s[~real, 0] == 0.0).all()
    assert (idx_s[:, 0] >= 0).all() and idx_s[:, 0].max() < 64


# ------------------------------------------------------ gradient properties
@settings(max_examples=6, deadline=None)
@given(n_dst=st.integers(1, 180), seed=st.integers(0, 10_000))
def test_csr_gradients_match_oracle(n_dst, seed):
    """custom_vjp backward (slab segment-sum ref) == direct oracle grads
    wrt h, w, AND edge_weight, at ragged/zero-degree shapes."""
    n_src, d, d_out = 48, 8, 8
    indptr, indices = _rand_csr(seed, n_dst, n_src)
    h, w, ew = _rand_inputs(seed, n_src, d, d_out, len(indices))

    def loss_kernel(h_, w_, ew_):
        return (ops.graph_agg_csr(h_, indptr, indices, w_,
                                  edge_weight=ew_) ** 2).sum()

    def loss_ref(h_, w_, ew_):
        return (ref.graph_agg_csr_ref(h_, indptr, indices, w_,
                                      edge_weight=ew_) ** 2).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(h, w, ew)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(h, w, ew)
    for a, b, name in zip(gk, gr, ("h", "w", "edge_weight")):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-4,
            err_msg=f"grad mismatch wrt {name}")


# -------------------------------------------------- CSR vs dense-path parity
@settings(max_examples=8, deadline=None)
@given(n_dst=st.integers(1, 256), fanout=st.integers(1, 9),
       seed=st.integers(0, 10_000))
def test_sparse_dispatch_twin_matches_dense_path(n_dst, fanout, seed):
    """Same (h, idx, mask, w): the in-trace ELL->slab CSR kernel must agree
    with the one-hot dense kernel — the bitwise contract behind the
    ``graph_agg`` density dispatch."""
    rng = np.random.default_rng(seed)
    n_src, d, d_out = 96, 16, 8
    h = jnp.asarray(rng.normal(size=(n_src, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n_src, size=(n_dst, fanout)), jnp.int32)
    mask = jnp.asarray(rng.random((n_dst, fanout)) < 0.7, jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, d_out)) * 0.3, jnp.float32)
    dense = ops._graph_agg(h, idx, mask, w)
    sparse = ops._graph_agg_sparse(h, idx, mask, w)
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)
    # gradients share the dense oracle's backward — must agree too
    gd = jax.grad(lambda h_: (ops._graph_agg(h_, idx, mask, w) ** 2).sum())(h)
    gs = jax.grad(
        lambda h_: (ops._graph_agg_sparse(h_, idx, mask, w) ** 2).sum())(h)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(gd),
                               rtol=5e-4, atol=5e-4)


def test_graph_agg_dispatches_to_csr_at_scale():
    """Above CSR_DISPATCH_MIN_SRC the public ``graph_agg`` routes to the
    segment-sum kernel and still matches the dense oracle."""
    rng = np.random.default_rng(5)
    n_src = ops.CSR_DISPATCH_MIN_SRC
    n_dst, fanout, d = 64, 4, 8
    h = jnp.asarray(rng.normal(size=(n_src, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n_src, size=(n_dst, fanout)), jnp.int32)
    mask = jnp.asarray(rng.random((n_dst, fanout)) < 0.8, jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    got = ops.graph_agg(h, idx, mask, w)
    want = ref.graph_agg_ref(h, idx, mask, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_ell_to_slabs_is_traceable_and_vmap_safe():
    """The ELL->slab relayout must stay jit/vmap-composable (the client
    axis of the GLASU core is vmapped over every kernel call)."""
    rng = np.random.default_rng(6)
    M, n_dst, fanout, n_src, d = 3, 140, 5, 64, 8
    h = jnp.asarray(rng.normal(size=(M, n_src, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n_src, size=(M, n_dst, fanout)),
                      jnp.int32)
    mask = jnp.asarray(rng.random((M, n_dst, fanout)) < 0.8, jnp.float32)
    w = jnp.asarray(rng.normal(size=(M, d, d)) * 0.3, jnp.float32)
    got = jax.vmap(ops._graph_agg_sparse)(h, idx, mask, w)
    want = jax.vmap(ref.graph_agg_ref)(h, idx, mask, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_csr_slab_ref_equals_csr_ref():
    """The traceable slab oracle (custom_vjp backward target) reproduces
    the plain CSR oracle through the planner's layout."""
    indptr, indices = _rand_csr(7, 260, 64)
    h, w, ew = _rand_inputs(7, 64, 16, 8, len(indices))
    idx_s, seg_s, ew_s, n_dst = plan_csr_slabs(indptr, indices,
                                               edge_weight=np.asarray(ew))
    got = ref.csr_slab_ref(h, jnp.asarray(idx_s), jnp.asarray(seg_s),
                           jnp.asarray(ew_s), w, n_dst)
    want = ref.graph_agg_csr_ref(h, indptr, indices, w, edge_weight=ew)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
