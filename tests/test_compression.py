"""Compressed embedding exchange: codecs, engines, meters, checkpoints.

Codec units pin the wire formats (including the edge cases: all-zero rows
under the int8 absmax guard, fp8 overflow clipping, top-k with k >= d
degenerating to exact identity) and that ``wire_bytes`` prices the actual
encoded payload exactly. Engine tests pin the compressed vmapped scan
against sequential rounds and against the independent message-passing
simulation, the byte meters against each other, and the error-feedback
accumulators through a bitwise checkpoint round-trip and a codec change
across a resume.

Numerical contract: quantization AMPLIFIES compilation-level ULP noise —
a last-ULP difference in an upload can flip a round-to-nearest bucket and
move the decoded value by a whole quantization step — so compressed
cross-program comparisons (scan vs sequential, sharded vs vmapped) are
pinned at ``COMP_TOL`` rather than the bitwise/ULP contracts of the
uncompressed engines. Within one program the math is deterministic:
checkpoint resume is still bitwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CompressionConfig, ExperimentConfig,
                       SimulationBackend, Trainer, VmappedBackend)
from repro.comm import compression as comp_lib
from repro.core import glasu
from repro.fed import simulation
from repro.graph.prefetch import stack_rounds
from repro.graph.sampler import GlasuSampler
from repro.graph.synth import make_vfl_dataset

COMP_TOL = dict(rtol=2e-4, atol=2e-4)

METHODS = [("int8", {}), ("fp8", {}), ("topk_ef", {"k": 2}),
           ("int8", {"error_feedback": True})]


def _payload_nbytes(payload):
    return sum(np.asarray(l).size * np.asarray(l).dtype.itemsize
               for l in jax.tree.leaves(payload))


# ------------------------------------------------------------------- codecs
def test_int8_roundtrip_bounded_and_zero_row_guard():
    comp = comp_lib.make_compressor(CompressionConfig("int8"))
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(6, 32)).astype(np.float32))
    x = x.at[2].set(0.0)                    # absmax == 0 row
    x_hat = comp.roundtrip(x)
    assert np.all(np.isfinite(np.asarray(x_hat)))
    # per-row error bounded by half a quantization step
    step = np.max(np.abs(np.asarray(x)), axis=1, keepdims=True) / 127.0
    assert np.all(np.abs(np.asarray(x_hat - x)) <= step / 2 + 1e-7)
    np.testing.assert_array_equal(np.asarray(x_hat[2]), np.zeros(32))


def test_fp8_overflow_clips_instead_of_nan():
    comp = comp_lib.make_compressor(CompressionConfig("fp8"))
    x = jnp.asarray([[1e6, -1e6, 0.5, 0.0]], jnp.float32)
    x_hat = np.asarray(comp.roundtrip(x))
    assert np.all(np.isfinite(x_hat))
    fmax = float(jnp.finfo(jnp.float8_e4m3fn).max)
    np.testing.assert_allclose(x_hat[0, :2], [fmax, -fmax])


def test_topk_keeps_largest_magnitudes():
    comp = comp_lib.make_compressor(CompressionConfig("topk_ef", k=3))
    x = jnp.asarray([[0.1, -5.0, 0.2, 4.0, -0.3, 3.0, 0.0, 0.05]],
                    jnp.float32)
    x_hat = np.asarray(comp.roundtrip(x))
    kept = np.flatnonzero(x_hat[0])
    np.testing.assert_array_equal(sorted(kept), [1, 3, 5])
    np.testing.assert_allclose(x_hat[0, kept], [-5.0, 4.0, 3.0], rtol=1e-3)


def test_topk_values_clip_to_f16_finite_range():
    """|value| > 65504 must ship as the f16 max, not overflow to inf
    (which would poison the server mean); the clipped-off magnitude lands
    in the EF residual instead."""
    comp = comp_lib.make_compressor(CompressionConfig("topk_ef", k=2))
    x = jnp.asarray([[1e6, -1e6, 0.5, 0.1, 0.0, 0.0, 0.0, 0.0]],
                    jnp.float32)
    x_hat = np.asarray(comp.roundtrip(x))
    assert np.all(np.isfinite(x_hat))
    np.testing.assert_allclose(x_hat[0, :2], [65504.0, -65504.0])
    _, xh, ef = comp_lib.roundtrip_with_ef(comp, x, jnp.zeros_like(x))
    assert np.all(np.isfinite(np.asarray(ef)))


def test_topk_wide_rows_use_i32_indices():
    """Rows wider than the int16 range (huge concat broadcasts) must ship
    i32 columns — a wrapped i16 index would scatter out of bounds and be
    silently dropped under jit."""
    comp = comp_lib.make_compressor(CompressionConfig("topk_ef", k=2))
    d = 2 ** 15 + 8
    x = np.zeros((1, d), np.float32)
    x[0, d - 1] = 3.0            # index beyond int16 range
    x[0, d - 2] = -2.0
    payload = comp.encode(jnp.asarray(x))
    assert payload["i"].dtype == jnp.int32
    x_hat = np.asarray(comp.decode(payload, d))
    np.testing.assert_allclose(x_hat[0, d - 1], 3.0, rtol=1e-3)
    np.testing.assert_allclose(x_hat[0, d - 2], -2.0, rtol=1e-3)
    assert comp.wire_bytes(1, d) == 2 * (2 + 4)
    assert _payload_nbytes(payload) == comp.wire_bytes(1, d)
    # narrow rows keep the 2-byte index format
    narrow = comp.encode(jnp.asarray(np.zeros((1, 16), np.float32)))
    assert narrow["i"].dtype == jnp.int16


def test_topk_k_geq_d_degenerates_to_identity():
    """k >= d keeps every entry: the codec ships the dense float32 row
    (cheaper than value+index pairs), the round-trip is EXACT, and the
    error-feedback residual is identically zero."""
    d = 16
    comp = comp_lib.make_compressor(CompressionConfig("topk_ef", k=d))
    x = jnp.asarray(np.random.default_rng(1).normal(
        size=(4, d)).astype(np.float32))
    payload = comp.encode(x)
    assert set(payload) == {"dense"}
    np.testing.assert_array_equal(np.asarray(comp.decode(payload, d)),
                                  np.asarray(x))
    assert comp.wire_bytes(4, d) == 4 * d * 4
    _, x_hat, ef = comp_lib.roundtrip_with_ef(comp, x, jnp.zeros_like(x))
    np.testing.assert_array_equal(np.asarray(ef), np.zeros_like(ef))


@pytest.mark.parametrize("method,kw", METHODS)
def test_wire_bytes_prices_actual_payload(method, kw):
    cc = CompressionConfig(method, **{k: v for k, v in kw.items()})
    comp = comp_lib.make_compressor(cc)
    for n, d in [(7, 16), (96, 64), (1, 8)]:
        x = jnp.asarray(np.random.default_rng(n).normal(
            size=(n, d)).astype(np.float32))
        assert _payload_nbytes(comp.encode(x)) == comp.wire_bytes(n, d)


def test_wire_ratios_meet_the_paper_targets():
    """The pure-embedding wire ratios that back the benchmark gate:
    int8 > 3x, topk_ef at k = d/8 >= 6x (at the cora-profile width)."""
    d = 64
    dense = 512 * d * 4
    int8 = comp_lib.make_compressor(CompressionConfig("int8"))
    topk = comp_lib.make_compressor(CompressionConfig("topk_ef", k=d // 8))
    assert dense / int8.wire_bytes(512, d) > 3.0
    assert dense / topk.wire_bytes(512, d) >= 6.0


def test_roundtrip_with_ef_conserves_signal():
    # classic EF (ef_decay=1): wire value plus kept residual IS the input
    comp = comp_lib.make_compressor(
        CompressionConfig("int8", error_feedback=True, ef_decay=1.0))
    x = jnp.asarray(np.random.default_rng(2).normal(
        size=(3, 5, 16)).astype(np.float32))
    ef = jnp.asarray(np.random.default_rng(3).normal(
        size=(3, 5, 16)).astype(np.float32)) * 0.01
    _, x_hat, new_ef = comp_lib.roundtrip_with_ef(comp, x, ef)
    np.testing.assert_allclose(np.asarray(x_hat + new_ef),
                               np.asarray(x + ef), rtol=1e-6, atol=1e-6)
    # decayed EF carries exactly ef_decay of that residual
    comp2 = comp_lib.make_compressor(
        CompressionConfig("int8", error_feedback=True, ef_decay=0.5))
    _, x_hat2, new_ef2 = comp_lib.roundtrip_with_ef(comp2, x, ef)
    np.testing.assert_allclose(np.asarray(new_ef2),
                               0.5 * np.asarray(new_ef), rtol=1e-6,
                               atol=1e-7)
    with pytest.raises(ValueError, match="ef_decay"):
        CompressionConfig("int8", ef_decay=1.5)


# ------------------------------------------------------------ config surface
def test_compression_config_validation():
    with pytest.raises(ValueError, match="unknown compression method"):
        CompressionConfig("int4")
    with pytest.raises(ValueError, match="requires k"):
        CompressionConfig("topk_ef")
    with pytest.raises(ValueError, match="only meaningful"):
        CompressionConfig("int8", k=4)
    assert CompressionConfig("topk_ef", k=4).resolved_error_feedback
    assert not CompressionConfig("int8").resolved_error_feedback
    assert CompressionConfig("int8", error_feedback=True) \
        .resolved_error_feedback
    assert not CompressionConfig("none").active
    assert comp_lib.make_compressor(CompressionConfig("identity")) is None


def test_experiment_config_compression_block():
    cfg = ExperimentConfig(name="c", dataset="tiny", hidden=16,
                           compression={"method": "topk_ef", "k": 2})
    assert isinstance(cfg.compression, CompressionConfig)
    assert cfg.compression.k == 2
    rt = ExperimentConfig.from_dict(cfg.to_dict())
    assert rt == cfg and isinstance(rt.compression, CompressionConfig)
    with pytest.raises(ValueError, match="invalid compression block"):
        ExperimentConfig(name="c", compression={"method": "nope"})
    with pytest.raises(ValueError, match="secure_agg"):
        ExperimentConfig(name="c", compression={"method": "int8"},
                         secure_agg=True)
    # a GlasuConfig built directly enforces the same incompatibility
    with pytest.raises(AssertionError, match="secure_agg"):
        glasu.GlasuConfig(secure_agg=True,
                          compression=CompressionConfig("int8"))


# ----------------------------------------------------------- engine parity
def _setup(method, kw, **cfg_kw):
    cfg = ExperimentConfig(
        name=f"comp-{method}", dataset="tiny", hidden=16, batch_size=8,
        size_cap=96, rounds=4, eval_every=4, lr=0.05, optimizer="adam",
        compression=dict(method=method, **kw), **cfg_kw)
    data = make_vfl_dataset("tiny", n_clients=cfg.n_clients, seed=cfg.seed)
    mcfg = cfg.glasu_config(data)
    sampler = GlasuSampler(data, cfg.sampler_config(), seed=cfg.seed)
    return cfg, data, mcfg, sampler


@pytest.mark.parametrize("method,kw", METHODS)
def test_scan_matches_sequential_rounds(method, kw):
    cfg, data, mcfg, sampler = _setup(method, kw)
    opt = cfg.make_optimizer()
    params = glasu.init_params(jax.random.PRNGKey(0), mcfg)
    cs0 = glasu.init_comp_state(mcfg, sampler.layer_sizes)
    rounds = [jax.tree.map(np.array, sampler.sample_round())
              for _ in range(4)]
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(1), jnp.arange(4))

    rf = glasu.make_round_fn(mcfg, opt)
    p1, s1, c1 = jax.tree.map(jnp.array, (params, opt.init(params), cs0))
    seq = []
    for t in range(4):
        p1, s1, c1, l = rf(p1, s1, c1, rounds[t], keys[t])
        seq.append(np.asarray(l))

    mf = glasu.make_multi_round_fn(mcfg, opt)
    p2, s2, c2 = jax.tree.map(jnp.array, (params, opt.init(params), cs0))
    p2, s2, c2, losses = mf(p2, s2, c2,
                            jax.tree.map(jnp.asarray, stack_rounds(rounds)),
                            keys)
    np.testing.assert_allclose(np.asarray(losses), np.stack(seq), **COMP_TOL)
    for (pa, la), (_, lb) in zip(jax.tree_util.tree_leaves_with_path(p1),
                                 jax.tree_util.tree_leaves_with_path(p2)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   err_msg=jax.tree_util.keystr(pa),
                                   **COMP_TOL)
    for (pa, la), (_, lb) in zip(jax.tree_util.tree_leaves_with_path(c1),
                                 jax.tree_util.tree_leaves_with_path(c2)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   err_msg=jax.tree_util.keystr(pa),
                                   **COMP_TOL)


@pytest.mark.parametrize("method,kw", [("int8", {}),
                                       ("topk_ef", {"k": 2})])
def test_vmapped_matches_simulation_compressed(method, kw):
    """The message-passing simulation is an independent implementation of
    the compressed protocol; two rounds must agree (and so must the EF
    accumulators and every byte meter)."""
    cfg, data, mcfg, sampler = _setup(method, kw)
    opt = cfg.make_optimizer()
    params = glasu.init_params(jax.random.PRNGKey(0), mcfg)
    rounds = [jax.tree.map(np.array, sampler.sample_round())
              for _ in range(2)]
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(1), jnp.arange(2))

    def run(backend):
        backend.bind(mcfg, opt, sampler)
        p = jax.tree.map(jnp.array, params)
        s = opt.init(p)
        losses, comm = [], None
        for t in range(2):
            out = backend.run_round(p, s, jax.tree.map(jnp.asarray,
                                                       rounds[t]), keys[t])
            p, s = out.params, out.opt_state
            losses.append(np.asarray(out.losses))
            comm = out.comm_bytes
        return p, np.stack(losses), comm, backend.comp_state

    p_v, l_v, comm_v, cs_v = run(VmappedBackend())
    p_s, l_s, comm_s, cs_s = run(SimulationBackend())
    assert comm_v == comm_s > 0
    np.testing.assert_allclose(l_s, l_v, **COMP_TOL)
    for (pa, la), (_, lb) in zip(jax.tree_util.tree_leaves_with_path(p_s),
                                 jax.tree_util.tree_leaves_with_path(p_v)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   err_msg=jax.tree_util.keystr(pa),
                                   **COMP_TOL)
    if cs_v:
        # EF accumulators are NOT compared element-wise across the two
        # implementations: a ULP-level tie between two near-equal
        # magnitudes makes top_k keep different entries, so the residuals
        # legitimately differ by a full entry value at those slots. The
        # behavioral contract is that losses/params agree (above) and
        # that each implementation conserves signal: x_hat + ef == input.
        assert jax.tree.structure(cs_s) == jax.tree.structure(cs_v)


@pytest.mark.parametrize("method,kw", METHODS)
def test_byte_meters_agree_and_shrink(method, kw):
    """analytic (sampler cost model) == measured (simulation message log)
    == shape-only replay, and all are smaller than the dense meter."""
    cfg, data, mcfg, sampler = _setup(method, kw)
    comp = comp_lib.make_compressor(mcfg.compression)
    analytic = sampler.comm_bytes_per_joint_inference(
        mcfg.hidden, mcfg.agg, compressor=comp)
    dense = sampler.comm_bytes_per_joint_inference(mcfg.hidden, mcfg.agg)
    assert analytic < dense

    sb = SimulationBackend()
    sb.bind(mcfg, cfg.make_optimizer(), sampler)
    params = glasu.init_params(jax.random.PRNGKey(0), mcfg)
    batch = jax.tree.map(jnp.array, sampler.sample_round())
    out = sb.run_round(params, sb.optimizer.init(params), batch,
                       jax.random.PRNGKey(0))
    assert out.comm_bytes == analytic      # audit already enforced at raise

    shell = sampler.shape_shell_batch()
    log = simulation.MessageLog()
    simulation.log_index_sync(log, shell, mcfg)
    simulation.log_agg_traffic(log, shell, mcfg, compressor=comp)
    assert log.total_bytes() == analytic
    for kind in ("upload", "broadcast", "index_sync"):
        assert log.total_bytes(kind) == out.message_log.total_bytes(kind)


# --------------------------------------------------- checkpointing of EF
def test_ef_accumulator_checkpoint_bitwise_roundtrip(tmp_path):
    """Interrupt/resume with error feedback: the comp_<step>.npz sidecar
    restores the accumulators bitwise, so a resumed run reproduces the
    uninterrupted one exactly (same program, same state)."""
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    base = ExperimentConfig(
        name="ef-ckpt", dataset="tiny", hidden=16, batch_size=8,
        size_cap=96, rounds=4, eval_every=2, lr=0.05, optimizer="adam",
        compression={"method": "topk_ef", "k": 2})
    cfg = base.with_(ckpt_dir=str(tmp_path), ckpt_every=2, rounds=2)
    Trainer(cfg, data=data).run()
    assert (tmp_path / "comp_00000002.npz").exists()

    res = Trainer(cfg.with_(rounds=4), data=data).run()   # resume 2 -> 4
    straight = Trainer(base, data=data).run()
    for (pa, la), (_, lb) in zip(
            jax.tree_util.tree_leaves_with_path(res.params),
            jax.tree_util.tree_leaves_with_path(straight.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=jax.tree_util.keystr(pa))
    assert res.comm_bytes == straight.comm_bytes


def test_ef_restored_bitwise_at_resume(tmp_path):
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = ExperimentConfig(
        name="ef-bits", dataset="tiny", hidden=16, batch_size=8,
        size_cap=96, rounds=2, eval_every=2, lr=0.05,
        compression={"method": "int8", "error_feedback": True},
        ckpt_dir=str(tmp_path), ckpt_every=2)
    t1 = Trainer(cfg, data=data)
    t1.run()
    saved_cs = jax.tree.map(np.array, t1.backend.comp_state)
    t2 = Trainer(cfg, data=data)            # resume landing on rounds == 2
    t2.run()
    for (pa, la), (_, lb) in zip(
            jax.tree_util.tree_leaves_with_path(saved_cs),
            jax.tree_util.tree_leaves_with_path(t2.backend.comp_state)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=jax.tree_util.keystr(pa))


def test_compression_is_resume_mutable(tmp_path):
    """The compression block may change across a resume (it is a wire
    strategy, not model state): codec changes reset the EF accumulators,
    enabling/disabling compression round-trips cleanly."""
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    base = ExperimentConfig(
        name="comp-resume", dataset="tiny", hidden=16, batch_size=8,
        size_cap=96, rounds=2, eval_every=2, lr=0.05,
        ckpt_dir=str(tmp_path), ckpt_every=2,
        compression={"method": "topk_ef", "k": 2})
    Trainer(base, data=data).run()
    # switch codec: topk_ef -> int8+EF; the stale accumulators must NOT be
    # restored (same tree shapes, different meaning)
    t2 = Trainer(base.with_(rounds=4,
                            compression={"method": "int8",
                                         "error_feedback": True}),
                 data=data)
    t2.run()
    # then drop compression entirely and resume again
    res = Trainer(base.with_(rounds=6, compression=None), data=data).run()
    assert res.rounds_run == 6
    # and re-enable from a dense checkpoint
    res = Trainer(base.with_(rounds=8), data=data).run()
    assert res.rounds_run == 8


def test_codec_change_resets_accumulators(tmp_path):
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    base = ExperimentConfig(
        name="comp-reset", dataset="tiny", hidden=16, batch_size=8,
        size_cap=96, rounds=2, eval_every=2, lr=0.05,
        ckpt_dir=str(tmp_path), ckpt_every=2,
        compression={"method": "topk_ef", "k": 2})
    t1 = Trainer(base, data=data)
    t1.run()
    assert any(float(jnp.sum(jnp.abs(v))) > 0
               for v in jax.tree.leaves(t1.backend.comp_state))
    t2 = Trainer(base.with_(rounds=2,
                            compression={"method": "int8",
                                         "error_feedback": True}),
                 data=data)
    t2.state.params = glasu.init_params(jax.random.PRNGKey(t2.cfg.seed),
                                        t2.model_cfg)
    t2.state.opt_state = t2.optimizer.init(t2.state.params)
    for h in t2.hooks:
        h.on_train_start(t2)                # resume to round 2, no new rounds
    for v in jax.tree.leaves(t2.backend.comp_state):
        np.testing.assert_array_equal(np.asarray(v), np.zeros_like(v))


# ----------------------------------------------------------- trainer E2E
def test_trainer_comm_bytes_shrink_and_loss_trains():
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    base = ExperimentConfig(name="comp-e2e", dataset="tiny", hidden=16,
                            batch_size=8, size_cap=96, rounds=4,
                            eval_every=4, lr=0.05, optimizer="adam")
    dense = Trainer(base, data=data).run()
    comp = Trainer(base.with_(compression={"method": "int8"}),
                   data=data).run()
    assert 0 < comp.comm_bytes < dense.comm_bytes
    assert np.isfinite(comp.history[-1]["loss"])


def test_uncompressed_backend_state_is_none():
    data = make_vfl_dataset("tiny", n_clients=3, seed=0)
    cfg = ExperimentConfig(name="dense", dataset="tiny", hidden=16,
                           batch_size=8, size_cap=96, rounds=0)
    t = Trainer(cfg, data=data)
    assert t.backend.compressor is None and t.backend.comp_state is None
