"""Roofline analysis (mandate g): three terms per (arch x shape) from the
dry-run JSON records produced by launch/dryrun.py.

  compute term    = per-device HLO FLOPs (trip-count-aware walker) / 197 TF/s
  memory term     = per-device HBM bytes (fusion-boundary model) / 819 GB/s
  collective term = per-device collective bytes / 50 GB/s ICI link

MODEL_FLOPS uses 6*N_active*D for training and 2*N_active*D for inference
tokens (D = global tokens). The ratio MODEL_FLOPS / HLO_FLOPs exposes remat
and dispatch overheads. Terms are SINGLE-POD (16x16); the multi-pod records
prove the pod axis lowers.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16e9


def model_flops(rec: dict) -> float:
    n_act = rec["active_params"]
    if rec["mode"] == "train":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 6.0 * n_act * tokens
    if rec["mode"] == "prefill":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 2.0 * n_act * tokens
    return 2.0 * n_act * rec["global_batch"]     # decode: one token/seq


def terms(rec: dict) -> dict:
    comp = rec["flops"] / PEAK_FLOPS
    memt = rec["hbm_bytes"] / HBM_BW
    coll = rec["collective_bytes"] / ICI_BW
    dom = max(("compute", comp), ("memory", memt), ("collective", coll),
              key=lambda kv: kv[1])
    mf = model_flops(rec)
    hlo_global = rec["flops"] * rec["n_devices"]
    mem = rec.get("memory", {})
    hbm_used = (mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0)
                - mem.get("alias_size_in_bytes", 0))
    return {
        "compute_s": comp, "memory_s": memt, "collective_s": coll,
        "dominant": dom[0], "model_flops": mf,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "hbm_used_gb": hbm_used / 1e9,
        "fits": hbm_used <= HBM_PER_CHIP,
    }


def advice(rec: dict, t: dict) -> str:
    if t["dominant"] == "collective":
        return ("skip/batch collectives (GLASU lazy aggregation, larger "
                "microbatch per sync)")
    if t["dominant"] == "memory":
        if rec["mode"] == "decode":
            return "shrink/ shard the KV cache (window, latent or ring cache)"
        return "raise arithmetic intensity (fuse, larger per-chip batch)"
    if t["useful_ratio"] < 0.5:
        return "reduce remat recompute / dispatch overcompute"
    return "compute-bound at healthy efficiency: scale chips or quantize"


def load(results_dir: str = "results/dryrun", mesh: str = "16x16") -> List[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        r = json.load(open(f))
        if r.get("ok") and r.get("mesh") == mesh:
            recs.append(r)
    return recs


def run(results_dir: str = "results/dryrun", emit_markdown: Optional[str] = None):
    recs = load(results_dir)
    rows = []
    for r in recs:
        t = terms(r)
        rows.append((r, t))
        print(f"roofline/{r['arch']}/{r['shape']},"
              f"compute_us={t['compute_s'] * 1e6:.1f},"
              f"memory_us={t['memory_s'] * 1e6:.1f};"
              f"collective_us={t['collective_s'] * 1e6:.1f};"
              f"dominant={t['dominant']};useful={t['useful_ratio']:.2f};"
              f"hbm_gb={t['hbm_used_gb']:.1f}")
    if emit_markdown:
        with open(emit_markdown, "w") as fh:
            fh.write("| arch | shape | compute (ms) | memory (ms) | "
                     "collective (ms) | dominant | MODEL/HLO | HBM GB/chip | "
                     "fits 16G | next lever |\n|---|---|---|---|---|---|---|---|---|---|\n")
            for r, t in rows:
                fh.write(
                    f"| {r['arch']} | {r['shape']} | {t['compute_s']*1e3:.2f} "
                    f"| {t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} "
                    f"| **{t['dominant']}** | {t['useful_ratio']:.2f} "
                    f"| {t['hbm_used_gb']:.1f} | "
                    f"{'y' if t['fits'] else 'NO'} | {advice(r, t)} |\n")
    return rows
