"""Paper Table 4: time / communication to reach a target accuracy vs Q."""
from .common import BenchSettings, csv, run_method


def run(dataset="cora", target=0.80, qs=(2, 4, 8, 16), seeds=(0,),
        rounds=None, settings=None):
    s = settings or BenchSettings()
    out = {}
    for q in qs:
        accs, times, comms, rounds_used = [], [], [], []
        for seed in seeds:
            r = run_method("glasu", dataset, seed=seed, s=s, q=q,
                           target_acc=target, rounds=rounds)
            accs.append(r.test_acc)
            times.append(r.wall_seconds)
            comms.append(r.comm_bytes)
            rounds_used.append(r.rounds_run)
        acc = sum(accs) / len(accs)
        out[q] = (acc, sum(times) / len(times), sum(comms) / len(comms))
        csv(f"table4/{dataset}/Q={q}", f"acc={acc * 100:.1f}",
            f"time_s={out[q][1]:.1f};comm_MB={out[q][2] / 1e6:.2f};"
            f"rounds={sum(rounds_used) / len(rounds_used):.0f}")
    return out
