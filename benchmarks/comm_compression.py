"""Compressed embedding exchange: bytes-per-round vs accuracy, gated.

Runs the cora-profile hot path (L=4 GCNII, hidden 64, M=3, batch 16,
fanout 3, size_cap 512 — the same shape every other training benchmark
uses) once per wire codec:

  none     — dense float32 exchange (baseline)
  int8     — per-row absmax quantization (+ f32 scale per row)
  fp8      — float8_e4m3fn cast
  topk_ef  — top-k magnitude sparsification at k = hidden/8, with decayed
             error feedback (f16 value + i16 index pairs)

and reports per-round communication (the audited byte meter, index-sync
traffic included) plus final training loss / validation accuracy.

Gates (full mode):
  * int8 reduces bytes/round by >= 3x; topk_ef (k = d/8) by >= 6x;
  * final-loss parity: every codec's final loss within ``LOSS_SLACK`` of
    the dense baseline (catches EF divergence — an unstable accumulator
    sends the loss to 10s while accuracy lags behind) and validation
    accuracy within ``ACC_SLACK``;
  * meter integrity on EVERY codec: the sharded backend binds green (its
    trace-recorded collective bytes audit term-by-term against the
    shape-replayed message log at bind — a divergence raises), and one
    simulated round's actual compressed payloads measure exactly the
    analytic bytes the training runs were charged.

``--smoke`` runs tiny shapes for CI signal (meters still audited, no
perf/parity gates). Results append to ``BENCH_comm.json`` so the
bytes-vs-accuracy trajectory accumulates per PR.

Run: ``PYTHONPATH=src python -m benchmarks.comm_compression [--smoke]``
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax

from repro.api import ExperimentConfig, Trainer, make_backend
from repro.comm.compression import make_compressor
from repro.core import glasu
from repro.fed import simulation
from repro.graph.sampler import GlasuSampler
from repro.graph.synth import make_vfl_dataset

HOT = dict(dataset="cora", n_clients=3, n_layers=4, hidden=64,
           backbone="gcnii", batch_size=16, fanout=3, size_cap=512)
SMOKE = dict(dataset="tiny", n_clients=3, n_layers=4, hidden=16,
             backbone="gcnii", batch_size=8, fanout=3, size_cap=96)

LOSS_SLACK = 0.5      # absolute final-loss slack vs the dense baseline
ACC_SLACK = 0.05      # absolute val-accuracy slack vs the dense baseline


def _codecs(hidden: int):
    return [
        ("none", None),
        ("int8", {"method": "int8"}),
        ("fp8", {"method": "fp8"}),
        (f"topk_ef_k{hidden // 8}",
         {"method": "topk_ef", "k": hidden // 8}),
    ]


def _audit_meters(cfg: ExperimentConfig, data) -> int:
    """Bind the sharded backend (collective-vs-log audit runs there) and
    replay one simulated round; returns the audited bytes/round."""
    mcfg = cfg.glasu_config(data)
    sampler = GlasuSampler(data, cfg.sampler_config(), seed=cfg.seed)
    opt = cfg.make_optimizer()
    sb = make_backend("sharded")
    sb.bind(mcfg, opt, sampler)          # raises if the meters disagree
    mb = make_backend("simulation")
    mb.bind(mcfg, opt, sampler)
    params = glasu.init_params(jax.random.PRNGKey(cfg.seed), mcfg)
    batch = jax.tree.map(jax.numpy.array, sampler.sample_round())
    out = mb.run_round(params, opt.init(params), batch,
                       jax.random.PRNGKey(0))
    up_down = out.message_log.total_bytes("upload") \
        + out.message_log.total_bytes("broadcast")
    assert sum(r.star_bytes() for r in sb.collectives) == up_down, \
        "collective records diverge from the simulated round's payloads"
    assert sb.bytes_per_round == out.comm_bytes, \
        "sharded and simulation byte meters diverge"
    return sb.bytes_per_round


def run(smoke: bool = False, out_path: str = "BENCH_comm.json",
        rounds: int = None):
    shape = SMOKE if smoke else HOT
    rounds = rounds or (8 if smoke else 60)
    base = ExperimentConfig(name="comm-bench", rounds=rounds,
                            eval_every=max(rounds // 3, 1), lr=0.01,
                            **shape)
    data = make_vfl_dataset(base.dataset, n_clients=base.n_clients,
                            seed=base.seed)

    results = {}
    for label, cc in _codecs(base.hidden):
        cfg = base.with_(name=f"comm-{label}", compression=cc)
        audited = _audit_meters(cfg, data)
        t0 = time.perf_counter()
        res = Trainer(cfg, data=data).run()
        per_round = res.comm_bytes // max(res.rounds_run, 1)
        assert per_round == audited, \
            f"{label}: trainer charged {per_round} B/round, audit says " \
            f"{audited}"
        results[label] = {
            "bytes_per_round": per_round,
            "final_loss": float(res.history[-1]["loss"]),
            "val_acc": float(res.val_acc),
            "wall_seconds": time.perf_counter() - t0,
        }

    dense = results["none"]
    for label, r in results.items():
        ratio = dense["bytes_per_round"] / r["bytes_per_round"]
        r["bytes_reduction"] = ratio
        print(f"comm/{label},{r['bytes_per_round']}B/round,"
              f"reduction={ratio:.2f}x loss={r['final_loss']:.4f} "
              f"val={r['val_acc']:.3f}")

    entry = {
        "bench": "comm_compression", "smoke": smoke, "rounds": rounds,
        "shape": shape, "results": results,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    path = Path(out_path)
    history = []
    if path.exists():
        try:
            history = json.loads(path.read_text())
        except (json.JSONDecodeError, ValueError):
            history = []
    if not isinstance(history, list):
        history = [history]
    history.append(entry)
    path.write_text(json.dumps(history, indent=1))
    print(f"comm/bench_json,{path},entries={len(history)}")

    if not smoke:
        topk_label = f"topk_ef_k{base.hidden // 8}"
        assert results["int8"]["bytes_reduction"] >= 3.0, \
            f"int8 must cut bytes/round >= 3x, got " \
            f"{results['int8']['bytes_reduction']:.2f}x"
        assert results[topk_label]["bytes_reduction"] >= 6.0, \
            f"topk_ef at k=d/8 must cut bytes/round >= 6x, got " \
            f"{results[topk_label]['bytes_reduction']:.2f}x"
        for label, r in results.items():
            assert r["final_loss"] <= dense["final_loss"] + LOSS_SLACK, \
                f"{label}: final loss {r['final_loss']:.3f} not within " \
                f"{LOSS_SLACK} of dense {dense['final_loss']:.3f} (EF " \
                f"divergence?)"
            assert r["val_acc"] >= dense["val_acc"] - ACC_SLACK, \
                f"{label}: val acc {r['val_acc']:.3f} more than " \
                f"{ACC_SLACK} below dense {dense['val_acc']:.3f}"
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, audits only, no perf gates (CI)")
    ap.add_argument("--out", default="BENCH_comm.json")
    ap.add_argument("--rounds", type=int, default=None)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out, rounds=args.rounds)


if __name__ == "__main__":
    main()
