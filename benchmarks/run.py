"""Benchmark orchestrator — one section per paper table/figure + roofline.

CSV convention: name,value,derived

  --quick   small rounds (CI-friendly)
  --full    paper-scale rounds + more datasets/seeds
"""
import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip-gnn", action="store_true",
                    help="only kernels + roofline (no GNN training)")
    args = ap.parse_args()

    from . import kernel_bench, roofline
    print("# kernels")
    kernel_bench.run()

    print("# roofline (from dry-run artifacts; run launch/dryrun first)")
    if os.path.isdir("results/dryrun"):
        roofline.run(emit_markdown="results/roofline_table.md")
    else:
        print("roofline/SKIPPED,no results/dryrun directory,")

    if args.skip_gnn:
        return

    from . import train_bench
    print("# train loop (scanned engine vs per-round)")
    train_bench.run(smoke=not args.full)

    from . import comm_compression
    print("# comm compression (bytes/round vs accuracy, meters audited)")
    comm_compression.run(smoke=not args.full)

    from . import serve_bench
    print("# serving (cold/warm/compressed query mixes, bytes audited)")
    serve_bench.run(smoke=not args.full)

    from . import (accuracy_parity, backbones, client_scaling, comm_model,
                   lazy_aggregation, stale_updates)
    from .common import BenchSettings

    if args.full:
        s = BenchSettings(rounds=240)
        datasets = ("cora", "citeseer", "suzhou", "venice")
        seeds = (0, 1, 2)
    else:
        s = BenchSettings(rounds=100)
        datasets = ("cora", "suzhou")
        seeds = (0,)

    print("# table2: accuracy parity")
    accuracy_parity.run(datasets=datasets, seeds=seeds, settings=s)
    print("# table3: lazy aggregation")
    lazy_aggregation.run(dataset="cora", seeds=seeds, settings=s)
    print("# table4: stale updates (time/comm to target)")
    stale_updates.run(dataset="cora", target=0.85, seeds=seeds, settings=s)
    print("# fig3: backbones")
    backbones.run(dataset="cora", seeds=seeds, settings=s)
    print("# table5: client scaling")
    client_scaling.run(dataset="citeseer", seeds=seeds, settings=s)
    print("# comm model QL/K")
    comm_model.run(dataset="cora", settings=s)


if __name__ == "__main__":
    main()
