"""Paper Table 2: test accuracy of Cent / StAl / Sim / GLASU-1 / GLASU-4."""
from .common import BenchSettings, csv, run_method

METHODS = ["cent", "stal", "sim", "glasu1", "glasu4"]


def run(datasets=("cora", "suzhou"), seeds=(0,), rounds=None, settings=None):
    s = settings or BenchSettings()
    rows = {}
    for ds in datasets:
        for m in METHODS:
            accs, comms = [], []
            for seed in seeds:
                q = 4 if m == "glasu4" else 1
                meth = "glasu" if m.startswith("glasu") else m
                r = run_method(meth, ds, seed=seed, s=s, q=q, rounds=rounds)
                accs.append(r.test_acc)
                comms.append(r.comm_bytes)
            acc = sum(accs) / len(accs)
            rows[(ds, m)] = acc
            csv(f"table2/{ds}/{m}", f"{acc * 100:.1f}",
                f"comm_MB={comms[0] / 1e6:.1f}")
    return rows
