"""Paper Table 3: accuracy / time / comm under K = 4, 2, 1 lazy aggregation."""
from .common import BenchSettings, csv, run_method


def run(dataset="cora", seeds=(0,), rounds=None, settings=None):
    s = settings or BenchSettings()
    base_time = base_comm = None
    out = {}
    for k in (4, 2, 1):
        accs, times, comms = [], [], []
        for seed in seeds:
            r = run_method("glasu", dataset, seed=seed, s=s, k=k, q=1,
                           rounds=rounds)
            accs.append(r.test_acc)
            times.append(r.wall_seconds)
            comms.append(r.comm_bytes)
        acc = sum(accs) / len(accs)
        t = sum(times) / len(times)
        c = sum(comms) / len(comms)
        if k == 4:
            base_time, base_comm = t, c
        saving_t = 100 * (1 - t / base_time)
        saving_c = 100 * (1 - c / base_comm)
        out[k] = (acc, t, c)
        csv(f"table3/{dataset}/K={k}", f"acc={acc * 100:.1f}",
            f"time_s={t:.1f};comm_MB={c / 1e6:.1f};"
            f"save_time%={saving_t:.1f};save_comm%={saving_c:.1f}")
    return out
