"""Training-loop throughput: scanned multi-round engine vs per-round loop.

Measures end-to-end rounds/sec of the vmapped backend at the paper's
hot-path shapes (cora profile: L=4 GCNII, hidden 64, batch 16, fanout 3,
size_cap 512, M=3) for three drivers:

  per_round — the historical Trainer loop: serial host sampling, a
              full-batch ``jnp.array`` copy, one jit dispatch per round;
  scan_K    — the device-resident engine: K pre-sampled rounds stacked and
              advanced by one ``lax.scan`` dispatch with donated
              params/opt_state, sampling prefetched on a worker thread
              (K ∈ {1, 8, 32}).

Gate (full mode): scan_8 must be strictly faster than per_round. Results
are appended to ``BENCH_train.json`` so the wall-clock trajectory
accumulates per PR; ``--smoke`` runs a tiny shape for CI signal (no perf
gate — shared CI boxes are too noisy to gate on) but still exercises every
driver and writes the JSON artifact.

Run: ``PYTHONPATH=src python -m benchmarks.train_bench [--smoke]``
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.api import ExperimentConfig
from repro.api.backends import make_backend
from repro.core import glasu
from repro.graph.prefetch import PrefetchSampler
from repro.graph.sampler import GlasuSampler
from repro.graph.synth import make_vfl_dataset

HOT = dict(dataset="cora", n_clients=3, n_layers=4, hidden=64,
           backbone="gcnii", batch_size=16, fanout=3, size_cap=512)
SMOKE = dict(dataset="tiny", n_clients=3, n_layers=4, hidden=16,
             backbone="gcnii", batch_size=8, fanout=3, size_cap=96)
# 1M-node power-law profile, streamed feature store (graph/synth.py
# POWERLAW_SPECS): the RSS gate below proves training never materializes X
SCALE = dict(dataset="powerlaw-1m", n_clients=2, n_layers=2, hidden=32,
             backbone="gcn", batch_size=16, fanout=3, size_cap=512,
             table_cap=8)


def _setup(shape):
    cfg = ExperimentConfig(name="train-bench", rounds=0, **shape)
    data = make_vfl_dataset(cfg.dataset, n_clients=cfg.n_clients,
                            seed=cfg.seed)
    mcfg = cfg.glasu_config(data)
    optimizer = cfg.make_optimizer()
    sampler = GlasuSampler(data, cfg.sampler_config(), seed=cfg.seed)
    backend = make_backend("vmapped")
    backend.bind(mcfg, optimizer, sampler)
    params = glasu.init_params(jax.random.PRNGKey(cfg.seed), mcfg)
    opt_state = optimizer.init(params)
    return data, cfg, mcfg, optimizer, sampler, backend, params, opt_state


def _per_round_loop(shape, rounds):
    """The pre-engine Trainer loop, reproduced as the baseline."""
    _, cfg, mcfg, _, sampler, backend, params, opt_state = _setup(shape)
    key = jax.random.PRNGKey(0)
    # warmup: compile the round fn outside the timed region
    batch = jax.tree.map(jnp.array, sampler.sample_round())
    out = backend.run_round(params, opt_state, batch, key)
    jax.block_until_ready(out.losses)
    params, opt_state = out.params, out.opt_state
    t0 = time.perf_counter()
    for t in range(rounds):
        batch = jax.tree.map(jnp.array, sampler.sample_round())
        out = backend.run_round(params, opt_state, batch,
                                jax.random.fold_in(key, t))
        params, opt_state = out.params, out.opt_state
    jax.block_until_ready(out.losses)
    return rounds / (time.perf_counter() - t0)


def _scan_loop(shape, rounds, k):
    """The device-resident engine at rounds_per_step=k."""
    assert rounds % k == 0
    _, cfg, mcfg, _, sampler, backend, params, opt_state = _setup(shape)
    key = jax.random.PRNGKey(0)
    fold_keys = jax.jit(jax.vmap(jax.random.fold_in, in_axes=(None, 0)))
    schedule = [k] * (rounds // k + 1)          # +1 warmup step
    prefetch = PrefetchSampler(sampler, schedule, n_buffers=2)
    try:
        step = prefetch.get()                   # warmup: compile
        keys = fold_keys(key, jnp.arange(k))
        out = backend.run_step(params, opt_state,
                               jax.device_put(step.data), keys)
        jax.block_until_ready(out.losses)
        params, opt_state = out.params, out.opt_state
        prefetch.retire(step, out.losses)
        t0 = time.perf_counter()
        t = k
        for _ in range(rounds // k):
            step = prefetch.get()
            keys = fold_keys(key, jnp.arange(t, t + k))
            out = backend.run_step(params, opt_state,
                                   jax.device_put(step.data), keys)
            params, opt_state = out.params, out.opt_state
            prefetch.retire(step, out.losses)
            t += k
        jax.block_until_ready(out.losses)
        return rounds / (time.perf_counter() - t0)
    finally:
        prefetch.close()


class _RssMonitor:
    """Samples the process RSS on a daemon thread; ``peak`` is the max."""

    def __init__(self, interval_s: float = 0.05):
        import threading
        import psutil
        self._proc = psutil.Process()
        self._interval = interval_s
        self._stop = threading.Event()
        self.peak = self._proc.memory_info().rss
        self._thread = threading.Thread(target=self._loop, daemon=True)

    def _loop(self):
        while not self._stop.wait(self._interval):
            self.peak = max(self.peak, self._proc.memory_info().rss)

    def __enter__(self):
        self._thread.start()
        return self

    def __exit__(self, *exc):
        self._stop.set()
        self._thread.join()
        self.peak = max(self.peak, self._proc.memory_info().rss)


def _scale_smoke(rounds: int = 2):
    """Streamed-store smoke on the 1M-node power-law profile.

    Gates (always on — memory bounds, not timing, so CI noise is moot):

      * the run COMPLETES: sampler build + ``rounds`` training rounds on a
        2^20-node graph through MemmapFeatureStore row gathers;
      * peak host RSS past dataset build stays BELOW what materializing the
        full per-client padded feature block (M, N, d_pad) would add — the
        invariant that makes the streamed store worth having;
      * steady-state jitted round bodies run under
        ``jax.transfer_guard("disallow")``: the store's host gathers stage
        batches explicitly (``device_put``), never as implicit uploads
        inside the round dispatch.
    """
    import gc

    import numpy as np
    import psutil

    t_build0 = time.perf_counter()
    data, cfg, mcfg, _, sampler, backend, params, opt_state = _setup(SCALE)
    build_s = time.perf_counter() - t_build0
    m, n = data.n_clients, data.n_nodes
    d_pad = max(c.feat_dim for c in data.clients)
    full_feat_bytes = m * n * d_pad * 4
    key = jax.random.PRNGKey(0)
    # warmup OUTSIDE the guard: compilation may stage closure constants
    batch = jax.tree.map(jnp.array, sampler.sample_round())
    out = backend.run_round(params, opt_state, jax.device_put(batch), key)
    jax.block_until_ready(out.losses)
    params, opt_state = out.params, out.opt_state

    # per-round keys staged before the guard: fold_in(key, int) implicitly
    # uploads its scalar, which is exactly what the guard exists to catch
    keys = [jax.random.fold_in(key, t) for t in range(rounds)]
    gc.collect()
    rss0 = psutil.Process().memory_info().rss
    t0 = time.perf_counter()
    with _RssMonitor() as mon:
        with jax.transfer_guard("disallow"):
            for t in range(rounds):
                batch = jax.tree.map(np.array, sampler.sample_round())
                out = backend.run_round(params, opt_state,
                                        jax.device_put(batch), keys[t])
                params, opt_state = out.params, out.opt_state
            jax.block_until_ready(out.losses)
    train_s = time.perf_counter() - t0
    loss = float(jax.device_get(out.losses).mean())
    assert np.isfinite(loss), f"scale smoke diverged: loss={loss}"
    delta = mon.peak - rss0
    print(f"train/scale_1m_build,{build_s:.1f}s,n={n},edges={data.full.n_edges}")
    print(f"train/scale_1m_rounds,{rounds / train_s:.2f}rounds/s,"
          f"loss={loss:.3f}")
    print(f"train/scale_1m_rss_delta,{delta / 1e6:.0f}MB,"
          f"budget_MB={full_feat_bytes / 1e6:.0f}")
    assert delta < full_feat_bytes, (
        f"streamed-store training grew RSS by {delta / 1e6:.0f}MB, at or "
        f"above the {full_feat_bytes / 1e6:.0f}MB a full (M, N, d_pad) "
        f"feature materialization would cost — the store is not streaming")
    return {"n_nodes": n, "n_edges": data.full.n_edges,
            "build_seconds": build_s, "rounds": rounds,
            "rounds_per_sec": rounds / train_s, "loss": loss,
            "rss_delta_bytes": int(delta),
            "full_feat_bytes": int(full_feat_bytes)}


def run(smoke: bool = False, out_path: str = "BENCH_train.json",
        rounds: int = None, reps: int = None):
    shape = SMOKE if smoke else HOT
    ks = (1, 8, 32)
    rounds = rounds or (32 if smoke else 96)
    rounds = ((rounds + 31) // 32) * 32         # round up to an lcm(ks) multiple
    reps = reps or (1 if smoke else 4)
    # Interleaved reps: each rep measures every driver back-to-back, so a
    # load spike hits neighbours, not one driver; best-of-reps is the
    # least-noise estimate per driver (kernel_bench's min-time rationale)
    # and the gate compares scan_8/per_round WITHIN a rep (paired windows).
    samples = {"per_round": []}
    samples.update({f"scan_{k}": [] for k in ks})
    for _ in range(reps):
        samples["per_round"].append(_per_round_loop(shape, rounds))
        for k in ks:
            samples[f"scan_{k}"].append(_scan_loop(shape, rounds, k))
    results = {d: max(v) for d, v in samples.items()}
    paired = max(s / p for s, p in zip(samples["scan_8"],
                                       samples["per_round"]))
    print(f"train/per_round,{results['per_round']:.2f}rounds/s,baseline")
    for k in ks:
        print(f"train/scan_k{k},{results[f'scan_{k}']:.2f}rounds/s,"
              f"speedup_vs_per_round="
              f"{results[f'scan_{k}'] / results['per_round']:.2f}x")
    print(f"train/scan_k8_paired_speedup,{paired:.2f}x,best_paired_rep")

    scale = _scale_smoke()

    entry = {
        "bench": "train", "smoke": smoke, "rounds_timed": rounds,
        "reps": reps, "shape": shape, "rounds_per_sec": results,
        "speedup_scan8_vs_per_round": results["scan_8"] / results["per_round"],
        "paired_speedup_scan8": paired,
        "scale_1m": scale,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    path = Path(out_path)
    history = []
    if path.exists():
        try:
            history = json.loads(path.read_text())
        except (json.JSONDecodeError, ValueError):
            history = []
    if not isinstance(history, list):
        history = [history]
    history.append(entry)
    path.write_text(json.dumps(history, indent=1))
    print(f"train/bench_json,{path},entries={len(history)}")

    if not smoke:
        assert paired > 1.0, (
            f"scanned engine (K=8) must beat the per-round loop in at least "
            f"one paired measurement window; best paired speedup {paired:.3f}"
            f" (best-of per driver: scan_8 {results['scan_8']:.2f} r/s vs "
            f"per_round {results['per_round']:.2f} r/s)")
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, no perf gate (CI)")
    ap.add_argument("--out", default="BENCH_train.json")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out, rounds=args.rounds,
        reps=args.reps)


if __name__ == "__main__":
    main()
