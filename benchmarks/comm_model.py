"""§3.4 communication-saving model: measured bytes vs the QL/K formula."""
from repro.core.glasu import GlasuConfig
from repro.graph.sampler import GlasuSampler, SamplerConfig
from repro.graph.synth import make_vfl_dataset

from .common import BenchSettings, agg_layers_for_k, csv


def run(dataset="cora", settings=None):
    s = settings or BenchSettings()
    data = make_vfl_dataset(dataset, n_clients=3, seed=0)
    base = None
    out = {}
    for (k, q) in [(4, 1), (2, 1), (1, 1), (2, 4), (1, 8)]:
        agg = agg_layers_for_k(s.n_layers, k)
        scfg = SamplerConfig(n_layers=s.n_layers, agg_layers=agg,
                             batch_size=s.batch_size, fanout=s.fanout,
                             size_cap=s.size_cap)
        sampler = GlasuSampler(data, scfg, seed=0)
        per_round = sampler.comm_bytes_per_joint_inference(s.hidden)
        per_update = per_round / q           # Q local updates per round
        if base is None:
            base = per_update
        measured = base / per_update
        predicted = (q * s.n_layers / k) / (s.n_layers / 4)  # vs K=4,Q=1 base
        out[(k, q)] = (per_update, measured)
        csv(f"comm/K={k},Q={q}", f"bytes_per_update={per_update:.0f}",
            f"saving_x={measured:.2f};predicted_QL/K_x={predicted:.2f}")
    return out
