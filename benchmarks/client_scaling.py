"""Paper Table 5: Cent / StAl / GLASU across M = 3, 5, 7 clients."""
from .common import BenchSettings, csv, run_method


def run(dataset="citeseer", ms=(3, 5, 7), seeds=(0,), rounds=None,
        settings=None):
    s = settings or BenchSettings()
    out = {}
    cent = run_method("cent", dataset, seed=seeds[0], s=s, rounds=rounds)
    csv(f"table5/{dataset}/cent", f"acc={cent.test_acc * 100:.1f}")
    for m in ms:
        for meth in ("stal", "glasu"):
            accs = []
            for seed in seeds:
                r = run_method(meth, dataset, n_clients=m, seed=seed, s=s,
                               q=1, rounds=rounds)
                accs.append(r.test_acc)
            acc = sum(accs) / len(accs)
            out[(m, meth)] = acc
            csv(f"table5/{dataset}/M={m}/{meth}", f"acc={acc * 100:.1f}")
    return out
