"""Paper Table 5 (Cent / StAl / GLASU across M = 3, 5, 7 clients) plus the
backend-scaling chart: per-round wall clock vs n_clients for the vmapped
(single-device stacked-axis) and sharded (one device per client,
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` on CPU) backends.

  PYTHONPATH=src python -m benchmarks.client_scaling --backend sharded
  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m benchmarks.client_scaling --backend both --scaling-only
"""
import argparse

from .common import BenchSettings, csv, run_method


def run(dataset="citeseer", ms=(3, 5, 7), seeds=(0,), rounds=None,
        settings=None, backend="vmapped"):
    s = settings or BenchSettings()
    out = {}
    cent = run_method("cent", dataset, seed=seeds[0], s=s, rounds=rounds)
    csv(f"table5/{dataset}/cent", f"acc={cent.test_acc * 100:.1f}")
    for m in ms:
        for meth in ("stal", "glasu"):
            accs = []
            for seed in seeds:
                r = run_method(meth, dataset, n_clients=m, seed=seed, s=s,
                               q=1, rounds=rounds, backend=backend)
                accs.append(r.test_acc)
            acc = sum(accs) / len(accs)
            out[(m, meth)] = acc
            csv(f"table5/{dataset}/M={m}/{meth}",
                f"acc={acc * 100:.1f}", f"backend={backend}")
    return out


def run_scaling(dataset="citeseer", ms=(3, 5, 7), rounds=16, reps=3,
                backends=("vmapped", "sharded"), settings=None):
    """Per-round wall clock vs client count, per backend.

    Times the backends' scanned ``run_step`` directly (``rounds`` rounds per
    dispatch, best of ``reps``, compile excluded via one warmup call) — the
    same hot path the Trainer drives, without eval/prefetch noise. For the
    sharded backend the client-mesh device count rides along in the derived
    column, so the chart distinguishes real multi-device placement from the
    degenerate 1-device mesh (run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to get one CPU
    device per client).
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.api import ExperimentConfig, make_backend
    from repro.core import glasu
    from repro.graph.prefetch import stack_rounds
    from repro.graph.sampler import GlasuSampler
    from repro.graph.synth import make_vfl_dataset

    s = settings or BenchSettings()
    out = {}
    for m in ms:
        cfg = ExperimentConfig(
            name=f"scaling-{dataset}-M{m}", dataset=dataset, n_clients=m,
            n_layers=s.n_layers, hidden=s.hidden, backbone=s.backbone,
            batch_size=s.batch_size, fanout=s.fanout, size_cap=s.size_cap,
            rounds=rounds, lr=s.lr)
        data = make_vfl_dataset(dataset, n_clients=m, seed=0)
        mcfg = cfg.glasu_config(data)
        sampler = GlasuSampler(data, cfg.sampler_config(), seed=0)
        params0 = glasu.init_params(jax.random.PRNGKey(0), mcfg)
        opt = cfg.make_optimizer()
        batches = jax.tree.map(
            jnp.asarray,
            stack_rounds([jax.tree.map(lambda x: x.copy(),
                                       sampler.sample_round())
                          for _ in range(rounds)]))
        keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            jax.random.PRNGKey(1), jnp.arange(rounds))
        for backend in backends:
            b = make_backend(backend)
            b.bind(mcfg, opt, sampler)
            best = float("inf")
            for rep in range(reps + 1):       # rep 0 = compile warmup
                p = jax.tree.map(jnp.array, params0)   # run_step donates
                o = opt.init(p)
                t0 = time.perf_counter()
                res = b.run_step(p, o, batches, keys)
                jax.block_until_ready(res.losses)
                jax.block_until_ready(jax.tree.leaves(res.params)[0])
                if rep:
                    best = min(best, time.perf_counter() - t0)
            devices = (b.mesh.shape["clients"] if backend == "sharded"
                       else 1)
            s_round = best / rounds
            out[(m, backend)] = s_round
            csv(f"scaling/{dataset}/M={m}/{backend}",
                f"s_per_round={s_round:.5f}",
                f"devices={devices},comm_bytes={res.comm_bytes_round}")
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="citeseer")
    ap.add_argument("--backend", default="vmapped",
                    choices=("vmapped", "sharded", "both"))
    ap.add_argument("--ms", type=int, nargs="+", default=[3, 5, 7])
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--scaling-only", action="store_true",
                    help="skip the Table-5 accuracy sweep")
    args = ap.parse_args()

    backends = (("vmapped", "sharded") if args.backend == "both"
                else (args.backend,))
    if not args.scaling_only:
        for backend in backends:
            run(args.dataset, ms=tuple(args.ms), rounds=args.rounds,
                backend=backend)
    print("# scaling: per-round wall clock vs n_clients")
    run_scaling(args.dataset, ms=tuple(args.ms), rounds=args.rounds,
                backends=backends)


if __name__ == "__main__":
    main()
