"""Kernel + sampler micro-benchmarks: vectorized paths vs the seed baselines.

On CPU these numbers are indicative only (interpret mode executes the kernel
body as XLA ops); the BlockSpec structure is what lowers on TPU. The seed
scalar-gather ``graph_agg`` kernel (128·F one-row dynamic-slice loads per
destination tile inside a double ``fori_loop``) and the seed python-loop
neighbor-table build are reproduced here verbatim as the comparison
baselines.

Run: ``PYTHONPATH=src python -m benchmarks.run`` (or import and call run()).
"""
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.graph.graph import Graph
from repro.graph.sampler import GlasuSampler, SamplerConfig, _padded_tables
from repro.graph.synth import DatasetSpec, make_vfl_dataset
from repro.kernels import ops, ref


def _time(fn, *args, iters=15):
    """Best-of-N wall time in µs (the minimum is the least-noise estimate on
    a shared CPU — same rationale as timeit's ``min(repeat(...))``)."""
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6


# ------------------------------------------------- seed scalar-gather kernel
def _seed_graph_agg_kernel(idx_ref, mask_ref, h_ref, w_ref, out_ref, *,
                           fanout):
    """The seed kernel: one neighbor row per DMA inside a double fori_loop."""
    acc = jnp.zeros((128, h_ref.shape[1]), jnp.float32)

    def body(f, acc):
        def row(r, acc):
            src = idx_ref[r, f]
            hrow = h_ref[pl.dslice(src, 1), :]
            m = mask_ref[r, f]
            return acc.at[r].add(hrow[0].astype(jnp.float32) * m)

        return jax.lax.fori_loop(0, 128, row, acc)

    acc = jax.lax.fori_loop(0, fanout, body, acc)
    denom = jnp.maximum(jnp.sum(mask_ref[...], axis=1, keepdims=True), 1.0)
    agg = (acc / denom).astype(w_ref.dtype)
    out_ref[...] = jnp.dot(agg, w_ref[...],
                           preferred_element_type=jnp.float32
                           ).astype(out_ref.dtype)


@jax.jit
def _seed_graph_agg(h, idx, mask, w):
    n_dst, fanout = idx.shape
    d, d_out = w.shape
    pad = (-n_dst) % 128
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_seed_graph_agg_kernel, fanout=fanout),
        grid=(idx.shape[0] // 128,),
        in_specs=[
            pl.BlockSpec((128, fanout), lambda i: (i, 0)),
            pl.BlockSpec((128, fanout), lambda i: (i, 0)),
            pl.BlockSpec((h.shape[0], d), lambda i: (0, 0)),
            pl.BlockSpec((d, d_out), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((128, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((idx.shape[0], d_out), w.dtype),
        interpret=True,
    )(idx, mask, h, w)
    return out[:n_dst]


# ------------------------------------------------ seed python-loop sampler
def _seed_padded_tables(g: Graph, cap: int, rng: np.random.Generator):
    """The seed table build: a Python loop over every node."""
    n = g.n_nodes
    table = np.full((n, cap), -1, dtype=np.int32)
    deg = np.zeros(n, dtype=np.int32)
    for i in range(n):
        nbrs = g.neighbors(i)
        if len(nbrs) > cap:
            nbrs = rng.choice(nbrs, size=cap, replace=False)
        table[i, :len(nbrs)] = nbrs
        deg[i] = len(nbrs)
    return table, deg


class _SeedSampler(GlasuSampler):
    """The seed round loop, verbatim: per-client python loops, modulo draw,
    sorted truncation, argsort+searchsorted positions, fresh per-round
    allocations. The per-node table build is timed separately via
    ``_seed_padded_tables``."""

    def sample_round(self):
        cfg, M = self.cfg, self.M
        L = cfg.n_layers
        train_idx = self.data.full.train_idx
        batch = self.rng.choice(
            train_idx, size=cfg.batch_size,
            replace=len(train_idx) < cfg.batch_size).astype(np.int32)
        cur = [batch.copy() for _ in range(M)]
        gidx, gmask = [None] * L, [None] * L
        rvalid, spos = [None] * L, [None] * L
        for l in range(L - 1, -1, -1):
            nbrs = [self._sample_neighbors(m, cur[m]) for m in range(M)]
            size = self.layer_sizes[l]
            if self._shared(l):
                shared_set = self._build_set(cur, nbrs, size)
                sets = [shared_set] * M
            else:
                sets = [self._build_set([cur[m]], [nbrs[m]], size)
                        for m in range(M)]
            gi = np.zeros((M, self.layer_sizes[l + 1], cfg.fanout + 1),
                          np.int32)
            gm = np.zeros_like(gi, dtype=np.float32)
            rv = np.zeros((M, self.layer_sizes[l + 1]), np.float32)
            sp = np.zeros((M, self.layer_sizes[l + 1]), np.int32)
            for m in range(M):
                cpos = self._positions(sets[m], cur[m])
                npos = self._positions(sets[m], nbrs[m])
                gi[m, :, 0] = np.maximum(cpos, 0)
                gm[m, :, 0] = (cpos >= 0).astype(np.float32)
                gi[m, :, 1:] = np.maximum(npos, 0)
                gm[m, :, 1:] = (npos >= 0).astype(np.float32)
                rv[m] = (cur[m] >= 0).astype(np.float32)
                gm[m] *= rv[m][:, None]
                sp[m] = np.maximum(cpos, 0)
            gidx[l], gmask[l], rvalid[l], spos[l] = gi, gm, rv, sp
            cur = sets
        feats = np.zeros((M, self.layer_sizes[0], self.d_pad), np.float32)
        for m in range(M):
            s = cur[m]
            ok = s >= 0
            x = self.data.clients[m].features
            feats[m, ok, :x.shape[1]] = x[s[ok]]
        labels = self.data.full.labels[batch].astype(np.int32)
        from repro.graph.sampler import SampledBatch
        return SampledBatch(feats, tuple(gidx), tuple(gmask), tuple(rvalid),
                            labels, tuple(spos))

    def _sample_neighbors(self, m, centers):
        table, deg = self.tables[m]
        f = self.cfg.fanout
        valid = centers >= 0
        safe = np.where(valid, centers, 0)
        d = deg[safe]
        cols = (self.rng.integers(0, 1 << 30, size=(len(centers), f))
                % np.maximum(d, 1)[:, None]).astype(np.int64)
        nb = table[safe[:, None], cols]
        nb = np.where((d[:, None] > 0) & valid[:, None], nb, -1)
        return nb.astype(np.int32)

    def _build_set(self, centers_list, nbrs_list, size):
        centers = np.unique(np.concatenate(centers_list))
        centers = centers[centers >= 0]
        others = np.unique(np.concatenate([x.ravel() for x in nbrs_list]))
        others = others[others >= 0]
        others = np.setdiff1d(others, centers, assume_unique=True)
        room = size - len(centers)
        if len(others) > room:
            others = others[:room]
        s = np.concatenate([centers, others])
        out = np.full(size, -1, dtype=np.int32)
        out[:len(s)] = s
        return out

    def _positions(self, node_set, query):
        order = np.argsort(node_set, kind="stable")
        sorted_set = node_set[order]
        q = query.ravel()
        loc = np.searchsorted(sorted_set, q)
        loc = np.clip(loc, 0, len(sorted_set) - 1)
        hit = (sorted_set[loc] == q) & (q >= 0)
        pos = np.where(hit, order[loc], -1)
        return pos.reshape(query.shape).astype(np.int32)


def _bench_graph_agg():
    """GLASU-representative shape: the sampler caps every layer's source set
    at size_cap (512 default), so n_src = 512 is what the training hot path
    actually sees. The one-hot gather-matmul is O(n_dst·n_src·d) on the MXU,
    so a second, oversized source buffer is reported for context (on CPU
    interpret the scalar seed loop can win there; on TPU the 128·F serial
    row DMAs of the seed kernel lose at every shape)."""
    rng = np.random.default_rng(0)
    shapes = [
        # (n_src, n_dst, F, gated): train-step aggregation and eval-table
        # shapes are the hot paths and must beat the seed kernel; the
        # oversized-source line is context only (interpret-mode CPU favors
        # the serial loop once n_src outgrows the sampler's caps)
        (512, 512, 8, True),       # training layer at size_cap, fanout 7+self
        (512, 2048, 33, True),     # eval chunk with table_cap 32 + self
        (2048, 512, 4, False),     # oversized source buffer (context)
    ]
    for n_src, n_dst, fanout, gate in shapes:
        h = jnp.asarray(rng.normal(size=(n_src, 128)), jnp.float32)
        idx = jnp.asarray(rng.integers(0, n_src, size=(n_dst, fanout)),
                          jnp.int32)
        mask = jnp.ones((n_dst, fanout), jnp.float32)
        w = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
        ref_fn = jax.jit(ref.graph_agg_ref)
        us_new = _time(ops.graph_agg, h, idx, mask, w)
        us_seed = _time(_seed_graph_agg, h, idx, mask, w)
        us_ref = _time(ref_fn, h, idx, mask, w)
        print(f"kernel/graph_agg_s{n_src}_d{n_dst}_f{fanout},{us_new:.0f},"
              f"seed_us={us_seed:.0f},ref_us={us_ref:.0f},"
              f"speedup_vs_seed={us_seed / us_new:.1f}x")
        if gate:
            assert us_new < us_seed, \
                "vectorized graph_agg must beat the seed kernel"


def _bench_csr_crossover():
    """Dense one-hot vs CSR segment-sum across power-law source-set sizes.

    Each sweep point draws its topology from the same Chung-Lu generator as
    the ``powerlaw-1m`` profile (``graph/synth.py``), so the measured
    crossover reflects that profile's degree skew, not a uniform-random
    gather. Both kernels see identical (h, idx, mask, w) inputs — the CSR
    path re-lays the fanout tables as edge slabs in-trace, exactly what
    ``ops.graph_agg`` dispatches to at scale.

    The gate names the winner at every shape instead of reducing to one
    scalar: the dense one-hot matmul must hold the sampler-capped set size
    (512) and CSR must win at and above ``ops.CSR_DISPATCH_MIN_SRC`` —
    i.e. the static-shape dispatch heuristic routes every swept shape to
    its measured winner.
    """
    from repro.graph.graph import scatter_neighbor_rows
    from repro.graph.synth import _pairs_to_csr, _powerlaw_pairs

    rng = np.random.default_rng(3)
    n_dst, fanout, d = 512, 8, 64
    dense_fn = jax.jit(ops._graph_agg)
    sparse_fn = jax.jit(ops._graph_agg_sparse)
    results = []
    for n_src in (512, 2048, 8192, 16384, 32768):
        pairs = _powerlaw_pairs(rng, n_src, 8.0, 2.1, 1024)
        indptr, indices = _pairs_to_csr(n_src, pairs)
        # destination rows: the first n_dst nodes (batch); sources span the
        # whole set — the shape the sampler hands the aggregation layer
        dst_indptr = indptr[:n_dst + 1]
        idx = np.zeros((n_dst, fanout), np.int32)
        mask = np.zeros((n_dst, fanout), np.float32)
        idx[:, 0] = np.arange(n_dst, dtype=np.int32)
        mask[:, 0] = 1.0
        scatter_neighbor_rows(idx, dst_indptr, indices, np.diff(dst_indptr),
                              fanout - 1, rng, col_offset=1, mask=mask)
        h = jnp.asarray(rng.normal(size=(n_src, d)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(d, d)), jnp.float32)
        idx, mask = jnp.asarray(idx), jnp.asarray(mask)
        np.testing.assert_allclose(
            np.asarray(sparse_fn(h, idx, mask, w)),
            np.asarray(dense_fn(h, idx, mask, w)), atol=1e-4)
        us_dense = _time(dense_fn, h, idx, mask, w)
        us_csr = _time(sparse_fn, h, idx, mask, w)
        winner = "csr" if us_csr < us_dense else "dense"
        dispatch = ("csr" if n_src >= ops.CSR_DISPATCH_MIN_SRC else "dense")
        results.append((n_src, winner, dispatch))
        print(f"kernel/agg_crossover_s{n_src},winner={winner},"
              f"dense_us={us_dense:.0f},csr_us={us_csr:.0f},"
              f"dispatch={dispatch}")
    crossover = min((s for s, w, _ in results if w == "csr"), default=None)
    print(f"kernel/agg_crossover_size,{crossover},"
          f"dispatch_min_src={ops.CSR_DISPATCH_MIN_SRC}")
    assert results[0][1] == "dense", \
        "one-hot matmul must win at the sampler-capped set size (512)"
    for n_src, winner, dispatch in results:
        if n_src >= ops.CSR_DISPATCH_MIN_SRC:
            assert winner == "csr", (
                f"CSR segment-sum must beat the dense one-hot path at "
                f"n_src={n_src} (>= dispatch threshold "
                f"{ops.CSR_DISPATCH_MIN_SRC}), but {winner} won")


def _bench_backbone_parity():
    """Parity of all three fused backbone kernels vs kernels/ref.py."""
    rng = np.random.default_rng(1)
    n_src, n_dst, f1, d = 512, 300, 5, 64
    h = jnp.asarray(rng.normal(size=(n_src, d)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(n_src, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n_src, size=(n_dst, f1)), jnp.int32)
    mask = np.asarray(rng.random((n_dst, f1)) < 0.8, np.float32)
    mask[:, 0] = 1.0
    mask = jnp.asarray(mask)
    w = jnp.asarray(rng.normal(size=(d, d)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.normal(size=(d,)) * 0.1, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.graph_agg(h, idx, mask, w)),
        np.asarray(ref.graph_agg_ref(h, idx, mask, w)), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ops.gcnii_layer(h, h0, idx, mask, w, b,
                                   alpha=0.1, beta=0.5)),
        np.asarray(ref.gcnii_layer_ref(h, h0, idx, mask, w, b, 0.1, 0.5)),
        atol=1e-5)
    n_heads, dh = 2, d // 2
    wg = jnp.asarray(rng.normal(size=(d, n_heads, dh)) * 0.1, jnp.float32)
    a_src = jnp.asarray(rng.normal(size=(n_heads, dh)) * 0.1, jnp.float32)
    a_dst = jnp.asarray(rng.normal(size=(n_heads, dh)) * 0.1, jnp.float32)
    bg = jnp.asarray(rng.normal(size=(n_heads * dh,)) * 0.1, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.gat_layer(h, idx, mask, wg, a_src, a_dst, bg)),
        np.asarray(ref.gat_layer_ref(h, idx, mask, wg, a_src, a_dst, bg)),
        atol=1e-5)
    ref_gcnii = jax.jit(lambda *a: ref.gcnii_layer_ref(*a, 0.1, 0.5))
    us_k = _time(lambda: ops.gcnii_layer(h, h0, idx, mask, w, b,
                                         alpha=0.1, beta=0.5))
    us_r = _time(lambda: ref_gcnii(h, h0, idx, mask, w, b))
    print(f"kernel/gcnii_layer,{us_k:.0f},ref_us={us_r:.0f},parity=1e-5")
    ref_gat = jax.jit(ref.gat_layer_ref)
    us_k = _time(lambda: ops.gat_layer(h, idx, mask, wg, a_src, a_dst, bg))
    us_r = _time(lambda: ref_gat(h, idx, mask, wg, a_src, a_dst, bg))
    print(f"kernel/gat_layer,{us_k:.0f},ref_us={us_r:.0f},parity=1e-5")


def _best_of(fn, reps=2):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _bench_sampler(rounds: int = 5):
    """Sampler throughput on a synthetic 10k-node graph.

    The gated metric is *cold-start* throughput — table build + the first
    ``rounds`` sampling rounds — which is the preset-sweep workload: every
    experiment (45-scenario registry, Table-4 early-stop runs) constructs
    its own sampler, so the seed's per-node Python table loop is paid per
    run, not once. Steady-state per-round time is reported separately
    (the O(1) position lookup, mark-array set dedup, batched client draw
    and scratch reuse give ~1.5x there)."""
    # Reddit-like degree profile (paper Table 1: avg deg 60) — hub nodes
    # above table_cap are exactly where the seed's per-node rng.choice loop
    # and the vectorized argpartition subsample diverge most
    spec = DatasetSpec(n_nodes=10_000, avg_deg=60.0, feat_dim=64, n_classes=8)
    data = make_vfl_dataset("synth10k", n_clients=3, seed=0, spec=spec)
    scfg = SamplerConfig(n_layers=4, agg_layers=(1, 3), batch_size=64,
                         fanout=3, size_cap=512, table_cap=32)

    t_seed_tables = _best_of(lambda: [
        _seed_padded_tables(c, scfg.table_cap, np.random.default_rng(1))
        for c in data.clients])
    t_new_tables = _best_of(lambda: [
        _padded_tables(c, scfg.table_cap, np.random.default_rng(1))
        for c in data.clients])

    seed_s = _SeedSampler(data, scfg, seed=0)
    new_s = GlasuSampler(data, scfg, seed=0)
    seed_s.sample_round()   # warmup
    new_s.sample_round()
    t_seed_rounds = _best_of(
        lambda: [seed_s.sample_round() for _ in range(rounds)])
    t_new_rounds = _best_of(
        lambda: [new_s.sample_round() for _ in range(rounds)])

    thr_seed = rounds / (t_seed_tables + t_seed_rounds)
    thr_new = rounds / (t_new_tables + t_new_rounds)
    print(f"sampler/padded_tables_10k,{t_new_tables * 1e3:.1f}ms,"
          f"seed_ms={t_seed_tables * 1e3:.1f},"
          f"speedup={t_seed_tables / max(t_new_tables, 1e-9):.1f}x")
    print(f"sampler/sample_round_10k,{t_new_rounds / rounds * 1e3:.2f}ms,"
          f"seed_ms={t_seed_rounds / rounds * 1e3:.2f},"
          f"round_speedup={t_seed_rounds / max(t_new_rounds, 1e-9):.1f}x")
    print(f"sampler/throughput_10k,{thr_new:.1f}rounds/s,"
          f"seed={thr_seed:.1f},speedup={thr_new / thr_seed:.1f}x")
    assert thr_new >= 5.0 * thr_seed, \
        "vectorized sampler must deliver >= 5x seed cold-start throughput"


def _bench_sampler_allocs(rounds: int = 10):
    """Steady-state host allocation per ``sample_round``.

    The vectorized sampler reuses per-layer index/mask/query scratch, an
    int32 id->position LUT, and the feature buffer across rounds; only
    transient draw/dedup temporaries should allocate. Gate: tracemalloc
    peak across ``rounds`` steady-state rounds must stay under the seed
    sampler's (which reallocates every per-layer block, the gather query,
    and the feature matrix each round)."""
    import tracemalloc

    spec = DatasetSpec(n_nodes=10_000, avg_deg=60.0, feat_dim=64, n_classes=8)
    data = make_vfl_dataset("synth10k", n_clients=3, seed=0, spec=spec)
    scfg = SamplerConfig(n_layers=4, agg_layers=(1, 3), batch_size=64,
                         fanout=3, size_cap=512, table_cap=32)

    def peak_bytes(sampler):
        sampler.sample_round()                  # steady state, not cold
        tracemalloc.start()
        for _ in range(rounds):
            sampler.sample_round()
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        return peak

    new_peak = peak_bytes(GlasuSampler(data, scfg, seed=0))
    seed_peak = peak_bytes(_SeedSampler(data, scfg, seed=0))
    print(f"sampler/alloc_peak_10rounds,{new_peak / 1e6:.2f}MB,"
          f"seed_MB={seed_peak / 1e6:.2f},"
          f"ratio={new_peak / max(seed_peak, 1):.2f}")
    assert new_peak < seed_peak, \
        "scratch-reusing sampler must allocate less per round than the seed"
    lut = GlasuSampler(data, scfg, seed=0)._pos_lut
    assert lut.dtype == np.int32, \
        f"position LUT should be int32 (positions < size_cap), got {lut.dtype}"


def run():
    _bench_graph_agg()
    _bench_csr_crossover()
    _bench_backbone_parity()
    _bench_sampler()
    _bench_sampler_allocs()

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 512, 4, 64)), jnp.float32)
    ref_fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us_k = _time(lambda q: ops.flash_attention(q, q, q), q)
    us_r = _time(lambda q: ref_fa(q, q, q), q)
    print(f"kernel/flash_attention,{us_k:.0f},ref_us={us_r:.0f}")
