"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp reference.

On CPU these numbers are indicative only (interpret mode executes the kernel
body as XLA ops); the BlockSpec structure is what lowers on TPU.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(2048, 128)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 2048, size=(512, 4)), jnp.int32)
    mask = jnp.ones((512, 4), jnp.float32)
    w = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    ref_fn = jax.jit(ref.graph_agg_ref)
    us_k = _time(ops.graph_agg, h, idx, mask, w)
    us_r = _time(ref_fn, h, idx, mask, w)
    print(f"kernel/graph_agg,{us_k:.0f},ref_us={us_r:.0f}")

    q = jnp.asarray(rng.normal(size=(1, 512, 4, 64)), jnp.float32)
    ref_fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us_k = _time(lambda q: ops.flash_attention(q, q, q), q)
    us_r = _time(lambda q: ref_fa(q, q, q), q)
    print(f"kernel/flash_attention,{us_k:.0f},ref_us={us_r:.0f}")
