"""Joint-inference serving: latency/throughput + audited query bytes, gated.

Trains a short cora-profile checkpoint, builds ``repro.serve`` sessions on
it, and measures the three query mixes the serving subsystem is built for:

  cold        — distinct never-seen nodes: full receptive-field plan,
                cross-client exchange at every aggregation layer
  warm-cache  — the same nodes again: every query hits the hot-node
                aggregate cache at the top layer, answers assemble from
                cached (M, h_agg) rows + one classifier matmul, zero
                wire bytes
  compressed  — cold queries with the PR 5 wire codecs (int8, topk_ef)
                on the embedding exchange

Reported: latency p50/p99 and queries/sec per mix, per-query byte bills
per codec, cache statistics.

Gates (full mode):
  * warm-cache throughput >= 2x cold (the point of the cache);
  * per-query bytes audited term-by-term (upload / broadcast /
    index_sync) against an independent ``fed.simulation``
    ``log_query_traffic`` MessageLog replay, for every codec — audited in
    smoke mode too;
  * compressed query bytes match the training-path codec pricing exactly:
    same ``Compressor.wire_bytes`` per fresh row as
    ``GlasuSampler.comm_bytes_per_joint_inference`` charges in training,
    verified against the dense session's identical fresh-row counts.

Results append to ``BENCH_serve.json`` (one trajectory entry per run).

Run: ``PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]``
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.api import ExperimentConfig, Trainer
from repro.comm.compression import make_compressor
from repro.serve import InferenceSession, ServeConfig

HOT = dict(dataset="cora", n_clients=3, n_layers=4, hidden=64,
           backbone="gcnii", batch_size=16, fanout=3, size_cap=512,
           rounds=30, max_batch=16, n_batches=16)
SMOKE = dict(dataset="tiny", n_clients=3, n_layers=4, hidden=16,
             backbone="gcnii", batch_size=8, fanout=3, size_cap=96,
             rounds=4, max_batch=8, n_batches=6)


def _train_checkpoint(prof: dict, rounds: int, ckpt_dir: str):
    cfg = ExperimentConfig(
        name="serve-bench", dataset=prof["dataset"],
        n_clients=prof["n_clients"], n_layers=prof["n_layers"],
        hidden=prof["hidden"], backbone=prof["backbone"],
        batch_size=prof["batch_size"], fanout=prof["fanout"],
        size_cap=prof["size_cap"], rounds=rounds, lr=0.05,
        optimizer="adam", eval_every=rounds, ckpt_dir=ckpt_dir,
        ckpt_every=0)
    Trainer(cfg).run()
    return cfg


def _query_stream(n_nodes: int, n_batches: int, batch: int, seed: int = 0):
    """Distinct node batches (no repeats across batches) — the cold mix."""
    rng = np.random.default_rng(seed)
    want = n_batches * batch
    ids = rng.permutation(n_nodes)[:want]
    if len(ids) < want:        # tiny graphs: tile, keeping batches distinct
        ids = np.resize(ids, want)
    return [ids[i * batch:(i + 1) * batch].astype(np.int32)
            for i in range(n_batches)]


def _audit_answer(ans, mcfg, comp):
    """Term-by-term: session byte counters vs the MessageLog replay."""
    lg = ans.log
    assert lg is not None, "audit needs record_log=True sessions"
    for kind, got in (("upload", ans.upload_bytes),
                      ("broadcast", ans.broadcast_bytes),
                      ("index_sync", ans.index_bytes)):
        logged = lg.total_bytes(kind)
        assert logged == got, \
            f"{kind}: session charged {got} B, message-log replay says " \
            f"{logged} B"
    # per-layer wire pricing must equal the training-path cost model
    for l, n in ans.fresh_rows.items():
        want_up = mcfg.n_clients * (
            comp.wire_bytes(n, mcfg.hidden) if comp else
            n * mcfg.hidden * 4) if n else 0
        got_up = sum(m.nbytes for m in lg.messages
                     if m.kind == "upload" and m.layer == l)
        assert got_up == want_up, \
            f"layer {l}: upload {got_up} B != codec pricing {want_up} B"


def _timed_mix(session, batches):
    t0 = time.perf_counter()
    answers = [session.answer(b) for b in batches]
    wall = time.perf_counter() - t0
    lat = np.asarray([a.latency_s for a in answers])
    n_q = sum(len(b) for b in batches)
    return answers, {
        "queries": n_q, "qps": n_q / wall, "wall_s": wall,
        "latency_p50_ms": float(np.percentile(lat, 50) * 1e3),
        "latency_p99_ms": float(np.percentile(lat, 99) * 1e3),
    }


def run(smoke: bool = False, out_path: str = "BENCH_serve.json",
        rounds: int = None):
    prof = SMOKE if smoke else HOT
    rounds = rounds if rounds is not None else prof["rounds"]
    serve_cfg = dict(max_batch=prof["max_batch"], record_log=True)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        cfg = _train_checkpoint(prof, rounds, ckpt_dir)
        results = {}

        # -- dense session: cold sweep, then the same nodes warm ---------
        s = InferenceSession.from_checkpoint(
            ckpt_dir, serve=ServeConfig(**serve_cfg))
        batches = _query_stream(s.N, prof["n_batches"], prof["max_batch"])
        s.answer(batches[0])          # trace the bucket + warm jit caches
        s.cache.clear()
        s.metrics = type(s.metrics)()

        cold_ans, cold = _timed_mix(s, batches)
        for a in cold_ans:
            _audit_answer(a, s.mcfg, None)
        assert all(a.cold for a in cold_ans), "cold mix hit the cache?"
        cold["bytes_per_query"] = sum(a.wire_bytes for a in cold_ans) \
            / cold["queries"]

        warm_ans, warm = _timed_mix(s, batches)
        assert not any(a.cold for a in warm_ans), \
            "warm mix missed the cache (capacity too small for the sweep?)"
        assert sum(a.wire_bytes for a in warm_ans) == 0, \
            "warm-cache answers must ship zero bytes"
        warm["bytes_per_query"] = 0.0
        for c, w in zip(cold_ans, warm_ans):
            assert np.array_equal(c.logits, w.logits), \
                "repeat query must be bitwise identical at fixed params"
        results["cold"], results["warm"] = cold, warm
        results["cache"] = {"entries": len(s.cache), "hits": s.cache.hits,
                            "misses": s.cache.misses,
                            "evictions": s.cache.evictions}

        # -- compressed sessions: cold queries, bytes audited ------------
        dense_fresh = [dict(a.fresh_rows) for a in cold_ans]
        codecs = {"int8": {"method": "int8"},
                  f"topk_ef_k{cfg.hidden // 8}": {
                      "method": "topk_ef", "k": max(1, cfg.hidden // 8),
                      "error_feedback": False}}
        for label, comp_cfg in codecs.items():
            sc = InferenceSession.from_checkpoint(
                ckpt_dir, serve=ServeConfig(**serve_cfg),
                compression=comp_cfg)
            comp = make_compressor(sc.mcfg.compression)
            c_ans, c_stats = _timed_mix(sc, batches)
            for a, df in zip(c_ans, dense_fresh):
                _audit_answer(a, sc.mcfg, comp)
                assert dict(a.fresh_rows) == df, \
                    "codec changed the fresh-row plan (it must not: " \
                    "plans depend on cache state, not on the codec)"
            c_bytes = sum(a.wire_bytes for a in c_ans)
            d_bytes = sum(a.wire_bytes for a in cold_ans)
            c_stats["bytes_per_query"] = c_bytes / c_stats["queries"]
            c_stats["bytes_reduction"] = d_bytes / max(c_bytes, 1)
            results[label] = c_stats
            print(f"serve/{label}_bytes_per_query,"
                  f"{c_stats['bytes_per_query']:.0f},"
                  f"reduction={c_stats['bytes_reduction']:.2f}x")

    print(f"serve/cold_qps,{cold['qps']:.1f},"
          f"p50={cold['latency_p50_ms']:.2f}ms "
          f"p99={cold['latency_p99_ms']:.2f}ms")
    print(f"serve/warm_qps,{warm['qps']:.1f},"
          f"p50={warm['latency_p50_ms']:.2f}ms "
          f"p99={warm['latency_p99_ms']:.2f}ms "
          f"speedup={warm['qps'] / cold['qps']:.2f}x")

    entry = {"ts": time.time(), "smoke": smoke, "profile": prof["dataset"],
             "rounds": rounds, "results": results}
    path = Path(out_path)
    history = []
    if path.exists():
        try:
            history = json.loads(path.read_text())
        except (json.JSONDecodeError, ValueError):
            history = []
    if not isinstance(history, list):
        history = [history]
    history.append(entry)
    path.write_text(json.dumps(history, indent=1))
    print(f"serve/bench_json,{path},entries={len(history)}")

    if not smoke:
        assert warm["qps"] >= 2.0 * cold["qps"], \
            f"warm-cache throughput must be >= 2x cold, got " \
            f"{warm['qps'] / cold['qps']:.2f}x " \
            f"({warm['qps']:.1f} vs {cold['qps']:.1f} q/s)"
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, audits only, no perf gates (CI)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--rounds", type=int, default=None)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out, rounds=args.rounds)


if __name__ == "__main__":
    main()
