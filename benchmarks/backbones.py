"""Paper Figure 3: GLASU across GCN / GAT / GCNII backbones."""
import dataclasses

from .common import BenchSettings, csv, run_method


def run(dataset="cora", seeds=(0,), rounds=None, settings=None):
    s = settings or BenchSettings()
    out = {}
    for bb in ("gcn", "gat", "gcnii"):
        sb = dataclasses.replace(s, backbone=bb)
        accs = []
        for seed in seeds:
            r = run_method("glasu", dataset, seed=seed, s=sb, q=1,
                           rounds=rounds)
            accs.append(r.test_acc)
        acc = sum(accs) / len(accs)
        out[bb] = acc
        csv(f"fig3/{dataset}/{bb}", f"acc={acc * 100:.1f}")
    return out
