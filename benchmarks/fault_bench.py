"""Fault-tolerant federated runtime: wall-clock-to-accuracy, gated.

Runs the cora-profile hot path (L=4 GCNII, hidden 64, M=3, batch 16 — the
shape every other training benchmark uses) through three operating points:

  fault_free  — the legacy engine, no fault model (accuracy anchor; it has
                no virtual clock)
  sync        — synchronous rounds under the skewed-latency profile: no
                deadline, so every round waits for its slowest upload
                (heavy-tailed stragglers set the pace), but nothing is
                ever absent
  deadline    — the fault-tolerant engine on the SAME latency profile plus
                a 20% upload-drop rate and a per-round deadline: late or
                lost uploads fall back to staleness-bounded cached
                embeddings and the round closes on time

All times are the fault schedule's VIRTUAL clock (milliseconds), so the
comparison measures the round protocol, not host jitter.

Gates (full mode):
  * accuracy under faults: the deadline run's final validation accuracy is
    within ``ACC_SLACK`` of the fault-free anchor (GLASU's stale-update
    tolerance, §3.5, doing operational work);
  * wall-clock-to-accuracy: the deadline engine reaches the target
    accuracy (anchor - ACC_SLACK) in strictly less virtual time than the
    synchronous-with-stragglers baseline;
  * meter integrity: simulated fault rounds' delivered-only message logs
    audit term-by-term against the analytic model under dropped uploads
    (index sync + n_present uploads + M broadcasts per aggregation
    layer), the sent-traffic meter prices the attempted uploads, and the
    trainer's accumulated bytes equal the sum of its per-round
    delivered-only prices.

``--smoke`` runs tiny shapes for CI signal (meters still audited, no
perf/parity gates). Results append to ``BENCH_fault.json``.

Run: ``PYTHONPATH=src python -m benchmarks.fault_bench [--smoke]``
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.api import ExperimentConfig, Trainer, make_backend
from repro.core import glasu
from repro.fed.faults import make_schedule
from repro.graph.sampler import GlasuSampler
from repro.graph.synth import make_vfl_dataset

HOT = dict(dataset="cora", n_clients=3, n_layers=4, hidden=64,
           backbone="gcnii", batch_size=16, fanout=3, size_cap=512)
SMOKE = dict(dataset="tiny", n_clients=3, n_layers=4, hidden=16,
             backbone="gcnii", batch_size=8, fanout=3, size_cap=96)

ACC_SLACK = 0.05      # absolute val-accuracy slack vs the fault-free anchor

# skewed latency: lognormal jitter around 20 ms with a 15% heavy Pareto
# tail — the straggler distribution the deadline protocol exists for
LATENCY = dict(base_latency_ms=20.0, latency_sigma=0.5,
               client_speed_sigma=0.2, straggler_prob=0.15,
               straggler_scale=10.0, straggler_alpha=1.5)
SYNC_FAULTS = dict(seed=7, **LATENCY)                    # no deadline: wait
DEADLINE_FAULTS = dict(seed=7, drop_prob=0.2, deadline_ms=60.0, **LATENCY)


def _audit_fault_meters(cfg: ExperimentConfig, data, rounds: int = 4) -> int:
    """Replay ``rounds`` simulated fault rounds and audit the byte meters
    term-by-term against the analytic model; returns delivered bytes."""
    mcfg = cfg.glasu_config(data)
    sampler = GlasuSampler(data, cfg.sampler_config(), seed=cfg.seed)
    opt = cfg.make_optimizer()
    mb = make_backend("simulation")
    mb.bind(mcfg, opt, sampler)          # run_round re-audits every round
    sched = make_schedule(cfg.faults, mcfg.n_clients)
    params = glasu.init_params(jax.random.PRNGKey(cfg.seed), mcfg)
    opt_state = opt.init(params)
    index_sync = sum(2 * mcfg.n_clients * sampler.layer_sizes[j] * 4
                     for j in range(mcfg.n_layers + 1) if sampler._shared(j))
    per_layer = [sampler.layer_sizes[l + 1] * mcfg.hidden * 4
                 for l in sorted(mcfg.agg_layers)]
    delivered = 0
    for _ in range(rounds):
        plan = sched.next_round()
        batch = jax.tree.map(jnp.asarray, sampler.sample_round())
        out = mb.run_round(params, opt_state, batch, jax.random.PRNGKey(0),
                           faults=plan)
        params, opt_state = out.params, out.opt_state
        log = out.message_log
        n_att = int(plan.attempted.sum())
        want = index_sync + sum(plan.n_present * b + mcfg.n_clients * b
                                for b in per_layer)
        sent = index_sync + sum(n_att * b + mcfg.n_clients * b
                                for b in per_layer)
        assert log.total_bytes() == want, \
            f"delivered meter {log.total_bytes()} != analytic {want}"
        assert log.total_bytes(delivered_only=False) == sent, \
            "sent-traffic meter disagrees with attempted uploads"
        delivered += want
    return delivered


def _time_to_target(history, target: float) -> float:
    """Virtual ms at the first eval entry reaching ``target`` val acc."""
    for h in history:
        if "virtual_ms" in h and h["val_acc"] >= target:
            return h["virtual_ms"]
    return float("inf")


def run(smoke: bool = False, out_path: str = "BENCH_fault.json",
        rounds: int = None):
    shape = SMOKE if smoke else HOT
    rounds = rounds or (8 if smoke else 60)
    base = ExperimentConfig(name="fault-bench", rounds=rounds,
                            eval_every=max(rounds // 6, 1), lr=0.01,
                            **shape)
    data = make_vfl_dataset(base.dataset, n_clients=base.n_clients,
                            seed=base.seed)

    audited = _audit_fault_meters(
        base.with_(name="fault-audit", faults=DEADLINE_FAULTS), data)
    print(f"fault/meter_audit,delivered_bytes={audited},term-by-term OK")

    points = {
        "fault_free": None,
        "sync": SYNC_FAULTS,
        "deadline": DEADLINE_FAULTS,
    }
    results = {}
    for label, faults in points.items():
        cfg = base.with_(name=f"fault-{label}", faults=faults)
        t0 = time.perf_counter()
        res = Trainer(cfg, data=data).run()
        evals = [h for h in res.history if "val_acc" in h]
        results[label] = {
            "val_acc": float(res.val_acc),
            "final_loss": float(res.history[-1]["loss"]),
            "comm_bytes": int(res.comm_bytes),
            "virtual_ms": float(evals[-1].get("virtual_ms", 0.0)),
            "participation": float(evals[-1].get("participation", 1.0)),
            "catch_up_rounds": int(evals[-1].get("catch_up_rounds", 0)),
            "history": [{k: h[k] for k in
                         ("round", "val_acc", "virtual_ms") if k in h}
                        for h in evals],
            "wall_seconds": time.perf_counter() - t0,
        }
        r = results[label]
        print(f"fault/{label},val={r['val_acc']:.3f} "
              f"virtual_ms={r['virtual_ms']:.0f} "
              f"participation={r['participation']:.2f} "
              f"bytes={r['comm_bytes']}")

    anchor = results["fault_free"]["val_acc"]
    target = anchor - ACC_SLACK
    t_sync = _time_to_target(results["sync"]["history"], target)
    t_dead = _time_to_target(results["deadline"]["history"], target)
    results["deadline"]["t_to_target_ms"] = t_dead
    results["sync"]["t_to_target_ms"] = t_sync
    print(f"fault/time_to_target,target={target:.3f} "
          f"sync={t_sync:.0f}ms deadline={t_dead:.0f}ms")

    entry = {
        "bench": "fault_bench", "smoke": smoke, "rounds": rounds,
        "shape": shape, "profiles": {"sync": SYNC_FAULTS,
                                     "deadline": DEADLINE_FAULTS},
        "audited_bytes": audited, "results": results,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    path = Path(out_path)
    history = []
    if path.exists():
        try:
            history = json.loads(path.read_text())
        except (json.JSONDecodeError, ValueError):
            history = []
    if not isinstance(history, list):
        history = [history]
    history.append(entry)
    path.write_text(json.dumps(history, indent=1))
    print(f"fault/bench_json,{path},entries={len(history)}")

    if not smoke:
        dead = results["deadline"]
        assert dead["val_acc"] >= anchor - ACC_SLACK, \
            f"deadline engine val acc {dead['val_acc']:.3f} more than " \
            f"{ACC_SLACK} below the fault-free anchor {anchor:.3f}"
        assert t_dead < t_sync, \
            f"deadline engine must beat the synchronous-with-stragglers " \
            f"baseline to {target:.3f} val acc: deadline {t_dead:.0f}ms " \
            f"vs sync {t_sync:.0f}ms"
        # dropped uploads were actually priced: fewer delivered bytes
        assert dead["comm_bytes"] < results["sync"]["comm_bytes"], \
            "deadline run must price fewer delivered bytes than sync"
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, audits only, no perf gates (CI)")
    ap.add_argument("--out", default="BENCH_fault.json")
    ap.add_argument("--rounds", type=int, default=None)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out, rounds=args.rounds)


if __name__ == "__main__":
    main()
