"""Shared harness for the paper-table benchmarks.

Method registry reproduces §5.2's compared algorithms:
  cent     — centralized (M=1, union graph, full features)
  stal     — standalone [8]: no communication, per-client eval
  sim      — simulated centralized [9]: K=L, Q=1
  glasu1   — GLASU, K=L/2 uniform, Q=1
  glasu4   — GLASU, K=L/2 uniform, Q=4

Each method maps onto one ``ExperimentConfig`` run through the unified
``api.Trainer`` — the method name picks the aggregation schedule, client
count, and eval mode.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

from repro.api import ExperimentConfig, Trainer
from repro.api import agg_layers_for_k  # noqa: F401 (re-export for callers)

_METHOD_MAP = {
    "cent": "centralized",
    "stal": "standalone",
    "sim": "simulated-centralized",
    "glasu": "glasu",
    "fedbcd": "fedbcd",
}


@dataclass
class BenchSettings:
    n_layers: int = 4
    hidden: int = 64
    batch_size: int = 16
    fanout: int = 3
    rounds: int = 120
    lr: float = 0.01
    backbone: str = "gcnii"
    eval_every: int = 20
    size_cap: int = 384


def run_method(method: str, dataset_name: str, n_clients: int = 3,
               seed: int = 0, s: BenchSettings = BenchSettings(),
               k: Optional[int] = None, q: int = 1,
               target_acc: Optional[float] = None, rounds: Optional[int] = None,
               backend: str = "vmapped"):
    api_method = _METHOD_MAP[method]
    if api_method == "simulated-centralized":
        k, q = None, 1          # Q=1 is part of the method's definition
    elif api_method == "standalone":
        k = None                # no aggregation schedule, but Q is honored
    cfg = ExperimentConfig(
        name=f"bench-{dataset_name}-{method}", dataset=dataset_name,
        method=api_method, backend=backend,
        n_clients=n_clients, n_layers=s.n_layers, hidden=s.hidden,
        backbone=s.backbone, k=k, n_local_steps=q,
        batch_size=s.batch_size, fanout=s.fanout, size_cap=s.size_cap,
        rounds=rounds or s.rounds, lr=s.lr, eval_every=s.eval_every,
        seed=seed, target_acc=target_acc)
    t0 = time.perf_counter()
    res = Trainer(cfg).run()
    res.wall_seconds = time.perf_counter() - t0
    return res


def csv(name: str, value, derived=""):
    print(f"{name},{value},{derived}", flush=True)
