"""Shared harness for the paper-table benchmarks.

Method registry reproduces §5.2's compared algorithms:
  cent     — centralized (M=1, union graph, full features)
  stal     — standalone [8]: no communication, per-client eval
  sim      — simulated centralized [9]: K=L, Q=1
  glasu1   — GLASU, K=L/2 uniform, Q=1
  glasu4   — GLASU, K=L/2 uniform, Q=4
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

from repro.core.glasu import GlasuConfig
from repro.core.train import TrainConfig, make_centralized_dataset, train_glasu
from repro.graph.sampler import SamplerConfig
from repro.graph.synth import make_vfl_dataset


@dataclass
class BenchSettings:
    n_layers: int = 4
    hidden: int = 64
    batch_size: int = 16
    fanout: int = 3
    rounds: int = 120
    lr: float = 0.01
    backbone: str = "gcnii"
    eval_every: int = 20
    size_cap: int = 384


def agg_layers_for_k(n_layers: int, k: int):
    """Paper's 'uniform' placement: K=1 -> last; K=2 -> middle+last; K=L -> all."""
    if k >= n_layers:
        return tuple(range(n_layers))
    step = n_layers // k
    return tuple(sorted({n_layers - 1 - i * step for i in range(k)}))


def run_method(method: str, dataset_name: str, n_clients: int = 3,
               seed: int = 0, s: BenchSettings = BenchSettings(),
               k: Optional[int] = None, q: int = 1,
               target_acc: Optional[float] = None, rounds: Optional[int] = None):
    data = make_vfl_dataset(dataset_name, n_clients=n_clients, seed=seed)
    rounds = rounds or s.rounds
    if method == "cent":
        data = make_centralized_dataset(data)
        n_clients = 1
    if method == "stal":
        agg = ()
        eval_mode = "per_client"
    else:
        if k is None:
            k = s.n_layers if method == "sim" else max(s.n_layers // 2, 1)
        agg = agg_layers_for_k(s.n_layers, k)
        eval_mode = "ensemble"
    if method == "sim":
        q = 1
    d_in = max(c.feat_dim for c in data.clients)
    mcfg = GlasuConfig(
        n_clients=n_clients, n_layers=s.n_layers, hidden=s.hidden,
        n_classes=data.n_classes, d_in=d_in, backbone=s.backbone,
        agg_layers=agg, n_local_steps=q)
    # standalone still needs a batch sampler; sharedness only at S[L]
    scfg = SamplerConfig(n_layers=s.n_layers,
                         agg_layers=agg if agg else (s.n_layers - 1,),
                         batch_size=s.batch_size, fanout=s.fanout,
                         size_cap=s.size_cap)
    if not agg:
        scfg = SamplerConfig(n_layers=s.n_layers, agg_layers=(s.n_layers - 1,),
                             batch_size=s.batch_size, fanout=s.fanout,
                             size_cap=s.size_cap)
        mcfg = GlasuConfig(
            n_clients=n_clients, n_layers=s.n_layers, hidden=s.hidden,
            n_classes=data.n_classes, d_in=d_in, backbone=s.backbone,
            agg_layers=(), n_local_steps=q)
    tcfg = TrainConfig(rounds=rounds, lr=s.lr, eval_every=s.eval_every,
                       seed=seed, eval_mode=eval_mode)
    t0 = time.perf_counter()
    res = train_glasu(data, mcfg, scfg, tcfg, target_acc=target_acc)
    res.wall_seconds = time.perf_counter() - t0
    return res


def csv(name: str, value, derived=""):
    print(f"{name},{value},{derived}", flush=True)
