"""Docs link/anchor checker + README quickstart doctest.

Validates, over ``docs/*.md`` and ``README.md``:

  * **markdown links** ``[text](target)`` with a relative target: the file
    exists (URL targets are skipped, fragments stripped);
  * **path references**: any backticked token that looks like a repo path
    (``benchmarks/run.py``, ``docs/BACKENDS.md``) resolves — either as
    given from the repo root or under ``src/repro/`` (the short anchor
    style the docs use for ``core/glasu.py``-like references);
  * **line anchors** `` `path:NNN` ``: the file exists AND has at least
    NNN lines; when the anchor is followed by a parenthesized
    `` (`symbol`) ``, the symbol must appear within ±10 lines of NNN —
    so the paper-to-code map in ``docs/ARCHITECTURE.md`` fails CI when
    code moves instead of silently pointing at the wrong function.

With ``--run-quickstart`` it also executes the first ``python`` fence of
the README's Quickstart section (needs ``PYTHONPATH=src``) — the CI docs
job runs it so the advertised five-liner stays green.

Run: ``PYTHONPATH=src python tools/check_docs.py [--run-quickstart]``
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# backticked repo-path-looking tokens (optionally with a :line anchor)
_PATH_RE = re.compile(
    r"`([A-Za-z0-9_.\-]+(?:/[A-Za-z0-9_.\-]+)+\."
    r"(?:py|md|json|yml|yaml|ini|txt))(?::(\d+))?`")
# the anchor's optional trailing symbol: `path:123` (`symbol`)
_SYMBOL_RE = re.compile(r"^\s*\(`([A-Za-z_][A-Za-z0-9_.]*)`\)")
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SYMBOL_WINDOW = 10


def _resolve(path: str) -> Path | None:
    for cand in (REPO / path, REPO / "src" / "repro" / path):
        if cand.is_file():
            return cand
    return None


def check_file(md: Path) -> list[str]:
    errors = []
    text = md.read_text()
    rel = md.relative_to(REPO)

    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        plain = target.split("#")[0]
        if plain and _resolve(plain) is None \
                and not (md.parent / plain).is_file():
            errors.append(f"{rel}: broken link -> {target}")

    for m in _PATH_RE.finditer(text):
        path, line_no = m.group(1), m.group(2)
        f = _resolve(path)
        if f is None:
            errors.append(f"{rel}: missing file -> {path}")
            continue
        if line_no is None:
            continue
        lines = f.read_text().splitlines()
        n = int(line_no)
        if n < 1 or n > len(lines):
            errors.append(f"{rel}: anchor {path}:{n} beyond end of file "
                          f"({len(lines)} lines)")
            continue
        sym = _SYMBOL_RE.match(text[m.end():])
        if sym:
            name = sym.group(1)
            lo, hi = max(0, n - 1 - _SYMBOL_WINDOW), n + _SYMBOL_WINDOW
            window = "\n".join(lines[lo:hi])
            if name not in window:
                errors.append(
                    f"{rel}: anchor {path}:{n} expects `{name}` within "
                    f"+/-{_SYMBOL_WINDOW} lines, not found (code moved? "
                    f"update the anchor)")
    return errors


def _run_section_fence(readme: Path, section: str) -> list[str]:
    text = readme.read_text()
    m = re.search(rf"## {section}.*?```python\n(.*?)```", text, re.S)
    if not m:
        return [f"{readme.name}: no python fence under '## {section}'"]
    snippet = m.group(1)
    print(f"-- executing README {section} fence "
          f"({len(snippet.splitlines())} lines) --")
    try:
        exec(compile(snippet, f"<README {section}>", "exec"), {})
    except Exception as e:          # noqa: BLE001 — report, don't crash
        return [f"README {section} fence failed: {type(e).__name__}: {e}"]
    return []


def run_quickstart(readme: Path) -> list[str]:
    """Execute the first python fence of Quickstart AND Serving — the two
    advertised end-to-end five-liners (train / checkpoint-and-serve)."""
    errors = _run_section_fence(readme, "Quickstart")
    errors += _run_section_fence(readme, "Serving")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--run-quickstart", action="store_true",
                    help="also execute the README quickstart snippet "
                         "(needs PYTHONPATH=src)")
    args = ap.parse_args()

    files = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]
    errors = []
    for md in files:
        found = check_file(md)
        errors.extend(found)
        print(f"{md.relative_to(REPO)}: "
              f"{'OK' if not found else f'{len(found)} problem(s)'}")
    if args.run_quickstart:
        errors.extend(run_quickstart(REPO / "README.md"))

    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
