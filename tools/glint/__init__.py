"""glint: static-analysis suite for this repo's JAX/Pallas codebase.

Three layers, all runnable as ``python -m tools.glint`` and as tier-1 tests
(``tests/test_glint.py``):

  * **Layer 1 — AST lint** (``tools/glint/rules.py``): GL0xx rules over
    ``src/`` and ``tests/`` for host-transfer hazards in traced code, PRNG
    key reuse, 64-bit dtype creep, Python-loop device code in hot modules,
    Pallas kernel hygiene (``program_id`` under vmap, grid divisibility,
    ``BlockSpec`` memory spaces), mutable default args, unseeded RNG, dead
    modules, and unused imports.
  * **Layer 2 — jaxpr contracts** (``tools/glint/contracts.py``): GL2xx
    checks that trace every registered public entry point with shape shells
    and assert properties of the closed jaxpr / lowered IR: no f64, no host
    callbacks on hot paths, effective buffer donation, and collective
    traffic matching the byte-meter records term by term.
  * **Layer 3 — runtime guards** (``tools/glint/pytest_plugin.py``): a
    ``retrace_guard`` fixture (jit ``_cache_size`` deltas) and a
    ``transfer_guard`` fixture (``jax.transfer_guard``) applied to the
    round-engine and conformance suites. Registered via ``pytest.ini``
    (``addopts = -p tools.glint.pytest_plugin``).

Suppressions are inline and must carry a reason::

    h = compute()  # glint: disable=GL004 static layer unroll (heterogeneous params)

or file-scoped (anywhere in the file, one rule per comment)::

    # glint: disable-file=GL010 loaded dynamically via configs.base registry

A suppression without a reason is itself a finding (GL000). The committed
baseline is zero unsuppressed findings over ``src/``; the CI ``analysis``
job fails on any unsuppressed finding and reports the suppression count so
growth stays visible PR over PR (see ``docs/ANALYSIS.md``).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence

REPO = Path(__file__).resolve().parent.parent.parent

# default lint roots, relative to the repo root
DEFAULT_ROOTS = ("src", "tests")

# "hot" device-code modules: Python-loop / host-transfer rules apply here
HOT_PREFIXES = ("src/repro/core/", "src/repro/kernels/", "src/repro/serve/")
# modules whose function bodies are (mostly) jit-traced: host-transfer
# hazards (np.* / float() / .item() on jnp values) are flagged here
TRACED_PREFIXES = ("src/repro/core/glasu.py", "src/repro/kernels/")

_SUPPRESS_RE = re.compile(
    r"#\s*glint:\s*(disable|disable-file)=(GL\d{3})\b[ \t]*(.*)")


@dataclass(frozen=True)
class Finding:
    """One lint/contract finding (suppressed findings are dropped before
    reporting, but counted)."""
    rule: str                 # e.g. "GL004"
    path: str                 # repo-relative posix path
    line: int                 # 1-based
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def to_dict(self) -> dict:
        return asdict(self)


@dataclass
class Suppressions:
    """Parsed ``# glint: disable=...`` comments for one file."""
    line_rules: Dict[int, set] = field(default_factory=dict)   # line -> rules
    file_rules: set = field(default_factory=set)
    bare: List[int] = field(default_factory=list)              # missing reason
    count: int = 0

    def covers(self, rule: str, line: int) -> bool:
        if rule in self.file_rules:
            return True
        return rule in self.line_rules.get(line, ())


def parse_suppressions(text: str) -> Suppressions:
    sup = Suppressions()
    for i, raw in enumerate(text.splitlines(), start=1):
        m = _SUPPRESS_RE.search(raw)
        if not m:
            continue
        kind, rule, reason = m.groups()
        sup.count += 1
        if not reason.strip():
            sup.bare.append(i)
        if kind == "disable-file":
            sup.file_rules.add(rule)
        else:
            sup.line_rules.setdefault(i, set()).add(rule)
    return sup


def lint_files(roots: Sequence[str] = DEFAULT_ROOTS,
               repo: Optional[Path] = None) -> List[Path]:
    """All Python files under ``roots`` (repo-relative), sorted."""
    repo = repo or REPO
    files: List[Path] = []
    for root in roots:
        base = repo / root
        if base.is_file():
            files.append(base)
        elif base.is_dir():
            files.extend(sorted(base.rglob("*.py")))
    return files


def run_lint(roots: Sequence[str] = DEFAULT_ROOTS,
             repo: Optional[Path] = None,
             rules: Optional[Sequence[str]] = None):
    """Run the AST layer. Returns ``(findings, report)`` where ``report``
    carries suppression accounting (see the CI ``analysis`` job)."""
    from . import rules as rules_mod
    repo = repo or REPO
    files = lint_files(roots, repo)
    active = rules_mod.resolve(rules)
    findings: List[Finding] = []
    suppressed = 0
    suppression_sites = 0
    for f in files:
        rel = f.relative_to(repo).as_posix()
        text = f.read_text()
        sup = parse_suppressions(text)
        suppression_sites += sup.count
        for ln in sup.bare:
            findings.append(Finding(
                "GL000", rel, ln,
                "suppression without a reason — say why the rule is wrong "
                "here (`# glint: disable=GLxxx <reason>`)"))
        raw = rules_mod.check_file(f, rel, text, active, repo=repo,
                                   all_files=files)
        for fd in raw:
            if sup.covers(fd.rule, fd.line):
                suppressed += 1
            else:
                findings.append(fd)
    report = {"files": len(files), "suppressed_findings": suppressed,
              "suppression_sites": suppression_sites}
    return findings, report
