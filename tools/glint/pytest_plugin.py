"""Layer 3: runtime guards as pytest fixtures.

Registered session-wide via ``pytest.ini`` (``addopts = -p
tools.glint.pytest_plugin``), so every test file can take these fixtures
without imports:

``retrace_guard``
    Watches jitted callables' compile-cache sizes. A hot-path test warms
    the function up, calls ``retrace_guard.watch(fn)``, keeps driving it,
    and the fixture fails the test at teardown if ANY watched function
    compiled again — the dispatch-cost model of the round engines (one
    compile per (K, shapes) signature) is enforced, not assumed.

``transfer_guard``
    A context-manager factory wrapping ``jax.transfer_guard("disallow")``.
    Inside the scope, any implicit host<->device transfer raises — jitted
    dispatches on device-resident inputs must not touch the host. Inputs
    are staged explicitly first (``jax.device_put`` / ``jax.device_get``
    and ``np.asarray(jax_array)`` count as explicit and stay allowed).
"""
from __future__ import annotations

import contextlib

import pytest


def jit_cache_size(fn) -> int:
    """Compile-cache size of a jitted callable (unwraps the ``._jit``
    handle the checked round-fn builders expose)."""
    inner = getattr(fn, "_jit", fn)
    size = getattr(inner, "_cache_size", None)
    if size is None:
        raise TypeError(
            f"{fn!r} exposes no _cache_size — pass the jitted callable "
            f"(or a wrapper with a ._jit attribute)")
    return size()


class RetraceGuard:
    """Collects (label, fn, baseline_cache_size, allowed_new_compiles)."""

    def __init__(self):
        self._watched = []

    def watch(self, fn, label: str = None, max_new: int = 0):
        """Snapshot ``fn``'s compile cache; at test teardown the test fails
        if more than ``max_new`` new signatures were compiled. Call AFTER
        warmup — the first dispatch is the one legitimate compile."""
        self._watched.append((label or getattr(fn, "__name__", repr(fn)),
                              fn, jit_cache_size(fn), max_new))
        return fn

    def check(self):
        """Assert now (also runs automatically at teardown)."""
        errors = []
        for label, fn, base, max_new in self._watched:
            delta = jit_cache_size(fn) - base
            if delta > max_new:
                errors.append(
                    f"`{label}` retraced: {delta} new compile(s) after "
                    f"watch() (allowed {max_new}) — a shape/dtype/static-"
                    f"arg signature changed on the hot path")
        if errors:
            pytest.fail("retrace_guard: " + "; ".join(errors))


@pytest.fixture
def retrace_guard():
    guard = RetraceGuard()
    yield guard
    guard.check()


@pytest.fixture
def transfer_guard():
    """``with transfer_guard():`` — implicit transfers raise inside."""
    import jax

    @contextlib.contextmanager
    def scope(level: str = "disallow"):
        with jax.transfer_guard(level):
            yield

    return scope
