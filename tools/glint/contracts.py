"""Layer 2: jaxpr contract checker (GL2xx).

Traces every registered public entry point with shape shells (no real
compute: ``jax.eval_shape`` params, zero-stride sampler shells) and asserts
properties of the closed jaxpr / lowered IR that tier-1 unit tests cannot
see:

  GL201  no 64-bit values anywhere in the trace (x64 is off; a silently
         truncated f64 literal means someone *meant* a different number)
  GL202  no host-callback / device_put primitives on hot paths (a stray
         ``debug_print`` or implicit transfer serializes every dispatch)
  GL203  buffer donation effective: each donated leaf of the multi-round
         step fns produces an input-output aliasing in the lowered IR
         (broken donation doubles parameter HBM traffic per step) — run
         once per execution policy, so a policy whose extra carry (EF
         accumulators, fault caches) breaks aliasing fails here
  GL204  the sharded round body's embedding collectives match the byte
         meter term by term: per-client wire bytes summed over ``all_gather``
         eqns equal the sum of ``CollectiveRecord.up_bytes`` (a drifted
         meter is a static failure here, not a benchmark drift) — run
         once per execution policy (plain / compressed / fault-tolerant /
         composed)

Entry points register in ``ENTRY_POINTS``; adding a public round/serve/
kernel builder without registering it is itself a finding (GL200-style
coverage is enforced in ``tests/test_glint.py``), and every execution
policy of the unified round body (``core.glasu.ExecPolicy``) must ship a
registered traceable entry for both multi-round builders
(``_check_policy_coverage``).
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Dict, List, Tuple

from . import Finding

_X64 = ("float64", "int64", "uint64", "complex128")
_CALLBACK_PRIMS = ("pure_callback", "io_callback", "debug_callback",
                   "callback", "outside_call", "device_put")


# ----------------------------------------------------------------- fixture
@functools.lru_cache(maxsize=None)
def _fixture():
    """Tiny shape-shell world shared by all contracts (built once)."""
    import jax
    import numpy as np
    from repro.core import glasu
    from repro.core.glasu import GlasuConfig
    from repro.graph.sampler import GlasuSampler, SamplerConfig
    from repro.graph.synth import make_vfl_dataset
    from repro.optim import optimizers as opt_lib

    m = 2
    data = make_vfl_dataset("tiny", n_clients=m, seed=0)
    d_in = max(c.feat_dim for c in data.clients)
    cfg = GlasuConfig(n_clients=m, n_layers=4, hidden=8,
                      n_classes=data.n_classes, d_in=d_in, backbone="gcn",
                      agg="mean", agg_layers=(1, 3), n_local_steps=1)
    scfg = SamplerConfig(n_layers=4, agg_layers=(1, 3), batch_size=4,
                         fanout=2, size_cap=32)
    sampler = GlasuSampler(data, scfg, seed=0)
    shell = sampler.shape_shell_batch()
    opt = opt_lib.sgd(0.1)
    params_abs = jax.eval_shape(lambda k: glasu.init_params(k, cfg),
                                jax.random.PRNGKey(0))
    opt_abs = jax.eval_shape(opt.init, params_abs)
    key_abs = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    batch_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype),
        shell)
    return dict(cfg=cfg, opt=opt, sampler=sampler, data=data,
                params=params_abs, opt_state=opt_abs, key=key_abs,
                batch=batch_abs, glasu=glasu)


def _stack_rounds(batch_abs, k: int):
    import jax
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct((k,) + a.shape, a.dtype), batch_abs)


def _keys_abs(k: int):
    import jax
    key = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    return jax.ShapeDtypeStruct((k,) + key.shape, key.dtype)


# ------------------------------------------------------------ jaxpr walking
def _walk_eqns(jaxpr):
    """Yield every eqn in ``jaxpr`` and all nested sub-jaxprs (pjit bodies,
    shard_map bodies, scan/cond branches, custom_vjp calls...)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _walk_eqns(sub)


def _sub_jaxprs(v):
    import jax.core as core
    if isinstance(v, core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, core.Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for item in v:
            yield from _sub_jaxprs(item)


def _all_avals(jaxpr):
    for eqn in _walk_eqns(jaxpr):
        for var in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(var, "aval", None)
            if aval is not None and hasattr(aval, "dtype"):
                yield eqn, aval


# ---------------------------------------------------------------- contracts
def _check_no_x64(name: str, closed, where: str) -> List[Finding]:
    out = []
    for eqn, aval in _all_avals(closed.jaxpr):
        if str(aval.dtype) in _X64:
            out.append(Finding(
                "GL201", where, 1,
                f"{name}: 64-bit value ({aval.dtype}) produced by "
                f"`{eqn.primitive.name}` in the traced jaxpr — x64 is "
                f"disabled repo-wide"))
            break
    for const in closed.consts:
        dt = getattr(const, "dtype", None)
        if dt is not None and str(dt) in _X64:
            out.append(Finding(
                "GL201", where, 1,
                f"{name}: 64-bit constant ({dt}) closed over by the jaxpr"))
            break
    return out


def _check_no_callbacks(name: str, closed, where: str) -> List[Finding]:
    out = []
    for eqn in _walk_eqns(closed.jaxpr):
        if eqn.primitive.name in _CALLBACK_PRIMS:
            out.append(Finding(
                "GL202", where, 1,
                f"{name}: `{eqn.primitive.name}` primitive on a hot path — "
                f"host callbacks/transfers serialize every dispatch"))
    return out


def _check_donation(name: str, jitted, args, n_donated_leaves: int,
                    where: str) -> List[Finding]:
    text = jitted.lower(*args).as_text()
    aliased = text.count("tf.aliasing_output")
    if aliased < n_donated_leaves:
        return [Finding(
            "GL203", where, 1,
            f"{name}: only {aliased} of {n_donated_leaves} donated leaves "
            f"are aliased input->output in the lowered IR — donation is "
            f"(partially) broken and parameter HBM traffic doubles")]
    return []


def _collect_gathers(closed):
    """(per_client_bytes, operand_ndim) per all_gather eqn in the trace."""
    out = []
    for eqn in _walk_eqns(closed.jaxpr):
        if eqn.primitive.name != "all_gather":
            continue
        aval = eqn.invars[0].aval
        # leading axis is the local client block; bytes of one client's
        # message = payload rows x width x itemsize
        per_client = (math.prod(aval.shape[1:]) * aval.dtype.itemsize
                      if aval.ndim >= 1 else aval.dtype.itemsize)
        out.append((per_client, aval.ndim))
    return out


# the ExecPolicy grid of the unified round body (core.glasu._round_body):
# identity/int8 codec x all-present/fault-tolerant participation. Every
# combination must ship a registered traceable entry for both multi-round
# builders — _check_policy_coverage fails the run otherwise.
POLICY_COMBOS = ("plain", "int8", "faults", "int8+faults")


def _policy_cfg(policy: str):
    """The fixture config under one execution-policy combination."""
    import dataclasses
    from repro.comm.compression import CompressionConfig

    cfg = _fixture()["cfg"]
    if "int8" in policy:
        cfg = dataclasses.replace(
            cfg, compression=CompressionConfig(method="int8"))
    if "faults" in policy:
        cfg = dataclasses.replace(cfg, fault_tolerant=True)
    return cfg


def _policy_args(cfg, k: int | None = None):
    """Abstract call args of a (multi-)round builder under ``cfg``'s
    policy: ``params, opt_state, [comp_state,] [fault_state,] batch(es),
    key(s)[, faults]`` — the unified builder signature."""
    import jax

    fx = _fixture()
    glasu = fx["glasu"]
    args = [fx["params"], fx["opt_state"]]
    if cfg.compression is not None and cfg.compression.active:
        args.append(jax.eval_shape(lambda: glasu.init_comp_state(
            cfg, fx["sampler"].layer_sizes)))
    if cfg.fault_tolerant:
        args.append(jax.eval_shape(lambda: glasu.init_fault_state(
            cfg, fx["sampler"].layer_sizes)))
    if k is None:
        args += [fx["batch"], fx["key"]]
    else:
        args += [_stack_rounds(fx["batch"], k), _keys_abs(k)]
    if cfg.fault_tolerant:
        shape = (cfg.n_clients,) if k is None else (k, cfg.n_clients)
        mask = jax.ShapeDtypeStruct(shape, "float32")
        args.append(glasu.RoundFaults(mask, mask))
    return tuple(args)


def _n_donated_leaves(cfg, args) -> int:
    """params + opt_state + every active carry (the donate_argnums set of
    the unified multi-round builders)."""
    import jax
    n_carries = 2 + int(cfg.compression is not None
                        and cfg.compression.active) + int(cfg.fault_tolerant)
    return len(jax.tree.leaves(args[:n_carries]))


def _check_collectives_vs_meter(policy: str = "plain") -> List[Finding]:
    """GL204: trace the sharded round body under one execution policy,
    compare its all_gather set against the CollectiveRecords the byte
    meter emits for the same trace."""
    import jax
    from repro.launch.mesh import make_client_mesh

    fx = _fixture()
    glasu = fx["glasu"]
    cfg = _policy_cfg(policy)
    where = "src/repro/core/glasu.py"
    mesh = make_client_mesh(cfg.n_clients)
    records = []
    fn = glasu.make_sharded_round_fn(cfg, fx["opt"], mesh,
                                     record=records.append, jit=False)
    args = _policy_args(cfg)
    with mesh:
        closed = jax.make_jaxpr(fn)(*args)

    name = f"make_sharded_round_fn[{policy}]"
    out = []
    if not records:
        return [Finding("GL204", where, 1,
                        f"{name}: byte meter recorded no collectives")]
    # embedding exchanges are >=2-D payloads; the 1-D all_gather is the
    # Q-scalar loss diagnostic, explicitly unmetered (see the
    # local_update_steps docstring)
    payload = [b for b, nd in _collect_gathers(closed) if nd >= 2]
    metered = sum(r.up_bytes for r in records)
    traced = sum(payload)
    if traced != metered:
        out.append(Finding(
            "GL204", where, 1,
            f"{name}: traced embedding all_gathers move {traced} B/client "
            f"but the byte meter prices {metered} B/client — the meter "
            f"drifted from the compiled collectives"))
    if len(payload) < len(records):
        out.append(Finding(
            "GL204", where, 1,
            f"{name}: {len(records)} CollectiveRecords but only "
            f"{len(payload)} embedding all_gathers in the trace"))
    return out


# ------------------------------------------------------------- entry points
def _ep_round_fn():
    import jax
    fx = _fixture()
    fn = fx["glasu"].make_round_fn(fx["cfg"], fx["opt"])
    closed = jax.make_jaxpr(fn)(fx["params"], fx["opt_state"], fx["batch"],
                                fx["key"])
    return closed, None


def _ep_multi_round_fn(policy: str = "plain"):
    import jax
    fx = _fixture()
    cfg = _policy_cfg(policy)
    k = 2
    fn = fx["glasu"].make_multi_round_fn(cfg, fx["opt"])
    args = _policy_args(cfg, k=k)
    closed = jax.make_jaxpr(fn)(*args)
    return closed, (fn, args, _n_donated_leaves(cfg, args))


def _ep_sharded_round_fn():
    import jax
    from repro.launch.mesh import make_client_mesh
    fx = _fixture()
    mesh = make_client_mesh(fx["cfg"].n_clients)
    fn = fx["glasu"].make_sharded_round_fn(fx["cfg"], fx["opt"], mesh,
                                           jit=False)
    with mesh:
        closed = jax.make_jaxpr(fn)(fx["params"], fx["opt_state"],
                                    fx["batch"], fx["key"])
    return closed, None


def _ep_sharded_multi_round_fn(policy: str = "plain"):
    import jax
    from repro.launch.mesh import make_client_mesh
    fx = _fixture()
    cfg = _policy_cfg(policy)
    k = 2
    mesh = make_client_mesh(cfg.n_clients)
    fn = fx["glasu"].make_sharded_multi_round_fn(cfg, fx["opt"], mesh)
    args = _policy_args(cfg, k=k)
    with mesh:
        closed = jax.make_jaxpr(fn)(*args)
        findings = _check_donation(
            f"make_sharded_multi_round_fn[{policy}]", fn, args,
            _n_donated_leaves(cfg, args), "src/repro/core/glasu.py")
    return closed, ("inline", findings)


def _ep_sharded_joint_fn():
    import jax
    from repro.launch.mesh import make_client_mesh
    fx = _fixture()
    mesh = make_client_mesh(fx["cfg"].n_clients)
    fn = fx["glasu"].make_sharded_joint_fn(fx["cfg"], mesh)
    with mesh:
        closed = jax.make_jaxpr(fn)(fx["params"], fx["batch"], fx["key"])
    return closed, None


def _ep_sharded_serve_fn():
    import jax
    from repro.launch.mesh import make_client_mesh
    fx = _fixture()
    cfg = fx["cfg"]
    mesh = make_client_mesh(cfg.n_clients)
    fn = fx["glasu"].make_sharded_serve_fn(cfg, mesh)
    sizes = fx["sampler"].layer_sizes
    # cache-injection shells: keep mask (n_{l+1},) + replicated row stacks
    # (M, n_{l+1}, h) for every aggregation layer (the session always passes
    # the full key set; all-zero masks mean no injection)
    inject = {l: (jax.ShapeDtypeStruct((sizes[l + 1],), "float32"),
                  jax.ShapeDtypeStruct((cfg.n_clients, sizes[l + 1],
                                        cfg.hidden), "float32"))
              for l in cfg.agg_layers}
    with mesh:
        closed = jax.make_jaxpr(fn)(fx["params"], fx["batch"], inject)
    return closed, None


def _ep_serve_forward():
    import jax
    fx = _fixture()
    closed = jax.make_jaxpr(
        lambda p, b: fx["glasu"].serve_forward(p, b, fx["cfg"]))(
            fx["params"], fx["batch"])
    return closed, None


def _ep_full_forward():
    import jax
    fx = _fixture()
    cfg, data = fx["cfg"], fx["data"]
    m = cfg.n_clients
    n = min(c.n_nodes for c in data.clients)
    feats = jax.ShapeDtypeStruct((m, n, cfg.d_in), "float32")
    width = 4
    nbr = jax.ShapeDtypeStruct((m, n, width), "int32")
    nbm = jax.ShapeDtypeStruct((m, n, width), "float32")
    closed = jax.make_jaxpr(
        lambda p, f, i, k: fx["glasu"].full_forward(p, cfg, f, i, k,
                                                    chunk=16))(
            fx["params"], feats, nbr, nbm)
    return closed, None


def _ep_graph_agg_kernel():
    import jax
    from repro.kernels.graph_agg import graph_agg_pallas
    h = jax.ShapeDtypeStruct((32, 8), "float32")
    idx = jax.ShapeDtypeStruct((16, 3), "int32")
    mask = jax.ShapeDtypeStruct((16, 3), "float32")
    w = jax.ShapeDtypeStruct((8, 8), "float32")
    closed = jax.make_jaxpr(graph_agg_pallas)(h, idx, mask, w)
    return closed, None


def _ep_graph_agg_csr_kernel():
    import jax
    import numpy as np
    from repro.graph.csr_plan import plan_csr_slabs
    from repro.kernels.graph_agg import graph_agg_csr_pallas
    # a tiny concrete CSR: the slab planner is host-side, so the traced
    # entry is the kernel over the planned static-shape slab arrays
    indptr = np.array([0, 2, 2, 5, 6], np.int32)        # zero-degree row 1
    indices = np.array([1, 3, 0, 2, 3, 1], np.int32)
    idx_s, seg_s, ew_s, n_dst = plan_csr_slabs(indptr, indices)
    h = jax.ShapeDtypeStruct((4, 8), "float32")
    w = jax.ShapeDtypeStruct((8, 8), "float32")
    slabs = [jax.ShapeDtypeStruct(a.shape, a.dtype.name)
             for a in (idx_s, seg_s, ew_s)]
    closed = jax.make_jaxpr(
        lambda h_, i_, s_, e_, w_: graph_agg_csr_pallas(h_, i_, s_, e_, w_,
                                                        n_dst))(
            h, *slabs, w)
    return closed, None


def _ep_gcnii_kernel():
    import jax
    from repro.kernels.graph_agg import gcnii_layer_pallas
    h = jax.ShapeDtypeStruct((32, 8), "float32")
    idx = jax.ShapeDtypeStruct((16, 4), "int32")
    mask = jax.ShapeDtypeStruct((16, 4), "float32")
    w = jax.ShapeDtypeStruct((8, 8), "float32")
    b = jax.ShapeDtypeStruct((8,), "float32")
    closed = jax.make_jaxpr(
        lambda *a: gcnii_layer_pallas(*a, alpha=0.1, beta=0.5))(
            h, h, idx, mask, w, b)
    return closed, None


def _ep_flash_kernel():
    import jax
    from repro.kernels.flash_attention import flash_attention_pallas
    q = jax.ShapeDtypeStruct((1, 64, 4, 8), "float32")
    k = jax.ShapeDtypeStruct((1, 64, 2, 8), "float32")   # GQA: 2 kv heads
    closed = jax.make_jaxpr(
        lambda q_, k_, v_: flash_attention_pallas(q_, k_, v_))(q, k, k)
    return closed, None


def _ep_gat_kernel():
    import jax
    from repro.kernels.graph_agg import gat_layer_pallas
    h = jax.ShapeDtypeStruct((32, 8), "float32")
    idx = jax.ShapeDtypeStruct((16, 4), "int32")
    mask = jax.ShapeDtypeStruct((16, 4), "float32")
    w = jax.ShapeDtypeStruct((8, 2, 4), "float32")
    a_src = jax.ShapeDtypeStruct((2, 4), "float32")
    a_dst = jax.ShapeDtypeStruct((2, 4), "float32")
    b = jax.ShapeDtypeStruct((8,), "float32")
    closed = jax.make_jaxpr(gat_layer_pallas)(h, idx, mask, w, a_src,
                                              a_dst, b)
    return closed, None


# name -> (builder, repo-relative path of the code under contract)
ENTRY_POINTS: Dict[str, Tuple[Callable, str]] = {
    "make_round_fn": (_ep_round_fn, "src/repro/core/glasu.py"),
    "make_multi_round_fn": (_ep_multi_round_fn, "src/repro/core/glasu.py"),
    "make_sharded_round_fn": (_ep_sharded_round_fn,
                              "src/repro/core/glasu.py"),
    "make_sharded_multi_round_fn": (_ep_sharded_multi_round_fn,
                                    "src/repro/core/glasu.py"),
    # non-plain ExecPolicy combinations of the unified round body: same
    # builders, extra carries (EF accumulators / fault caches) donated
    **{f"make_multi_round_fn[{_p}]": (
        functools.partial(_ep_multi_round_fn, _p),
        "src/repro/core/glasu.py") for _p in POLICY_COMBOS[1:]},
    **{f"make_sharded_multi_round_fn[{_p}]": (
        functools.partial(_ep_sharded_multi_round_fn, _p),
        "src/repro/core/glasu.py") for _p in POLICY_COMBOS[1:]},
    "make_sharded_joint_fn": (_ep_sharded_joint_fn,
                              "src/repro/core/glasu.py"),
    "make_sharded_serve_fn": (_ep_sharded_serve_fn,
                              "src/repro/core/glasu.py"),
    "serve_forward": (_ep_serve_forward, "src/repro/core/glasu.py"),
    "full_forward": (_ep_full_forward, "src/repro/core/glasu.py"),
    "graph_agg_pallas": (_ep_graph_agg_kernel,
                         "src/repro/kernels/graph_agg.py"),
    "graph_agg_csr_pallas": (_ep_graph_agg_csr_kernel,
                             "src/repro/kernels/graph_agg.py"),
    "gcnii_layer_pallas": (_ep_gcnii_kernel,
                           "src/repro/kernels/graph_agg.py"),
    "gat_layer_pallas": (_ep_gat_kernel, "src/repro/kernels/graph_agg.py"),
    "flash_attention_pallas": (_ep_flash_kernel,
                               "src/repro/kernels/flash_attention.py"),
}


def _check_policy_coverage() -> List[Finding]:
    """Every ExecPolicy combination of the unified round body must ship a
    registered traceable entry for both multi-round builders — the jit
    boundaries the Trainer actually dispatches. A policy added to
    ``POLICY_COMBOS`` without its entries is a finding, not a silent gap
    in contract coverage."""
    out = []
    for pol in POLICY_COMBOS:
        for base in ("make_multi_round_fn", "make_sharded_multi_round_fn"):
            key = base if pol == "plain" else f"{base}[{pol}]"
            if key not in ENTRY_POINTS:
                out.append(Finding(
                    "GL200", "tools/glint/contracts.py", 1,
                    f"execution policy {pol!r} ships without a registered "
                    f"traceable entry for {base} — GL203/GL204 never run "
                    f"against that combination"))
    return out


def run_contracts(names=None):
    """Run the GL2xx layer. Returns ``(findings, report)``."""
    findings: List[Finding] = []
    checked = []
    if names is None:
        findings.extend(_check_policy_coverage())
    for name, (builder, where) in ENTRY_POINTS.items():
        if names is not None and name not in names:
            continue
        closed, extra = builder()
        findings.extend(_check_no_x64(name, closed, where))
        findings.extend(_check_no_callbacks(name, closed, where))
        if extra == "skip-donation":
            pass
        elif isinstance(extra, tuple) and extra and extra[0] == "inline":
            findings.extend(extra[1])
        elif extra is not None:
            fn, args, n_leaves = extra
            findings.extend(_check_donation(name, fn, args, n_leaves,
                                            where))
        checked.append(name)
    if names is None or "collectives" in (names or ()):
        for pol in POLICY_COMBOS:
            findings.extend(_check_collectives_vs_meter(pol))
        checked.append("collectives-vs-meter")
    report = {"entry_points": checked}
    return findings, report
