"""CLI: ``python -m tools.glint [--format text|json] [--rules GL001,GL002]
[--no-contracts] [roots...]``.

Exit status 0 iff zero unsuppressed findings. ``--format json`` emits the
machine-readable report the CI ``analysis`` job uploads as an artifact.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import DEFAULT_ROOTS, REPO, run_lint


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m tools.glint")
    ap.add_argument("roots", nargs="*", default=list(DEFAULT_ROOTS),
                    help="repo-relative files/dirs to lint "
                         f"(default: {' '.join(DEFAULT_ROOTS)})")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--rules", default=None,
                    help="comma-separated GL0xx subset (default: all)")
    ap.add_argument("--no-contracts", action="store_true",
                    help="skip the jaxpr contract layer (GL2xx) — faster, "
                         "no jax import")
    args = ap.parse_args(argv)

    rules = args.rules.split(",") if args.rules else None
    findings, report = run_lint(args.roots, repo=REPO, rules=rules)

    if not args.no_contracts and rules is None:
        from . import contracts
        cf, creport = contracts.run_contracts()
        findings.extend(cf)
        report["contracts"] = creport

    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    report["findings"] = len(findings)

    if args.format == "json":
        json.dump({"findings": [f.to_dict() for f in findings],
                   "report": report}, sys.stdout, indent=2)
        print()
    else:
        for f in findings:
            print(f.format())
        print(f"glint: {len(findings)} finding(s) in {report['files']} "
              f"file(s); {report['suppressed_findings']} suppressed "
              f"({report['suppression_sites']} suppression site(s))")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
