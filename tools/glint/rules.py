"""Layer 1: AST lint rules (GL0xx).

Each rule is a function ``(module: ParsedModule, ctx: LintContext) ->
list[Finding]`` registered in ``RULES``. Rules are deliberately lexical —
they over-approximate and rely on reasoned inline suppressions
(``# glint: disable=GLxxx reason``) where the code is right and the rule is
wrong. See ``docs/ANALYSIS.md`` for the catalog with examples.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

from . import Finding, HOT_PREFIXES, TRACED_PREFIXES

# jax.random samplers CONSUME a key (its stream is spent); split/fold_in
# DERIVE fresh keys from it. A key may be derived from repeatedly (with
# distinct fold_in constants) but once consumed it must never be used again.
KEY_CONSUMERS = frozenset({
    "normal", "uniform", "bernoulli", "randint", "truncated_normal",
    "gumbel", "choice", "permutation", "categorical", "laplace",
    "exponential", "bits", "beta", "cauchy", "dirichlet", "gamma",
    "poisson", "rademacher", "shuffle",
})
KEY_DERIVERS = frozenset({"split", "fold_in"})

# numpy legacy global-state RNG entry points (GL009)
_NP_GLOBAL_RNG = frozenset({
    "seed", "rand", "randn", "randint", "random", "choice", "shuffle",
    "permutation", "normal", "uniform", "random_sample", "standard_normal",
})

_X64_NAMES = frozenset({"float64", "int64", "uint64", "complex128"})


@dataclass
class ParsedModule:
    path: Path                # absolute
    rel: str                  # repo-relative posix
    text: str
    tree: ast.Module

    @property
    def is_hot(self) -> bool:
        return self.rel.startswith(HOT_PREFIXES)

    @property
    def is_traced(self) -> bool:
        return self.rel.startswith(TRACED_PREFIXES)


@dataclass
class LintContext:
    repo: Path
    all_files: Sequence[Path] = ()
    _import_graph: Optional[dict] = field(default=None, repr=False)


# --------------------------------------------------------------- ast helpers
def _dotted(node: ast.AST) -> str:
    """'jax.random.normal' for nested Attribute/Name chains, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _functions(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _assigned_names(target: ast.AST) -> List[str]:
    out = []
    for node in ast.walk(target):
        if isinstance(node, ast.Name):
            out.append(node.id)
    return out


# ------------------------------------------------------------------- rules
def rule_gl001(mod: ParsedModule, ctx: LintContext) -> List[Finding]:
    """GL001 host-transfer hazard in traced code: ``np.*`` compute,
    ``float()``/``int()`` casts, ``.item()``/``.tolist()`` inside modules
    whose function bodies are jit-traced (``core/glasu.py``, ``kernels/``).
    Any of these forces an implicit device->host sync (or a host constant
    re-uploaded every call) in the middle of a traced round body/kernel."""
    if not mod.is_traced:
        return []
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func)
        if name.startswith("np.") or name.startswith("numpy."):
            # np.dtype / np.issubdtype-style metadata probes are host-only
            # and shape-static; everything else is a transfer hazard
            leaf = name.split(".")[-1]
            if leaf not in ("dtype", "issubdtype", "ndim", "prod"):
                out.append(Finding(
                    "GL001", mod.rel, node.lineno,
                    f"`{name}(...)` in traced module — numpy materializes "
                    f"on host; use jnp (or hoist to untraced setup)"))
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("item", "tolist") and not node.args:
            out.append(Finding(
                "GL001", mod.rel, node.lineno,
                f"`.{node.func.attr}()` in traced module — implicit "
                f"device->host transfer; keep values on device or use "
                f"jax.device_get at an explicit sync point"))
        elif isinstance(node.func, ast.Name) \
                and node.func.id in ("float", "int") and node.args:
            arg = node.args[0]
            # float(2.0), int(x.shape[0]), len(...)-style statics are fine
            if isinstance(arg, ast.Constant):
                continue
            s = ast.dump(arg)
            if "attr='shape'" in s or "func=Name(id='len'" in s:
                continue
            out.append(Finding(
                "GL001", mod.rel, node.lineno,
                f"`{node.func.id}(...)` on a non-literal in traced module — "
                f"forces a device->host sync if the value is traced"))
    return out


def rule_gl002(mod: ParsedModule, ctx: LintContext) -> List[Finding]:
    """GL002 PRNG key reuse: within one function, a key passed to a
    ``jax.random`` sampler (consumption) must never be used again, and a key
    may be split at most once / folded only with distinct constants.
    Reassignment (``key, sub = split(key)``) resets the tracking."""
    out = []
    for fn in _functions(mod.tree):
        # uses[name] -> list of ("consume"|"derive", line, detail)
        uses: Dict[str, List[tuple]] = {}

        class V(ast.NodeVisitor):
            def visit_FunctionDef(self, node):
                if node is not fn:
                    return          # nested functions get their own pass
                self.generic_visit(node)

            visit_AsyncFunctionDef = visit_FunctionDef

            def visit_Lambda(self, node):
                return          # lambda params shadow outer key names

            def visit_Call(self, node):
                name = _dotted(node.func)
                leaf = name.split(".")[-1]
                key_arg = None
                if node.args and isinstance(node.args[0], ast.Name):
                    key_arg = node.args[0].id
                for kw in node.keywords:
                    if kw.arg == "key" and isinstance(kw.value, ast.Name):
                        key_arg = kw.value.id
                is_random = (".random." in name or name.startswith("random."))\
                    and (leaf in KEY_CONSUMERS or leaf in KEY_DERIVERS)
                if is_random and key_arg is not None:
                    kind = "derive" if leaf in KEY_DERIVERS else "consume"
                    detail = None
                    if leaf == "fold_in" and len(node.args) > 1 \
                            and isinstance(node.args[1], ast.Constant):
                        detail = ("fold", node.args[1].value)
                    elif leaf == "split":
                        detail = ("split",)
                    prior = uses.setdefault(key_arg, [])
                    consumed = [u for u in prior if u[0] == "consume"]
                    if consumed:
                        out.append(Finding(
                            "GL002", mod.rel, node.lineno,
                            f"key `{key_arg}` already consumed by a sampler "
                            f"at line {consumed[0][1]} — derive subkeys "
                            f"(split/fold_in) BEFORE sampling, never after"))
                    elif kind == "consume" and prior:
                        out.append(Finding(
                            "GL002", mod.rel, node.lineno,
                            f"key `{key_arg}` sampled after being derived "
                            f"from at line {prior[0][1]} — sample from a "
                            f"derived subkey instead of the parent"))
                    elif detail is not None and detail in \
                            [u[2] for u in prior]:
                        dup = next(u for u in prior if u[2] == detail)
                        what = "split twice" if detail == ("split",) else \
                            f"fold_in with the same constant {detail[1]!r}"
                        out.append(Finding(
                            "GL002", mod.rel, node.lineno,
                            f"key `{key_arg}` {what} (first at line "
                            f"{dup[1]}) — the two streams are identical"))
                    prior.append((kind, node.lineno, detail))
                self.generic_visit(node)

            def visit_Assign(self, node):
                self.visit(node.value)
                for t in node.targets:
                    for nm in _assigned_names(t):
                        uses.pop(nm, None)

            def visit_AugAssign(self, node):
                self.visit(node.value)
                for nm in _assigned_names(node.target):
                    uses.pop(nm, None)

        V().visit(fn)
    return out


def rule_gl003(mod: ParsedModule, ctx: LintContext) -> List[Finding]:
    """GL003 64-bit dtype creep: x64 is disabled repo-wide (the sampler's
    int32 LUT contract, float32-ULP conformance tolerances); any explicit
    64-bit dtype is either dead (silently truncated by jax) or doubles a
    buffer that every meter prices at 4 B."""
    out = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Attribute) and node.attr in _X64_NAMES:
            base = _dotted(node.value)
            if base in ("np", "numpy", "jnp", "jax.numpy"):
                out.append(Finding(
                    "GL003", mod.rel, node.lineno,
                    f"`{base}.{node.attr}` — 64-bit dtype with x64 disabled "
                    f"(use the 32-bit counterpart)"))
        elif isinstance(node, ast.Constant) and node.value in _X64_NAMES:
            out.append(Finding(
                "GL003", mod.rel, node.lineno,
                f"dtype string {node.value!r} — 64-bit dtype with x64 "
                f"disabled (use the 32-bit counterpart)"))
        elif isinstance(node, ast.Call):
            name = _dotted(node.func)
            if name.endswith("config.update") and node.args \
                    and isinstance(node.args[0], ast.Constant) \
                    and node.args[0].value == "jax_enable_x64":
                out.append(Finding(
                    "GL003", mod.rel, node.lineno,
                    "toggling jax_enable_x64 in library code — the repo "
                    "contract is x64 off everywhere"))
    return out


_DEVICE_PREFIXES = ("jnp.", "jax.lax.", "jax.nn.", "jax.vmap", "jax.jit",
                    "jax.grad", "jax.value_and_grad", "jax.random.")


def rule_gl004(mod: ParsedModule, ctx: LintContext) -> List[Finding]:
    """GL004 device ops inside Python loops in hot modules (core/, kernels/,
    serve/): each iteration traces/unrolls its own copy of the op — use
    ``lax.scan``/``lax.map`` (or vectorize) so one compiled body is reused.
    Static unrolls that are genuinely heterogeneous (per-layer params,
    trace-time fanout) carry a reasoned suppression instead."""
    if not mod.is_hot:
        return []
    out = []
    for loop in ast.walk(mod.tree):
        if not isinstance(loop, (ast.For, ast.While)):
            continue
        hit = None
        for node in ast.walk(loop):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue            # nested defs are traced at call time
            if isinstance(node, ast.Call):
                name = _dotted(node.func)
                if name.startswith(_DEVICE_PREFIXES):
                    hit = name
                    break
        if hit:
            out.append(Finding(
                "GL004", mod.rel, loop.lineno,
                f"`{hit}` inside a Python {type(loop).__name__.lower()} "
                f"loop in a hot module — every iteration unrolls into the "
                f"trace; use lax.scan/lax.map or vectorize"))
    return out


def rule_gl005(mod: ParsedModule, ctx: LintContext) -> List[Finding]:
    """GL005 ``pl.program_id`` in a Pallas kernel: ``jax.vmap`` over a
    pallas_call PREPENDS a grid axis, silently shifting every program_id
    axis — kernels reachable from vmapped call sites must take grid
    coordinates as data (BlockSpec-indexed offset arrays) instead. Kernels
    that are provably never vmapped suppress with that reason."""
    out = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) \
                and _dotted(node.func).endswith("program_id"):
            out.append(Finding(
                "GL005", mod.rel, node.lineno,
                "`program_id` in a Pallas kernel — vmap prepends a grid "
                "axis and shifts program_id axes; pass the coordinate as "
                "data via a BlockSpec-indexed offsets array (see "
                "kernels/graph_agg.py col_ref)"))
    return out


def rule_gl006(mod: ParsedModule, ctx: LintContext) -> List[Finding]:
    """GL006 pallas_call grid divisibility: a ``grid=`` entry computed with
    ``//`` silently drops remainder rows unless the operands were padded to
    the block multiple (or divisibility is asserted) in the same function."""
    out = []
    for fn in _functions(mod.tree):
        calls = [n for n in ast.walk(fn) if isinstance(n, ast.Call)
                 and _dotted(n.func).endswith("pallas_call")]
        if not calls:
            continue
        grid_divides = False
        for node in ast.walk(fn):
            if isinstance(node, ast.keyword) and node.arg == "grid":
                if any(isinstance(b, ast.BinOp)
                       and isinstance(b.op, ast.FloorDiv)
                       for b in ast.walk(node.value)):
                    grid_divides = True
            if isinstance(node, ast.Assign) \
                    and any(isinstance(b, ast.BinOp)
                            and isinstance(b.op, ast.FloorDiv)
                            for b in ast.walk(node.value)) \
                    and any(nm == "grid" for t in node.targets
                            for nm in _assigned_names(t)):
                grid_divides = True
        if not grid_divides:
            continue
        guarded = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and "pad" in _dotted(node.func):
                guarded = True
            if isinstance(node, ast.Assert) \
                    and any(isinstance(b, ast.BinOp)
                            and isinstance(b.op, ast.Mod)
                            for b in ast.walk(node.test)):
                guarded = True
        if not guarded:
            out.append(Finding(
                "GL006", mod.rel, calls[0].lineno,
                f"`{fn.name}` computes a pallas grid with `//` but neither "
                f"pads operands to the block multiple nor asserts "
                f"divisibility — remainder rows are silently dropped"))
    return out


def rule_gl007(mod: ParsedModule, ctx: LintContext) -> List[Finding]:
    """GL007 ``pl.BlockSpec`` without an explicit ``memory_space``: on TPU
    the placement default depends on shape/rank heuristics; stating
    VMEM/SMEM/ANY per operand documents the VMEM budget math the kernel
    docstrings do by hand and fails loudly when a tile outgrows it."""
    out = []
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call)
                and _dotted(node.func).endswith("BlockSpec")
                and _dotted(node.func).split(".")[0] in ("pl", "pallas")):
            continue
        if not any(kw.arg == "memory_space" for kw in node.keywords):
            out.append(Finding(
                "GL007", mod.rel, node.lineno,
                "pl.BlockSpec without memory_space= — annotate VMEM/SMEM/"
                "ANY so tile placement (and the VMEM budget) is explicit"))
    return out


def rule_gl008(mod: ParsedModule, ctx: LintContext) -> List[Finding]:
    """GL008 mutable default argument: shared across calls; a mutated
    default leaks state between rounds/tests."""
    out = []
    for fn in _functions(mod.tree):
        for default in list(fn.args.defaults) + \
                [d for d in fn.args.kw_defaults if d is not None]:
            if isinstance(default, (ast.List, ast.Dict, ast.Set)) or \
                    (isinstance(default, ast.Call)
                     and _dotted(default.func) in ("list", "dict", "set")):
                out.append(Finding(
                    "GL008", mod.rel, default.lineno,
                    f"mutable default argument in `{fn.name}` — use None "
                    f"and construct inside the body"))
    return out


def rule_gl009(mod: ParsedModule, ctx: LintContext) -> List[Finding]:
    """GL009 unseeded / global-state RNG: ``np.random.*`` legacy API and
    stdlib ``random`` share hidden global state (non-reproducible rounds,
    cross-test coupling); ``default_rng()`` without a seed is
    non-reproducible. Use ``np.random.default_rng(seed)``."""
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func)
        if name in (f"np.random.{f}" for f in _NP_GLOBAL_RNG):
            out.append(Finding(
                "GL009", mod.rel, node.lineno,
                f"`{name}` uses numpy's global RNG state — use a seeded "
                f"np.random.default_rng(seed) Generator"))
        elif name.endswith("default_rng") and not node.args \
                and not node.keywords:
            out.append(Finding(
                "GL009", mod.rel, node.lineno,
                "`default_rng()` without a seed — pass an explicit seed "
                "for reproducible rounds"))
        elif name.startswith("random.") and name.split(".")[1] in (
                "random", "randint", "choice", "shuffle", "uniform",
                "randrange", "sample", "seed", "gauss"):
            out.append(Finding(
                "GL009", mod.rel, node.lineno,
                f"stdlib `{name}` uses global RNG state — use a seeded "
                f"np.random.default_rng(seed) Generator"))
    return out


def _import_graph(ctx: LintContext) -> dict:
    """module dotted name -> set of dotted names it imports (resolved)."""
    if ctx._import_graph is not None:
        return ctx._import_graph
    graph: Dict[str, set] = {}
    roots = set()
    for f in ctx.all_files:
        rel = f.relative_to(ctx.repo).as_posix()
        if rel.startswith("src/"):
            dotted = rel[len("src/"):-3].replace("/", ".")
        else:
            dotted = rel[:-3].replace("/", ".")
        # relative imports inside __init__.py resolve against the package
        # itself, so keep the `__init__` leaf while computing bases
        pkg_parts = dotted.split(".")
        dotted = dotted.removesuffix(".__init__")
        roots.add(dotted)
        imports = set()
        try:
            tree = ast.parse(f.read_text())
        except SyntaxError:
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    imports.add(a.name)
            elif isinstance(node, ast.ImportFrom):
                if node.level:
                    base = pkg_parts[:-node.level] if node.level <= \
                        len(pkg_parts) else []
                    prefix = ".".join(base)
                    modname = f"{prefix}.{node.module}" if node.module \
                        else prefix
                else:
                    modname = node.module or ""
                imports.add(modname)
                for a in node.names:
                    imports.add(f"{modname}.{a.name}")
        graph[dotted] = imports
    ctx._import_graph = {"graph": graph, "modules": roots}
    return ctx._import_graph


def rule_gl010(mod: ParsedModule, ctx: LintContext) -> List[Finding]:
    """GL010 dead module: a ``src/`` module no other module, test, example,
    or benchmark statically imports. Dynamically-loaded registry members
    (``importlib`` via ``configs.base``) must say so with a file-level
    suppression — dynamic loading is exactly how dead stubs hide."""
    rel = mod.rel
    if not rel.startswith("src/") or rel.endswith("__init__.py") \
            or rel.endswith("__main__.py"):
        return []
    # `python -m`-style entry points are roots of the graph, not dead code
    for node in mod.tree.body:
        if isinstance(node, ast.If) and "__main__" in ast.dump(node.test):
            return []
    dotted = rel[len("src/"):-3].replace("/", ".")
    info = _import_graph(ctx)
    for other, imports in info["graph"].items():
        if other == dotted:
            continue
        for imp in imports:
            if imp == dotted or imp.startswith(dotted + "."):
                return []
    return [Finding(
        "GL010", rel, 1,
        f"module `{dotted}` is imported by nothing under src/tests/"
        f"examples/benchmarks — delete it, or mark it as a dynamic "
        f"registry member with a file-level suppression")]


def rule_gl011(mod: ParsedModule, ctx: LintContext) -> List[Finding]:
    """GL011 unused import (``__init__.py`` re-exports and ``__all__``
    members excluded)."""
    if mod.rel.endswith("__init__.py"):
        return []
    exported = set()
    for node in mod.tree.body:
        if isinstance(node, ast.Assign) \
                and any(nm == "__all__" for t in node.targets
                        for nm in _assigned_names(t)) \
                and isinstance(node.value, (ast.List, ast.Tuple)):
            exported = {e.value for e in node.value.elts
                        if isinstance(e, ast.Constant)}
    imported: Dict[str, tuple] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                name = a.asname or a.name.split(".")[0]
                imported[name] = (a.name, node.lineno)
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                name = a.asname or a.name
                imported[name] = (a.name, node.lineno)
    used = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            base = _dotted(node)
            if base:
                used.add(base.split(".")[0])
    # string-annotation / doctest references keep an import alive
    out = []
    for name, (target, line) in imported.items():
        if name in used or name in exported:
            continue
        if f"``{name}" in mod.text or f"`{name}." in mod.text or \
                f"{name}." in "".join(
                    n.value for n in ast.walk(mod.tree)
                    if isinstance(n, ast.Constant)
                    and isinstance(n.value, str)):
            continue
        out.append(Finding(
            "GL011", mod.rel, line,
            f"`{name}` imported but unused"))
    return out


def rule_gl012(mod: ParsedModule, ctx: LintContext) -> List[Finding]:
    """GL012 swallowed exception in ``src/``: a bare ``except:`` (or
    ``except Exception/BaseException``) whose body neither re-raises, nor
    logs/prints, nor *uses* the bound exception (propagating it into a
    queue/future counts as handling). A silent catch-all turned a corrupt
    checkpoint into a quiet cold start once; the fault-tolerant runtime
    (docs/FAULTS.md) depends on failures being loud. Handlers that must
    stay silent by design carry a reasoned suppression."""
    if not mod.rel.startswith("src/"):
        return []
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        t = node.type
        if t is not None:
            name = _dotted(t)
            if name.split(".")[-1] not in ("Exception", "BaseException"):
                continue            # narrow catch: fine
        handled = False
        for sub in ast.walk(ast.Module(body=node.body, type_ignores=[])):
            if isinstance(sub, ast.Raise):
                handled = True      # re-raise (incl. `raise X from e`)
            elif isinstance(sub, ast.Name) and sub.id == node.name:
                handled = True      # exception object used: propagated
            elif isinstance(sub, ast.Call):
                leaf = _dotted(sub.func).split(".")[-1].lower()
                if "log" in leaf or "warn" in leaf or leaf == "print":
                    handled = True  # at least surfaced
        if not handled:
            what = "bare `except:`" if t is None else f"`except {_dotted(t)}`"
            out.append(Finding(
                "GL012", mod.rel, node.lineno,
                f"{what} swallows the exception — re-raise, log, or "
                f"propagate it (or narrow the catch); silent catch-alls "
                f"hide real faults (see docs/FAULTS.md)"))
    return out


RULES: Dict[str, Callable] = {
    "GL001": rule_gl001, "GL002": rule_gl002, "GL003": rule_gl003,
    "GL004": rule_gl004, "GL005": rule_gl005, "GL006": rule_gl006,
    "GL007": rule_gl007, "GL008": rule_gl008, "GL009": rule_gl009,
    "GL010": rule_gl010, "GL011": rule_gl011, "GL012": rule_gl012,
}

SHORT = {
    "GL000": "bare-suppression", "GL001": "host-transfer-in-traced-code",
    "GL002": "prng-key-reuse", "GL003": "x64-creep",
    "GL004": "device-op-in-python-loop", "GL005": "program-id-under-vmap",
    "GL006": "pallas-grid-divisibility", "GL007": "blockspec-memory-space",
    "GL008": "mutable-default-arg", "GL009": "unseeded-rng",
    "GL010": "dead-module", "GL011": "unused-import",
    "GL012": "swallowed-exception",
}


def resolve(rules: Optional[Sequence[str]]) -> Dict[str, Callable]:
    if not rules:
        return dict(RULES)
    unknown = set(rules) - set(RULES)
    if unknown:
        raise ValueError(f"unknown rule ids: {sorted(unknown)}")
    return {r: RULES[r] for r in rules}


def check_file(path: Path, rel: str, text: str,
               active: Dict[str, Callable], repo: Path,
               all_files: Sequence[Path] = ()) -> List[Finding]:
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Finding("GL000", rel, e.lineno or 1,
                        f"syntax error: {e.msg}")]
    mod = ParsedModule(path=path, rel=rel, text=text, tree=tree)
    # the cached context (and its import graph) is only valid for the same
    # repo AND the same file set — a changed file list must invalidate it
    ctx_key = (repo, tuple(all_files))
    ctx = check_file._ctx if getattr(check_file, "_ctx_key", None) == ctx_key \
        else LintContext(repo=repo, all_files=all_files)
    check_file._ctx, check_file._ctx_key = ctx, ctx_key
    findings: List[Finding] = []
    for fn in active.values():
        findings.extend(fn(mod, ctx))
    return sorted(findings, key=lambda f: (f.line, f.rule))
